//! Calibration walkthrough: stream a corpus through the dense model
//! with activation taps, search a mixed-precision `QuantPlan` under a
//! bits/weight budget, and compare it end-to-end against the uniform
//! FP5.33 plan it replaces.
//!
//! Run: `cargo run --release --example calibrate_plan`

use ams_quant::calib::{CalibConfig, Calibrator};
use ams_quant::formats::registry::Scheme;
use ams_quant::model::synthetic::synthetic_checkpoint;
use ams_quant::model::transformer::Transformer;
use ams_quant::model::ModelConfig;
use ams_quant::quant::{QuantConfig, Quantizer};

fn main() -> anyhow::Result<()> {
    // 1. The dense reference model (stand-in for a real checkpoint).
    let ck = synthetic_checkpoint(&ModelConfig::tiny_lm(), 7);
    let base = Transformer::from_checkpoint(&ck)?;
    let dense_params = base.projection_bytes() / 2;

    // 2. The baseline the search has to beat: uniform FP5.33 everywhere.
    let uniform = base.quantized(&QuantConfig::paper(Scheme::parse("fp5.33").unwrap()))?;
    let ubits = ((uniform.projection_bytes() + uniform.projection_scale_bytes()) * 8) as f64
        / dense_params as f64;
    println!("uniform fp5.33: {ubits:.3} bits/w (payload + scales)");

    // 3. Calibrate under that same budget: taps -> activation-weighted
    //    sensitivity per layer -> greedy budgeted search.
    let cal = Calibrator::new(CalibConfig {
        budget_bits: ubits,
        calib_tokens: 2048,
        window: 128,
        seed: 1,
        ..CalibConfig::default()
    });
    let corpus = cal.synthetic_corpus(base.cfg.vocab_size);
    let (plan, report) = cal.calibrate(&base, &corpus)?;
    println!("{}", report.table().to_console());
    println!(
        "searched: {:.3} bits/w (budget {:.3}, {}), act-SQNR {:.2} dB",
        report.achieved_bits,
        report.budget_bits,
        if report.budget_met { "met" } else { "NOT met" },
        report.act_sqnr_db
    );

    // 4. End-to-end check on a probe stream: logit error vs dense.
    let searched = base.quantized_with(&Quantizer::new(plan))?;
    let probe: Vec<u32> = (0..120u32).map(|i| (i * 31 + 5) % base.cfg.vocab_size as u32).collect();
    let noise = |q: &Transformer| -> f64 {
        let mut cd = base.new_cache();
        let mut cq = q.new_cache();
        let mut n = 0f64;
        for (pos, &t) in probe.iter().enumerate() {
            let ld = base.forward(t, pos, &mut cd);
            let lq = q.forward(t, pos, &mut cq);
            n += ld.iter().zip(&lq).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
        }
        n
    };
    let (ns, nu) = (noise(&searched), noise(&uniform));
    println!("logit sq-error vs dense: searched {ns:.3e}  uniform fp5.33 {nu:.3e}");
    println!(
        "searched plan is {:.2}x {} at equal bits",
        (nu / ns).max(ns / nu),
        if ns <= nu { "better" } else { "worse" }
    );
    Ok(())
}
