//! Figure 1 / Figure 4 walkthrough: shows the bit-level life of a weight
//! group — RTN codes, the adaptive shared LSB, the packed half-word, and
//! the SHIFT/AND/OR restoration back to FP16 bits.
//!
//! Run: `cargo run --release --example packing_demo`

use ams_quant::formats::fp16::fp16_to_f32;
use ams_quant::formats::registry::Scheme;
use ams_quant::formats::FpFormat;
use ams_quant::pack;
use ams_quant::quant::sharing::quantize;
use ams_quant::quant::QuantConfig;
use ams_quant::restore::code_to_fp16_bits;
use ams_quant::tensor::Tensor;

fn main() {
    // Three weights forming one FP5.33 group (e2m3, k=3).
    let w = Tensor::from_vec(&[1, 3], vec![0.91, -0.42, 0.17]);
    let scheme = Scheme::parse("fp5.33").unwrap();
    let fmt = FpFormat::E2M3;
    println!("weights: {:?}", w.data());

    let q = quantize(&w, &QuantConfig::paper(scheme)).unwrap();
    println!("\nchannel scale s = amax/M = {:.6}", q.scales[0]);
    println!("RTN+shared codes (s|ee|mmm):");
    for (i, &c) in q.codes.iter().enumerate() {
        println!(
            "  w[{i}] = {:>6.3} -> code {:#08b} = {:.4} (dequant {:.4})",
            w.data()[i],
            c,
            fmt.decode(c),
            fmt.decode(c) * q.scales[0],
        );
    }
    println!("shared mantissa LSB (adaptive search): {}", q.shared_bits[0]);

    // Pack: the paper's special case — 3x5-bit high segments + shared bit
    // fit exactly one u16 ("continuous packing without segmentation").
    let p = pack::pack(&q).unwrap();
    assert_eq!(p.row_stride, 1);
    let word = p.words[0];
    println!("\npacked half-word: {word:#018b}");
    println!("  [shared|hi2|hi1|hi0] = [{}|{:05b}|{:05b}|{:05b}]",
        (word >> 15) & 1, (word >> 10) & 0x1F, (word >> 5) & 0x1F, word & 0x1F);

    // Restore via bit ops (Figure 4).
    println!("\nrestoration (SHIFT/AND/OR -> FP16 bits):");
    let shared = (word >> 15) & 1;
    for j in 0..3 {
        let code = (((word >> (5 * j)) & 0x1F) << 1) | shared;
        let h = code_to_fp16_bits(fmt, code);
        println!(
            "  lane {j}: code {code:#08b} -> fp16 {h:#06x} = {:.4} ; x scale = {:.4}",
            fp16_to_f32(h),
            fp16_to_f32(h) * q.scales[0]
        );
    }
    println!("\nstorage: {} bits for 3 weights = {:.2} bits/weight", 16, 16.0 / 3.0);
}
