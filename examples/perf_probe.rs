use ams_quant::experiments::make_linear;
use ams_quant::formats::registry::Scheme;
use ams_quant::gemm::simd;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::util::prng::Rng;
use ams_quant::util::timer::Timer;
fn main() {
    println!("avx512: {}", simd::is_avx512());
    let mut rng = Rng::new(1);
    let args: Vec<String> = std::env::args().collect();
    let rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let cols: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8192);
    let w = llm_weight(rows, cols, &WeightProfile::default(), &mut rng);
    let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    println!("shape {rows}x{cols} = {:.1} MB fp16", (rows*cols*2) as f64/1e6);
    let mut fp16_ns = 0.0;
    for name in ["fp16", "fp8", "fp6", "fp5", "fp5.33", "fp4.25"] {
        let lin = make_linear(&w, Scheme::parse(name).unwrap());
        let mut y = vec![0f32; rows];
        // warmup
        for _ in 0..2 { lin.gemv(&x, &mut y); }
        let t = Timer::start();
        let mut iters = 0;
        while t.elapsed_secs() < 1.0 { lin.gemv(&x, &mut y); std::hint::black_box(&y); iters += 1; }
        let ns_per_w = t.elapsed_secs() * 1e9 / (iters * rows * cols) as f64;
        if name == "fp16" { fp16_ns = ns_per_w; }
        println!("{name:8} {ns_per_w:.3} ns/weight  speedup vs fp16: {:.2}", fp16_ns / ns_per_w);
    }
}
