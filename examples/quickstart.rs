//! Quickstart: build a `Quantizer`, run the full RTN → adaptive-search →
//! pack pipeline on a weight matrix, inspect the per-layer report, and
//! run the fused GEMV — the 60-second tour of the API.
//!
//! Run: `cargo run --release --example quickstart`

use ams_quant::formats::registry::Scheme;
use ams_quant::gemm::QuantLinear;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::quant::{Granularity, LayerRole, QuantConfig, Quantizer};
use ams_quant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // 1. An LLM-like weight matrix [out_channels, in_channels].
    let w = llm_weight(256, 1024, &WeightProfile::default(), &mut rng);
    println!("weights: 256x1024, amax={:.4}", w.abs_max());

    // 2. The paper's pipeline through the one public entry point:
    //    channel-wise RTN to e2m2, then groups of k=4 share their mantissa
    //    LSB -> 4.25 bits/weight, packed in one call.
    let scheme = Scheme::parse("fp4.25").unwrap();
    let quantizer = Quantizer::uniform(QuantConfig::paper(scheme))?;
    let (packed, report) = quantizer.quantize_layer("demo", LayerRole::Other, &w)?;
    println!(
        "scheme: {}  ({} bits/weight nominal, {:.3} achieved)",
        scheme.label(),
        scheme.bits_per_weight(),
        report.bits_per_weight
    );
    println!("weight MSE:  {:.3e}", report.mse);
    println!("weight SQNR: {:.2} dB", report.sqnr_db);
    println!(
        "adaptive search picked shared bit 1 for {}/{} groups",
        report.shared_ones, report.shared_groups
    );
    println!(
        "packed: {} bytes ({:.2}x smaller than fp16)",
        packed.payload_bytes(),
        16.0 / packed.bits_per_weight()
    );

    // 3. Fused unpack-dequant GEMV straight off the packed words.
    let lin = QuantLinear::new(packed);
    let x: Vec<f32> = (0..1024).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0f32; 256];
    lin.gemv(&x, &mut y);

    // Compare against the dense reference.
    let yref = lin.gemv_reference(&x);
    let max_err = y
        .iter()
        .zip(&yref)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("fused GEMV vs reference: max |Δ| = {max_err:.2e}");
    assert!(max_err < 1e-4);

    // 4. The same pipeline with group-wise scales (g = 64): finer scale
    //    granularity, still served by the fused kernels.
    let grouped = Quantizer::uniform(
        QuantConfig::paper(scheme).with_granularity(Granularity::PerGroup(64)),
    )?;
    let (gp, grep) = grouped.quantize_layer("demo-g64", LayerRole::Other, &w)?;
    println!(
        "per-group(64): SQNR {:.2} dB (vs {:.2} per-channel), +{:.2} bits/weight of scales",
        grep.sqnr_db,
        report.sqnr_db,
        32.0 / 64.0
    );
    let glin = QuantLinear::new(gp);
    let mut gy = vec![0f32; 256];
    glin.gemv(&x, &mut gy);
    let gref = glin.gemv_reference(&x);
    let gerr = gy.iter().zip(&gref).fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(gerr < 1e-4);
    println!("OK");
    Ok(())
}
