//! Quickstart: quantize a weight matrix to FP4.25, pack it, run the fused
//! GEMV, and inspect error/compression — the 60-second tour of the API.
//!
//! Run: `cargo run --release --example quickstart`

use ams_quant::formats::registry::Scheme;
use ams_quant::gemm::QuantLinear;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::pack;
use ams_quant::quant::error::sqnr_db;
use ams_quant::quant::sharing::quantize;
use ams_quant::quant::QuantConfig;
use ams_quant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // 1. An LLM-like weight matrix [out_channels, in_channels].
    let w = llm_weight(256, 1024, &WeightProfile::default(), &mut rng);
    println!("weights: 256x1024, amax={:.4}", w.abs_max());

    // 2. Quantize with the paper's pipeline: channel-wise RTN to e2m2,
    //    then groups of k=4 share their mantissa LSB -> 4.25 bits/weight.
    let scheme = Scheme::parse("fp4.25").unwrap();
    let q = quantize(&w, &QuantConfig::paper(scheme));
    let deq = q.dequantize();
    println!(
        "scheme: {}  ({} bits/weight)",
        scheme.label(),
        scheme.bits_per_weight()
    );
    println!("weight MSE:  {:.3e}", w.mse(&deq));
    println!("weight SQNR: {:.2} dB", sqnr_db(&w, &deq));

    // 3. Pack for serving: 16 high-segment words + 1 shared-LSB word per
    //    64 weights (§3.2 of the paper).
    let packed = pack::pack(&q);
    println!(
        "packed: {} bytes  ({:.3} bits/weight incl. row padding, {:.2}x smaller than fp16)",
        packed.payload_bytes(),
        packed.bits_per_weight(),
        16.0 / packed.bits_per_weight()
    );

    // 4. Fused unpack-dequant GEMV straight off the packed words.
    let lin = QuantLinear::new(packed);
    let x: Vec<f32> = (0..1024).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0f32; 256];
    lin.gemv(&x, &mut y);

    // Compare against the dense reference.
    let yref = lin.gemv_reference(&x);
    let max_err = y
        .iter()
        .zip(&yref)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("fused GEMV vs reference: max |Δ| = {max_err:.2e}");
    assert!(max_err < 1e-4);
    println!("OK");
    Ok(())
}
