//! E9 — the end-to-end driver: load the build-time-trained tiny LM,
//! quantize it to each serving scheme, and serve batched generation
//! requests through the L3 coordinator (continuous batching), reporting
//! throughput, latency percentiles, weight footprint and output quality
//! (greedy agreement with the FP16-served outputs).
//!
//! This proves all layers compose: checkpoint -> quantizer -> packed
//! kernels -> batched decode -> coordinator -> metrics.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example serve_llm [-- --requests 24 --max-batch 8]

use ams_quant::coordinator::{Engine, GenRequest, RequestHandle};
use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::quant::QuantConfig;
use ams_quant::report::{f, Table};
use ams_quant::util::cli::Args;
use ams_quant::util::prng::Rng;
use ams_quant::util::timer::Timer;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 24);
    let max_batch = args.get_usize("max-batch", 8);
    let max_new = args.get_usize("max-new-tokens", 48);

    let (base, heldout, kind) = exp::load_model(Path::new("artifacts"))?;
    println!(
        "model: {kind} ({} params); {n_requests} requests x {max_new} tokens, max_batch={max_batch}\n",
        base.cfg.param_count()
    );

    // Shared request set (prompts drawn from the heldout corpus).
    let mut rng = Rng::new(7);
    let prompts: Vec<Vec<u32>> = (0..n_requests)
        .map(|_| {
            let start = rng.range(0, heldout.len().saturating_sub(64).max(1));
            heldout[start..(start + 24).min(heldout.len())].to_vec()
        })
        .collect();

    let schemes = ["fp16", "fp6", "fp5.33", "fp4.25", "fp4"];
    let mut table = Table::new(
        "E9 — batched serving across schemes",
        &["Scheme", "weights MB", "tok/s", "p50 s", "p90 s", "occupancy", "agree-with-fp16 %"],
    );
    let mut fp16_outputs: Vec<Vec<u32>> = Vec::new();

    for name in schemes {
        let scheme = Scheme::parse(name).unwrap();
        // fp16 storage runs through the same packed path (the W16A16
        // baseline) — one Quantizer entry point for every scheme.
        let model = base.quantized(&QuantConfig::paper(scheme)).unwrap();
        let bytes = model.projection_bytes();
        let eng = Engine::builder().max_batch(max_batch).seed(1).build(model);
        let wall = Timer::start();
        let handles: Vec<RequestHandle> = prompts
            .iter()
            .enumerate()
            .map(|(id, p)| {
                eng.submit(GenRequest::greedy(id as u64, p.clone(), max_new))
                    .expect("engine accepts while under capacity")
            })
            .collect();
        let mut responses: Vec<_> = handles
            .into_iter()
            .filter_map(|h| h.wait())
            .collect();
        let wall_s = wall.elapsed_secs();
        responses.sort_by_key(|r| r.id);
        eng.drain();
        let lat = eng.latency();
        let stats = eng.shutdown();

        let agree = if fp16_outputs.is_empty() {
            fp16_outputs = responses.iter().map(|r| r.tokens.clone()).collect();
            100.0
        } else {
            let mut same = 0usize;
            let mut total = 0usize;
            for (r, rref) in responses.iter().zip(&fp16_outputs) {
                for (a, b) in r.tokens.iter().zip(rref) {
                    same += usize::from(a == b);
                    total += 1;
                }
            }
            100.0 * same as f64 / total.max(1) as f64
        };

        table.row(vec![
            scheme.label(),
            f(bytes as f64 / 1e6, 2),
            f(stats.tokens_generated as f64 / wall_s, 1),
            f(lat.percentile(50.0), 3),
            f(lat.percentile(90.0), 3),
            f(stats.mean_batch_occupancy(), 2),
            f(agree, 2),
        ]);
        println!("{name}: done in {:.2}s", wall_s);
    }
    println!("\n{}", table.to_console());
    println!("markdown for EXPERIMENTS.md:\n{}", table.to_markdown());
    Ok(())
}
