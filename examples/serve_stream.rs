//! Streaming serving walkthrough: builder → submit → stream → cancel.
//!
//! Demonstrates the full `Engine` request lifecycle on the tiny LM
//! (trained checkpoint if `make artifacts` ran, synthetic otherwise):
//!
//! 1. configure an engine (replicas, batch, bounded queue, dispatch);
//! 2. submit requests and receive per-request `RequestHandle`s;
//! 3. stream `Event::{Queued, FirstToken, Token, Done}` as tokens are
//!    generated (TTFT measured from submission, queue wait included);
//! 4. cancel an in-flight request and observe its terminal `Cancelled`;
//! 5. shed load with `try_submit` when the bounded queue is full.
//!
//! Run: cargo run --release --example serve_stream [-- --scheme fp5.33]

use ams_quant::coordinator::{DispatchPolicy, Engine, EngineError, Event, GenRequest};
use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::model::tokenizer;
use ams_quant::quant::QuantConfig;
use ams_quant::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scheme_name = args.get_or("scheme", "fp5.33");
    let scheme = Scheme::parse(scheme_name).map_err(|e| anyhow::anyhow!(e))?;

    let (base, heldout, kind) = exp::load_model(Path::new("artifacts"))?;
    let model = base.quantized(&QuantConfig::paper(scheme)).unwrap();
    println!("model: {kind}, scheme: {scheme_name}\n");

    // 1. Builder: every serving knob in one place.
    let eng = Engine::builder()
        .replicas(1)
        .max_batch(4)
        .queue_capacity(16)
        .dispatch(DispatchPolicy::LeastOutstanding)
        .seed(7)
        .build(model);

    // 2. Submit: each request gets its own streaming handle.
    let prompt: Vec<u32> = heldout[..24.min(heldout.len())].to_vec();
    let mut streaming = eng.submit(GenRequest::greedy(0, prompt.clone(), 32))?;
    let doomed = eng.submit(GenRequest::greedy(1, prompt, 4000))?;

    // 3. Stream: tokens arrive as they are generated.
    println!("request 0 streaming:");
    while let Some(ev) = streaming.next_event() {
        match ev {
            Event::Queued { id } => println!("  [queued]    request {id}"),
            Event::FirstToken { token, ttft_s, .. } => {
                println!("  [first]     {token:4}  (ttft {:.2} ms)", ttft_s * 1e3)
            }
            Event::Token { token, index, .. } => println!("  [token {index:2}]  {token:4}"),
            Event::Done(r) => {
                println!(
                    "  [done]      {} tokens in {:.2} ms: {:?}",
                    r.tokens.len(),
                    r.total_s * 1e3,
                    tokenizer::decode(&r.tokens)
                );
            }
            Event::Cancelled { .. } | Event::TimedOut { .. } | Event::Failed { .. } => {
                unreachable!("request 0 completes normally")
            }
        }
    }

    // 4. Cancel: the scheduler drops the sequence at the next step
    //    boundary and frees its KV cache; the stream ends with Cancelled.
    doomed.cancel();
    match doomed.wait() {
        None => println!("\nrequest 1 cancelled mid-generation, as asked"),
        Some(r) => println!("\nrequest 1 outran the cancel with {} tokens", r.tokens.len()),
    }

    // 5. Backpressure: try_submit never blocks — it hands the request
    //    back when the bounded queue is full.
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for id in 2..40u64 {
        match eng.try_submit(GenRequest::greedy(id, vec![1, 2, 3], 500)) {
            Ok(h) => accepted.push(h),
            Err(EngineError::QueueFull(_)) => shed += 1,
            Err(e) => return Err(anyhow::anyhow!(e)),
        }
    }
    println!("burst of 38: {} accepted, {shed} shed via QueueFull", accepted.len());
    for h in &accepted {
        h.cancel();
    }
    for h in accepted {
        h.wait();
    }

    let stats = eng.shutdown();
    println!(
        "\nengine stats: {} completed, {} cancelled, occupancy {:.2}",
        stats.requests,
        stats.cancelled,
        stats.mean_batch_occupancy()
    );
    Ok(())
}
