// quick probe of simulator values vs paper Table 3
use ams_quant::formats::registry::Scheme;
use ams_quant::sim::*;
fn main() {
    let dev = Device::paper();
    for (name, rows, cols) in table3_shapes() {
        println!("== {name}");
        for s in ["fp8","fp6","fp5.33","fp5","fp4.25"] {
            let row = speedup_row(&dev, rows, cols, Scheme::parse(s).unwrap(), &TABLE3_BATCHES);
            println!("{s:8} {:?}", row.iter().map(|v| (v*100.0).round()/100.0).collect::<Vec<_>>());
        }
    }
}
