//! Sweet-spot sweep (Figure 3 + Table 2 / Figure 5 + ablation A3):
//! evaluates the trained tiny LM under every scheme of the paper and
//! prints the accuracy matrix plus the k-sweep bits-vs-MSE frontier.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example sweet_spot_sweep [-- --tokens 3000]

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::formats::FpFormat;
use ams_quant::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let tokens = args.get_usize("tokens", 3000);
    let artifacts = Path::new("artifacts");
    let (model, heldout, kind) = exp::load_model(artifacts)?;
    println!("model: {kind}; params ~{}\n", model.cfg.param_count());

    // Figure 3: the preliminary RTN study.
    let rows = exp::accuracy_suite(&model, &heldout, &Scheme::fig3_set(), tokens);
    println!(
        "{}",
        exp::accuracy_table(&rows, "Figure 3 (proxy): naive RTN schemes").to_console()
    );

    // Table 2 / Figure 5: the full AMS matrix.
    let rows = exp::accuracy_suite(&model, &heldout, &Scheme::table2_set(), tokens);
    println!(
        "{}",
        exp::accuracy_table(&rows, "Table 2 (proxy): AMS-Quant schemes").to_console()
    );

    // The paper's headline ordering, asserted:
    let kl = |label: &str| {
        rows.iter()
            .find(|r| r.scheme.starts_with(label))
            .map(|r| r.kl)
            .unwrap()
    };
    let (kl6, kl533, kl425, kl4) = (kl("FP6"), kl("FP5.33"), kl("FP4.25"), kl("FP4 "));
    println!("KL ordering: fp6 {kl6:.2e} <= fp5.33 {kl533:.2e} <= fp4.25 {kl425:.2e} < fp4 {kl4:.2e}");
    assert!(kl6 <= kl533 * 1.5, "fp5.33 must stay at fp6 level");
    assert!(kl425 < kl4, "fp4.25 must beat fp4 (the sweet-spot claim)");

    // Ablation A3: k sweep.
    println!("{}", exp::k_sweep(FpFormat::E2M2, &[2, 3, 4, 8, 16], 7).to_console());
    println!("OK");
    Ok(())
}
