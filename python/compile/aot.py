"""AOT lowering: jax/Pallas → HLO text artifacts for the rust runtime.

Emits HLO *text* (NOT `.serialize()`): the image's xla_extension 0.5.1
rejects jax≥0.5 protos (64-bit instruction ids); the text parser reassigns
ids — see /opt/xla-example/README.md and aot_recipe.md.

Artifacts (one per scheme × shape × batch, see MANIFEST below):
    artifacts/linear_<scheme>_<rows>x<cols>_b<batch>.hlo.txt
        (packed u32 [rows, w32], scales f32 [rows], x f32 [batch, cols])
        -> (y f32 [batch, rows],)
plus artifacts/manifest.json describing every entry.

Python runs once at build time; the rust coordinator serves from the
compiled executables (rust/src/runtime/).
"""

import argparse
import functools
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import ref
from compile.kernels.ams_dequant import dequant_linear
from compile.kernels.formats import parse_scheme

# (scheme, rows, cols, batches): small shapes keep PJRT compile times sane;
# kernel-level perf at paper shapes is measured by the rust native path and
# the roofline simulator (Table 3).
MANIFEST = [
    ("fp16", 256, 128, [1, 8]),
    ("fp6", 256, 128, [1, 8]),
    ("fp5.33", 256, 128, [1, 8]),
    ("fp4.25", 256, 128, [1, 8]),
    ("fp5.33", 128, 344, [4]),
    ("fp4.25", 128, 344, [4]),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_linear(scheme_name: str, rows: int, cols: int, batch: int) -> str:
    scheme = parse_scheme(scheme_name)
    stride16 = ref.row_stride(scheme, cols)
    w32 = -(-stride16 // 2)

    def fn(words, scales, x):
        return (dequant_linear(words, scales, x, scheme=scheme, rows=rows, cols=cols),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((rows, w32), np.uint32),
        jax.ShapeDtypeStruct((rows,), np.float32),
        jax.ShapeDtypeStruct((batch, cols), np.float32),
    )
    return to_hlo_text(lowered)


def artifact_name(scheme: str, rows: int, cols: int, batch: int) -> str:
    safe = scheme.replace(".", "p")
    return f"linear_{safe}_{rows}x{cols}_b{batch}.hlo.txt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for scheme, rows, cols, batches in MANIFEST:
        for batch in batches:
            name = artifact_name(scheme, rows, cols, batch)
            path = os.path.join(args.out_dir, name)
            entry = {
                "file": name,
                "scheme": scheme,
                "rows": rows,
                "cols": cols,
                "batch": batch,
                "w32_stride": -(-ref.row_stride(parse_scheme(scheme), cols) // 2),
            }
            manifest.append(entry)
            if os.path.exists(path) and not args.force:
                print(f"keep    {name}")
                continue
            text = lower_linear(scheme, rows, cols, batch)
            with open(path, "w") as f:
                f.write(text)
            print(f"lowered {name} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
