"""AMSZ checkpoint writer/reader (python mirror of
rust/src/model/checkpoint.rs). Little-endian f32 payload, JSON header."""

import json
import struct

import numpy as np

MAGIC = b"AMSZ1\n"


def save(path: str, config_dict: dict, tensors: dict):
    """tensors: name -> np.ndarray (float32)."""
    entries = []
    offset = 0
    names = sorted(tensors)  # BTreeMap ordering on the rust side
    for name in names:
        t = np.asarray(tensors[name], dtype=np.float32)
        entries.append(
            {
                "name": name,
                "shape": list(t.shape),
                "offset": offset,
                "count": int(t.size),
            }
        )
        offset += t.size
    header = json.dumps({"config": config_dict, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for name in names:
            f.write(np.asarray(tensors[name], dtype="<f4").tobytes())


def load(path: str):
    """Returns (config_dict, {name: np.ndarray})."""
    with open(path, "rb") as f:
        assert f.read(6) == MAGIC, f"{path}: bad magic"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        payload = np.frombuffer(f.read(), dtype="<f4")
    tensors = {}
    for e in header["tensors"]:
        data = payload[e["offset"] : e["offset"] + e["count"]]
        tensors[e["name"]] = data.reshape(e["shape"]).copy()
    return header["config"], tensors
