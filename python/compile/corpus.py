"""Deterministic synthetic corpus for the tiny char LM.

The grammar mixes three structures the eval harness later probes:
- periodic motifs ("abcabcabc...") — pattern-completion task;
- key-value facts with consistent bindings ("the COLOR of OBJ is VALUE.")
  — knowledge-ish recall;
- counting runs ("1 2 3 4 ...") — simple systematic structure.

Byte-level, ASCII only; seeded; identical across python/rust consumers.
"""

import numpy as np

OBJECTS = ["lamp", "door", "cube", "ring", "bell", "leaf", "sand", "wire"]
COLORS = ["red", "blue", "green", "gold", "gray", "pink"]
VERBS = ["holds", "moves", "finds", "keeps", "sends", "takes"]
NAMES = ["ada", "bob", "cyd", "dan", "eve", "fay"]


def make_corpus(n_chars: int = 200_000, seed: int = 1234) -> str:
    rng = np.random.default_rng(seed)
    # Fixed world: every object has one color for the whole corpus.
    color_of = {o: COLORS[rng.integers(0, len(COLORS))] for o in OBJECTS}
    parts = []
    total = 0
    while total < n_chars:
        r = rng.random()
        if r < 0.35:
            o = OBJECTS[rng.integers(0, len(OBJECTS))]
            s = f"the {o} is {color_of[o]}. "
        elif r < 0.55:
            a = NAMES[rng.integers(0, len(NAMES))]
            v = VERBS[rng.integers(0, len(VERBS))]
            o = OBJECTS[rng.integers(0, len(OBJECTS))]
            s = f"{a} {v} the {o}. "
        elif r < 0.8:
            motif = "".join(
                chr(ord("a") + rng.integers(0, 26)) for _ in range(rng.integers(2, 5))
            )
            s = motif * int(rng.integers(4, 9)) + " "
        else:
            start = int(rng.integers(0, 6))
            s = " ".join(str(start + j) for j in range(int(rng.integers(4, 9)))) + ". "
        parts.append(s)
        total += len(s)
    return "".join(parts)[:n_chars]


def train_heldout(n_chars: int = 200_000, seed: int = 1234, holdout_frac: float = 0.05):
    text = make_corpus(n_chars, seed)
    cut = int(len(text) * (1.0 - holdout_frac))
    return text[:cut], text[cut:]
