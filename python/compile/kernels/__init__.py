"""L1 kernels: Pallas fused dequant-GEMV + pure reference oracle."""
