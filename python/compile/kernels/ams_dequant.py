"""L1 Pallas kernel: fused unpack → dequant → matmul for packed AMS weights.

TPU adaptation of the paper's CUDA restoration kernels (DESIGN.md
§Hardware-Adaptation):

- the packed u32 words are the kernel operand; BlockSpec streams whole
  row-tiles HBM→VMEM, so HBM traffic equals the packed bit count (the
  quantity the CUDA kernel's coalesced loads optimize);
- unpacking is vectorized integer SHIFT/AND/OR over int32 lanes (VPU),
  followed by one ≤256-entry table gather per code — the register-level
  restoration of §3.2;
- the dequantized tile feeds `jnp.dot` (MXU) with fp32 accumulation;
- `interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; real-TPU performance is *estimated* in EXPERIMENTS.md §Perf
  from the VMEM footprint and MXU tile shapes.

The kernel is shape-specialized at lowering time (static `cols`, `batch`,
scheme), which is exactly how the AOT artifacts are produced.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .formats import Scheme
from . import ref


def _u16_view(words_u32: jnp.ndarray) -> jnp.ndarray:
    """[rows, w32] u32 -> [rows, 2*w32] logical u16 words (little-endian).

    Only python-int shifts and reshapes: Pallas kernels may not capture
    constant index arrays, so every unpack below is expressed as
    stack/reshape with scalar shift amounts — which is also exactly the
    vectorized SHIFT/AND/OR the paper's restoration performs.
    """
    rows = words_u32.shape[0]
    lo = words_u32 & jnp.uint32(0xFFFF)
    hi = words_u32 >> jnp.uint32(16)
    return jnp.stack([lo, hi], axis=2).reshape(rows, -1)


def _lanes(u16: jnp.ndarray, per: int, bits: int, mask: int) -> jnp.ndarray:
    """Split each u16 word into `per` fields of `bits` bits, LSB-first:
    [rows, n] -> [rows, n*per]."""
    rows = u16.shape[0]
    fields = [(u16 >> jnp.uint32(bits * j)) & jnp.uint32(mask) for j in range(per)]
    return jnp.stack(fields, axis=2).reshape(rows, -1)


def _unpack_codes(words_u32: jnp.ndarray, scheme: Scheme, cols: int) -> jnp.ndarray:
    """words_u32: [tile_rows, w32] uint32 -> codes [tile_rows, cols] uint32."""
    u16 = _u16_view(words_u32)
    ceil = lambda a, b: -(-a // b)
    if scheme.kind == "fp16":
        return u16[:, :cols]
    if scheme.kind == "int":
        bits = scheme.int_bits
        return _lanes(u16, 16 // bits, bits, (1 << bits) - 1)[:, :cols]
    bits = scheme.fmt.bits
    if scheme.kind == "fp":
        if bits == 8:
            return _lanes(u16, 2, 8, 0xFF)[:, :cols]
        if bits == 4:
            return _lanes(u16, 4, 4, 0xF)[:, :cols]
        if bits == 6:
            hi_words = ceil(cols, 4)
            hi = _lanes(u16[:, :hi_words], 4, 4, 0xF)[:, :cols]
            lo = _lanes(u16[:, hi_words:], 8, 2, 0x3)[:, :cols]
            return (hi << 2) | lo
        if bits == 5:
            hi_words = ceil(cols, 4)
            hi = _lanes(u16[:, :hi_words], 4, 4, 0xF)[:, :cols]
            lsb = _lanes(u16[:, hi_words:], 16, 1, 0x1)[:, :cols]
            return (hi << 1) | lsb
        raise ValueError(f"no kernel for fp {bits}-bit")
    if scheme.fmt.name() == "e2m3" and scheme.k == 3:
        n = ceil(cols, 3)
        w = u16[:, :n]
        hi = _lanes(w, 3, 5, 0x1F)[:, :cols]
        shared = jnp.repeat((w >> jnp.uint32(15)) & jnp.uint32(1), 3, axis=1)[:, :cols]
        return (hi << 1) | shared
    # AMS e2m2 family (FP4.5 / FP4.33 / FP4.25).
    hi_words = ceil(cols, 4)
    hi = _lanes(u16[:, :hi_words], 4, 4, 0xF)[:, :cols]
    n_groups = ceil(cols, scheme.k)
    bits_ = _lanes(u16[:, hi_words:], 16, 1, 0x1)[:, :n_groups]
    shared = jnp.repeat(bits_, scheme.k, axis=1)[:, :cols]
    return (hi << 1) | shared


def _decode_arith(codes: jnp.ndarray, scheme: Scheme) -> jnp.ndarray:
    """Arithmetic FPx decode (no gather tables — Pallas-friendly and the
    literal register-level restoration of §3.2):

    value = (-1)^s · [E≠0] (1 + man·2⁻ᵐ)·2^(E-bias)  +  [E=0] man·2^(1-bias-m)
    """
    if scheme.kind == "int":
        offset = 1 << (scheme.int_bits - 1)
        return codes.astype(jnp.float32) - jnp.float32(offset)
    fmt = scheme.fmt
    e, m = fmt.ebits, fmt.mbits
    s = (codes >> jnp.uint32(e + m)) & jnp.uint32(1)
    ef = ((codes >> jnp.uint32(m)) & jnp.uint32((1 << e) - 1)).astype(jnp.float32)
    man = (codes & jnp.uint32((1 << m) - 1)).astype(jnp.float32)
    is_norm = ef > 0
    exp = jnp.where(is_norm, ef, 1.0) - jnp.float32(fmt.bias)
    frac = jnp.where(is_norm, 1.0 + man * (2.0**-m), man * (2.0**-m))
    mag = frac * jnp.exp2(exp)
    return jnp.where(s == 1, -mag, mag)


def _dequant_tile(words, scales, scheme: Scheme, cols: int) -> jnp.ndarray:
    """[tile_rows, w32] u32 + [tile_rows] f32 -> [tile_rows, cols] f32."""
    codes = _unpack_codes(words, scheme, cols)
    if scheme.kind == "fp16":
        half = jax.lax.bitcast_convert_type(codes.astype(jnp.uint16), jnp.float16)
        return half.astype(jnp.float32)
    return _decode_arith(codes, scheme) * scales[:, None]


def _kernel(w_ref, s_ref, x_ref, o_ref, *, scheme: Scheme, cols: int):
    """One grid step: dequantize a row-tile of W and matmul with x.

    VMEM residency per step: the packed tile (~tile_rows·cols·bpw/8 bytes),
    the dequantized tile (tile_rows·cols·4), x (batch·cols·4) and the output
    tile — sized to stay ≪16 MiB for MXU-shaped tiles.
    """
    wdeq = _dequant_tile(w_ref[...], s_ref[...], scheme, cols)  # [tile, cols]
    # MXU: [batch, cols] @ [cols, tile] with fp32 accumulation.
    o_ref[...] = jnp.dot(
        x_ref[...], wdeq.T, preferred_element_type=jnp.float32
    )


def dequant_linear(
    words_u32: jnp.ndarray,
    scales: jnp.ndarray,
    x: jnp.ndarray,
    *,
    scheme: Scheme,
    rows: int,
    cols: int,
    tile_rows: int = 128,
) -> jnp.ndarray:
    """y[batch, rows] = x[batch, cols] @ dequant(words)ᵀ via pallas_call.

    Grid over row tiles; `rows` must be divisible by the tile (the AOT
    path pads rows — model dims here are multiples of 64).
    """
    batch = x.shape[0]
    tile = min(tile_rows, rows)
    while rows % tile != 0:
        tile //= 2
    tile = max(tile, 1)
    grid = (rows // tile,)
    w32 = words_u32.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel, scheme=scheme, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, w32), lambda r: (r, 0)),
            pl.BlockSpec((tile,), lambda r: (r,)),
            pl.BlockSpec((batch, cols), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, tile), lambda r: (0, r)),
        out_shape=jax.ShapeDtypeStruct((batch, rows), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(words_u32, scales, x)


def dequant_linear_jnp(words_u32, scales, x, *, scheme: Scheme, rows: int, cols: int):
    """Same computation without pallas (plain jnp) — used to sanity-check
    the BlockSpec plumbing and as the L2 fallback for shapes where tiling
    is awkward."""
    del rows
    wdeq = _dequant_tile(words_u32, scales, scheme, cols)
    return jnp.dot(x, wdeq.T, preferred_element_type=jnp.float32)


def quantize_and_pack(w: np.ndarray, scheme: Scheme):
    """Build-time convenience: quantize + pack a weight matrix.

    Returns (words_u32 [rows, w32], scales [rows] f32).
    """
    if scheme.kind == "fp16":
        half = w.astype(np.float16).view(np.uint16)
        words = half
        scales = np.ones(w.shape[0], dtype=np.float32)
        return ref.to_u32(words), scales
    codes, scales = ref.quantize(w, scheme)
    words = ref.pack_rows(scheme, codes)
    return ref.to_u32(words), scales
