"""FPx format algebra (python mirror of rust/src/formats/).

Pure-python decode tables shared by the Pallas kernel (as gather tables),
the jnp quantizer and the ref oracle. Values are bit-exact with the rust
implementation: no infinities/NaN (MX convention), IEEE bias 2^(e-1)-1.
"""

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class FpFormat:
    ebits: int
    mbits: int

    @property
    def bits(self) -> int:
        return 1 + self.ebits + self.mbits

    @property
    def bias(self) -> int:
        return (1 << (self.ebits - 1)) - 1

    @property
    def code_count(self) -> int:
        return 1 << self.bits

    def decode(self, code: int) -> float:
        s = (code >> (self.ebits + self.mbits)) & 1
        e = (code >> self.mbits) & ((1 << self.ebits) - 1)
        man = code & ((1 << self.mbits) - 1)
        scale = 2.0 ** (-self.mbits)
        if e != 0:
            mag = (1.0 + man * scale) * 2.0 ** (e - self.bias)
        else:
            mag = (man * scale) * 2.0 ** (1 - self.bias)
        return -mag if s else mag

    def max_normal(self) -> float:
        return self.decode(((1 << self.ebits) - 1) << self.mbits | ((1 << self.mbits) - 1))

    def decode_table(self) -> np.ndarray:
        """code -> f32 value, as a float32 numpy array (gather table)."""
        return np.array([self.decode(c) for c in range(self.code_count)], dtype=np.float32)

    def all_values(self) -> np.ndarray:
        return np.sort(self.decode_table())

    def name(self) -> str:
        return f"e{self.ebits}m{self.mbits}"


E2M1 = FpFormat(2, 1)
E2M2 = FpFormat(2, 2)
E2M3 = FpFormat(2, 3)
E3M2 = FpFormat(3, 2)
E4M3 = FpFormat(4, 3)

FORMATS = {f.name(): f for f in [E2M1, E2M2, E2M3, E3M2, E4M3]}


@dataclass(frozen=True)
class Scheme:
    """Mirror of rust Scheme: kind in {fp16, fp, ams, int}."""

    kind: str
    fmt: FpFormat | None = None
    k: int = 1
    int_bits: int = 0

    @property
    def bits_per_weight(self) -> float:
        if self.kind == "fp16":
            return 16.0
        if self.kind == "fp":
            return float(self.fmt.bits)
        if self.kind == "ams":
            return (self.fmt.bits - 1) + 1.0 / self.k
        return float(self.int_bits)

    def dequant_table(self) -> np.ndarray:
        if self.kind == "fp16":
            raise ValueError("fp16 uses bitcast, not a table")
        if self.kind == "int":
            n = 1 << self.int_bits
            return (np.arange(n) - n // 2).astype(np.float32)
        return self.fmt.decode_table()


def parse_scheme(name: str) -> Scheme:
    n = name.strip().lower()
    table = {
        "fp16": Scheme("fp16"),
        "fp8": Scheme("fp", E4M3),
        "fp8-e4m3": Scheme("fp", E4M3),
        "fp6": Scheme("fp", E2M3),
        "fp6-e2m3": Scheme("fp", E2M3),
        "fp6-e3m2": Scheme("fp", E3M2),
        "fp5": Scheme("fp", E2M2),
        "fp5-e2m2": Scheme("fp", E2M2),
        "fp4": Scheme("fp", E2M1),
        "fp4-e2m1": Scheme("fp", E2M1),
        "fp5.33": Scheme("ams", E2M3, k=3),
        "fp5.3": Scheme("ams", E2M3, k=3),
        "fp4.5": Scheme("ams", E2M2, k=2),
        "fp4.33": Scheme("ams", E2M2, k=3),
        "fp4.3": Scheme("ams", E2M2, k=3),
        "fp4.25": Scheme("ams", E2M2, k=4),
        "int8": Scheme("int", int_bits=8),
        "int4": Scheme("int", int_bits=4),
    }
    if n in table:
        return table[n]
    raise ValueError(f"unknown scheme '{name}'")
