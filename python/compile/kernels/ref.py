"""Pure numpy/jnp oracle for AMS-Quant: RTN quantization, mantissa sharing
with adaptive search, bit-packing (bit-exact with rust/src/pack/), and the
dequant-GEMV reference the Pallas kernel is tested against.

Everything here is build/test-time only and favours clarity over speed.
"""

import numpy as np

from .formats import FpFormat, Scheme


# --- RTN quantization -----------------------------------------------------


def encode_rtn(fmt: FpFormat, x: np.ndarray) -> np.ndarray:
    """Vectorized round-to-nearest (ties-to-even on the code LSB).

    Returns uint16 codes. Mirrors rust `FpFormat::encode_rtn`.
    """
    mags = np.array(
        [fmt.decode(c) for c in range(1 << (fmt.ebits + fmt.mbits))], dtype=np.float64
    )  # positive magnitude grid, ascending by construction
    ax = np.abs(x.astype(np.float64))
    hi = np.searchsorted(mags, ax, side="left").clip(0, len(mags) - 1)
    lo = (hi - 1).clip(0)
    d_lo = ax - mags[lo]
    d_hi = mags[hi] - ax
    pick_hi = (d_hi < d_lo) | ((d_hi == d_lo) & (lo % 2 == 1))
    code = np.where(pick_hi, hi, lo)
    # searchsorted 'left' puts exact matches at their own index -> d_hi==0.
    exact = ax >= mags[-1]
    code = np.where(exact, len(mags) - 1, code)
    sign = (x < 0) | ((x == 0) & (np.signbit(x)))
    return (code | (sign.astype(np.int64) << (fmt.ebits + fmt.mbits))).astype(np.uint16)


def compute_scales(w: np.ndarray, fmt: FpFormat) -> np.ndarray:
    """Per-output-channel scale s = amax(row) / max_normal (Eqn. 1)."""
    amax = np.abs(w).max(axis=1)
    s = amax / fmt.max_normal()
    s[s == 0.0] = 1.0
    return s.astype(np.float32)


def quantize_rtn(w: np.ndarray, fmt: FpFormat):
    """Channel-wise RTN. Returns (codes [rows, cols] u16, scales [rows])."""
    scales = compute_scales(w, fmt)
    codes = encode_rtn(fmt, w / scales[:, None])
    return codes, scales


def decode_codes(fmt: FpFormat, codes: np.ndarray) -> np.ndarray:
    return fmt.decode_table()[codes]


# --- Mantissa sharing + adaptive search ------------------------------------


def apply_sharing(
    fmt: FpFormat,
    codes: np.ndarray,
    w: np.ndarray,
    scales: np.ndarray,
    k: int,
    policy: str = "adaptive",
) -> np.ndarray:
    """Share the mantissa LSB within groups of k along the input dim.

    policy: 'adaptive' (MSE search, the paper), 'zero', 'one'.
    Mirrors rust `quant::sharing::apply_sharing` with SharePolicy::SetLsb.
    """
    rows, cols = codes.shape
    table = fmt.decode_table()
    out = codes.copy()
    for g0 in range(0, cols, k):
        grp = slice(g0, min(g0 + k, cols))
        c = codes[:, grp]
        if policy == "zero":
            m0 = np.zeros(rows, dtype=np.uint16)
        elif policy == "one":
            m0 = np.ones(rows, dtype=np.uint16)
        else:
            err = []
            for bit in (0, 1):
                cand = (c & ~np.uint16(1)) | np.uint16(bit)
                deq = table[cand] * scales[:, None]
                err.append(((deq - w[:, grp]) ** 2).sum(axis=1))
            m0 = (err[1] < err[0]).astype(np.uint16)
        out[:, grp] = (c & ~np.uint16(1)) | m0[:, None]
    return out


def quantize(w: np.ndarray, scheme: Scheme, policy: str = "adaptive"):
    """Full pipeline -> (codes, scales). Mirrors rust quant::sharing::quantize."""
    if scheme.kind == "int":
        qmax = (1 << (scheme.int_bits - 1)) - 1
        amax = np.abs(w).max(axis=1)
        s = amax / qmax
        s[s == 0.0] = 1.0
        q = np.clip(np.round(w / s[:, None]), -qmax, qmax).astype(np.int64)
        return (q + (1 << (scheme.int_bits - 1))).astype(np.uint16), s.astype(np.float32)
    codes, scales = quantize_rtn(w, scheme.fmt)
    if scheme.kind == "ams":
        codes = apply_sharing(scheme.fmt, codes, w, scales, scheme.k, policy)
    return codes, scales


# --- Packing (bit-exact mirror of rust/src/pack/) ---------------------------


def row_stride(scheme: Scheme, cols: int) -> int:
    """u16 words per packed row."""
    ceil = lambda a, b: -(-a // b)
    if scheme.kind == "fp16":
        return cols
    if scheme.kind == "int":
        return ceil(cols, 16 // scheme.int_bits)
    bits = scheme.fmt.bits
    if scheme.kind == "fp":
        if bits == 8:
            return ceil(cols, 2)
        if bits == 6:
            return ceil(cols, 4) + ceil(cols, 8)
        if bits == 5:
            return ceil(cols, 4) + ceil(cols, 16)
        if bits == 4:
            return ceil(cols, 4)
        raise ValueError(f"no layout for fp {bits}-bit")
    # AMS
    if scheme.fmt.name() == "e2m3" and scheme.k == 3:
        return ceil(cols, 3)
    if bits == 5:
        return ceil(cols, 4) + ceil(ceil(cols, scheme.k), 16)
    raise ValueError(f"no specialized layout for ams {scheme.fmt.name()} k={scheme.k}")


def pack_rows(scheme: Scheme, codes: np.ndarray) -> np.ndarray:
    """codes [rows, cols] u16 -> packed words [rows, row_stride] u16."""
    rows, cols = codes.shape
    stride = row_stride(scheme, cols)
    out = np.zeros((rows, stride), dtype=np.uint32)
    c = codes.astype(np.uint32)
    ceil = lambda a, b: -(-a // b)

    def fixed(bits):
        per = 16 // bits
        for i in range(cols):
            out[:, i // per] |= (c[:, i] & ((1 << bits) - 1)) << (bits * (i % per))

    if scheme.kind == "fp16":
        out[:, :cols] = c
    elif scheme.kind == "int":
        fixed(scheme.int_bits)
    elif scheme.kind == "fp":
        bits = scheme.fmt.bits
        if bits == 8:
            fixed(8)
        elif bits == 4:
            fixed(4)
        elif bits == 6:
            hi_words = ceil(cols, 4)
            for i in range(cols):
                out[:, i // 4] |= ((c[:, i] >> 2) & 0xF) << (4 * (i % 4))
                out[:, hi_words + i // 8] |= (c[:, i] & 0x3) << (2 * (i % 8))
        elif bits == 5:
            hi_words = ceil(cols, 4)
            for i in range(cols):
                out[:, i // 4] |= ((c[:, i] >> 1) & 0xF) << (4 * (i % 4))
                out[:, hi_words + i // 16] |= (c[:, i] & 1) << (i % 16)
    elif scheme.fmt.name() == "e2m3" and scheme.k == 3:
        for i in range(cols):
            out[:, i // 3] |= ((c[:, i] >> 1) & 0x1F) << (5 * (i % 3))
        for g0 in range(0, cols, 3):
            out[:, g0 // 3] |= (c[:, g0] & 1) << 15
    else:  # ams e2m2 family
        hi_words = ceil(cols, 4)
        for i in range(cols):
            out[:, i // 4] |= ((c[:, i] >> 1) & 0xF) << (4 * (i % 4))
        for gi, g0 in enumerate(range(0, cols, scheme.k)):
            out[:, hi_words + gi // 16] |= (c[:, g0] & 1) << (gi % 16)
    return out.astype(np.uint16)


def to_u32(words: np.ndarray) -> np.ndarray:
    """[rows, stride] u16 -> [rows, ceil(stride/2)] u32 little-endian pairs
    (mirror of rust runtime::pack_words_u32)."""
    rows, stride = words.shape
    if stride % 2:
        words = np.concatenate([words, np.zeros((rows, 1), dtype=np.uint16)], axis=1)
    w = words.astype(np.uint32)
    return w[:, 0::2] | (w[:, 1::2] << 16)


def unpack_rows(scheme: Scheme, words: np.ndarray, cols: int) -> np.ndarray:
    """Inverse of pack_rows (u16 words -> codes)."""
    w = words.astype(np.uint32)
    ceil = lambda a, b: -(-a // b)
    i = np.arange(cols)

    if scheme.kind == "fp16":
        return w[:, :cols].astype(np.uint16)
    if scheme.kind == "int":
        bits = scheme.int_bits
        per = 16 // bits
        return ((w[:, i // per] >> (bits * (i % per))) & ((1 << bits) - 1)).astype(np.uint16)
    bits = scheme.fmt.bits
    if scheme.kind == "fp":
        if bits == 8:
            return ((w[:, i // 2] >> (8 * (i % 2))) & 0xFF).astype(np.uint16)
        if bits == 4:
            return ((w[:, i // 4] >> (4 * (i % 4))) & 0xF).astype(np.uint16)
        if bits == 6:
            hi_words = ceil(cols, 4)
            hi = (w[:, i // 4] >> (4 * (i % 4))) & 0xF
            lo = (w[:, hi_words + i // 8] >> (2 * (i % 8))) & 0x3
            return ((hi << 2) | lo).astype(np.uint16)
        if bits == 5:
            hi_words = ceil(cols, 4)
            hi = (w[:, i // 4] >> (4 * (i % 4))) & 0xF
            lsb = (w[:, hi_words + i // 16] >> (i % 16)) & 1
            return ((hi << 1) | lsb).astype(np.uint16)
    if scheme.fmt.name() == "e2m3" and scheme.k == 3:
        word = w[:, i // 3]
        hi = (word >> (5 * (i % 3))) & 0x1F
        shared = (word >> 15) & 1
        return ((hi << 1) | shared).astype(np.uint16)
    hi_words = ceil(cols, 4)
    hi = (w[:, i // 4] >> (4 * (i % 4))) & 0xF
    g = i // scheme.k
    shared = (w[:, hi_words + g // 16] >> (g % 16)) & 1
    return ((hi << 1) | shared).astype(np.uint16)


# --- Reference dequant-GEMV -------------------------------------------------


def dequant_rows(scheme: Scheme, words: np.ndarray, cols: int, scales: np.ndarray) -> np.ndarray:
    """Packed words -> dequantized f32 weight matrix [rows, cols]."""
    codes = unpack_rows(scheme, words, cols)
    if scheme.kind == "fp16":
        # fp16 baseline stores raw half bits; scales are 1.
        return np.ascontiguousarray(codes).view(np.float16).astype(np.float32)
    table = scheme.dequant_table()
    return table[codes] * scales[:, None].astype(np.float32)


def gemv_ref(scheme: Scheme, words: np.ndarray, cols: int, scales: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y[batch, rows] = x[batch, cols] @ dequant(W).T — the oracle."""
    wdeq = dequant_rows(scheme, words, cols, scales)
    return x.astype(np.float32) @ wdeq.T
