"""L2: the JAX transformer (Qwen-style: RMSNorm, NeoX RoPE, GQA, SwiGLU).

Build-time only. Architecture and parameter naming mirror
rust/src/model/transformer.rs exactly; `rust/tests/parity.rs` checks logits
agreement on a shared AMSZ checkpoint. Linear convention: weights are
`[out, in]`, applied as `x @ W.T` (= rust's `W x`).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

ROPE_THETA = 10_000.0
NORM_EPS = 1e-5


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 344
    max_seq: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def to_json_dict(self) -> dict:
        return {
            "vocab_size": self.vocab_size,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "n_kv_heads": self.n_kv_heads,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
        }


TINY_LM = ModelConfig()  # must match rust ModelConfig::tiny_lm()


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """He-ish init; tensor names match the AMSZ layout."""
    rng = np.random.default_rng(seed)
    p = {}

    def mat(name, out_d, in_d, std):
        p[name] = rng.normal(0.0, std, (out_d, in_d)).astype(np.float32)

    d = cfg.d_model
    mat("embed", cfg.vocab_size, d, 0.02)
    for i in range(cfg.n_layers):
        p[f"layers.{i}.attn_norm"] = np.ones(d, dtype=np.float32)
        p[f"layers.{i}.mlp_norm"] = np.ones(d, dtype=np.float32)
        mat(f"layers.{i}.wq", d, d, 0.02)
        mat(f"layers.{i}.wk", cfg.kv_dim, d, 0.02)
        mat(f"layers.{i}.wv", cfg.kv_dim, d, 0.02)
        mat(f"layers.{i}.wo", d, d, 0.02 / np.sqrt(2 * cfg.n_layers))
        mat(f"layers.{i}.w_gate", cfg.d_ff, d, 0.02)
        mat(f"layers.{i}.w_up", cfg.d_ff, d, 0.02)
        mat(f"layers.{i}.w_down", d, cfg.d_ff, 0.02 / np.sqrt(2 * cfg.n_layers))
    p["final_norm"] = np.ones(d, dtype=np.float32)
    mat("lm_head", cfg.vocab_size, d, 0.02)
    return p


def rmsnorm(x, w):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + NORM_EPS) * w


def rope(x, positions):
    """NeoX-style rotary embedding.

    x: [..., T, H, head_dim]; positions: [T] (broadcast over leading dims).
    Pairs (i, i + head_dim/2), angle = pos * theta^(-2i/head_dim).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = ROPE_THETA ** (-2.0 * jnp.arange(half) / hd)  # [half]
    ang = positions[:, None] * freqs[None, :]  # [T, half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[None, :, None, :]
    cos = cos[None, :, None, :]
    a, b = x[..., :half], x[..., half:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)


def forward_seq(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced forward over full sequences.

    tokens: [B, T] int32 -> logits [B, T, vocab].
    """
    B, T = tokens.shape
    hd = cfg.head_dim
    reps = cfg.n_heads // cfg.n_kv_heads
    pos = jnp.arange(T).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))

    x = jnp.asarray(params["embed"])[tokens]  # [B, T, d]
    for i in range(cfg.n_layers):
        g = lambda n: jnp.asarray(params[f"layers.{i}.{n}"])
        h = rmsnorm(x, g("attn_norm"))
        q = (h @ g("wq").T).reshape(B, T, cfg.n_heads, hd)
        k = (h @ g("wk").T).reshape(B, T, cfg.n_kv_heads, hd)
        v = (h @ g("wv").T).reshape(B, T, cfg.n_kv_heads, hd)
        q = rope(q, pos)
        k = rope(k, pos)
        # GQA: expand kv heads.
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, T, cfg.d_model)
        x = x + attn @ g("wo").T
        h = rmsnorm(x, g("mlp_norm"))
        gate = h @ g("w_gate").T
        up = h @ g("w_up").T
        x = x + (jax.nn.silu(gate) * up) @ g("w_down").T
    x = rmsnorm(x, jnp.asarray(params["final_norm"]))
    return x @ jnp.asarray(params["lm_head"]).T


def loss_fn(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy (mean nats/token)."""
    logits = forward_seq(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()
