"""Build-time trainer for the tiny char LM (the E9 end-to-end model).

Hand-rolled Adam (no optax offline), jit-compiled loss/grad, byte-level
synthetic corpus. Outputs into --out (default ../artifacts):

- tiny_lm.amsz        trained checkpoint (AMSZ, loaded by the rust engine)
- corpus_heldout.txt  eval slice for perplexity (rust eval harness)
- parity.json         tokens + reference logits for rust/tests/parity.rs

Run via `make train` (a no-op if outputs exist).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import ckpt_io, corpus as corpus_mod
from compile.model import TINY_LM, forward_seq, init_params, loss_fn


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    t = step + 1
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = new_m[k] / (1 - b1**t)
        vhat = new_v[k] / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v


def sample_batch(data: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    starts = rng.integers(0, len(data) - seq - 1, size=batch)
    return np.stack([data[s : s + seq + 1] for s in starts]).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = TINY_LM
    train_text, heldout_text = corpus_mod.train_heldout()
    data = np.frombuffer(train_text.encode(), dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(args.seed)

    params = {k: jnp.asarray(v) for k, v in init_params(cfg, args.seed).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}

    @jax.jit
    def step_fn(params, m, v, tokens, step):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, tokens))(params)
        params, m, v = adam_update(params, grads, m, v, step, args.lr)
        return params, m, v, loss

    losses = []
    for step in range(args.steps):
        tokens = jnp.asarray(sample_batch(data, args.batch, args.seq, rng))
        params, m, v, loss = step_fn(params, m, v, tokens, step)
        losses.append(float(loss))
        if step % 50 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}", flush=True)

    assert losses[-1] < losses[0] * 0.7, (
        f"training did not converge: {losses[0]:.3f} -> {losses[-1]:.3f}"
    )

    np_params = {k: np.asarray(vv) for k, vv in params.items()}
    ckpt_io.save(os.path.join(args.out, "tiny_lm.amsz"), cfg.to_json_dict(), np_params)
    with open(os.path.join(args.out, "corpus_heldout.txt"), "w") as f:
        f.write(heldout_text)
    with open(os.path.join(args.out, "loss_curve.json"), "w") as f:
        json.dump({"losses": losses, "steps": args.steps}, f)

    # Parity vector: logits for a short prompt, from the JAX side.
    probe = np.frombuffer(b"the lamp is ", dtype=np.uint8).astype(np.int32)[None, :]
    logits = np.asarray(forward_seq(params, cfg, jnp.asarray(probe)))[0]
    with open(os.path.join(args.out, "parity.json"), "w") as f:
        json.dump(
            {
                "tokens": probe[0].tolist(),
                "logits_last": logits[-1].tolist(),
                "logits_all_norm": float(np.linalg.norm(logits)),
            },
            f,
        )
    print(f"saved checkpoint + heldout + parity to {args.out}")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
