"""AOT lowering tests: HLO text is produced and structurally sane."""

import numpy as np

from compile.aot import artifact_name, lower_linear


def test_lower_small_linear():
    text = lower_linear("fp5.33", 16, 12, 2)
    assert "HloModule" in text
    # Tuple return convention for the rust loader.
    assert "ROOT" in text


def test_lower_fp16_baseline():
    text = lower_linear("fp16", 8, 8, 1)
    assert "HloModule" in text


def test_artifact_naming():
    assert artifact_name("fp5.33", 256, 128, 8) == "linear_fp5p33_256x128_b8.hlo.txt"


def test_lowered_text_reparses_in_jax():
    # The text must at least be parseable back by jax's own xla_client.
    from jax._src.lib import xla_client as xc

    text = lower_linear("fp4.25", 8, 16, 1)
    # No direct text->computation parser is exposed here; structural checks:
    assert text.count("ENTRY") == 1
    assert "u32" in text or "s32" in text  # packed words parameter present
    assert "f32[1,8]" in text  # output shape [batch, rows]
    _ = xc  # imported to assert availability
    _ = np
