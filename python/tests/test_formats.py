"""Format algebra tests (mirror of rust formats:: tests — Table 1 exact)."""

import numpy as np
import pytest

from compile.kernels.formats import E2M1, E2M2, E2M3, E3M2, FORMATS, parse_scheme


def test_table1_e2m3():
    assert E2M3.bias == 1
    assert E2M3.max_normal() == 7.5
    assert E2M3.decode(0b01000) == 1.0  # min normal (exp=1, man=0)
    assert E2M3.decode(0b00111) == 0.875  # max subnormal
    assert E2M3.decode(0b00001) == 0.125  # min subnormal


def test_table1_e3m2():
    assert E3M2.bias == 3
    assert E3M2.max_normal() == 28.0
    assert E3M2.decode(0b00100) == 0.25  # min normal
    assert E3M2.decode(0b00001) == 0.0625  # min subnormal


def test_e2m1_value_set():
    vals = sorted(E2M1.decode(c) for c in range(8))
    assert vals == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_monotone_positive_grid():
    for f in FORMATS.values():
        mags = [f.decode(c) for c in range(1 << (f.ebits + f.mbits))]
        assert all(b > a for a, b in zip(mags, mags[1:])), f.name()


def test_decode_table_matches_decode():
    for f in FORMATS.values():
        t = f.decode_table()
        for c in range(f.code_count):
            assert t[c] == np.float32(f.decode(c))


def test_scheme_bits_per_weight():
    assert parse_scheme("fp5.33").bits_per_weight == pytest.approx(16 / 3)
    assert parse_scheme("fp4.25").bits_per_weight == 4.25
    assert parse_scheme("fp16").bits_per_weight == 16.0
    assert parse_scheme("int8").bits_per_weight == 8.0


def test_parse_rejects_unknown():
    with pytest.raises(ValueError):
        parse_scheme("fp9.99")


def test_negative_codes():
    f = E2M2
    top = f.ebits + f.mbits
    for c in range(1 << top):
        assert f.decode(c | (1 << top)) == -f.decode(c)
