"""L1 Pallas kernel vs pure reference — the core correctness signal.

Hypothesis sweeps shapes and schemes; assert_allclose against ref.gemv_ref.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ams_dequant import dequant_linear, dequant_linear_jnp, quantize_and_pack
from compile.kernels.formats import parse_scheme

SCHEMES = [
    "fp16", "fp8", "int8", "int4", "fp6", "fp6-e3m2", "fp5", "fp4", "fp5.33", "fp4.5", "fp4.25",
]


def run_case(name, rows, cols, batch, seed, sigma=0.02, use_pallas=True):
    sch = parse_scheme(name)
    rng = np.random.default_rng(seed)
    w = rng.normal(0, sigma, (rows, cols)).astype(np.float32)
    x = rng.normal(0, 1, (batch, cols)).astype(np.float32)
    words32, scales = quantize_and_pack(w, sch)
    fn = dequant_linear if use_pallas else dequant_linear_jnp
    y = np.asarray(fn(words32, scales, x, scheme=sch, rows=rows, cols=cols))
    if sch.kind == "fp16":
        yref = x @ w.astype(np.float16).astype(np.float32).T
    else:
        codes, s = ref.quantize(w, sch)
        yref = ref.gemv_ref(sch, ref.pack_rows(sch, codes), cols, s, x)
    np.testing.assert_allclose(y, yref, rtol=1e-5, atol=1e-5)
    return y, yref


@pytest.mark.parametrize("name", SCHEMES)
def test_kernel_matches_ref(name):
    run_case(name, rows=16, cols=48, batch=4, seed=1)


@pytest.mark.parametrize("name", ["fp5.33", "fp4.25", "fp6"])
def test_kernel_row_tiling(name):
    # rows > tile forces a multi-step grid.
    run_case(name, rows=256, cols=32, batch=2, seed=2)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(SCHEMES),
    cols=st.integers(min_value=1, max_value=96),
    rows=st.sampled_from([1, 2, 4, 8, 32]),
    batch=st.integers(min_value=1, max_value=5),
)
def test_kernel_hypothesis_sweep(name, cols, rows, batch):
    run_case(name, rows, cols, batch, seed=cols * 131 + rows)


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(["fp5.33", "fp4.25"]),
    sigma=st.sampled_from([1e-4, 0.02, 1.0, 50.0]),
)
def test_kernel_scale_invariance(name, sigma):
    # Dequant error scales with the data, never explodes.
    y, yref = run_case(name, rows=8, cols=24, batch=2, seed=7, sigma=sigma)
    assert np.isfinite(y).all()


@pytest.mark.parametrize("name", ["fp5.33", "fp4.25", "fp16"])
def test_pallas_equals_plain_jnp(name):
    # The BlockSpec plumbing must not change the math.
    ya, _ = run_case(name, rows=64, cols=40, batch=3, seed=3, use_pallas=True)
    yb, _ = run_case(name, rows=64, cols=40, batch=3, seed=3, use_pallas=False)
    np.testing.assert_allclose(ya, yb, rtol=1e-6, atol=1e-6)


def test_zero_weights():
    sch = parse_scheme("fp4.25")
    w = np.zeros((8, 16), dtype=np.float32)
    words32, scales = quantize_and_pack(w, sch)
    x = np.ones((2, 16), dtype=np.float32)
    y = np.asarray(dequant_linear(words32, scales, x, scheme=sch, rows=8, cols=16))
    assert (y == 0).all()
