"""L2 model tests: shapes, causality, training signal, checkpoint IO."""

import numpy as np
import jax.numpy as jnp

from compile import ckpt_io
from compile.corpus import make_corpus, train_heldout
from compile.model import ModelConfig, forward_seq, init_params, loss_fn


CFG = ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=64, max_seq=64)


def test_forward_shapes():
    p = init_params(CFG, 0)
    tokens = jnp.asarray(np.arange(12, dtype=np.int32).reshape(2, 6) % 64)
    logits = forward_seq(p, CFG, tokens)
    assert logits.shape == (2, 6, 64)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    # Changing a future token must not change earlier logits.
    p = init_params(CFG, 1)
    t1 = np.array([[1, 2, 3, 4, 5, 6]], dtype=np.int32)
    t2 = t1.copy()
    t2[0, -1] = 9
    l1 = np.asarray(forward_seq(p, CFG, jnp.asarray(t1)))
    l2 = np.asarray(forward_seq(p, CFG, jnp.asarray(t2)))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert np.abs(l1[0, -1] - l2[0, -1]).max() > 1e-6


def test_loss_decreases_quickly():
    import jax

    p = {k: jnp.asarray(v) for k, v in init_params(CFG, 2).items()}
    rng = np.random.default_rng(0)
    data = np.frombuffer(make_corpus(20_000, 7).encode(), np.uint8).astype(np.int32) % 64
    grab = lambda: jnp.asarray(
        np.stack([data[s : s + 33] for s in rng.integers(0, len(data) - 34, 8)])
    )
    loss_grad = jax.jit(jax.value_and_grad(lambda p, t: loss_fn(p, CFG, t)))
    l0, _ = loss_grad(p, grab())
    for _ in range(30):
        _, g = loss_grad(p, grab())
        p = {k: v - 0.01 * g[k] for k, v in p.items()}
    l1, _ = loss_grad(p, grab())
    assert float(l1) < float(l0), f"{float(l0)} -> {float(l1)}"


def test_corpus_deterministic_and_split():
    a, b = train_heldout(10_000, 42), train_heldout(10_000, 42)
    assert a == b
    train, held = a
    assert len(held) > 0 and len(train) > len(held)
    assert all(ord(c) < 256 for c in held[:1000])


def test_ckpt_roundtrip(tmp_path):
    p = init_params(CFG, 3)
    path = str(tmp_path / "m.amsz")
    ckpt_io.save(path, CFG.to_json_dict(), p)
    cfg2, t2 = ckpt_io.load(path)
    assert cfg2["d_model"] == 32
    for k, v in p.items():
        np.testing.assert_array_equal(t2[k], v)
