"""Reference-pipeline tests: RTN, sharing/adaptive search, pack/unpack."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.formats import E2M2, E2M3, parse_scheme

SCHEMES = [
    "fp8", "int8", "int4", "fp6", "fp6-e3m2", "fp5", "fp4", "fp5.33", "fp4.5", "fp4.25",
]


def rand_w(rows, cols, seed=0, sigma=0.02):
    return np.random.default_rng(seed).normal(0, sigma, (rows, cols)).astype(np.float32)


def test_rtn_is_nearest():
    fmt = E2M3
    vals = fmt.decode_table().astype(np.float64)
    xs = np.random.default_rng(1).uniform(-8, 8, 500).astype(np.float32)
    codes = ref.encode_rtn(fmt, xs)
    got = fmt.decode_table()[codes]
    for x, g in zip(xs, got):
        best = np.abs(vals - x).min()
        assert abs(g - x) <= best + 1e-6


def test_rtn_saturates():
    fmt = E2M3
    codes = ref.encode_rtn(fmt, np.array([100.0, -100.0], dtype=np.float32))
    assert fmt.decode_table()[codes[0]] == 7.5
    assert fmt.decode_table()[codes[1]] == -7.5


def test_scales_channelwise():
    w = np.array([[1.0, -3.0, 0.5], [0.25, 0.1, -0.2]], dtype=np.float32)
    s = ref.compute_scales(w, E2M3)
    assert s == pytest.approx([3.0 / 7.5, 0.25 / 7.5])


def test_sharing_shares_lsb():
    w = rand_w(4, 33, 2)
    sch = parse_scheme("fp5.33")
    codes, scales = ref.quantize(w, sch)
    for r in range(4):
        for g0 in range(0, 33, 3):
            lsbs = codes[r, g0 : g0 + 3] & 1
            assert (lsbs == lsbs[0]).all()


def test_adaptive_beats_fixed():
    w = rand_w(8, 64, 3)
    for name in ["fp5.33", "fp4.25"]:
        sch = parse_scheme(name)
        table = sch.fmt.decode_table()

        def mse(policy):
            c, s = ref.quantize_rtn(w, sch.fmt)
            c = ref.apply_sharing(sch.fmt, c, w, s, sch.k, policy)
            return ((table[c] * s[:, None] - w) ** 2).mean()

        assert mse("adaptive") <= mse("zero") + 1e-12
        assert mse("adaptive") <= mse("one") + 1e-12


def test_mse_ordering_across_formats():
    w = rand_w(16, 192, 4)

    def mse(name):
        sch = parse_scheme(name)
        c, s = ref.quantize(w, sch)
        return ((sch.dequant_table()[c] * s[:, None] - w) ** 2).mean()

    m6, m533, m5, m425, m4 = (
        mse("fp6"), mse("fp5.33"), mse("fp5"), mse("fp4.25"), mse("fp4"),
    )
    assert m6 <= m533 <= m5 * 1.5
    assert m5 <= m425 < m4


@pytest.mark.parametrize("name", SCHEMES)
def test_pack_roundtrip(name):
    sch = parse_scheme(name)
    for cols in [1, 3, 4, 16, 17, 47, 64, 100]:
        w = rand_w(3, cols, cols)
        codes, _ = ref.quantize(w, sch)
        words = ref.pack_rows(sch, codes)
        assert words.shape[1] == ref.row_stride(sch, cols)
        back = ref.unpack_rows(sch, words, cols)
        np.testing.assert_array_equal(back, codes, err_msg=f"{name} cols={cols}")


@settings(max_examples=25, deadline=None)
@given(
    cols=st.integers(min_value=1, max_value=130),
    rows=st.integers(min_value=1, max_value=6),
    name=st.sampled_from(SCHEMES),
)
def test_pack_roundtrip_hypothesis(cols, rows, name):
    sch = parse_scheme(name)
    w = rand_w(rows, cols, cols * 7 + rows)
    codes, _ = ref.quantize(w, sch)
    back = ref.unpack_rows(sch, ref.pack_rows(sch, codes), cols)
    np.testing.assert_array_equal(back, codes)


def test_bits_per_weight_at_divisible_cols():
    for name, expect in [("fp5.33", 16 / 3), ("fp4.25", 4.25), ("fp6", 6.0), ("fp5", 5.0)]:
        sch = parse_scheme(name)
        stride = ref.row_stride(sch, 768)
        assert stride * 16 / 768 == pytest.approx(expect), name


def test_u32_repack():
    sch = parse_scheme("fp5.33")
    w = rand_w(2, 6, 9)
    codes, _ = ref.quantize(w, sch)
    words = ref.pack_rows(sch, codes)
    u32 = ref.to_u32(words)
    assert u32.dtype == np.uint32
    assert (u32[:, 0] & 0xFFFF == words[:, 0]).all()
    assert (u32[:, 0] >> 16 == words[:, 1]).all()
