//! E9 perf — batched decode throughput of the transformer engine across
//! schemes and batch sizes (the model-level realization of Table 3's
//! batch sweep: linear layers dominate, attention is per-sequence).

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::model::transformer::{ForwardScratch, KvCache};
use ams_quant::quant::QuantConfig;
use ams_quant::report::{f, Table};
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig};
use ams_quant::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let batches: Vec<usize> = if quick { vec![1, 8] } else { vec![1, 4, 8, 16, 32] };
    let steps = args.get_usize("steps", 8);

    let (base, _held, kind) = exp::load_model(Path::new("artifacts")).expect("load model");
    println!("# e2e decode bench: {kind} model, {steps} steps/iteration\n");

    let mut header = vec!["Scheme".to_string()];
    header.extend(batches.iter().map(|b| format!("tok/s b={b}")));
    header.push("speedup b=8 vs fp16".into());
    let mut t = Table::new(
        "E9 — batched decode throughput",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let mut fp16_b8 = 0.0f64;
    // One scratch for the whole sweep: the serving-loop usage pattern
    // (buffers are high-water sized, decode steps allocate nothing).
    let mut scratch = ForwardScratch::new();
    for name in ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "fp4"] {
        let scheme = Scheme::parse(name).unwrap();
        let model = base.quantized(&QuantConfig::paper(scheme));
        let mut cells = vec![scheme.label()];
        let mut b8_rate = 0.0;
        for &b in &batches {
            let tokens: Vec<u32> = (0..b).map(|i| (i as u32 * 17 + 32) % 255).collect();
            let scratch = &mut scratch;
            let mut fcall = || {
                let mut caches: Vec<KvCache> = (0..b).map(|_| model.new_cache()).collect();
                for _ in 0..steps {
                    black_box(model.forward_batch_with(&tokens, &mut caches, scratch).len());
                }
            };
            let r = bench_with_units(
                &format!("{name}/b{b}"),
                &cfg,
                (b * steps) as f64,
                &mut fcall,
            );
            let rate = r.rate();
            if b == 8 {
                b8_rate = rate;
                if scheme == Scheme::Fp16 {
                    fp16_b8 = rate;
                }
            }
            cells.push(f(rate, 1));
        }
        cells.push(if fp16_b8 > 0.0 {
            f(b8_rate / fp16_b8, 2)
        } else {
            "-".into()
        });
        t.row(cells);
    }
    println!("{}", t.to_console());
    println!("{}", t.to_markdown());
}
