//! E9 perf — batched decode throughput of the transformer engine across
//! schemes and batch sizes (the model-level realization of Table 3's
//! batch sweep: linear layers dominate, attention is per-sequence), plus
//! an end-to-end serving trajectory through the `Engine` (throughput,
//! batch occupancy, TTFT percentiles) written to `BENCH_SERVE.json`
//! (`--json-serve PATH` to override) so serving-latency regressions are
//! diffable across commits, like `BENCH_GEMM.json` for the kernels.
//! Schema v2 adds paged-KV columns per entry and a `paged_admission`
//! probe: at fixed KV memory (a pool sized for 2 worst-case sequences)
//! the paged path must admit more than 2 concurrent sequences. Schema
//! v3 adds a `spec_decode` probe: a speculative engine (draft depth
//! ≥ 2) on a hi/lo-split scheme must land at least one draft — the
//! acceptance rate and draft economics are recorded for diffing.
//! Schema v4 sources percentiles from the engine's streaming metrics
//! histograms (`Engine::metrics_snapshot`) and adds `ttft_p90_s` /
//! `step_time_p99_s` per serve entry — CI asserts both. Schema v5 adds
//! a `tenant_mix` probe: the same heavy/light two-tenant workload runs
//! untenanted (youngest-first preemption), tenanted (fair-share), and
//! tenanted with a per-tenant page quota; per-tenant TTFT and latency
//! percentiles are recorded per policy so CI can assert fair-share
//! shields the light tenant from the heavy one's pool pressure.
//!
//! Flags: `--steps N` decode steps per iteration, `--serve-requests N`,
//! `--serve-max-batch B`, `--serve-max-new-tokens T`, `--json-serve PATH`.
//! Honors `AMS_BENCH_QUICK` / `AMS_BENCH_MEASURE_SECS`.

use ams_quant::coordinator::{Engine, GenRequest, GenResponse, Priority, RequestHandle};
use ams_quant::obs::{labeled, names};
use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::model::transformer::{ForwardScratch, KvCache, Transformer};
use ams_quant::quant::QuantConfig;
use ams_quant::report::{f, Table};
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig};
use ams_quant::util::cli::Args;
use ams_quant::util::json::Json;
use ams_quant::util::timer::Timer;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let batches: Vec<usize> = if quick { vec![1, 8] } else { vec![1, 4, 8, 16, 32] };
    let steps = args.get_usize("steps", 8);

    let (base, _held, kind) = exp::load_model(Path::new("artifacts")).expect("load model");
    println!("# e2e decode bench: {kind} model, {steps} steps/iteration\n");

    let mut header = vec!["Scheme".to_string()];
    header.extend(batches.iter().map(|b| format!("tok/s b={b}")));
    header.push("speedup b=8 vs fp16".into());
    let mut t = Table::new(
        "E9 — batched decode throughput",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let mut fp16_b8 = 0.0f64;
    // One scratch for the whole sweep: the serving-loop usage pattern
    // (buffers are high-water sized, decode steps allocate nothing).
    let mut scratch = ForwardScratch::new();
    for name in ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "fp4"] {
        let scheme = Scheme::parse(name).unwrap();
        let model = base.quantized(&QuantConfig::paper(scheme)).unwrap();
        let mut cells = vec![scheme.label()];
        let mut b8_rate = 0.0;
        for &b in &batches {
            let tokens: Vec<u32> = (0..b).map(|i| (i as u32 * 17 + 32) % 255).collect();
            let scratch = &mut scratch;
            let mut fcall = || {
                let mut caches: Vec<KvCache> = (0..b).map(|_| model.new_cache()).collect();
                for _ in 0..steps {
                    black_box(model.forward_batch_with(&tokens, &mut caches, scratch).len());
                }
            };
            let r = bench_with_units(
                &format!("{name}/b{b}"),
                &cfg,
                (b * steps) as f64,
                &mut fcall,
            );
            let rate = r.rate();
            if b == 8 {
                b8_rate = rate;
                if scheme == Scheme::Fp16 {
                    fp16_b8 = rate;
                }
            }
            cells.push(f(rate, 1));
        }
        cells.push(if fp16_b8 > 0.0 {
            f(b8_rate / fp16_b8, 2)
        } else {
            "-".into()
        });
        t.row(cells);
    }
    println!("{}", t.to_console());
    println!("{}", t.to_markdown());

    serve_trajectory(&args, &base, quick);
}

/// End-to-end serving sweep: one `Engine` per scheme, a fixed request
/// mix, JSON trajectory of throughput / occupancy / TTFT percentiles.
fn serve_trajectory(args: &Args, base: &Transformer, quick: bool) {
    let n_requests = args.get_usize("serve-requests", if quick { 8 } else { 24 });
    let max_batch = args.get_usize("serve-max-batch", 8);
    let max_new = args.get_usize("serve-max-new-tokens", if quick { 8 } else { 24 });
    let json_path = args.get_or("json-serve", "BENCH_SERVE.json").to_string();

    let vocab = base.cfg.vocab_size as u32;
    let prompts: Vec<Vec<u32>> = (0..n_requests)
        .map(|i| {
            let plen = 4 + (i * 5) % 17;
            (0..plen as u32).map(|j| (j * 13 + i as u32 * 7 + 1) % vocab).collect()
        })
        .collect();

    let mut table = Table::new(
        &format!("E9 — serving trajectory ({n_requests} req, max_batch={max_batch})"),
        &["Scheme", "tok/s", "occupancy", "ttft p50 ms", "ttft p99 ms", "lat p50 ms"],
    );
    let mut results: Vec<Json> = Vec::new();
    for name in ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "fp4"] {
        let scheme = Scheme::parse(name).unwrap();
        let model = base.quantized(&QuantConfig::paper(scheme)).unwrap();
        let eng = Engine::builder().max_batch(max_batch).seed(1).build(model);
        let wall = Timer::start();
        let handles: Vec<RequestHandle> = prompts
            .iter()
            .enumerate()
            .map(|(id, p)| {
                eng.submit(GenRequest::greedy(id as u64, p.clone(), max_new))
                    .expect("engine accepts while under capacity")
            })
            .collect();
        let done = handles.into_iter().filter_map(|h| h.wait()).count();
        let wall_s = wall.elapsed_secs();
        eng.drain();
        let snap = eng.metrics_snapshot();
        let ttft = eng.ttft();
        let lat = eng.latency();
        let step_time = snap.hist(names::STEP_TIME);
        let kv_pages_peak = eng.kv_pages_peak();
        let stats = eng.shutdown();
        assert_eq!(done, n_requests, "{name}: all requests must complete");

        let tps = stats.tokens_generated as f64 / wall_s;
        table.row(vec![
            scheme.label(),
            f(tps, 1),
            f(stats.mean_batch_occupancy(), 2),
            f(ttft.p50 * 1e3, 3),
            f(ttft.p99 * 1e3, 3),
            f(lat.p50 * 1e3, 3),
        ]);
        let mut entry = Json::obj();
        entry
            .set("name", Json::Str(format!("serve/{name}/b{max_batch}")))
            .set("scheme", Json::Str(name.into()))
            .set("requests", Json::Num(n_requests as f64))
            .set("max_batch", Json::Num(max_batch as f64))
            .set("max_new_tokens", Json::Num(max_new as f64))
            .set("wall_s", Json::Num(wall_s))
            .set("tokens_per_s", Json::Num(tps))
            .set("mean_occupancy", Json::Num(stats.mean_batch_occupancy()))
            .set("decode_steps", Json::Num(stats.decode_steps as f64))
            .set("ttft_p50_s", Json::Num(ttft.p50))
            .set("ttft_p90_s", Json::Num(ttft.p90))
            .set("ttft_p99_s", Json::Num(ttft.p99))
            .set("latency_p50_s", Json::Num(lat.p50))
            .set("latency_p99_s", Json::Num(lat.p99))
            // Schema v4: streaming-histogram percentiles from the
            // metrics registry (O(1) memory, bounded relative error).
            .set("step_time_p50_s", Json::Num(step_time.p50))
            .set("step_time_p99_s", Json::Num(step_time.p99))
            // Paged-KV columns (schema v2). These runs use the default
            // worst-case pool, so preemptions must stay zero.
            .set("kv_page_size", Json::Num(16.0))
            .set("kv_pool_pages", Json::Num(0.0))
            .set("kv_pages_peak", Json::Num(kv_pages_peak as f64))
            .set("prefix_hits", Json::Num(stats.prefix_hits as f64))
            .set("preemptions", Json::Num(stats.preemptions as f64))
            .set("peak_concurrency", Json::Num(stats.peak_concurrency as f64));
        results.push(entry);
    }
    println!("{}", table.to_console());
    println!("{}", table.to_markdown());

    results.push(paged_admission(base, quick));
    results.push(spec_decode_probe(base, quick));
    results.push(tenant_mix_probe(base, quick));

    let mut root = Json::obj();
    root.set("bench", Json::Str("serve".into()))
        .set("schema_version", Json::Num(5.0))
        .set("requests", Json::Num(n_requests as f64))
        .set("max_batch", Json::Num(max_batch as f64))
        .set("max_new_tokens", Json::Num(max_new as f64))
        .set("results", Json::Arr(results));
    match std::fs::write(&json_path, root.to_string_pretty()) {
        Ok(()) => eprintln!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}

/// The tentpole's headline number: admitted concurrency at **fixed KV
/// memory**. The pool holds exactly 2 worst-case sequences
/// (`2 * ceil(max_seq / page_size)` pages), so a contiguous,
/// reservation-based cache could never run more than 2 sequences at
/// once. Paged allocation + a shared prompt prefix admit whatever
/// actually fits, and the measured `peak_concurrency` must beat the
/// worst-case bound — CI asserts it.
fn paged_admission(base: &Transformer, quick: bool) -> Json {
    let page_size = 16usize;
    let worst_pages_per_seq = base.cfg.max_seq.div_ceil(page_size);
    let pool_pages = 2 * worst_pages_per_seq;
    let worst_case_admissible = pool_pages / worst_pages_per_seq; // = 2
    let n_requests = if quick { 12 } else { 16 };
    let max_new = 4usize;

    let model = base.quantized(&QuantConfig::paper(Scheme::parse("fp5.33").unwrap())).unwrap();
    let vocab = model.cfg.vocab_size as u32;
    // One page of common prefix, then a distinct tail per request: only
    // the page-aligned prefix is shareable.
    let prefix: Vec<u32> = (0..page_size as u32).map(|j| (j * 11 + 3) % vocab).collect();
    let eng = Engine::builder()
        .max_batch(8)
        .kv_page_size(page_size)
        .kv_pool_pages(pool_pages)
        .seed(1)
        .build(model);
    let wall = Timer::start();
    let handles: Vec<RequestHandle> = (0..n_requests as u64)
        .map(|id| {
            let mut prompt = prefix.clone();
            prompt.extend((0..4).map(|j| (id as u32 * 5 + j + 1) % vocab));
            eng.submit(GenRequest::greedy(id, prompt, max_new)).expect("submit")
        })
        .collect();
    let done = handles.into_iter().filter_map(|h| h.wait()).count();
    let wall_s = wall.elapsed_secs();
    eng.drain();
    let kv_pages_peak = eng.kv_pages_peak();
    let stats = eng.shutdown();
    assert_eq!(done, n_requests, "paged_admission: all requests complete");
    assert!(
        stats.peak_concurrency > worst_case_admissible,
        "paged admission must beat the worst-case reservation bound \
         (peak {} vs bound {})",
        stats.peak_concurrency,
        worst_case_admissible
    );

    println!(
        "# paged_admission: pool={pool_pages} pages (page_size={page_size}) holds \
         {worst_case_admissible} worst-case seqs; measured peak concurrency {} \
         (prefix hits {}, preemptions {}, pages peak {kv_pages_peak})",
        stats.peak_concurrency, stats.prefix_hits, stats.preemptions
    );
    let mut entry = Json::obj();
    entry
        .set("name", Json::Str("serve/paged_admission".into()))
        .set("scheme", Json::Str("fp5.33".into()))
        .set("requests", Json::Num(n_requests as f64))
        .set("max_batch", Json::Num(8.0))
        .set("max_new_tokens", Json::Num(max_new as f64))
        .set("wall_s", Json::Num(wall_s))
        .set("kv_page_size", Json::Num(page_size as f64))
        .set("kv_pool_pages", Json::Num(pool_pages as f64))
        .set("worst_case_admissible", Json::Num(worst_case_admissible as f64))
        .set("kv_pages_peak", Json::Num(kv_pages_peak as f64))
        .set("prefix_hits", Json::Num(stats.prefix_hits as f64))
        .set("preemptions", Json::Num(stats.preemptions as f64))
        .set("peak_concurrency", Json::Num(stats.peak_concurrency as f64));
    entry
}

/// Schema v3 probe: self-speculative decoding economics. A speculative
/// engine (draft depth ≥ 2) serves a greedy workload on a hi/lo-split
/// scheme; the verify pass must accept at least one draft — CI asserts
/// `acceptance_rate > 0` — and the entry records the draft/accept
/// counts so speculation regressions are diffable across commits.
fn spec_decode_probe(base: &Transformer, quick: bool) -> Json {
    let draft_depth = 3usize;
    let n_requests = if quick { 6 } else { 12 };
    let max_new = if quick { 12 } else { 24 };
    let model =
        base.quantized(&QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap())).unwrap();
    let vocab = model.cfg.vocab_size as u32;
    let eng = Engine::builder()
        .max_batch(4)
        .speculative(true)
        .draft_depth(draft_depth)
        .seed(1)
        .build(model);
    let wall = Timer::start();
    let handles: Vec<RequestHandle> = (0..n_requests as u64)
        .map(|id| {
            let prompt: Vec<u32> =
                (0..6).map(|j| (id as u32 * 7 + j * 3 + 1) % vocab).collect();
            eng.submit(GenRequest::greedy(id, prompt, max_new)).expect("submit")
        })
        .collect();
    let done = handles.into_iter().filter_map(|h| h.wait()).count();
    let wall_s = wall.elapsed_secs();
    eng.drain();
    let kv_pages_peak = eng.kv_pages_peak();
    let stats = eng.shutdown();
    assert_eq!(done, n_requests, "spec_decode: all requests complete");
    assert!(stats.drafted > 0, "spec_decode: speculative rounds must run");
    assert!(
        stats.acceptance_rate() > 0.0,
        "spec_decode: the hi stream landed no drafts (drafted {}, accepted {})",
        stats.drafted,
        stats.accepted
    );

    println!(
        "# spec_decode: fp6-e2m3 depth={draft_depth} drafted={} accepted={} \
         acceptance={:.3} tok/s={:.1}",
        stats.drafted,
        stats.accepted,
        stats.acceptance_rate(),
        stats.tokens_generated as f64 / wall_s
    );
    let mut entry = Json::obj();
    entry
        .set("name", Json::Str("serve/spec_decode".into()))
        .set("scheme", Json::Str("fp6-e2m3".into()))
        .set("requests", Json::Num(n_requests as f64))
        .set("max_batch", Json::Num(4.0))
        .set("max_new_tokens", Json::Num(max_new as f64))
        .set("wall_s", Json::Num(wall_s))
        .set("tokens_per_s", Json::Num(stats.tokens_generated as f64 / wall_s))
        .set("draft_depth", Json::Num(draft_depth as f64))
        .set("drafted", Json::Num(stats.drafted as f64))
        .set("accepted", Json::Num(stats.accepted as f64))
        .set("acceptance_rate", Json::Num(stats.acceptance_rate()))
        .set("kv_page_size", Json::Num(16.0))
        .set("kv_pool_pages", Json::Num(0.0))
        .set("kv_pages_peak", Json::Num(kv_pages_peak as f64))
        .set("prefix_hits", Json::Num(stats.prefix_hits as f64))
        .set("preemptions", Json::Num(stats.preemptions as f64))
        .set("peak_concurrency", Json::Num(stats.peak_concurrency as f64));
    entry
}

/// Nearest-rank percentile over raw per-request samples (the probe has
/// few requests per tenant, so exact order statistics beat the
/// streaming histograms here).
fn pctl(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("latency samples are finite"));
    v[(q * (v.len() - 1) as f64).round() as usize]
}

/// Schema v5 probe: multi-tenant scheduling under pool pressure. A
/// heavy tenant (6 long bulk decodes) and a light tenant (2 short bulk
/// decodes, submitted last) share a pool deliberately too small for
/// the workload's full KV growth, so the scheduler must preempt. The
/// same workload runs under three policies:
///
/// - `yf` — untenanted: every request joins the shared default tenant,
///   which degenerates fair-share to plain youngest-first, so the
///   light requests (youngest) absorb the preemption storm.
/// - `fair` — tenanted, no quota: fair-share parks the youngest bulk
///   of the most-over-share tenant, i.e. the heavy one.
/// - `fair_quota` — tenanted with a per-tenant page quota below the
///   heavy tenant's appetite, so quota pressure is also billed to the
///   offender.
///
/// Per-tenant TTFT/latency percentiles are recorded per policy; CI
/// asserts the light tenant's tail under fair-share does not regress
/// past youngest-first.
fn tenant_mix_probe(base: &Transformer, quick: bool) -> Json {
    let page_size = 16usize;
    let pool_pages = 18usize;
    let heavy_n = 6usize;
    let light_n = 2usize;
    let heavy_new = if quick { 24 } else { 40 };
    let light_new = 8usize;
    let quota_pages = 12usize;
    let vocab = base.cfg.vocab_size as u32;
    // Distinct from the first token so tenants' prompts never share a
    // page-aligned prefix and pool pressure stays policy-independent.
    let heavy_prompt =
        |id: u64| (0..30u32).map(|j| (j * 13 + id as u32 * 7 + 1) % vocab).collect::<Vec<u32>>();
    let light_prompt =
        |id: u64| (0..8u32).map(|j| (j * 5 + id as u32 * 11 + 2) % vocab).collect::<Vec<u32>>();

    let mut entry = Json::obj();
    entry
        .set("name", Json::Str("serve/tenant_mix".into()))
        .set("scheme", Json::Str("fp5.33".into()))
        .set("heavy_requests", Json::Num(heavy_n as f64))
        .set("light_requests", Json::Num(light_n as f64))
        .set("max_batch", Json::Num(8.0))
        .set("kv_page_size", Json::Num(page_size as f64))
        .set("kv_pool_pages", Json::Num(pool_pages as f64))
        .set("quota_pages", Json::Num(quota_pages as f64));

    for (cfg, tenanted, quota) in
        [("yf", false, 0usize), ("fair", true, 0), ("fair_quota", true, quota_pages)]
    {
        let model =
            base.quantized(&QuantConfig::paper(Scheme::parse("fp5.33").unwrap())).unwrap();
        let eng = Engine::builder()
            .max_batch(8)
            .kv_page_size(page_size)
            .kv_pool_pages(pool_pages)
            .tenant_quota_pages(quota)
            .seed(1)
            .build(model);
        let wall = Timer::start();
        let heavy_handles: Vec<RequestHandle> = (0..heavy_n as u64)
            .map(|id| {
                let mut req = GenRequest::greedy(id, heavy_prompt(id), heavy_new)
                    .with_priority(Priority::Bulk);
                if tenanted {
                    req = req.with_tenant(1);
                }
                eng.submit(req).expect("submit heavy")
            })
            .collect();
        let light_handles: Vec<RequestHandle> = (0..light_n as u64)
            .map(|id| {
                let mut req =
                    GenRequest::greedy(heavy_n as u64 + id, light_prompt(id), light_new)
                        .with_priority(Priority::Bulk);
                if tenanted {
                    req = req.with_tenant(2);
                }
                eng.submit(req).expect("submit light")
            })
            .collect();
        let heavy: Vec<GenResponse> =
            heavy_handles.into_iter().filter_map(|h| h.wait()).collect();
        let light: Vec<GenResponse> =
            light_handles.into_iter().filter_map(|h| h.wait()).collect();
        let wall_s = wall.elapsed_secs();
        eng.drain();
        let snap = eng.metrics_snapshot();
        let stats = eng.shutdown();
        assert_eq!(
            heavy.len() + light.len(),
            heavy_n + light_n,
            "tenant_mix/{cfg}: all requests complete"
        );
        if cfg == "yf" {
            // The comparison is vacuous unless the pool was actually
            // under enough pressure to preempt someone.
            assert!(
                stats.preemptions > 0,
                "tenant_mix: the pool must be under preemption pressure (got 0)"
            );
        }
        if tenanted {
            let lt = snap.hist(&labeled(names::TTFT, "tenant", 2));
            assert_eq!(
                lt.count, light_n as u64,
                "tenant_mix/{cfg}: labeled TTFT histogram must see every light request"
            );
        }
        for (t, rs) in [("heavy", &heavy), ("light", &light)] {
            let ttfts: Vec<f64> = rs.iter().map(|r| r.ttft_s).collect();
            let lats: Vec<f64> = rs.iter().map(|r| r.total_s).collect();
            entry
                .set(&format!("{cfg}_{t}_ttft_p50_s"), Json::Num(pctl(&ttfts, 0.50)))
                .set(&format!("{cfg}_{t}_ttft_p99_s"), Json::Num(pctl(&ttfts, 0.99)))
                .set(&format!("{cfg}_{t}_latency_p50_s"), Json::Num(pctl(&lats, 0.50)))
                .set(&format!("{cfg}_{t}_latency_p99_s"), Json::Num(pctl(&lats, 0.99)));
        }
        entry
            .set(&format!("{cfg}_preemptions"), Json::Num(stats.preemptions as f64))
            .set(&format!("{cfg}_mean_occupancy"), Json::Num(stats.mean_batch_occupancy()))
            .set(&format!("{cfg}_wall_s"), Json::Num(wall_s))
            .set(
                &format!("{cfg}_tokens_per_s"),
                Json::Num(stats.tokens_generated as f64 / wall_s),
            );
        println!(
            "# tenant_mix/{cfg}: preemptions={} light lat p99 {:.3}ms ttft p99 {:.3}ms",
            stats.preemptions,
            pctl(&light.iter().map(|r| r.total_s).collect::<Vec<_>>(), 0.99) * 1e3,
            pctl(&light.iter().map(|r| r.ttft_s).collect::<Vec<_>>(), 0.99) * 1e3,
        );
    }
    entry
}
