//! Batched fused-GEMM throughput — scheme × batch ∈ {1, 4, 16, 64} on
//! MLP-shaped matrices (the projections that dominate decode). Prints the
//! per-shape speedup table and writes a JSON trajectory file
//! (`BENCH_GEMM.json` by default, `--json PATH` to override) so runs are
//! diffable across commits.
//!
//! Flags: `--d N` model width (default 768; MLP shapes are [4d, d] and
//! [d, 4d]), `--threads N` (default 1 = serial kernels; capped at the
//! shared pool size — set `AMS_THREADS` to grow the pool), `--json PATH`.
//! Honors `AMS_BENCH_QUICK` / `AMS_BENCH_MEASURE_SECS`.

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::gemm::GemmScratch;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::report::{f, Table};
use ams_quant::tensor::Tensor;
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig};
use ams_quant::util::cli::Args;
use ams_quant::util::json::Json;
use ams_quant::util::prng::Rng;

const BATCHES: [usize; 4] = [1, 4, 16, 64];
const SCHEMES: [&str; 6] = ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "int4"];

fn main() {
    let args = Args::from_env();
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let d = args.get_usize("d", if quick { 256 } else { 768 });
    let threads = args.get_usize("threads", 1);
    let json_path = args.get_or("json", "BENCH_GEMM.json").to_string();

    let shapes: [(&str, usize, usize); 2] = [("mlp-up", 4 * d, d), ("mlp-down", d, 4 * d)];
    let mut rng = Rng::new(0xD0D0);
    let mut results: Vec<Json> = Vec::new();

    println!("# fused tiled GEMM bench (d={d}, threads={threads}, tokens/s per scheme×batch)\n");
    for (shape_name, rows, cols) in shapes {
        let w = llm_weight(rows, cols, &WeightProfile::default(), &mut rng);
        let mut header = vec!["Scheme".to_string()];
        header.extend(BATCHES.iter().map(|b| format!("tok/s b={b}")));
        header.extend(BATCHES.iter().map(|b| format!("× fp16 b={b}")));
        let mut table = Table::new(
            &format!("GEMM throughput — {shape_name} [{rows}x{cols}]"),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );

        let mut fp16_rate = [0f64; BATCHES.len()];
        for scheme_name in SCHEMES {
            let scheme = Scheme::parse(scheme_name).unwrap();
            let lin = exp::make_linear(&w, scheme);
            let mut scratch = GemmScratch::new();
            let mut cells = vec![scheme.label()];
            let mut rates = [0f64; BATCHES.len()];
            for (bi, &batch) in BATCHES.iter().enumerate() {
                let x = exp::random_acts(batch, cols, &mut rng);
                let mut y = Tensor::zeros(&[batch, rows]);
                let mut fcall = || {
                    if threads > 1 {
                        lin.gemm_parallel_into(&x, &mut y, threads, &mut scratch);
                    } else {
                        lin.gemm_into(&x, &mut y, &mut scratch);
                    }
                    black_box(y.data().len());
                };
                let r = bench_with_units(
                    &format!("{shape_name}/{scheme_name}/b{batch}"),
                    &cfg,
                    batch as f64,
                    &mut fcall,
                );
                rates[bi] = r.rate();
                let mut entry = Json::obj();
                entry
                    .set("name", Json::Str(format!("{shape_name}/{scheme_name}/b{batch}")))
                    .set("shape", Json::Str(shape_name.into()))
                    .set("rows", Json::Num(rows as f64))
                    .set("cols", Json::Num(cols as f64))
                    .set("scheme", Json::Str(scheme_name.into()))
                    .set("batch", Json::Num(batch as f64))
                    .set("threads", Json::Num(threads as f64))
                    .set("iters", Json::Num(r.iters as f64))
                    .set("median_secs", Json::Num(r.median_secs))
                    .set("mean_secs", Json::Num(r.mean_secs))
                    .set("p10_secs", Json::Num(r.p10_secs))
                    .set("p90_secs", Json::Num(r.p90_secs))
                    .set("tokens_per_s", Json::Num(r.rate()));
                results.push(entry);
            }
            if scheme == Scheme::Fp16 {
                fp16_rate = rates;
            }
            for &rate in &rates {
                cells.push(f(rate, 1));
            }
            for (bi, &rate) in rates.iter().enumerate() {
                cells.push(if fp16_rate[bi] > 0.0 {
                    f(rate / fp16_rate[bi], 2)
                } else {
                    "-".into()
                });
            }
            table.row(cells);
        }
        println!("{}", table.to_console());
        println!("{}", table.to_markdown());
    }

    let mut root = Json::obj();
    root.set("bench", Json::Str("gemm".into()))
        .set("schema_version", Json::Num(1.0))
        .set("d", Json::Num(d as f64))
        .set("threads", Json::Num(threads as f64))
        .set("measure_secs", Json::Num(cfg.measure_secs))
        .set("results", Json::Arr(results));
    match std::fs::write(&json_path, root.to_string_pretty()) {
        Ok(()) => eprintln!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}
