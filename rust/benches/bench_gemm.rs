//! Batched fused-GEMM throughput — scheme × batch ∈ {1, 4, 16, 64} on
//! MLP-shaped matrices (the projections that dominate decode). Prints the
//! per-shape speedup table and writes a JSON trajectory file
//! (`BENCH_GEMM.json` by default, `--json PATH` to override) so runs are
//! diffable across commits.
//!
//! Grouped schemes (`PerGroup(g)`) are benched on **both** grouped decode
//! paths — the stream-direct default and the forced buffered fallback —
//! so the trajectory records the stream-direct win per commit (the CI
//! quick-bench job distills it into `GROUPED_DELTA.md`). JSON entries
//! carry `granularity` / `group_size` / `decode_path` fields; per-channel
//! entries record `decode_path: "fused"`.
//!
//! Flags: `--d N` model width (default 768; MLP shapes are [4d, d] and
//! [d, 4d]), `--threads N` (default 1 = serial kernels; capped at the
//! shared pool size — set `AMS_THREADS` to grow the pool), `--json PATH`.
//! Honors `AMS_BENCH_QUICK` / `AMS_BENCH_MEASURE_SECS`.

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::gemm::{GemmScratch, GroupDecodePath, QuantLinear};
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::quant::{Granularity, QuantConfig};
use ams_quant::report::{f, Table};
use ams_quant::tensor::Tensor;
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig};
use ams_quant::util::cli::Args;
use ams_quant::util::json::Json;
use ams_quant::util::prng::Rng;

const BATCHES: [usize; 4] = [1, 4, 16, 64];
const SCHEMES: [&str; 6] = ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "int4"];
/// Grouped-scheme entries: (scheme, g) — all stream-direct-eligible.
const GROUPED: [(&str, usize); 4] = [("fp6", 64), ("fp5", 32), ("fp4.25", 32), ("fp4.25", 64)];

/// Bench one linear at every batch width, appending one JSON entry per
/// batch; returns the tokens/s rates. `group_size == 0` means
/// per-channel (`decode_path: "fused"`).
#[allow(clippy::too_many_arguments)]
fn bench_linear(
    lin: &QuantLinear,
    bench_name: &str,
    shape_name: &str,
    scheme_name: &str,
    group_size: usize,
    decode_path: &str,
    threads: usize,
    cfg: &BenchConfig,
    rng: &mut Rng,
    results: &mut Vec<Json>,
) -> [f64; BATCHES.len()] {
    let (rows, cols) = (lin.rows(), lin.cols());
    let mut scratch = GemmScratch::new();
    let mut rates = [0f64; BATCHES.len()];
    for (bi, &batch) in BATCHES.iter().enumerate() {
        let x = exp::random_acts(batch, cols, rng);
        let mut y = Tensor::zeros(&[batch, rows]);
        let mut fcall = || {
            if threads > 1 {
                lin.gemm_parallel_into(&x, &mut y, threads, &mut scratch);
            } else {
                lin.gemm_into(&x, &mut y, &mut scratch);
            }
            black_box(y.data().len());
        };
        let r = bench_with_units(&format!("{bench_name}/b{batch}"), cfg, batch as f64, &mut fcall);
        rates[bi] = r.rate();
        let mut entry = Json::obj();
        entry
            .set("name", Json::Str(format!("{bench_name}/b{batch}")))
            .set("shape", Json::Str(shape_name.into()))
            .set("rows", Json::Num(rows as f64))
            .set("cols", Json::Num(cols as f64))
            .set("scheme", Json::Str(scheme_name.into()))
            .set(
                "granularity",
                Json::Str(if group_size == 0 {
                    "per-channel".into()
                } else {
                    format!("g{group_size}")
                }),
            )
            .set("group_size", Json::Num(group_size as f64))
            .set("decode_path", Json::Str(decode_path.into()))
            .set("batch", Json::Num(batch as f64))
            .set("threads", Json::Num(threads as f64))
            .set("iters", Json::Num(r.iters as f64))
            .set("median_secs", Json::Num(r.median_secs))
            .set("mean_secs", Json::Num(r.mean_secs))
            .set("p10_secs", Json::Num(r.p10_secs))
            .set("p90_secs", Json::Num(r.p90_secs))
            .set("tokens_per_s", Json::Num(r.rate()));
        results.push(entry);
    }
    rates
}

fn main() {
    let args = Args::from_env();
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let d = args.get_usize("d", if quick { 256 } else { 768 });
    let threads = args.get_usize("threads", 1);
    let json_path = args.get_or("json", "BENCH_GEMM.json").to_string();

    let shapes: [(&str, usize, usize); 2] = [("mlp-up", 4 * d, d), ("mlp-down", d, 4 * d)];
    let mut rng = Rng::new(0xD0D0);
    let mut results: Vec<Json> = Vec::new();
    // (shape, scheme, g, batch) -> stream-direct / buffered tok/s ratio.
    let mut delta_rows: Vec<(String, f64)> = Vec::new();

    println!("# fused tiled GEMM bench (d={d}, threads={threads}, tokens/s per scheme×batch)\n");
    for (shape_name, rows, cols) in shapes {
        let w = llm_weight(rows, cols, &WeightProfile::default(), &mut rng);
        let mut header = vec!["Scheme".to_string()];
        header.extend(BATCHES.iter().map(|b| format!("tok/s b={b}")));
        header.extend(BATCHES.iter().map(|b| format!("× fp16 b={b}")));
        let mut table = Table::new(
            &format!("GEMM throughput — {shape_name} [{rows}x{cols}]"),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let push_row = |table: &mut Table, label: String, rates: &[f64], fp16: &[f64]| {
            let mut cells = vec![label];
            for &rate in rates {
                cells.push(f(rate, 1));
            }
            for (bi, &rate) in rates.iter().enumerate() {
                cells.push(if fp16[bi] > 0.0 { f(rate / fp16[bi], 2) } else { "-".into() });
            }
            table.row(cells);
        };

        let mut fp16_rate = [0f64; BATCHES.len()];
        for scheme_name in SCHEMES {
            let scheme = Scheme::parse(scheme_name).unwrap();
            let lin = exp::make_linear(&w, scheme);
            let rates = bench_linear(
                &lin,
                &format!("{shape_name}/{scheme_name}"),
                shape_name,
                scheme_name,
                0,
                "fused",
                threads,
                &cfg,
                &mut rng,
                &mut results,
            );
            if scheme == Scheme::Fp16 {
                fp16_rate = rates;
            }
            push_row(&mut table, scheme.label(), &rates, &fp16_rate);
        }

        // Grouped schemes: stream-direct default vs forced buffered.
        for (scheme_name, g) in GROUPED {
            let qcfg = QuantConfig::paper(Scheme::parse(scheme_name).unwrap())
                .with_granularity(Granularity::PerGroup(g));
            let lin = exp::make_linear_with(&w, &qcfg);
            assert_eq!(
                lin.group_decode_path(),
                Some(GroupDecodePath::StreamDirect),
                "{scheme_name} g={g} must be stream-direct-eligible"
            );
            let mut buffered = lin.clone();
            buffered.force_buffered_group_decode();
            let stream_rates = bench_linear(
                &lin,
                &format!("{shape_name}/{scheme_name}-g{g}/stream"),
                shape_name,
                scheme_name,
                g,
                "stream",
                threads,
                &cfg,
                &mut rng,
                &mut results,
            );
            let buf_rates = bench_linear(
                &buffered,
                &format!("{shape_name}/{scheme_name}-g{g}/buffered"),
                shape_name,
                scheme_name,
                g,
                "buffered",
                threads,
                &cfg,
                &mut rng,
                &mut results,
            );
            push_row(&mut table, format!("{scheme_name}-g{g} (stream)"), &stream_rates, &fp16_rate);
            push_row(&mut table, format!("{scheme_name}-g{g} (buffered)"), &buf_rates, &fp16_rate);
            for (bi, &batch) in BATCHES.iter().enumerate() {
                if buf_rates[bi] > 0.0 {
                    delta_rows.push((
                        format!("{shape_name}/{scheme_name} g{g} b{batch}"),
                        stream_rates[bi] / buf_rates[bi],
                    ));
                }
            }
        }
        println!("{}", table.to_console());
        println!("{}", table.to_markdown());
    }

    println!("# stream-direct vs buffered grouped decode (tokens/s ratio; >1 = stream wins)");
    for (name, ratio) in &delta_rows {
        println!("#   {name}: {ratio:.2}x");
    }

    let mut root = Json::obj();
    root.set("bench", Json::Str("gemm".into()))
        .set("schema_version", Json::Num(2.0))
        .set("d", Json::Num(d as f64))
        .set("threads", Json::Num(threads as f64))
        .set("measure_secs", Json::Num(cfg.measure_secs))
        .set("results", Json::Arr(results));
    match std::fs::write(&json_path, root.to_string_pretty()) {
        Ok(()) => eprintln!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}
