//! E6/E7 measured — Table 3 / Figure 6 on this CPU: wall-clock speedups of
//! the packed fused dequant-GEMM kernels vs the fp16-storage baseline, at
//! the paper's layer shapes scaled by --shrink (default 8; use
//! AMS_BENCH_QUICK=1 for CI).
//!
//! The paper's claim shape: speedup ordered by bits/weight at small batch
//! (memory-bound), shrinking as batch grows (compute takes over).
//! Grouped-scale rows (`PerGroup(g)`, served through the stream-direct
//! segment kernels at aligned g) ride the same table, so the scale-
//! granularity cost shows up next to the per-channel formats.

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::quant::{Granularity, QuantConfig};
use ams_quant::util::bench::BenchConfig;
use ams_quant::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let shrink = args.get_usize("shrink", if quick { 20 } else { 8 });
    let threads = args.get_usize("threads", 1);
    let cfg = BenchConfig::from_env();
    let batches: Vec<usize> = if quick {
        vec![1, 8, 32]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    let mut entries: Vec<(String, QuantConfig)> = ["fp8", "int8", "fp6", "fp5", "fp5.33", "fp4.25"]
        .iter()
        .map(|s| {
            let scheme = Scheme::parse(s).unwrap();
            (scheme.label(), QuantConfig::paper(scheme))
        })
        .collect();
    // Grouped-scale variants: stream-direct decode at word-aligned g.
    for (name, g) in [("fp6", 64usize), ("fp4.25", 32)] {
        let scheme = Scheme::parse(name).unwrap();
        entries.push((
            format!("{} g{g}", scheme.label()),
            QuantConfig::paper(scheme).with_granularity(Granularity::PerGroup(g)),
        ));
    }
    let shapes = exp::scaled_table3_shapes(shrink);
    println!(
        "# measured Table 3 / Fig 6 (CPU, shrink={shrink}, threads={threads}, speedup vs fp16-storage GEMM)\n"
    );
    for t in exp::table3_measured_configs(&shapes, &entries, &batches, &cfg, threads) {
        println!("{}", t.to_console());
        println!("{}", t.to_markdown());
    }
}
