//! E6/E7 measured — Table 3 / Figure 6 on this CPU: wall-clock speedups of
//! the packed fused dequant-GEMM kernels vs the fp16-storage baseline, at
//! the paper's layer shapes scaled by --shrink (default 8; use
//! AMS_BENCH_QUICK=1 for CI).
//!
//! The paper's claim shape: speedup ordered by bits/weight at small batch
//! (memory-bound), shrinking as batch grows (compute takes over).

use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::util::bench::BenchConfig;
use ams_quant::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let quick = std::env::var("AMS_BENCH_QUICK").is_ok();
    let shrink = args.get_usize("shrink", if quick { 20 } else { 8 });
    let threads = args.get_usize("threads", 1);
    let cfg = BenchConfig::from_env();
    let batches: Vec<usize> = if quick {
        vec![1, 8, 32]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    let schemes: Vec<Scheme> = ["fp8", "int8", "fp6", "fp5", "fp5.33", "fp4.25"]
        .iter()
        .map(|s| Scheme::parse(s).unwrap())
        .collect();
    let shapes = exp::scaled_table3_shapes(shrink);
    println!(
        "# measured Table 3 / Fig 6 (CPU, shrink={shrink}, threads={threads}, speedup vs fp16-storage GEMM)\n"
    );
    for t in exp::table3_measured(&shapes, &schemes, &batches, &cfg, threads) {
        println!("{}", t.to_console());
        println!("{}", t.to_markdown());
    }
}
