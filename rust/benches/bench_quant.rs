//! A1/A2 ablations + quantizer throughput.
//!
//! A1: adaptive search vs fixed-0 / fixed-1 / majority — MSE and cost.
//! A2: sharing along input vs output channels under channel-wise outliers.
//! Plus SetLsb (paper-literal) vs Reround (nearest-with-LSB) policies.

use ams_quant::formats::registry::Scheme;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::quant::metrics::sqnr_db;
use ams_quant::quant::sharing::quantize;
use ams_quant::quant::{QuantConfig, SearchPolicy, ShareDim, SharePolicy};
use ams_quant::report::{f, Table};
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig, BenchSuite};
use ams_quant::util::prng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(3);
    let rows = 128;
    let cols = 2048;
    let w = llm_weight(rows, cols, &WeightProfile::default(), &mut rng);

    // --- A1: search policy ablation ---------------------------------------
    let mut t = Table::new(
        "A1 — adaptive search ablation (fp4.25 on outlier-y weights)",
        &["policy", "MSE", "SQNR dB", "quantize ms"],
    );
    let scheme = Scheme::parse("fp4.25").unwrap();
    for (label, policy) in [
        ("adaptive (paper)", SearchPolicy::AdaptiveMse),
        ("always-0", SearchPolicy::AlwaysZero),
        ("always-1", SearchPolicy::AlwaysOne),
        ("majority", SearchPolicy::Majority),
    ] {
        let mut qc = QuantConfig::paper(scheme);
        qc.search_policy = policy;
        let q = quantize(&w, &qc).unwrap();
        let deq = q.dequantize();
        let mut fcall = || {
            black_box(quantize(&w, &qc).unwrap().codes.len());
        };
        let r = bench_with_units(label, &cfg, (rows * cols) as f64, &mut fcall);
        t.row(vec![
            label.into(),
            format!("{:.4e}", w.mse(&deq)),
            f(sqnr_db(&w, &deq), 2),
            f(r.median_secs * 1e3, 2),
        ]);
    }
    println!("{}", t.to_console());
    println!("{}", t.to_markdown());

    // --- SetLsb vs Reround -------------------------------------------------
    let mut t = Table::new(
        "A1b — share policy (G operator): SetLsb (paper) vs Reround",
        &["scheme", "SetLsb MSE", "Reround MSE", "improvement %"],
    );
    for name in ["fp5.33", "fp4.5", "fp4.25"] {
        let scheme = Scheme::parse(name).unwrap();
        let mut qc = QuantConfig::paper(scheme);
        qc.share_policy = SharePolicy::SetLsb;
        let m_set = w.mse(&quantize(&w, &qc).unwrap().dequantize());
        qc.share_policy = SharePolicy::Reround;
        let m_rr = w.mse(&quantize(&w, &qc).unwrap().dequantize());
        t.row(vec![
            scheme.label(),
            format!("{m_set:.4e}"),
            format!("{m_rr:.4e}"),
            f(100.0 * (m_set - m_rr) / m_set, 2),
        ]);
    }
    println!("{}", t.to_console());
    println!("{}", t.to_markdown());

    // --- A2: sharing dimension under channel outliers ----------------------
    let profile = WeightProfile {
        outlier_frac: 0.04,
        outlier_gain: 12.0,
        ..WeightProfile::default()
    };
    let w2 = llm_weight(rows, cols, &profile, &mut rng);
    let mut t = Table::new(
        "A2 — sharing dimension under channel-wise outliers",
        &["scheme", "input-dim MSE (paper)", "output-dim MSE", "input better %"],
    );
    for name in ["fp5.33", "fp4.25"] {
        let scheme = Scheme::parse(name).unwrap();
        let mut qc = QuantConfig::paper(scheme);
        qc.share_dim = ShareDim::Input;
        let m_in = w2.mse(&quantize(&w2, &qc).unwrap().dequantize());
        qc.share_dim = ShareDim::Output;
        let m_out = w2.mse(&quantize(&w2, &qc).unwrap().dequantize());
        t.row(vec![
            scheme.label(),
            format!("{m_in:.4e}"),
            format!("{m_out:.4e}"),
            f(100.0 * (m_out - m_in) / m_out, 2),
        ]);
    }
    println!("{}", t.to_console());
    println!("{}", t.to_markdown());

    // --- throughput ---------------------------------------------------------
    let mut suite = BenchSuite::new();
    for name in ["fp6", "fp5.33", "fp4.25", "int8"] {
        let scheme = Scheme::parse(name).unwrap();
        let qc = QuantConfig::paper(scheme);
        let mut fcall = || {
            if matches!(scheme, Scheme::Int { .. }) {
                black_box(ams_quant::baselines::quantize_int(&w, scheme).words.len());
            } else {
                black_box(quantize(&w, &qc).unwrap().codes.len());
            }
        };
        suite.push(bench_with_units(
            &format!("quantize/{name}"),
            &cfg,
            (rows * cols) as f64,
            &mut fcall,
        ));
    }
    println!("\n{}", suite.to_markdown());
}
