//! E8/A4 — restoration microbenches (Fig 4 path): bit-op vs LUT code→fp16
//! conversion, and fused unpack+dequant throughput per packed layout
//! (weights/s), the building block of every GEMV row kernel.

use ams_quant::experiments::make_linear;
use ams_quant::formats::registry::Scheme;
use ams_quant::formats::FpFormat;
use ams_quant::gemm::kernels::row_values;
use ams_quant::model::synthetic::{llm_weight, WeightProfile};
use ams_quant::restore::{code_to_fp16_bits, Fp16Lut};
use ams_quant::util::bench::{bench_with_units, black_box, BenchConfig, BenchSuite};
use ams_quant::util::prng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let mut rng = Rng::new(1);

    // --- A4: bitops vs LUT on a code stream -------------------------------
    let n = 1 << 16;
    for fmt in [FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2] {
        let codes: Vec<u16> = (0..n)
            .map(|_| (rng.next_u32() as u16) & fmt.code_mask())
            .collect();
        let mut out = vec![0u16; n];
        let mut fcall = || {
            for (o, &c) in out.iter_mut().zip(&codes) {
                *o = code_to_fp16_bits(fmt, c);
            }
            black_box(out[0]);
        };
        suite.push(bench_with_units(
            &format!("restore/bitops/{}", fmt.name()),
            &cfg,
            n as f64,
            &mut fcall,
        ));
        let lut = Fp16Lut::new(fmt);
        let mut fcall = || {
            for (o, &c) in out.iter_mut().zip(&codes) {
                *o = lut.get(c);
            }
            black_box(out[0]);
        };
        suite.push(bench_with_units(
            &format!("restore/lut/{}", fmt.name()),
            &cfg,
            n as f64,
            &mut fcall,
        ));
    }

    // --- fused unpack+dequant per layout (row_values) ---------------------
    let cols = 8192;
    let w = llm_weight(4, cols, &WeightProfile::default(), &mut rng);
    for name in ["fp16", "fp8", "int8", "int4", "fp6", "fp5", "fp5.33", "fp4.5", "fp4.25"] {
        let scheme = Scheme::parse(name).unwrap();
        let lin = make_linear(&w, scheme);
        let mut vals = vec![0f32; cols];
        let mut fcall = || {
            row_values(
                scheme,
                lin.packed.row_words(0),
                cols,
                lin.table(),
                &mut vals,
            );
            black_box(vals[0]);
        };
        suite.push(bench_with_units(
            &format!("unpack+dequant/{name}"),
            &cfg,
            cols as f64,
            &mut fcall,
        ));
    }

    println!("\n{}", suite.to_markdown());
}
