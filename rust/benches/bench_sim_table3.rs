//! E6 — Table 3 + Figure 6 from the roofline simulator of the paper's
//! device (22 TFLOPS, 290 GB/s). Instant; prints paper-comparable grids.

use ams_quant::experiments as exp;

fn main() {
    println!("# Simulated Table 3 (paper device: 22 TFLOPS, 290 GB/s)\n");
    for t in exp::table3_sim() {
        println!("{}", t.to_console());
        println!("{}", t.to_markdown());
    }
    println!("# Ideal memory-bound roofline\n");
    println!("{}", exp::roofline_table(25600, 5120).to_console());
}
