//! Comparator implementations the paper benchmarks against:
//!
//! - **W16A16 (cuBLAS analog)**: native fp16 weight storage, dense GEMV —
//!   [`pack_fp16`].
//! - **W8A16 (TensorRT-LLM analog)**: per-channel symmetric INT8 —
//!   [`quantize_int`] with `Scheme::Int { bits: 8 }`.
//! - **INT4 RTN**: the classic low-bit integer baseline (Fig. 2 context).
//! - **TC-FPx (fp6-llm analog)**: the FP6 (4+2) and FP5 (4+1) layouts live
//!   in [`crate::pack`] and run through the same kernels; this module only
//!   adds the integer paths.
//!
//! All baselines share the GEMV kernels and scale conventions of the main
//! path so speed and accuracy comparisons isolate the *format*, exactly as
//! in the paper's §4.2. Both helpers are thin conveniences over the
//! unified [`Quantizer`](crate::quant::Quantizer) pipeline — the single
//! entry point that produces every packed layout.

use crate::formats::registry::Scheme;
use crate::pack::PackedTensor;
use crate::quant::pipeline::quantize_packed;
use crate::quant::QuantConfig;
use crate::tensor::Tensor;

/// Store a weight tensor as raw fp16 words (the W16A16 baseline).
/// Delegates to the [`Quantizer`](crate::quant::Quantizer) pipeline's
/// FP16 passthrough path.
pub fn pack_fp16(w: &Tensor) -> PackedTensor {
    quantize_packed(w, &QuantConfig::paper(Scheme::Fp16))
        .expect("fp16 passthrough of a 2-D tensor is always packable")
}

/// Symmetric per-channel integer RTN quantization (INT4 / INT8), stored
/// offset-binary so the shared dequant-table machinery applies:
/// `code = round(w/s) + 2^(b-1)`, `value = code - 2^(b-1)`,
/// `s = amax / (2^(b-1) - 1)`. Delegates to the
/// [`Quantizer`](crate::quant::Quantizer) pipeline's integer path (which
/// also serves per-tensor/per-group scales; this baseline keeps the
/// paper's per-channel convention).
pub fn quantize_int(w: &Tensor, scheme: Scheme) -> PackedTensor {
    assert!(
        matches!(scheme, Scheme::Int { bits: 4 | 8 }),
        "quantize_int serves int4/int8, got {scheme:?}"
    );
    quantize_packed(w, &QuantConfig::paper(scheme))
        .expect("per-channel int4/int8 of a 2-D tensor is always packable")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::QuantLinear;
    use crate::quant::metrics::sqnr_db;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    #[test]
    fn fp16_baseline_is_lossless_for_half_values() {
        // Values already on the fp16 grid survive exactly.
        let w = Tensor::from_vec(&[2, 2], vec![0.5, -1.25, 3.0, 0.0]);
        let p = pack_fp16(&w);
        let lin = QuantLinear::new(p);
        let x = vec![1.0, 1.0];
        let mut y = vec![0f32; 2];
        lin.gemv(&x, &mut y);
        assert_eq!(y, vec![-0.75, 3.0]);
    }

    #[test]
    fn int8_bounds_and_roundtrip() {
        let mut rng = Rng::new(1);
        let w = init::gaussian(&[8, 64], 0.0, 0.02, &mut rng);
        let p = quantize_int(&w, Scheme::Int { bits: 8 });
        let lin = QuantLinear::new(p);
        let deq = {
            let mut t = Tensor::zeros(&[8, 64]);
            let table = crate::gemm::dequant_table(Scheme::Int { bits: 8 });
            for r in 0..8 {
                let mut codes = vec![0u16; 64];
                crate::pack::unpack_row(Scheme::Int { bits: 8 }, lin.packed.row_words(r), 64, &mut codes);
                for c in 0..64 {
                    t.set2(r, c, table[codes[c] as usize] * lin.packed.scales[r]);
                }
            }
            t
        };
        // INT8 per-channel should be quite accurate: > 30 dB SQNR.
        assert!(sqnr_db(&w, &deq) > 30.0, "sqnr={}", sqnr_db(&w, &deq));
    }

    #[test]
    fn int4_worse_than_int8() {
        let mut rng = Rng::new(2);
        let w = init::gaussian(&[8, 128], 0.0, 0.02, &mut rng);
        let reconstruct = |scheme: Scheme| {
            let p = quantize_int(&w, scheme);
            let table = crate::gemm::dequant_table(scheme);
            let mut t = Tensor::zeros(&[8, 128]);
            for r in 0..8 {
                let mut codes = vec![0u16; 128];
                crate::pack::unpack_row(scheme, p.row_words(r), 128, &mut codes);
                for c in 0..128 {
                    t.set2(r, c, table[codes[c] as usize] * p.scales[r]);
                }
            }
            t
        };
        let s8 = sqnr_db(&w, &reconstruct(Scheme::Int { bits: 8 }));
        let s4 = sqnr_db(&w, &reconstruct(Scheme::Int { bits: 4 }));
        assert!(s8 > s4 + 10.0, "int8={s8} int4={s4}");
    }

    #[test]
    fn fp_beats_int_at_same_bits_on_gaussian() {
        // The paper's motivating claim (§2.2): bell-shaped weights favour
        // floating-point grids. Compare FP4-e2m1 vs INT4 SQNR.
        use crate::quant::sharing::quantize as quantize_fp;
        use crate::quant::QuantConfig;
        let mut rng = Rng::new(3);
        let w = init::gaussian(&[16, 256], 0.0, 0.02, &mut rng);
        let fp4 = quantize_fp(&w, &QuantConfig::paper(Scheme::parse("fp4-e2m1").unwrap()))
            .unwrap()
            .dequantize();
        let int4 = {
            let p = quantize_int(&w, Scheme::Int { bits: 4 });
            let table = crate::gemm::dequant_table(Scheme::Int { bits: 4 });
            let mut t = Tensor::zeros(&[16, 256]);
            for r in 0..16 {
                let mut codes = vec![0u16; 256];
                crate::pack::unpack_row(Scheme::Int { bits: 4 }, p.row_words(r), 256, &mut codes);
                for c in 0..256 {
                    t.set2(r, c, table[codes[c] as usize] * p.scales[r]);
                }
            }
            t
        };
        let s_fp = sqnr_db(&w, &fp4);
        let s_int = sqnr_db(&w, &int4);
        assert!(s_fp > s_int, "fp4 {s_fp} dB vs int4 {s_int} dB");
    }

    #[test]
    fn zero_row_scale_safe() {
        let w = Tensor::zeros(&[2, 16]);
        let p = quantize_int(&w, Scheme::Int { bits: 4 });
        assert!(p.scales.iter().all(|&s| s == 1.0));
        let lin = QuantLinear::new(p);
        let mut y = vec![1f32; 2];
        lin.gemv(&vec![1.0; 16], &mut y);
        assert_eq!(y, vec![0.0, 0.0]);
    }
}
