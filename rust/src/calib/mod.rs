//! Calibration subsystem: activation-aware sensitivity analysis and
//! automatic [`QuantPlan`] search — the data-driven layer between the
//! quantization pipeline and the model.
//!
//! The paper's Adaptive Searching optimizes *which mantissa bit each
//! group shares*; this module optimizes *which format each layer gets*.
//! The flow (`calibrate` CLI, `quantize --auto-plan`, or this API):
//!
//! 1. [`Calibrator::collect`] streams a calibration corpus through the
//!    dense reference model via
//!    [`Transformer::forward_prefill_tapped`](crate::model::transformer::Transformer::forward_prefill_tapped),
//!    accumulating per-channel activation moments at every projection
//!    input ([`stats`]) — running statistics only, no activation storage.
//! 2. [`sensitivity`] scores every candidate [`QuantConfig`] per layer by
//!    *output-space* noise against those activations
//!    (`Σ ΔW² · E[x²]`), replacing weight-space MSE as the ranking
//!    signal — a layer only earns bits if its quantization error is
//!    amplified by what it actually sees at inference time.
//! 3. [`search`] runs a greedy marginal-ratio descent under a global
//!    bits-per-weight budget (e.g. `--budget-bits 5.0`), with a uniform
//!    fallback so the result never loses to any feasible uniform plan on
//!    the calibration objective.
//! 4. [`report`] serializes the whole decision as a [`CalibReport`]
//!    (JSON), converts it to a ready-to-use [`QuantPlan`], and emits the
//!    provenance blob AMSQ checkpoints embed.

pub mod report;
pub mod search;
pub mod sensitivity;
pub mod stats;

pub use report::{config_label, CalibReport, CandidateSummary, LayerChoice};
pub use search::{search_plan, SearchOutcome};
pub use sensitivity::{score_layer, score_model, CandidateScore, LayerSensitivity};
pub use stats::{ActivationStats, LayerTaps, ModelTaps};

use crate::formats::registry::Scheme;
use crate::model::transformer::Transformer;
use crate::quant::{Granularity, QuantConfig, QuantError, QuantPlan};
use crate::util::prng::Rng;

/// Why a calibration run was rejected.
#[derive(Clone, Debug, PartialEq)]
pub enum CalibError {
    /// A candidate failed the quantization pipeline.
    Quant(QuantError),
    /// The calibration corpus is empty (or shorter than one position).
    EmptyCorpus,
    /// The corpus contains a token id the model's embedding cannot look
    /// up — caught up front so a mismatched corpus/checkpoint pair
    /// errors cleanly instead of panicking mid-prefill.
    TokenOutOfVocab { token: u32, vocab: usize },
    /// Calibration needs the dense reference model; this projection is
    /// already packed.
    NotDense { layer: String },
}

impl std::fmt::Display for CalibError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CalibError::Quant(e) => write!(f, "candidate quantization failed: {e}"),
            CalibError::EmptyCorpus => write!(f, "calibration corpus is empty"),
            CalibError::TokenOutOfVocab { token, vocab } => write!(
                f,
                "corpus token {token} exceeds the model vocab ({vocab}); \
                 the corpus does not match this checkpoint"
            ),
            CalibError::NotDense { layer } => write!(
                f,
                "layer '{layer}' is already quantized; calibration needs the dense reference model"
            ),
        }
    }
}

impl std::error::Error for CalibError {}

impl From<QuantError> for CalibError {
    fn from(e: QuantError) -> CalibError {
        CalibError::Quant(e)
    }
}

/// The default candidate ladder: the paper's format vocabulary from FP4
/// up to FP8 at per-channel scales with paper policies, plus
/// `PerGroup(32/64)` variants of the low-bit formats (`32/g` extra
/// bits/w for the group-scale stream). The fp4.25/fp5 variants decode
/// stream-direct at these segment-aligned g (see
/// [`crate::gemm::GroupDecodePath`]); plain fp4 serves on the buffered
/// grouped fallback (codes-family layout — a stream-direct table path
/// is a ROADMAP follow-on) but stays in the ladder as the best
/// accuracy-per-bit point on outlier-heavy layers. Grouped variants let
/// the search trade scale granularity against format bits (the
/// FineQuant / M-ANT axis).
pub fn default_candidates() -> Vec<QuantConfig> {
    let mut v: Vec<QuantConfig> = ["fp4", "fp4.25", "fp4.33", "fp4.5", "fp5", "fp5.33", "fp6", "fp8"]
        .iter()
        .map(|s| QuantConfig::paper(Scheme::parse(s).expect("known scheme")))
        .collect();
    for name in ["fp4", "fp4.25", "fp5"] {
        for g in [32usize, 64] {
            v.push(
                QuantConfig::paper(Scheme::parse(name).expect("known scheme"))
                    .with_granularity(Granularity::PerGroup(g)),
            );
        }
    }
    v
}

/// Calibration parameters.
#[derive(Clone, Debug)]
pub struct CalibConfig {
    /// Global parameter-weighted bits/weight ceiling (scale streams
    /// included) the searched plan must respect.
    pub budget_bits: f64,
    /// Cap on corpus tokens streamed through the taps.
    pub calib_tokens: usize,
    /// Prefill window length (clamped to the model context).
    pub window: usize,
    /// Recorded in the report; drives [`Calibrator::synthetic_corpus`].
    pub seed: u64,
    /// Also score and budget the lm_head (default: leave it dense).
    pub include_lm_head: bool,
    /// Candidate configs per layer (default: [`default_candidates`]).
    pub candidates: Vec<QuantConfig>,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            budget_bits: 5.0,
            calib_tokens: 4096,
            window: 128,
            seed: 0,
            include_lm_head: false,
            candidates: default_candidates(),
        }
    }
}

/// The calibration driver: corpus → taps → sensitivity → searched plan.
///
/// Fully deterministic: the same model, corpus and config produce a
/// bit-identical [`CalibReport`] and [`QuantPlan`] (pinned by tests).
#[derive(Clone, Debug)]
pub struct Calibrator {
    cfg: CalibConfig,
}

impl Calibrator {
    pub fn new(cfg: CalibConfig) -> Calibrator {
        Calibrator { cfg }
    }

    pub fn config(&self) -> &CalibConfig {
        &self.cfg
    }

    /// A deterministic synthetic calibration stream for models without a
    /// held-out corpus (seeded from the config).
    pub fn synthetic_corpus(&self, vocab_size: usize) -> Vec<u32> {
        let mut rng = Rng::new(self.cfg.seed);
        (0..self.cfg.calib_tokens)
            .map(|_| rng.below(vocab_size as u64) as u32)
            .collect()
    }

    /// Stream the corpus through the reference model, accumulating
    /// activation moments at every tap site. Each window runs as one
    /// tapped chunked prefill against a fresh KV cache.
    pub fn collect(&self, model: &Transformer, corpus: &[u32]) -> Result<ModelTaps, CalibError> {
        let corpus = &corpus[..corpus.len().min(self.cfg.calib_tokens)];
        if corpus.is_empty() {
            return Err(CalibError::EmptyCorpus);
        }
        if let Some(&t) = corpus.iter().find(|&&t| t as usize >= model.cfg.vocab_size) {
            return Err(CalibError::TokenOutOfVocab {
                token: t,
                vocab: model.cfg.vocab_size,
            });
        }
        let window = self.cfg.window.clamp(1, model.cfg.max_seq);
        let mut taps = ModelTaps::new(&model.cfg);
        let mut scratch = model.new_scratch();
        for chunk in corpus.chunks(window) {
            let mut cache = model.new_cache();
            model.forward_prefill_tapped(chunk, &mut cache, &mut scratch, &mut taps);
        }
        Ok(taps)
    }

    /// The whole flow: collect taps, score every candidate per layer,
    /// search the plan under the budget, and return the ready-to-use
    /// [`QuantPlan`] plus the serializable [`CalibReport`].
    pub fn calibrate(
        &self,
        model: &Transformer,
        corpus: &[u32],
    ) -> Result<(QuantPlan, CalibReport), CalibError> {
        let taps = self.collect(model, corpus)?;
        let layers = score_model(model, &taps, &self.cfg.candidates, self.cfg.include_lm_head)?;
        let outcome = search_plan(&layers, self.cfg.budget_bits);
        let report = CalibReport::from_search(
            &layers,
            &outcome,
            self.cfg.budget_bits,
            taps.tokens_seen,
            taps.windows,
            self.cfg.seed,
        );
        let plan = report.to_plan()?;
        Ok((plan, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;

    fn tiny() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 17);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    #[test]
    fn ladder_carries_grouped_candidates() {
        let cands = default_candidates();
        assert_eq!(cands.len(), 8 + 6);
        let grouped: Vec<_> = cands
            .iter()
            .filter(|c| matches!(c.granularity, Granularity::PerGroup(_)))
            .collect();
        assert_eq!(grouped.len(), 6);
        for g in [32usize, 64] {
            for name in ["fp4", "fp4.25", "fp5"] {
                assert!(
                    grouped.iter().any(|c| c.scheme == Scheme::parse(name).unwrap()
                        && c.granularity == Granularity::PerGroup(g)),
                    "{name} PerGroup({g}) missing from the ladder"
                );
            }
        }
        // Every candidate must be packable (the builder's invariant).
        for c in &cands {
            assert!(QuantPlan::uniform(*c).is_ok(), "{c:?}");
        }
    }

    #[test]
    fn collect_streams_the_corpus() {
        let m = tiny();
        let cal = Calibrator::new(CalibConfig {
            calib_tokens: 100,
            window: 16,
            ..CalibConfig::default()
        });
        let corpus: Vec<u32> = (0..200).map(|i| (i * 7 % 64) as u32).collect();
        let taps = cal.collect(&m, &corpus).unwrap();
        assert_eq!(taps.tokens_seen, 100, "capped at calib_tokens");
        assert_eq!(taps.windows, 100 / 16 + 1);
        let s = taps.stats_for("layers.0.wq").unwrap();
        assert_eq!(s.rows() as usize, 100, "every position taps the attn input");
        assert!(s.mean_sq(0) > 0.0);
        assert!(s.abs_max() > 0.0);
        // The head tap records one row per window (last position only).
        assert_eq!(taps.head_in.rows(), taps.windows);
    }

    #[test]
    fn empty_corpus_rejected() {
        let m = tiny();
        let cal = Calibrator::new(CalibConfig::default());
        assert!(matches!(
            cal.collect(&m, &[]),
            Err(CalibError::EmptyCorpus)
        ));
    }

    #[test]
    fn out_of_vocab_corpus_rejected() {
        // test_tiny's vocab is 64; a byte-level corpus (ids up to 255)
        // must error cleanly, not panic in the embedding lookup.
        let m = tiny();
        let cal = Calibrator::new(CalibConfig::default());
        match cal.collect(&m, &[1, 2, 200]) {
            Err(CalibError::TokenOutOfVocab { token: 200, vocab: 64 }) => {}
            other => panic!("expected TokenOutOfVocab, got {other:?}"),
        }
    }

    #[test]
    fn quantized_model_rejected() {
        use crate::quant::QuantConfig;
        let m = tiny()
            .quantized(&QuantConfig::paper(Scheme::parse("fp6").unwrap()))
            .unwrap();
        let cal = Calibrator::new(CalibConfig {
            calib_tokens: 32,
            ..CalibConfig::default()
        });
        let corpus: Vec<u32> = (0..32).map(|i| i % 60).collect();
        match cal.calibrate(&m, &corpus) {
            Err(CalibError::NotDense { layer }) => assert_eq!(layer, "layers.0.wq"),
            other => panic!("expected NotDense, got {other:?}"),
        }
    }

    #[test]
    fn calibrate_respects_budget_and_orders_layers() {
        let m = tiny();
        let cal = Calibrator::new(CalibConfig {
            budget_bits: 5.0,
            calib_tokens: 128,
            window: 32,
            ..CalibConfig::default()
        });
        let corpus: Vec<u32> = (0..128).map(|i| (i * 13 % 64) as u32).collect();
        let (plan, report) = cal.calibrate(&m, &corpus).unwrap();
        assert!(report.budget_met);
        assert!(report.achieved_bits <= 5.0 + 1e-9);
        assert_eq!(report.layers.len(), m.cfg.n_layers * 7);
        // The plan quantizes and serves.
        let q = m.quantized_with(&crate::quant::Quantizer::new(plan)).unwrap();
        let mut c = q.new_cache();
        let l = q.forward(1, 0, &mut c);
        assert!(l.iter().all(|v| v.is_finite()));
        // Tap-aware budget accounting matches the packed reality.
        let dense_params = m.projection_bytes() / 2;
        let packed_bits = ((q.projection_bytes() + q.projection_scale_bytes()) * 8) as f64
            / dense_params as f64;
        assert!(
            (packed_bits - report.achieved_bits).abs() < 1e-6,
            "report bits {} vs packed {}",
            report.achieved_bits,
            packed_bits
        );
    }
}
