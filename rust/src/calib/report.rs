//! The serializable calibration artifact: per-layer scores, chosen
//! configs and achieved budget, plus conversion into a ready-to-use
//! [`QuantPlan`] and the compact provenance blob embedded into AMSQ
//! checkpoint headers.

use super::search::SearchOutcome;
use super::sensitivity::LayerSensitivity;
use crate::quant::{Granularity, LayerRole, QuantConfig, QuantError, QuantPlan};
use crate::report::{f, Table};
use crate::util::json::Json;

/// Human-readable label of a candidate config: the scheme id plus a
/// `-gN` suffix for group-wise scales, so the `PerGroup` ladder variants
/// (PR 5) stay distinguishable from their per-channel twins in report
/// tables and candidate matrices.
pub fn config_label(cfg: &QuantConfig) -> String {
    match cfg.granularity {
        Granularity::PerGroup(g) => format!("{}-g{g}", cfg.scheme.id()),
        _ => cfg.scheme.id(),
    }
}

/// One candidate's summary inside the per-layer report record.
#[derive(Clone, Debug)]
pub struct CandidateSummary {
    /// Config label ([`config_label`]): scheme id, `-gN`-suffixed for
    /// group-wise candidates.
    pub scheme: String,
    pub bits_per_weight: f64,
    pub act_sqnr_db: f64,
}

/// The chosen config and scores of one layer.
#[derive(Clone, Debug)]
pub struct LayerChoice {
    pub layer: String,
    pub role: LayerRole,
    pub config: QuantConfig,
    pub params: usize,
    pub bits_per_weight: f64,
    pub act_sqnr_db: f64,
    pub weight_mse: f64,
    /// Weight-space SQNR of the chosen config's hi-stream truncated
    /// reconstruction (the speculative draft weights); NaN when the
    /// layout has no hi/lo split.
    pub hi_sqnr_db: f64,
    /// Every candidate considered, ascending bit cost.
    pub candidates: Vec<CandidateSummary>,
}

/// The full calibration record — everything the offline search saw and
/// decided, serializable to JSON (`calibrate --report`).
#[derive(Clone, Debug)]
pub struct CalibReport {
    pub budget_bits: f64,
    pub achieved_bits: f64,
    pub budget_met: bool,
    /// Prefill positions streamed through the taps.
    pub calib_tokens: u64,
    /// Prefill windows streamed.
    pub windows: u64,
    pub seed: u64,
    /// Model-wide activation-weighted SQNR of the chosen assignment.
    pub act_sqnr_db: f64,
    pub layers: Vec<LayerChoice>,
}

impl CalibReport {
    /// Assemble the report from the scored layers and the search outcome.
    pub(super) fn from_search(
        layers: &[LayerSensitivity],
        outcome: &SearchOutcome,
        budget_bits: f64,
        calib_tokens: u64,
        windows: u64,
        seed: u64,
    ) -> CalibReport {
        let chosen_layers = layers
            .iter()
            .zip(&outcome.chosen)
            .map(|(l, &ci)| {
                let c = &l.candidates[ci];
                LayerChoice {
                    layer: l.layer.clone(),
                    role: l.role,
                    config: c.config,
                    params: l.params,
                    bits_per_weight: c.bits_per_weight,
                    act_sqnr_db: c.act_sqnr_db,
                    weight_mse: c.weight_mse,
                    hi_sqnr_db: c.hi_sqnr_db,
                    candidates: l
                        .candidates
                        .iter()
                        .map(|c| CandidateSummary {
                            scheme: config_label(&c.config),
                            bits_per_weight: c.bits_per_weight,
                            act_sqnr_db: c.act_sqnr_db,
                        })
                        .collect(),
                }
            })
            .collect();
        let act_sqnr_db =
            super::sensitivity::sqnr_db(outcome.total_signal, outcome.total_noise);
        CalibReport {
            budget_bits,
            achieved_bits: outcome.achieved_bits,
            budget_met: outcome.budget_met,
            calib_tokens,
            windows,
            seed,
            act_sqnr_db,
            layers: chosen_layers,
        }
    }

    /// Build the ready-to-serve plan: every scored layer gets an
    /// exact-name override (the lm_head is targeted only when it was
    /// calibrated, so an un-scored head stays dense as usual).
    pub fn to_plan(&self) -> Result<QuantPlan, QuantError> {
        let default = self
            .layers
            .first()
            .map(|l| l.config)
            .expect("calibration scored at least one layer");
        let mut b = QuantPlan::builder(default);
        for l in &self.layers {
            b = b.layer(&l.layer, l.config);
        }
        b.build()
    }

    /// Compact provenance blob for AMSQ checkpoint headers: enough to
    /// reproduce the calibration (`budget`, corpus size, seed) and to
    /// audit what it achieved, without the per-layer detail.
    pub fn provenance(&self) -> Json {
        let mut o = Json::obj();
        o.set("budget_bits", Json::Num(self.budget_bits))
            .set("achieved_bits", Json::Num(self.achieved_bits))
            .set("budget_met", Json::Bool(self.budget_met))
            .set("calib_tokens", Json::Num(self.calib_tokens as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("act_sqnr_db", Json::Num(self.act_sqnr_db));
        o
    }

    /// Full JSON serialization (`CALIB_REPORT.json`). Field order is the
    /// serializer's (sorted keys), so two runs over the same inputs emit
    /// byte-identical text — the determinism contract the tests pin.
    pub fn to_json(&self) -> Json {
        let mut o = self.provenance();
        o.set("windows", Json::Num(self.windows as f64));
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut e = Json::obj();
                e.set("layer", Json::Str(l.layer.clone()))
                    .set("role", Json::Str(l.role.name().to_string()))
                    .set("config", l.config.to_json())
                    .set("params", Json::Num(l.params as f64))
                    .set("bits_per_weight", Json::Num(l.bits_per_weight))
                    .set("act_sqnr_db", Json::Num(l.act_sqnr_db))
                    .set("weight_mse", Json::Num(l.weight_mse))
                    .set(
                        "candidates",
                        Json::Arr(
                            l.candidates
                                .iter()
                                .map(|c| {
                                    let mut e = Json::obj();
                                    e.set("scheme", Json::Str(c.scheme.clone()))
                                        .set("bits_per_weight", Json::Num(c.bits_per_weight))
                                        .set("act_sqnr_db", Json::Num(c.act_sqnr_db));
                                    e
                                })
                                .collect(),
                        ),
                    );
                e
            })
            .collect();
        o.set("layers", Json::Arr(layers));
        o
    }

    /// Per-layer table for the CLI / examples.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Calibrated plan — budget {:.2} bits/w, achieved {:.3}",
                self.budget_bits, self.achieved_bits
            ),
            &["layer", "role", "scheme", "bits/w", "act SQNR dB", "weight MSE", "hi SQNR dB"],
        );
        for l in &self.layers {
            t.row(vec![
                l.layer.clone(),
                l.role.name().to_string(),
                config_label(&l.config),
                f(l.bits_per_weight, 3),
                f(l.act_sqnr_db, 2),
                format!("{:.3e}", l.weight_mse),
                // "-" = no hi/lo split; the hi-only draft decode cannot
                // serve the chosen layout.
                if l.hi_sqnr_db.is_nan() { "-".to_string() } else { f(l.hi_sqnr_db, 2) },
            ]);
        }
        t
    }
}
