//! Budgeted plan search: greedy/Pareto descent over per-layer candidate
//! configs under a global bits-per-weight budget.
//!
//! Every layer starts at its cheapest candidate; the search then
//! repeatedly applies the single upgrade (layer → more expensive
//! candidate) with the best activation-noise reduction per weighted bit
//! spent, while the parameter-weighted average stays within the budget.
//! This is the classic marginal-ratio greedy on a layer-separable
//! objective — near-optimal when each layer's bits→noise frontier is
//! convex, which the FPx ladder empirically is. As a safety net the
//! result is compared against every *uniform* assignment that fits the
//! budget, and the best by total activation noise wins, so the searched
//! plan never loses to a feasible uniform plan on its own objective.

use super::sensitivity::LayerSensitivity;

/// Outcome of a budgeted search: one chosen candidate index per layer
/// (into `LayerSensitivity::candidates`) plus the achieved aggregates.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    pub chosen: Vec<usize>,
    /// Parameter-weighted average bits/weight (scale streams included).
    pub achieved_bits: f64,
    /// False when even the cheapest assignment exceeds the budget.
    pub budget_met: bool,
    /// Total activation-weighted noise power of the chosen assignment.
    pub total_noise: f64,
    /// Total activation-weighted signal power (assignment-independent).
    pub total_signal: f64,
}

fn weighted_bits(layers: &[LayerSensitivity], chosen: &[usize]) -> f64 {
    let mut bits = 0f64;
    let mut params = 0f64;
    for (l, &c) in layers.iter().zip(chosen) {
        bits += l.candidates[c].bits_per_weight * l.params as f64;
        params += l.params as f64;
    }
    bits / params.max(1.0)
}

fn total_noise(layers: &[LayerSensitivity], chosen: &[usize]) -> f64 {
    layers
        .iter()
        .zip(chosen)
        .map(|(l, &c)| l.candidates[c].act_noise)
        .sum()
}

/// Run the greedy descent. `layers` must be non-empty and every layer
/// must carry at least one candidate (all layers share the same
/// candidate list in the [`Calibrator`](super::Calibrator) flow).
pub fn search_plan(layers: &[LayerSensitivity], budget_bits: f64) -> SearchOutcome {
    assert!(!layers.is_empty(), "nothing to search");
    let total_params: f64 = layers.iter().map(|l| l.params as f64).sum();
    // Start: cheapest candidate everywhere (index 0 — candidates are
    // sorted by ascending bits, ties by ascending noise).
    let mut chosen: Vec<usize> = vec![0; layers.len()];
    let mut bits = weighted_bits(layers, &chosen);
    loop {
        // Best feasible upgrade by noise-reduction per weighted bit.
        let mut best: Option<(f64, usize, usize)> = None; // (ratio, layer, cand)
        for (li, l) in layers.iter().enumerate() {
            let cur = &l.candidates[chosen[li]];
            for (ci, cand) in l.candidates.iter().enumerate().skip(chosen[li] + 1) {
                if cand.act_noise >= cur.act_noise {
                    continue; // not an improvement
                }
                let dbits =
                    (cand.bits_per_weight - cur.bits_per_weight) * l.params as f64 / total_params;
                if bits + dbits > budget_bits + 1e-12 {
                    continue; // does not fit
                }
                let gain = cur.act_noise - cand.act_noise;
                // A zero-cost improvement is infinitely good; otherwise
                // marginal gain per global bit spent.
                let ratio = if dbits <= 0.0 { f64::INFINITY } else { gain / dbits };
                let better = match best {
                    None => true,
                    // Strict > keeps the tie-break deterministic: first
                    // layer in model order, then cheapest candidate.
                    Some((r, _, _)) => ratio > r,
                };
                if better {
                    best = Some((ratio, li, ci));
                }
            }
        }
        match best {
            Some((_, li, ci)) => {
                chosen[li] = ci;
                bits = weighted_bits(layers, &chosen);
            }
            None => break,
        }
    }
    // Uniform safety net: every single-*config* assignment that fits
    // the budget and beats the greedy result on total noise wins. Match
    // by config identity, not sorted index — per-layer bit ties (e.g.
    // two schemes word-padding to the same bits/w at some width) can
    // order the candidate lists differently per layer.
    let mut best_noise = total_noise(layers, &chosen);
    for cand in &layers[0].candidates {
        let uniform: Option<Vec<usize>> = layers
            .iter()
            .map(|l| l.candidates.iter().position(|c| c.config == cand.config))
            .collect();
        let Some(uniform) = uniform else { continue };
        if weighted_bits(layers, &uniform) <= budget_bits + 1e-12 {
            let noise = total_noise(layers, &uniform);
            if noise < best_noise {
                best_noise = noise;
                chosen = uniform;
            }
        }
    }
    let achieved_bits = weighted_bits(layers, &chosen);
    SearchOutcome {
        budget_met: achieved_bits <= budget_bits + 1e-12,
        total_noise: total_noise(layers, &chosen),
        total_signal: layers.iter().map(|l| l.act_signal).sum(),
        achieved_bits,
        chosen,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::sensitivity::CandidateScore;
    use crate::formats::registry::Scheme;
    use crate::quant::{LayerRole, QuantConfig};

    fn cand(bits: f64, noise: f64) -> CandidateScore {
        // Distinct config per bits tier, so the config-identity uniform
        // fallback sees real uniform assignments in these fixtures.
        let scheme = match bits as u32 {
            4 => "fp4",
            5 => "fp5",
            6 => "fp6",
            _ => "fp8",
        };
        CandidateScore {
            config: QuantConfig::paper(Scheme::parse(scheme).unwrap()),
            bits_per_weight: bits,
            act_noise: noise,
            act_sqnr_db: 0.0,
            weight_mse: noise,
            hi_sqnr_db: f64::NAN,
        }
    }

    fn layer(name: &str, params: usize, cands: Vec<CandidateScore>) -> LayerSensitivity {
        LayerSensitivity {
            layer: name.to_string(),
            role: LayerRole::Other,
            rows: params,
            cols: 1,
            params,
            act_signal: 1.0,
            candidates: cands,
        }
    }

    #[test]
    fn spends_budget_on_the_sensitive_layer() {
        // Layer a: upgrading buys a 100x noise drop; layer b: almost
        // nothing. Budget allows exactly one upgrade.
        let layers = vec![
            layer("a", 100, vec![cand(4.0, 100.0), cand(6.0, 1.0)]),
            layer("b", 100, vec![cand(4.0, 1.0), cand(6.0, 0.9)]),
        ];
        let out = search_plan(&layers, 5.0);
        assert_eq!(out.chosen, vec![1, 0], "budget goes to the sensitive layer");
        assert!(out.budget_met);
        assert!((out.achieved_bits - 5.0).abs() < 1e-9);
        assert!((out.total_noise - 2.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_budget_stays_at_cheapest() {
        let layers = vec![layer("a", 10, vec![cand(4.0, 1.0), cand(8.0, 0.1)])];
        let out = search_plan(&layers, 3.0);
        assert_eq!(out.chosen, vec![0]);
        assert!(!out.budget_met, "cheapest already exceeds the budget");
        assert!((out.achieved_bits - 4.0).abs() < 1e-9);
    }

    #[test]
    fn generous_budget_takes_everything() {
        let layers = vec![
            layer("a", 10, vec![cand(4.0, 1.0), cand(8.0, 0.1)]),
            layer("b", 30, vec![cand(4.0, 2.0), cand(8.0, 0.2)]),
        ];
        let out = search_plan(&layers, 8.0);
        assert_eq!(out.chosen, vec![1, 1]);
        assert!((out.achieved_bits - 8.0).abs() < 1e-9);
    }

    #[test]
    fn never_loses_to_a_feasible_uniform_plan() {
        // A frontier crafted to trap pure greedy: a huge cheap first
        // upgrade on one layer starves the budget for the uniformly
        // better middle candidate. The uniform fallback must rescue it.
        let layers = vec![
            layer(
                "a",
                100,
                vec![cand(4.0, 10.0), cand(5.0, 9.9), cand(6.0, 0.1)],
            ),
            layer(
                "b",
                100,
                vec![cand(4.0, 10.0), cand(5.0, 0.5), cand(6.0, 0.4)],
            ),
        ];
        let out = search_plan(&layers, 5.0);
        let uniform_mid_noise = 9.9 + 0.5;
        assert!(
            out.total_noise <= uniform_mid_noise + 1e-12,
            "fallback guarantees parity with feasible uniform plans: {} vs {}",
            out.total_noise,
            uniform_mid_noise
        );
        assert!(out.achieved_bits <= 5.0 + 1e-12);
    }

    /// PR 5: `PerGroup` variants ride the ladder like any other config —
    /// at a bit-cost tie with a per-channel format the lower-noise
    /// grouped candidate wins, and the config-identity uniform fallback
    /// matches grouped configs correctly.
    #[test]
    fn grouped_candidates_compete_at_equal_bits() {
        use crate::quant::Granularity;
        let pg = |name: &str, g: usize| {
            QuantConfig::paper(Scheme::parse(name).unwrap())
                .with_granularity(Granularity::PerGroup(g))
        };
        let cand_cfg = |config: QuantConfig, bits: f64, noise: f64| CandidateScore {
            config,
            bits_per_weight: bits,
            act_noise: noise,
            act_sqnr_db: 0.0,
            weight_mse: noise,
            hi_sqnr_db: f64::NAN,
        };
        // fp4 + PerGroup(32) prices like fp5 per-channel (4 + 32/32 ≈ 5);
        // on the outlier layer it is the low-noise candidate at that
        // price point, on the smooth layer the per-channel format wins.
        let outlier = layer(
            "outlier",
            100,
            vec![
                cand(4.0, 50.0),
                cand_cfg(pg("fp4", 32), 5.0, 0.5),
                cand(5.0, 30.0),
                cand(6.0, 0.3),
            ],
        );
        let smooth = layer(
            "smooth",
            100,
            vec![
                cand(4.0, 1.0),
                cand_cfg(pg("fp4", 32), 5.0, 0.9),
                cand(5.0, 0.95),
                cand(6.0, 0.2),
            ],
        );
        // Budget 4.75: exactly one half-bit upgrade fits — the marginal-
        // ratio greedy must spend it on the grouped candidate of the
        // outlier layer (ratio ~99 vs ≤2 for every alternative).
        let out = search_plan(&[outlier.clone(), smooth.clone()], 4.75);
        assert!(out.budget_met);
        let chosen_outlier = &outlier.candidates[out.chosen[0]].config;
        assert_eq!(
            chosen_outlier.granularity,
            Granularity::PerGroup(32),
            "grouped candidate must win the outlier layer: {out:?}"
        );
        assert_eq!(
            smooth.candidates[out.chosen[1]].config.granularity,
            Granularity::PerChannel,
            "the smooth layer stays per-channel: {out:?}"
        );
        assert!((out.achieved_bits - 4.5).abs() < 1e-9);
        assert!((out.total_noise - (0.5 + 1.0)).abs() < 1e-12, "{}", out.total_noise);
    }

    #[test]
    fn deterministic_tie_break() {
        let mk = || {
            vec![
                layer("a", 10, vec![cand(4.0, 1.0), cand(5.0, 0.5)]),
                layer("b", 10, vec![cand(4.0, 1.0), cand(5.0, 0.5)]),
            ]
        };
        let a = search_plan(&mk(), 4.5);
        let b = search_plan(&mk(), 4.5);
        assert_eq!(a.chosen, b.chosen);
        assert_eq!(a.chosen, vec![1, 0], "first layer wins the tie");
    }
}
