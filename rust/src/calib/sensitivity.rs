//! Activation-weighted sensitivity scoring: how much output-space noise
//! each candidate [`QuantConfig`] injects at each layer, *as seen by the
//! calibration activations* — the ranking signal that replaces
//! weight-space MSE.
//!
//! Under the diagonal approximation the output-space noise power of a
//! quantized projection is `E‖(W − Ŵ)x‖² ≈ Σ_rc ΔW²_rc · E[x_c²]`, and
//! the matching signal power is `Σ_rc W²_rc · E[x_c²]`. Both need only
//! the per-channel second moments the taps collect, so scoring a
//! candidate costs one quantize + one dequantize pass — the same
//! machinery the per-layer [`QuantReport`](crate::quant::QuantReport)s
//! run, reweighted by what the layer actually sees at inference time.

use super::stats::{ActivationStats, ModelTaps};
use super::CalibError;
use crate::model::transformer::{Linear, Transformer};
use crate::quant::pipeline::quantize_packed;
use crate::quant::{LayerRole, QuantConfig};
use crate::tensor::Tensor;

/// Exact reconstruction caps the reported SQNR at a large *finite*
/// figure (300 dB ≈ 30 orders of magnitude — unreachable for any lossy
/// candidate), so a zero-noise candidate (fp16 passthrough, all-zero
/// weights) stays serializable: `f64::INFINITY` would render as invalid
/// JSON in `CALIB_REPORT.json` and in AMSQ provenance headers.
pub(super) fn sqnr_db(signal: f64, noise: f64) -> f64 {
    if noise <= 0.0 {
        return 300.0;
    }
    (10.0 * (signal / noise).log10()).min(300.0)
}

/// One candidate config's score at one layer.
#[derive(Clone, Debug)]
pub struct CandidateScore {
    pub config: QuantConfig,
    /// Achieved storage bits/weight of the candidate *including* its
    /// scale streams (payload padding and per-group overhead count, so
    /// the budget the search enforces is the honest on-disk figure).
    pub bits_per_weight: f64,
    /// Total activation-weighted output noise power `Σ_rc ΔW² E[x_c²]`.
    pub act_noise: f64,
    /// `10 log10(signal / noise)` with the same activation weighting.
    pub act_sqnr_db: f64,
    /// Plain weight-space reconstruction MSE (the old ranking signal,
    /// kept for comparison in the report).
    pub weight_mse: f64,
    /// Weight-space SQNR (dB) of the hi-stream truncated reconstruction
    /// — the effective draft weights of the speculative decode path.
    /// NaN when the candidate's layout has no hi/lo split.
    pub hi_sqnr_db: f64,
}

/// A layer's full sensitivity profile: its activation-weighted signal
/// power and every candidate's score, sorted by ascending bit cost.
#[derive(Clone, Debug)]
pub struct LayerSensitivity {
    pub layer: String,
    pub role: LayerRole,
    pub rows: usize,
    pub cols: usize,
    /// `rows * cols` — the weight the search gives this layer when
    /// averaging bits/weight across the model.
    pub params: usize,
    /// Activation-weighted signal power `Σ_rc W² E[x_c²]`.
    pub act_signal: f64,
    pub candidates: Vec<CandidateScore>,
}

/// Score one dense projection against every candidate config.
pub fn score_layer(
    name: &str,
    role: LayerRole,
    w: &Tensor,
    stats: &ActivationStats,
    candidates: &[QuantConfig],
) -> Result<LayerSensitivity, CalibError> {
    assert_eq!(w.cols(), stats.channels(), "tap/projection dimension mismatch");
    let (rows, cols) = (w.rows(), w.cols());
    // Per-channel activation power, floored so a channel the corpus
    // never excites cannot erase a weight column from the score.
    let mut chan_pow = vec![0f64; cols];
    let mut max_pow = 0f64;
    for (c, p) in chan_pow.iter_mut().enumerate() {
        *p = stats.mean_sq(c);
        max_pow = max_pow.max(*p);
    }
    let floor = (max_pow * 1e-6).max(f64::MIN_POSITIVE);
    for p in chan_pow.iter_mut() {
        *p = p.max(floor);
    }
    let mut act_signal = 0f64;
    for r in 0..rows {
        for (c, &x) in w.row(r).iter().enumerate() {
            act_signal += (x as f64) * (x as f64) * chan_pow[c];
        }
    }
    let mut scored = Vec::with_capacity(candidates.len());
    for cfg in candidates {
        let packed = quantize_packed(w, cfg)?;
        let deq = packed.dequantize();
        let mut act_noise = 0f64;
        let mut weight_sse = 0f64;
        for r in 0..rows {
            for (c, (&a, &b)) in w.row(r).iter().zip(deq.row(r)).enumerate() {
                let d = (a as f64) - (b as f64);
                act_noise += d * d * chan_pow[c];
                weight_sse += d * d;
            }
        }
        let bits_per_weight =
            ((packed.payload_bytes() + packed.scale_bytes()) * 8) as f64 / (rows * cols) as f64;
        let act_sqnr_db = sqnr_db(act_signal, act_noise);
        let hi_sqnr_db = crate::gemm::QuantLinear::new(packed)
            .hi_dequantize()
            .map_or(f64::NAN, |hi| crate::quant::metrics::sqnr_db(w, &hi));
        scored.push(CandidateScore {
            config: *cfg,
            bits_per_weight,
            act_noise,
            act_sqnr_db,
            weight_mse: weight_sse / (rows * cols) as f64,
            hi_sqnr_db,
        });
    }
    // Ascending bit cost; ties broken by lower noise so the search's
    // "cheapest start" is deterministic and never dominated.
    scored.sort_by(|a, b| {
        a.bits_per_weight
            .total_cmp(&b.bits_per_weight)
            .then(a.act_noise.total_cmp(&b.act_noise))
    });
    Ok(LayerSensitivity {
        layer: name.to_string(),
        role,
        rows,
        cols,
        params: rows * cols,
        act_signal,
        candidates: scored,
    })
}

/// Score every projection of a dense model, in checkpoint order
/// (`layers.0.wq` ... `layers.{L-1}.w_down`, then `lm_head` when
/// `include_lm_head`). The model must be the dense reference — a packed
/// source has already lost the weights the candidates are judged against.
pub fn score_model(
    model: &Transformer,
    taps: &ModelTaps,
    candidates: &[QuantConfig],
    include_lm_head: bool,
) -> Result<Vec<LayerSensitivity>, CalibError> {
    fn dense<'a>(name: &str, l: &'a Linear) -> Result<&'a Tensor, CalibError> {
        match l {
            Linear::Dense(t) => Ok(t),
            Linear::Quant(_) => Err(CalibError::NotDense {
                layer: name.to_string(),
            }),
        }
    }
    let mut out = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        for (field, role, lin) in [
            ("wq", LayerRole::Attention, &l.wq),
            ("wk", LayerRole::Attention, &l.wk),
            ("wv", LayerRole::Attention, &l.wv),
            ("wo", LayerRole::Attention, &l.wo),
            ("w_gate", LayerRole::Mlp, &l.w_gate),
            ("w_up", LayerRole::Mlp, &l.w_up),
            ("w_down", LayerRole::Mlp, &l.w_down),
        ] {
            let name = format!("layers.{i}.{field}");
            let w = dense(&name, lin)?;
            let stats = taps.stats_for(&name).expect("known projection name");
            out.push(score_layer(&name, role, w, stats, candidates)?);
        }
    }
    if include_lm_head {
        let w = dense("lm_head", &model.lm_head)?;
        let stats = taps.stats_for("lm_head").expect("known projection name");
        out.push(score_layer("lm_head", LayerRole::LmHead, w, stats, candidates)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::registry::Scheme;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    fn cfg(name: &str) -> QuantConfig {
        QuantConfig::paper(Scheme::parse(name).unwrap())
    }

    #[test]
    fn more_bits_less_noise() {
        let mut rng = Rng::new(5);
        let w = init::gaussian(&[8, 64], 0.0, 0.02, &mut rng);
        let mut stats = ActivationStats::new(64);
        for _ in 0..16 {
            let row: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            stats.record(&row);
        }
        let s = score_layer(
            "layers.0.wq",
            LayerRole::Attention,
            &w,
            &stats,
            &[cfg("fp4"), cfg("fp6"), cfg("fp8")],
        )
        .unwrap();
        assert_eq!(s.candidates.len(), 3);
        // Sorted ascending in bits; noise strictly improves with bits.
        assert!(s.candidates[0].bits_per_weight < s.candidates[2].bits_per_weight);
        assert!(s.candidates[0].act_noise > s.candidates[1].act_noise);
        assert!(s.candidates[1].act_noise > s.candidates[2].act_noise);
        assert!(s.candidates[2].act_sqnr_db > s.candidates[0].act_sqnr_db);
    }

    #[test]
    fn activation_weighting_changes_the_ranking_signal() {
        // Two layers with identical weights but different activation
        // power must get proportionally different noise scores.
        let mut rng = Rng::new(6);
        let w = init::gaussian(&[4, 32], 0.0, 0.02, &mut rng);
        let mut quiet = ActivationStats::new(32);
        let mut loud = ActivationStats::new(32);
        quiet.record(&[0.1; 32]);
        loud.record(&[10.0; 32]);
        let cands = [cfg("fp4.25")];
        let sq = score_layer("a", LayerRole::Other, &w, &quiet, &cands).unwrap();
        let sl = score_layer("b", LayerRole::Other, &w, &loud, &cands).unwrap();
        let ratio = sl.candidates[0].act_noise / sq.candidates[0].act_noise;
        assert!(
            (ratio - 10_000.0).abs() / 10_000.0 < 1e-6,
            "noise must scale with activation power: ratio {ratio}"
        );
        // SQNR (signal/noise) is invariant to a uniform activation gain.
        assert!(
            (sq.candidates[0].act_sqnr_db - sl.candidates[0].act_sqnr_db).abs() < 1e-9
        );
        // Weight-space MSE ignores activations entirely.
        assert!(
            (sq.candidates[0].weight_mse - sl.candidates[0].weight_mse).abs() < 1e-15
        );
    }
}
