//! Lightweight activation statistics — the taps the calibration pass
//! hangs off [`Transformer::forward_prefill_tapped`](crate::model::transformer::Transformer::forward_prefill_tapped).
//!
//! Nothing here stores activations. Each tap site keeps running moments
//! only: per-input-channel sums of squares, a row counter and the
//! absolute maximum. That is exactly what the activation-weighted
//! sensitivity score needs — under the diagonal approximation,
//! `E‖(W − Ŵ)x‖² ≈ Σ_rc ΔW²_rc · E[x_c²]`, so per-channel second
//! moments substitute for the full calibration activations at O(d)
//! memory per site instead of O(tokens · d).

use crate::model::ModelConfig;
use crate::tensor::Tensor;

/// Running per-channel activation moments at one tap site.
#[derive(Clone, Debug)]
pub struct ActivationStats {
    channels: usize,
    rows: u64,
    sumsq: Vec<f64>,
    abs_max: f32,
}

impl ActivationStats {
    pub fn new(channels: usize) -> ActivationStats {
        ActivationStats {
            channels,
            rows: 0,
            sumsq: vec![0.0; channels],
            abs_max: 0.0,
        }
    }

    /// Input dimension of the projection(s) this site feeds.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Activation rows (positions) recorded so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Largest |x| seen at this site (outlier magnitude telemetry).
    pub fn abs_max(&self) -> f32 {
        self.abs_max
    }

    /// Record one activation row (a single position's input vector).
    pub fn record(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.channels, "tap dimension mismatch");
        for (s, &x) in self.sumsq.iter_mut().zip(row) {
            *s += (x as f64) * (x as f64);
            let a = x.abs();
            if a > self.abs_max {
                self.abs_max = a;
            }
        }
        self.rows += 1;
    }

    /// Record every row of a `[n, channels]` activation block.
    pub fn record_rows(&mut self, t: &Tensor) {
        for r in 0..t.rows() {
            self.record(t.row(r));
        }
    }

    /// Mean square of channel `c` over everything recorded. Falls back to
    /// 1.0 before any data arrives, so an un-calibrated site degrades the
    /// sensitivity score to plain (unweighted) weight MSE instead of
    /// zeroing it out.
    pub fn mean_sq(&self, c: usize) -> f64 {
        if self.rows == 0 {
            1.0
        } else {
            self.sumsq[c] / self.rows as f64
        }
    }

    /// Fold another site's moments into this one (multi-corpus runs).
    pub fn merge(&mut self, other: &ActivationStats) {
        assert_eq!(self.channels, other.channels, "tap dimension mismatch");
        for (s, o) in self.sumsq.iter_mut().zip(&other.sumsq) {
            *s += o;
        }
        self.rows += other.rows;
        self.abs_max = self.abs_max.max(other.abs_max);
    }
}

/// The four per-layer tap sites of a transformer block, keyed by which
/// projections read them.
#[derive(Clone, Debug)]
pub struct LayerTaps {
    /// Post-attn-norm hidden state — input to wq/wk/wv.
    pub attn_in: ActivationStats,
    /// Attention output — input to wo.
    pub attn_out: ActivationStats,
    /// Post-mlp-norm hidden state — input to w_gate/w_up.
    pub mlp_in: ActivationStats,
    /// SwiGLU activation — input to w_down.
    pub mlp_act: ActivationStats,
}

/// All tap sites of one model: per-layer blocks plus the final-norm
/// output feeding the lm_head.
#[derive(Clone, Debug)]
pub struct ModelTaps {
    pub layers: Vec<LayerTaps>,
    pub head_in: ActivationStats,
    /// Prefill positions streamed through the taps.
    pub tokens_seen: u64,
    /// Prefill windows (independent sequences) streamed.
    pub windows: u64,
}

impl ModelTaps {
    pub fn new(cfg: &ModelConfig) -> ModelTaps {
        let layers = (0..cfg.n_layers)
            .map(|_| LayerTaps {
                attn_in: ActivationStats::new(cfg.d_model),
                attn_out: ActivationStats::new(cfg.d_model),
                mlp_in: ActivationStats::new(cfg.d_model),
                mlp_act: ActivationStats::new(cfg.d_ff),
            })
            .collect();
        ModelTaps {
            layers,
            head_in: ActivationStats::new(cfg.d_model),
            tokens_seen: 0,
            windows: 0,
        }
    }

    /// The stats of the tap site feeding a projection, by checkpoint
    /// layer name (`layers.{i}.wq`, ..., `lm_head`). `None` for names the
    /// tap layout does not know.
    pub fn stats_for(&self, layer: &str) -> Option<&ActivationStats> {
        if layer == "lm_head" {
            return Some(&self.head_in);
        }
        let (idx, field) = layer.strip_prefix("layers.")?.split_once('.')?;
        let l = self.layers.get(idx.parse::<usize>().ok()?)?;
        match field {
            "wq" | "wk" | "wv" => Some(&l.attn_in),
            "wo" => Some(&l.attn_out),
            "w_gate" | "w_up" => Some(&l.mlp_in),
            "w_down" => Some(&l.mlp_act),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_accumulate() {
        let mut s = ActivationStats::new(2);
        assert_eq!(s.mean_sq(0), 1.0, "empty site weights like plain MSE");
        s.record(&[1.0, -2.0]);
        s.record(&[3.0, 0.0]);
        assert_eq!(s.rows(), 2);
        assert!((s.mean_sq(0) - 5.0).abs() < 1e-12);
        assert!((s.mean_sq(1) - 2.0).abs() < 1e-12);
        assert_eq!(s.abs_max(), 3.0);
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = ActivationStats::new(1);
        let mut b = ActivationStats::new(1);
        a.record(&[2.0]);
        b.record(&[4.0]);
        let mut both = ActivationStats::new(1);
        both.record(&[2.0]);
        both.record(&[4.0]);
        a.merge(&b);
        assert_eq!(a.rows(), 2);
        assert!((a.mean_sq(0) - both.mean_sq(0)).abs() < 1e-12);
        assert_eq!(a.abs_max(), 4.0);
    }

    #[test]
    fn stats_for_maps_projection_names() {
        let cfg = ModelConfig::test_tiny();
        let taps = ModelTaps::new(&cfg);
        for name in ["layers.0.wq", "layers.1.wo", "layers.0.w_up", "lm_head"] {
            assert!(taps.stats_for(name).is_some(), "{name}");
        }
        assert_eq!(
            taps.stats_for("layers.0.w_down").unwrap().channels(),
            cfg.d_ff
        );
        assert_eq!(taps.stats_for("layers.0.wq").unwrap().channels(), cfg.d_model);
        assert!(taps.stats_for("layers.9.wq").is_none());
        assert!(taps.stats_for("layers.0.nope").is_none());
        assert!(taps.stats_for("embed").is_none());
    }
}
