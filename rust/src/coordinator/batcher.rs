//! Continuous dynamic batching scheduler.
//!
//! Pure state machine (no threads) so it is unit-testable: the engine
//! worker drives it with `admit_submission` / `step`. Invariants
//! (property-tested): every admitted request reaches exactly one terminal
//! [`Outcome`] (`Done`, `Cancelled`, `TimedOut` or `Failed`), no token is
//! generated after `max_new_tokens`, the running batch never exceeds
//! `max_batch`, and a cancelled or deadline-expired sequence never
//! occupies a batch slot on the step after its flag/deadline is observed.
//!
//! KV storage is **paged**: every sequence owns a [`PagedKvCache`]
//! drawing fixed-size pages from the scheduler's [`PagePool`] one page
//! at a time, instead of a worst-case contiguous reservation. Admission
//! is therefore bounded by *actual* page consumption: a sequence enters
//! whenever a batch slot and its next chunk's pages are free. On pool
//! exhaustion the scheduler frees memory in escalation order — evict
//! unreferenced prefix-trie pages, then **preempt the youngest bulk**
//! decode sequence of the most-over-share tenant (fair share; with a
//! single tenant this is plain youngest-first — its pages free
//! immediately; its decode state parks and later resumes by
//! re-prefilling prompt + generated tokens, with prefix-shared pages
//! skipping most of that compute) — so interactive traffic is never
//! stalled behind bulk. A prompt whose page-aligned prefix was already
//! committed by an earlier sequence **of the same tenant** adopts those
//! pages copy-on-write and skips their prefill entirely
//! ([`Scheduler::prefix_hits`]); prefix tries are tenant-scoped, so
//! identical prompts never share pages (or leak hit timing) across
//! tenants. An optional [`BatchPolicy::tenant_quota_pages`] caps every
//! tenant's live pages, and quota-bound pressure only ever parks the
//! offending tenant's own sequences.
//!
//! Admission runs a **chunked prefill**: prompt chunks go through
//! [`Transformer::forward_prefill_with`], so every projection sees one
//! `[chunk_len, ·]` GEMM through the tiled fused kernels instead of
//! per-token GEMVs. Chunks are capped at [`BatchPolicy::prefill_chunk`]
//! positions (default 128) and interleave with decode steps — one chunk
//! per prefilling sequence per step — so a very long prompt cannot
//! stall co-batched decodes for its whole prefill. Request timing
//! (TTFT, total) measures from [`Submission`] creation — queue wait
//! included.
//!
//! For fault injection the scheduler hits the [`failpoint::STEP`] site
//! at every step boundary, [`failpoint::PREFILL`] before every prompt
//! chunk, and [`failpoint::POOL`] once per step (a denied hit forces a
//! synthetic preemption round, exactly as a real exhausted pool would);
//! after a panic unwinds through `step`, the supervising engine
//! worker reclaims the in-flight submissions with
//! [`Scheduler::take_inflight`] and settles each with a terminal event.

use super::failpoint::{self, FailPoints};
use super::{Event, GenRequest, GenResponse, Priority};
use crate::kv::{AsKvStore, KvGauges, KvStore, PageGeometry, PagePool, PagedKvCache, TenantId};
use crate::model::transformer::{ForwardScratch, Transformer};
use crate::obs::{names, Gauge, Histogram, MetricsRegistry, SpanKind, TraceSink};
use crate::spec::{Controller, SeqSpec, SpecPolicy};
use crate::util::metrics::Counter;
use crate::util::prng::Rng;
use crate::util::timer::Timer;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum sequences decoded together.
    pub max_batch: usize,
    /// Optional token id that terminates a sequence early.
    pub eos: Option<u32>,
    /// Prefill chunk cap in positions (default 128): a prompt longer
    /// than this prefills one chunk per scheduler step, interleaved with
    /// the running batch's decode steps, so a very long prompt no longer
    /// stalls co-batched decodes for its whole prefill.
    pub prefill_chunk: usize,
    /// Positions per KV page (default 16). Smaller pages waste less
    /// memory on short tails and share finer prefix granularity; larger
    /// pages mean fewer block-table entries.
    pub kv_page_size: usize,
    /// Total pages in the KV pool. `0` (the default) sizes the pool for
    /// worst-case reservation — `max_batch` full-context sequences — so
    /// preemption never triggers; a smaller explicit pool admits on
    /// actual consumption and preempts under pressure.
    pub kv_pool_pages: usize,
    /// Per-tenant KV page quota (`0`, the default, means unlimited).
    /// With a quota set, each tenant's live pages — its sequences plus
    /// its cached prefix pages — are capped, so one tenant cannot starve
    /// the pool for the rest; admission, parking and preemption all
    /// account against it.
    pub tenant_quota_pages: usize,
    /// Self-speculative decoding knobs. When enabled, greedy sequences
    /// decode through draft/verify rounds (token-identical to plain
    /// greedy); non-greedy samplers keep the plain batched path.
    pub spec: SpecPolicy,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            eos: None,
            prefill_chunk: 128,
            kv_page_size: 16,
            kv_pool_pages: 0,
            tenant_quota_pages: 0,
            spec: SpecPolicy::default(),
        }
    }
}

/// RAII share of a replica's outstanding-request counter: incremented on
/// acquire, decremented on drop. Attached to a [`Submission`] at
/// dispatch so the count stays exact on *every* settle path — normal
/// completion, cancel, deadline expiry, and the panic path where the
/// worker never gets to report an [`Outcome`] (the unwound scheduler
/// drops or hands back its submissions, and each drop releases its
/// share).
pub(crate) struct OutstandingGuard(Arc<AtomicUsize>);

impl OutstandingGuard {
    pub fn acquire(counter: &Arc<AtomicUsize>) -> OutstandingGuard {
        counter.fetch_add(1, Ordering::SeqCst);
        OutstandingGuard(Arc::clone(counter))
    }
}

impl Drop for OutstandingGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A request wrapped with its lifecycle plumbing: the submission-time
/// stopwatch (TTFT, total time and both deadlines are measured from
/// here, so queue wait counts), the shared cancel flag, and an optional
/// per-request event channel. [`Engine::submit`](super::Engine::submit)
/// builds one per request; direct scheduler users get the same wrapping
/// via [`Scheduler::admit`].
pub struct Submission {
    req: GenRequest,
    submitted: Timer,
    cancel: Arc<AtomicBool>,
    events: Option<mpsc::Sender<Event>>,
    /// Engine-attached outstanding-counter share (None for bare
    /// scheduler users).
    guard: Option<OutstandingGuard>,
    /// Times this submission has been re-dispatched after a replica
    /// panic; capped by the engine so a poison-pill request cannot
    /// crash-loop the fleet.
    retries: u32,
}

impl Submission {
    /// Wrap a request; the TTFT stopwatch starts now.
    pub fn new(req: GenRequest) -> Submission {
        Submission {
            req,
            submitted: Timer::start(),
            cancel: Arc::new(AtomicBool::new(false)),
            events: None,
            guard: None,
            retries: 0,
        }
    }

    /// Wrap a request with a per-request event stream.
    pub fn with_events(req: GenRequest, events: mpsc::Sender<Event>) -> Submission {
        Submission {
            events: Some(events),
            ..Submission::new(req)
        }
    }

    pub fn id(&self) -> u64 {
        self.req.id
    }

    /// Shared flag that cancels this request at the next step boundary.
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }

    pub fn into_request(self) -> GenRequest {
        self.req
    }

    pub(crate) fn priority(&self) -> Priority {
        self.req.priority
    }

    /// Whether the cancel flag is set (the admission queue and scheduler
    /// both observe it to skip doomed work early).
    pub(crate) fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Whether the queue deadline elapsed (meaningful only while the
    /// submission is still queued).
    pub(crate) fn queue_expired(&self) -> bool {
        self.req
            .queue_deadline
            .is_some_and(|d| self.submitted.elapsed() >= d)
    }

    /// Whether the end-to-end deadline elapsed.
    pub(crate) fn total_expired(&self) -> bool {
        self.req
            .total_deadline
            .is_some_and(|d| self.submitted.elapsed() >= d)
    }

    /// Attach an engine outstanding-counter share (replaces any previous
    /// one; the old share releases on drop).
    pub(crate) fn attach_guard(&mut self, guard: OutstandingGuard) {
        self.guard = Some(guard);
    }

    /// Move the outstanding-counter share to another replica's counter —
    /// used when a request is re-dispatched after a panic.
    pub(crate) fn retarget(&mut self, counter: &Arc<AtomicUsize>) {
        self.guard = Some(OutstandingGuard::acquire(counter));
    }

    pub(crate) fn retries(&self) -> u32 {
        self.retries
    }

    pub(crate) fn mark_retried(&mut self) {
        self.retries += 1;
    }

    /// Terminal settle on the panic path: emit [`Event::Failed`] and
    /// release the outstanding share.
    pub(crate) fn settle_failed(self, error: &str) {
        let id = self.id();
        self.emit_with(|| Event::Failed {
            id,
            error: error.to_string(),
        });
    }

    /// Terminal settle for a cancelled submission reclaimed outside the
    /// scheduler (e.g. in-flight during a replica panic).
    pub(crate) fn settle_cancelled(self, tokens: Vec<u32>) {
        let id = self.id();
        self.emit_with(|| Event::Cancelled { id, tokens });
    }

    /// Best-effort event emission (a dropped handle just detaches the
    /// stream; the request keeps running).
    fn emit(&self, ev: Event) {
        if let Some(tx) = &self.events {
            let _ = tx.send(ev);
        }
    }

    /// Lazy variant for events whose construction allocates (terminal
    /// events clone the token vector): the closure only runs when a
    /// stream is attached, so bare-scheduler users pay nothing.
    fn emit_with(&self, f: impl FnOnce() -> Event) {
        if let Some(tx) = &self.events {
            let _ = tx.send(f());
        }
    }
}

/// Terminal result of one scheduled request.
#[derive(Clone, Debug)]
pub enum Outcome {
    Done(GenResponse),
    /// Cancelled before completion; carries the tokens generated so far
    /// (empty if the request never left the queue).
    Cancelled { id: u64, tokens: Vec<u32> },
    /// A queue or total deadline expired; carries the tokens generated
    /// before eviction (empty if the request never left the queue).
    TimedOut { id: u64, tokens: Vec<u32> },
    /// The scheduler could not place the request at all — its KV
    /// footprint exceeds the whole page pool even with everything else
    /// evicted. Mirrors [`Event::Failed`].
    Failed { id: u64, error: String },
}

impl Outcome {
    pub fn id(&self) -> u64 {
        match self {
            Outcome::Done(r) => r.id,
            Outcome::Cancelled { id, .. }
            | Outcome::TimedOut { id, .. }
            | Outcome::Failed { id, .. } => *id,
        }
    }

    pub fn into_done(self) -> Option<GenResponse> {
        match self {
            Outcome::Done(r) => Some(r),
            Outcome::Cancelled { .. } | Outcome::TimedOut { .. } | Outcome::Failed { .. } => None,
        }
    }
}

struct Active {
    sub: Submission,
    cache: PagedKvCache,
    generated: Vec<u32>,
    next_token: u32,
    ttft_s: f64,
    steps: usize,
    /// Admission order; pool pressure preempts the *youngest* bulk
    /// sequence first, so long-running work closest to completion is
    /// protected.
    seq_no: u64,
    /// Adaptive speculative draft-depth state (idle unless
    /// [`BatchPolicy::spec`] is enabled and the sampler is greedy).
    spec: SeqSpec,
}

/// A sequence mid-prefill: it owns a batch slot and a KV cache but has
/// not produced its first token yet (fresh admissions) or is rebuilding
/// the cache it lost to a preemption. One chunk of its stream runs per
/// scheduler step (see [`BatchPolicy::prefill_chunk`]).
struct Prefilling {
    sub: Submission,
    cache: PagedKvCache,
    /// Stream positions already written into the cache (adopted prefix
    /// pages count — they skipped compute entirely).
    consumed: usize,
    /// Prefill stream override for resumed sequences: prompt followed
    /// by the already-generated tokens minus the last, which decodes
    /// next. `None` means the plain prompt.
    tokens: Option<Vec<u32>>,
    /// Present when this prefill rebuilds a preempted sequence.
    resume: Option<ResumeState>,
    seq_no: u64,
}

/// Decode state carried across a preemption: everything needed to put
/// the sequence back into the batch once its KV cache is rebuilt.
struct ResumeState {
    generated: Vec<u32>,
    ttft_s: f64,
    steps: usize,
}

/// A sequence parked under page-pool pressure. Its KV pages are already
/// released; on resume the prompt + generated prefix re-prefills
/// (prefix-shared pages skip most of that compute).
struct Preempted {
    sub: Submission,
    generated: Vec<u32>,
    ttft_s: f64,
    steps: usize,
    /// Step counter value when parked: a sequence never resumes in the
    /// very step that parked it, so park/resume cannot livelock inside
    /// one step.
    parked_tick: u64,
}

impl AsKvStore for Active {
    type Store = PagedKvCache;
    fn kv(&self) -> &PagedKvCache {
        &self.cache
    }
    fn kv_mut(&mut self) -> &mut PagedKvCache {
        &mut self.cache
    }
}

/// Observability wiring for one scheduler: registry-resolved metric
/// handles plus the span-trace sink, tagged with the owning replica's
/// trace track. The engine attaches one per replica via
/// [`Scheduler::with_obs`]; bare schedulers (unit tests, direct users)
/// run without it and pay nothing on the hot path. Handles are `Arc`s
/// into the shared [`MetricsRegistry`], so recording is lock-free and
/// the registry snapshot sees every replica's data.
#[derive(Clone)]
pub struct SchedObs {
    trace: Arc<TraceSink>,
    replica: usize,
    queue_wait: Arc<Histogram>,
    step_time: Arc<Histogram>,
    prefill_chunk: Arc<Histogram>,
    spec_round: Arc<Histogram>,
    spec_draft: Arc<Histogram>,
    spec_verify: Arc<Histogram>,
    decode_steps: Arc<Counter>,
    batched_tokens: Arc<Counter>,
    drafted: Arc<Counter>,
    accepted: Arc<Counter>,
    spec_rounds: Arc<Counter>,
    peak_concurrency: Arc<Gauge>,
}

impl SchedObs {
    /// Resolve every handle this scheduler records through; `replica` is
    /// the trace track (`tid`) its span events render on.
    pub fn new(registry: &MetricsRegistry, trace: Arc<TraceSink>, replica: usize) -> SchedObs {
        SchedObs {
            trace,
            replica,
            queue_wait: registry.histogram(names::QUEUE_WAIT),
            step_time: registry.histogram(names::STEP_TIME),
            prefill_chunk: registry.histogram(names::PREFILL_CHUNK),
            spec_round: registry.histogram(names::SPEC_ROUND),
            spec_draft: registry.histogram(names::SPEC_DRAFT),
            spec_verify: registry.histogram(names::SPEC_VERIFY),
            decode_steps: registry.counter(names::DECODE_STEPS),
            batched_tokens: registry.counter(names::BATCHED_TOKENS),
            drafted: registry.counter(names::SPEC_DRAFTED),
            accepted: registry.counter(names::SPEC_ACCEPTED),
            spec_rounds: registry.counter(names::SPEC_ROUNDS),
            peak_concurrency: registry.gauge(names::PEAK_CONCURRENCY),
        }
    }
}

/// Continuous-batching scheduler bound to one model replica. Owns one
/// [`ForwardScratch`], so steady-state decode steps perform no heap
/// allocation (caches are decoded in place — no per-step cache churn),
/// and one [`PagePool`] that every sequence's [`PagedKvCache`] draws
/// from one page at a time.
///
/// Weights are held behind an `Arc`: they are read-only at serve time,
/// so N replica schedulers over one model share a single copy (~1×
/// memory instead of N×). `Scheduler::new` still accepts a bare
/// `Transformer` (it converts via `Into<Arc<_>>`).
pub struct Scheduler {
    model: Arc<Transformer>,
    policy: BatchPolicy,
    queue: VecDeque<Submission>,
    active: Vec<Active>,
    prefilling: Vec<Prefilling>,
    /// Sequences parked under page-pool pressure, oldest first.
    preempted: VecDeque<Preempted>,
    pool: PagePool,
    rng: Rng,
    scratch: ForwardScratch,
    /// Reused per-step token staging buffer.
    tok_buf: Vec<u32>,
    failpoints: Arc<FailPoints>,
    fp_tag: u64,
    /// Observability wiring (histograms, live counters, span traces);
    /// absent for bare schedulers.
    obs: Option<SchedObs>,
    /// Step counter; gates same-step park/resume cycles.
    tick: u64,
    /// Monotone admission counter backing `Active::seq_no`.
    seq_counter: u64,
    pub steps_executed: u64,
    pub batched_tokens: u64,
    /// Requests settled `TimedOut` by this scheduler.
    pub timed_out: u64,
    /// Prefix-trie pages adopted instead of prefilled.
    pub prefix_hits: u64,
    /// Times a sequence was parked under pool pressure (preemptions and
    /// re-parks of sequences that could not yet resume).
    pub preemptions: u64,
    /// Highest batch occupancy (active + prefilling) observed.
    pub peak_batch: usize,
    /// Speculative-decoding controller: reusable draft/verify buffers
    /// plus the replica's `drafted`/`accepted` counters.
    pub spec: Controller,
}

impl Scheduler {
    pub fn new(model: impl Into<Arc<Transformer>>, policy: BatchPolicy, seed: u64) -> Scheduler {
        let model = model.into();
        let geom = PageGeometry::of(&model.cfg, policy.kv_page_size);
        let capacity = if policy.kv_pool_pages > 0 {
            policy.kv_pool_pages
        } else {
            // Worst-case reservation: a full batch of full-context
            // sequences always fits, so the default never preempts.
            policy.max_batch.max(1) * model.cfg.max_seq.div_ceil(geom.page_size)
        };
        let pool = PagePool::new(geom, capacity, Arc::new(KvGauges::default()));
        pool.set_tenant_quota(policy.tenant_quota_pages);
        Scheduler {
            model,
            policy,
            queue: VecDeque::new(),
            active: Vec::new(),
            prefilling: Vec::new(),
            preempted: VecDeque::new(),
            pool,
            rng: Rng::new(seed),
            scratch: ForwardScratch::new(),
            tok_buf: Vec::new(),
            failpoints: FailPoints::new(),
            fp_tag: 0,
            obs: None,
            tick: 0,
            seq_counter: 0,
            steps_executed: 0,
            batched_tokens: 0,
            timed_out: 0,
            prefix_hits: 0,
            preemptions: 0,
            peak_batch: 0,
            spec: Controller::new(),
        }
    }

    /// Wire this scheduler into a fault-injection registry; `tag` is the
    /// owning replica's index.
    pub fn with_failpoints(mut self, failpoints: Arc<FailPoints>, tag: u64) -> Scheduler {
        self.failpoints = failpoints;
        self.fp_tag = tag;
        self
    }

    /// Rebuild the page pool against shared gauges (engine wiring; must
    /// run before any admission touches the pool).
    pub fn with_kv_gauges(mut self, gauges: Arc<KvGauges>) -> Scheduler {
        assert_eq!(self.pool.used(), 0, "with_kv_gauges after pages were allocated");
        self.pool = PagePool::new(self.pool.geometry(), self.pool.capacity(), gauges);
        self.pool.set_tenant_quota(self.policy.tenant_quota_pages);
        self
    }

    /// Attach observability wiring: span-trace emission and live metric
    /// recording for every request this scheduler serves (engine
    /// wiring; see [`SchedObs`]).
    pub fn with_obs(mut self, obs: SchedObs) -> Scheduler {
        self.obs = Some(obs);
        self
    }

    /// The KV page pool backing this scheduler's sequences.
    pub fn kv_pool(&self) -> &PagePool {
        &self.pool
    }

    pub fn model(&self) -> &Transformer {
        &self.model
    }

    /// Enqueue a bare request (admission happens at the next step
    /// boundary; the TTFT stopwatch starts now).
    pub fn admit(&mut self, req: GenRequest) {
        self.admit_submission(Submission::new(req));
    }

    /// Enqueue a wrapped request carrying its own submission timer,
    /// cancel flag and event stream.
    pub fn admit_submission(&mut self, sub: Submission) {
        self.queue.push_back(sub);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.prefilling.len() + self.active.len() + self.preempted.len()
    }

    /// Ids currently occupying batch slots with decode state
    /// (introspection/tests; excludes sequences still prefilling — see
    /// [`Scheduler::prefilling_ids`]).
    pub fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|a| a.sub.id()).collect()
    }

    /// Ids of sequences mid-prefill (they hold batch slots but have not
    /// produced a first token yet).
    pub fn prefilling_ids(&self) -> Vec<u64> {
        self.prefilling.iter().map(|p| p.sub.id()).collect()
    }

    /// Ids of sequences parked under page-pool pressure.
    pub fn preempted_ids(&self) -> Vec<u64> {
        self.preempted.iter().map(|p| p.sub.id()).collect()
    }

    /// Reclaim every in-flight submission after a panic unwound through
    /// [`Scheduler::step`]: queued, prefilling and active sequences come
    /// back with the tokens they had generated, and their KV caches are
    /// released. The supervisor settles each with a terminal event
    /// (retry, `Cancelled` or `Failed`) — the scheduler itself cannot,
    /// because it no longer knows which outcomes of the panicking step
    /// already reached their streams.
    ///
    /// Submissions whose terminal outcome was emitted *before* the panic
    /// left scheduler state at that moment, so they cannot reappear here
    /// — the exactly-one-terminal-event invariant survives the unwind.
    pub(crate) fn take_inflight(&mut self) -> Vec<(Submission, Vec<u32>)> {
        let mut out = Vec::new();
        for sub in self.queue.drain(..) {
            out.push((sub, Vec::new()));
        }
        for p in self.prefilling.drain(..) {
            // A resumed sequence rebuilding its cache still owns the
            // tokens it generated before preemption.
            let generated = p.resume.map(|r| r.generated).unwrap_or_default();
            out.push((p.sub, generated));
        }
        for a in self.active.drain(..) {
            out.push((a.sub, a.generated));
        }
        for p in self.preempted.drain(..) {
            out.push((p.sub, p.generated));
        }
        out
    }

    /// Run the next stream chunk (at most `prefill_chunk` positions) of
    /// `prefilling[idx]`, in place — no per-step buffer churn on the
    /// decode hot path. The chunk's pages are reserved up front; on pool
    /// exhaustion the scheduler frees what it can ([`Self::try_free`])
    /// and otherwise parks or fails the sequence. Intermediate chunks
    /// write the cache only (no lm_head pass); the final chunk samples
    /// the first token and moves the sequence into the running batch
    /// (`swap_remove`) — or, for a resumed sequence, restores its saved
    /// decode state without re-emitting `FirstToken`. Returns true when
    /// the sequence left the prefilling list.
    fn advance_prefill_at(&mut self, idx: usize, out: &mut Vec<Outcome>) -> bool {
        self.failpoints.hit(failpoint::PREFILL, self.fp_tag);
        let chunk_t0 = self.obs.as_ref().map(|o| o.trace.now_us());
        let chunk = self.policy.prefill_chunk.max(1);
        let (consumed, end, stream_len) = {
            let p = &self.prefilling[idx];
            let stream_len = p.tokens.as_deref().unwrap_or(&p.sub.req.prompt).len();
            (p.consumed, (p.consumed + chunk).min(stream_len), stream_len)
        };
        let need = self.prefilling[idx].cache.pages_needed(end);
        let tenant = self.prefilling[idx].cache.tenant();
        if need > self.pool.tenant_available(tenant) && !self.try_free_for(tenant, need) {
            return self.park_or_fail_prefill(idx, out);
        }
        if end < stream_len {
            let p = &mut self.prefilling[idx];
            p.cache.reserve(end).expect("pages freed before reserve");
            let stream = p.tokens.as_deref().unwrap_or(&p.sub.req.prompt);
            self.model
                .forward_prefill_chunk(&stream[consumed..end], &mut p.cache, &mut self.scratch);
            p.consumed = end;
            if let (Some(o), Some(t0)) = (&self.obs, chunk_t0) {
                o.trace.span(o.replica, p.sub.id(), SpanKind::PrefillChunk, t0);
                o.prefill_chunk
                    .record(o.trace.now_us().saturating_sub(t0) as f64 / 1e6);
            }
            return false;
        }
        let Prefilling {
            sub,
            mut cache,
            tokens,
            resume,
            seq_no,
            ..
        } = self.prefilling.swap_remove(idx);
        cache.reserve(end).expect("pages freed before reserve");
        let stream = tokens.as_deref().unwrap_or(&sub.req.prompt);
        let active = match resume {
            None => {
                let logits = self.model.forward_prefill_with(
                    &stream[consumed..end],
                    &mut cache,
                    &mut self.scratch,
                );
                let first = sub.req.sampler.sample(logits, &mut self.rng);
                let ttft_s = sub.submitted.elapsed_secs();
                sub.emit(Event::FirstToken {
                    id: sub.id(),
                    token: first,
                    ttft_s,
                });
                Active {
                    sub,
                    cache,
                    generated: vec![first],
                    next_token: first,
                    ttft_s,
                    steps: 1,
                    seq_no,
                    spec: SeqSpec::new(&self.policy.spec),
                }
            }
            Some(rs) => {
                // Rebuilding a preempted sequence: no logits and no
                // FirstToken re-emission — its stream already emitted
                // them before it was parked.
                self.model
                    .forward_prefill_chunk(&stream[consumed..end], &mut cache, &mut self.scratch);
                let next = *rs.generated.last().expect("preempted decode state has tokens");
                Active {
                    sub,
                    cache,
                    generated: rs.generated,
                    next_token: next,
                    ttft_s: rs.ttft_s,
                    steps: rs.steps,
                    seq_no,
                    // A resumed sequence restarts its depth adaptation.
                    spec: SeqSpec::new(&self.policy.spec),
                }
            }
        };
        if let (Some(o), Some(t0)) = (&self.obs, chunk_t0) {
            o.trace.span(o.replica, active.sub.id(), SpanKind::PrefillChunk, t0);
            o.prefill_chunk
                .record(o.trace.now_us().saturating_sub(t0) as f64 / 1e6);
        }
        // Commit the full prompt pages so identical prompt prefixes can
        // adopt them (insert dedups: already-committed pages win). The
        // trie is tenant-scoped, so only this tenant's later prompts
        // ever see them.
        let ps = self.pool.geometry().page_size;
        let full = active.sub.req.prompt.len() / ps;
        if full > 0 {
            self.pool.commit_prefix_for(
                active.cache.tenant(),
                &active.sub.req.prompt[..full * ps],
                &active.cache.table()[..full],
            );
        }
        self.active.push(active);
        true
    }

    /// Admit a request into a batch slot: adopt any committed prefix
    /// pages from the pool's trie (refcount bumps — their prefill is
    /// skipped entirely), then run the first prefill chunk immediately
    /// (prompts within the chunk cap complete prefill in one pass).
    /// `tokens` and `resume` carry a preempted sequence's rebuilt stream
    /// and decode state; both are `None` for fresh admissions.
    fn begin_prefill(
        &mut self,
        sub: Submission,
        tokens: Option<Vec<u32>>,
        resume: Option<ResumeState>,
        out: &mut Vec<Outcome>,
    ) {
        assert!(
            !sub.req.prompt.is_empty(),
            "empty prompt: nothing to condition on"
        );
        let tenant = sub.req.effective_tenant();
        let mut cache = PagedKvCache::for_tenant(&self.pool, tenant);
        let ps = self.pool.geometry().page_size;
        let stream_len = tokens.as_deref().unwrap_or(&sub.req.prompt).len();
        // Never adopt the final position: the last chunk must recompute
        // so fresh prefills produce first-token logits.
        let max_pages = (stream_len - 1) / ps;
        let shared = self
            .pool
            .shared_prefix_for(tenant, tokens.as_deref().unwrap_or(&sub.req.prompt), max_pages);
        let matched = shared.len();
        if matched > 0 {
            self.prefix_hits += matched as u64;
            self.pool
                .gauges()
                .prefix_hits
                .fetch_add(matched as u64, std::sync::atomic::Ordering::Relaxed);
            cache.adopt_prefix(shared);
        }
        let seq_no = self.seq_counter;
        self.seq_counter += 1;
        self.prefilling.push(Prefilling {
            sub,
            cache,
            consumed: matched * ps,
            tokens,
            resume,
            seq_no,
        });
        self.advance_prefill_at(self.prefilling.len() - 1, out);
    }

    /// Try to make `need` pages allocatable *for `tenant`*: evict trie
    /// entries no live sequence references (any tenant's — freeing a
    /// page always relieves the pool, and freeing this tenant's own
    /// cached pages also relieves its quota), then preempt bulk decode
    /// sequences — the offending tenant's own when the shortfall is
    /// quota-bound (other tenants' pages cannot relieve a quota), the
    /// most-over-share tenant's otherwise. Interactive sequences are
    /// never preempted here. Returns false when the target is
    /// unreachable.
    fn try_free_for(&mut self, tenant: TenantId, need: usize) -> bool {
        loop {
            if self.pool.tenant_available(tenant) >= need {
                return true;
            }
            if self.pool.evict_unreferenced() > 0 {
                continue;
            }
            let quota = self.pool.tenant_quota();
            let quota_bound =
                quota > 0 && quota.saturating_sub(self.pool.used_by(tenant)) < need;
            let victim = if quota_bound { Some(tenant) } else { None };
            if !self.preempt_youngest_bulk_of(victim) {
                return false;
            }
        }
    }

    /// Park the bulk decode sequence chosen by fair share, freeing its
    /// pages. With `tenant` set, only that tenant's bulk sequences are
    /// candidates (quota-bound pressure: only the offender's own pages
    /// relieve it). Otherwise the victim tenant is the one most over its
    /// share — the share (quota when set, an equal capacity split
    /// otherwise) is uniform across tenants, so the most-over-share
    /// tenant is simply the heaviest page user among those owning bulk
    /// work — and within it the *youngest* bulk sequence parks first. A
    /// single tenant degenerates exactly to plain youngest-first.
    /// Returns false when no eligible bulk sequence is active.
    fn preempt_youngest_bulk_of(&mut self, tenant: Option<TenantId>) -> bool {
        let victim_tenant = match tenant {
            Some(t) => t,
            None => {
                let Some(t) = self
                    .active
                    .iter()
                    .filter(|a| a.sub.priority() == Priority::Bulk)
                    .map(|a| a.cache.tenant())
                    .max_by_key(|&t| (self.pool.used_by(t), std::cmp::Reverse(t)))
                else {
                    return false;
                };
                t
            }
        };
        let Some(idx) = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, a)| {
                a.sub.priority() == Priority::Bulk && a.cache.tenant() == victim_tenant
            })
            .max_by_key(|(_, a)| a.seq_no)
            .map(|(i, _)| i)
        else {
            return false;
        };
        self.park(idx);
        true
    }

    /// Fair-share preemption round (also the synthetic-pressure
    /// failpoint's entry): park the youngest bulk sequence of the
    /// most-over-share tenant. Returns false when no bulk sequence is
    /// active.
    fn preempt_youngest_bulk(&mut self) -> bool {
        self.preempt_youngest_bulk_of(None)
    }

    /// Move `active[idx]` to the preempted queue; dropping its cache
    /// releases every page it held exclusively.
    fn park(&mut self, idx: usize) {
        let a = self.active.swap_remove(idx);
        if let Some(o) = &self.obs {
            o.trace.instant(o.replica, a.sub.id(), SpanKind::Preempted);
        }
        self.note_preemption();
        self.preempted.push_back(Preempted {
            sub: a.sub,
            generated: a.generated,
            ttft_s: a.ttft_s,
            steps: a.steps,
            parked_tick: self.tick,
        });
    }

    /// A prefilling sequence could not get pages even after freeing:
    /// park it for resume — unless nothing else is in flight, in which
    /// case not even the whole pool can hold it and it fails instead of
    /// spinning forever. Always removes `prefilling[idx]`.
    fn park_or_fail_prefill(&mut self, idx: usize, out: &mut Vec<Outcome>) -> bool {
        // A stream whose own footprint exceeds its tenant's quota can
        // never fit, no matter how much of the fleet drains — all of a
        // sequence's pages bill its own tenant (cross-tenant sharing is
        // impossible), so it fails terminally instead of parking forever.
        let quota = self.pool.tenant_quota();
        let over_quota = quota > 0 && {
            let p = &self.prefilling[idx];
            let ps = self.pool.geometry().page_size;
            let stream_len = p.tokens.as_deref().unwrap_or(&p.sub.req.prompt).len();
            stream_len.div_ceil(ps) > quota
        };
        let Prefilling { sub, resume, .. } = self.prefilling.swap_remove(idx);
        let (generated, ttft_s, steps) = match resume {
            Some(rs) => (rs.generated, rs.ttft_s, rs.steps),
            None => (Vec::new(), 0.0, 0),
        };
        if over_quota {
            out.push(Self::failed_out(sub, "kv tenant quota exceeded"));
            return true;
        }
        if self.active.is_empty() && self.prefilling.is_empty() {
            out.push(Self::failed_out(sub, "kv page pool exhausted"));
            return true;
        }
        if let Some(o) = &self.obs {
            o.trace.instant(o.replica, sub.id(), SpanKind::Preempted);
        }
        self.note_preemption();
        self.preempted.push_back(Preempted {
            sub,
            generated,
            ttft_s,
            steps,
            parked_tick: self.tick,
        });
        true
    }

    /// Re-admit a parked sequence through the prefill path. A sequence
    /// parked before its first token restarts from scratch; one parked
    /// mid-decode re-prefills prompt + generated tokens (minus the last,
    /// which decodes next) and then rejoins the batch where it left off.
    fn resume_preempted(&mut self, p: Preempted, out: &mut Vec<Outcome>) {
        if let Some(o) = &self.obs {
            o.trace.instant(o.replica, p.sub.id(), SpanKind::Resumed);
        }
        let Preempted {
            sub,
            generated,
            ttft_s,
            steps,
            ..
        } = p;
        if generated.is_empty() {
            self.begin_prefill(sub, None, None, out);
        } else {
            let mut stream = sub.req.prompt.clone();
            stream.extend_from_slice(&generated[..generated.len() - 1]);
            self.begin_prefill(
                sub,
                Some(stream),
                Some(ResumeState {
                    generated,
                    ttft_s,
                    steps,
                }),
                out,
            );
        }
    }

    /// Make every active sequence's next decode position writable before
    /// the batched forward, so row writes cannot fail mid-step. Under
    /// exhaustion: evict, preempt bulk, and as a last resort park the
    /// youngest active outright — the batch must shrink or the step
    /// cannot run at all. (A sequence too big for even an empty pool
    /// settles `Failed` on its resume prefill.)
    fn ensure_decode_pages(&mut self) {
        loop {
            // Per-tenant page demand for the next decode position; the
            // aggregate bounds the pool, each tenant's sum its quota.
            let mut need_by: Vec<(TenantId, usize)> = Vec::new();
            for a in &self.active {
                let t = a.cache.tenant();
                let n = a.cache.pages_needed(a.cache.len() + 1);
                match need_by.iter_mut().find(|(id, _)| *id == t) {
                    Some((_, tot)) => *tot += n,
                    None => need_by.push((t, n)),
                }
            }
            let total: usize = need_by.iter().map(|&(_, n)| n).sum();
            let pool_bound = total > self.pool.available();
            let quota_victim = need_by
                .iter()
                .find(|&&(t, n)| n > self.pool.tenant_available(t))
                .map(|&(t, _)| t);
            if !pool_bound && quota_victim.is_none() {
                break;
            }
            if self.pool.evict_unreferenced() > 0 {
                continue;
            }
            if pool_bound {
                if self.preempt_youngest_bulk() {
                    continue;
                }
                let idx = self
                    .active
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, a)| a.seq_no)
                    .map(|(i, _)| i)
                    .expect("need > 0 implies a non-empty batch");
                self.park(idx);
            } else {
                // Quota-bound only: just this tenant must shrink — its
                // youngest bulk sequence first, then (last resort) its
                // youngest active outright.
                let t = quota_victim.expect("not pool-bound, so a quota victim exists");
                if self.preempt_youngest_bulk_of(Some(t)) {
                    continue;
                }
                let idx = self
                    .active
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| a.cache.tenant() == t)
                    .max_by_key(|(_, a)| a.seq_no)
                    .map(|(i, _)| i)
                    .expect("the quota victim owns active sequences");
                self.park(idx);
            }
        }
        for a in &mut self.active {
            let len = a.cache.len();
            a.cache.reserve(len + 1).expect("pages available after ensure");
        }
    }

    fn note_preemption(&mut self) {
        self.preemptions += 1;
        self.pool
            .gauges()
            .preemptions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn failed_out(sub: Submission, error: &str) -> Outcome {
        let id = sub.id();
        sub.emit_with(|| Event::Failed {
            id,
            error: error.to_string(),
        });
        Outcome::Failed {
            id,
            error: error.to_string(),
        }
    }

    fn cancel_out(sub: Submission, tokens: Vec<u32>) -> Outcome {
        sub.emit_with(|| Event::Cancelled {
            id: sub.id(),
            tokens: tokens.clone(),
        });
        Outcome::Cancelled {
            id: sub.id(),
            tokens,
        }
    }

    fn timeout_out(sub: Submission, tokens: Vec<u32>) -> Outcome {
        sub.emit_with(|| Event::TimedOut {
            id: sub.id(),
            tokens: tokens.clone(),
        });
        Outcome::TimedOut {
            id: sub.id(),
            tokens,
        }
    }

    /// Drop dead work at the step boundary: cancelled requests and
    /// deadline-expired requests leave the queue, the prefill list and
    /// the batch (cancel wins when both apply — the caller asked first).
    /// Queued requests are discarded before they ever prefill;
    /// prefilling sequences abandon the rest of their prompt; active
    /// sequences leave the batch. In every case the KV cache storage is
    /// released immediately.
    fn sweep_dead(&mut self, out: &mut Vec<Outcome>) {
        let mut i = 0;
        while i < self.queue.len() {
            let s = &self.queue[i];
            if s.cancelled() {
                let sub = self.queue.remove(i).expect("index in bounds");
                out.push(Self::cancel_out(sub, Vec::new()));
            } else if s.queue_expired() || s.total_expired() {
                let sub = self.queue.remove(i).expect("index in bounds");
                self.timed_out += 1;
                out.push(Self::timeout_out(sub, Vec::new()));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.prefilling.len() {
            let s = &self.prefilling[i].sub;
            if s.cancelled() {
                let p = self.prefilling.swap_remove(i);
                out.push(Self::cancel_out(p.sub, Vec::new()));
            } else if s.total_expired() {
                let p = self.prefilling.swap_remove(i);
                self.timed_out += 1;
                out.push(Self::timeout_out(p.sub, Vec::new()));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            let s = &self.active[i].sub;
            if s.cancelled() {
                // Dropping the Active frees its KV cache immediately — a
                // cancelled sequence holds no memory past this boundary.
                let a = self.active.swap_remove(i);
                out.push(Self::cancel_out(a.sub, a.generated));
            } else if s.total_expired() {
                let a = self.active.swap_remove(i);
                self.timed_out += 1;
                out.push(Self::timeout_out(a.sub, a.generated));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.preempted.len() {
            let s = &self.preempted[i].sub;
            if s.cancelled() {
                let p = self.preempted.remove(i).expect("index in bounds");
                out.push(Self::cancel_out(p.sub, p.generated));
            } else if s.total_expired() {
                let p = self.preempted.remove(i).expect("index in bounds");
                self.timed_out += 1;
                out.push(Self::timeout_out(p.sub, p.generated));
            } else {
                i += 1;
            }
        }
    }

    /// One scheduler iteration: sweep cancellations/expiries, advance
    /// in-flight prefills by one chunk each, admit up to capacity (first
    /// prefill chunk), run one batched decode step, retire finished
    /// sequences. Long prompts therefore interleave with decodes instead
    /// of stalling them. Returns the terminal outcomes of this step.
    pub fn step(&mut self) -> Vec<Outcome> {
        let step_t0 = self.obs.as_ref().map(|o| o.trace.now_us());
        let steps0 = self.steps_executed;
        let tokens0 = self.batched_tokens;
        let drafted0 = self.spec.drafted;
        let accepted0 = self.spec.accepted;
        let rounds0 = self.spec.rounds;
        let mut out = Vec::new();
        self.step_inner(&mut out);
        if let Some(o) = &self.obs {
            let now = o.trace.now_us();
            o.step_time
                .record(now.saturating_sub(step_t0.unwrap_or(now)) as f64 / 1e6);
            // Live deltas of the scheduler counters, so a registry
            // snapshot taken mid-run sees fleet totals without waiting
            // for the per-worker `ServeStats` merge at shutdown.
            o.decode_steps.add(self.steps_executed - steps0);
            o.batched_tokens.add(self.batched_tokens - tokens0);
            o.drafted.add(self.spec.drafted - drafted0);
            o.accepted.add(self.spec.accepted - accepted0);
            o.spec_rounds.add(self.spec.rounds - rounds0);
            let peak = self.peak_batch as u64;
            if peak > o.peak_concurrency.get() {
                o.peak_concurrency.set(peak);
            }
            // Every terminal `Outcome` flows through this return value —
            // the single choke point for the exactly-one-terminal-span
            // invariant (the engine's panic path emits its own for
            // submissions reclaimed from an unwound scheduler).
            for oc in &out {
                let kind = match oc {
                    Outcome::Done(_) => SpanKind::Done,
                    Outcome::Cancelled { .. } => SpanKind::Cancelled,
                    Outcome::TimedOut { .. } => SpanKind::TimedOut,
                    Outcome::Failed { .. } => SpanKind::Failed,
                };
                o.trace.instant(o.replica, oc.id(), kind);
            }
            // Chaos hook: a denied hit forces a span-ring wraparound,
            // proving export degrades (oldest dropped, counted) instead
            // of panicking or growing without bound.
            if self.failpoints.hit(failpoint::TRACE_BUF, self.fp_tag) {
                o.trace.force_wrap(o.replica);
            }
        }
        out
    }

    fn step_inner(&mut self, out: &mut Vec<Outcome>) {
        self.failpoints.hit(failpoint::STEP, self.fp_tag);
        self.tick += 1;
        self.sweep_dead(out);
        // Synthetic page-pool pressure: each denied POOL hit forces one
        // preemption round, exactly as a real exhausted pool would.
        if self.failpoints.hit(failpoint::POOL, self.fp_tag) {
            self.preempt_youngest_bulk();
        }
        // Advance sequences admitted in earlier steps by one chunk each
        // (in place; a finishing sequence swap-removes, and the element
        // swapped into its slot is advanced next — each exactly once).
        let mut i = 0;
        while i < self.prefilling.len() {
            if !self.advance_prefill_at(i, out) {
                i += 1;
            }
        }
        // Resume parked sequences (oldest first) before admitting new
        // work — but never in the very step that parked them.
        while self.active.len() + self.prefilling.len() < self.policy.max_batch
            && self
                .preempted
                .front()
                .is_some_and(|p| p.parked_tick < self.tick)
        {
            let p = self.preempted.pop_front().expect("front checked");
            self.resume_preempted(p, out);
        }
        // Admission: prefilling sequences occupy batch slots too.
        while self.active.len() + self.prefilling.len() < self.policy.max_batch {
            match self.queue.pop_front() {
                Some(sub) if sub.cancelled() => out.push(Self::cancel_out(sub, Vec::new())),
                Some(sub) if sub.queue_expired() || sub.total_expired() => {
                    self.timed_out += 1;
                    out.push(Self::timeout_out(sub, Vec::new()));
                }
                Some(sub) => {
                    // Fresh admission off the queue (resumes re-enter
                    // through `resume_preempted`, which never re-counts
                    // queue wait).
                    if let Some(o) = &self.obs {
                        o.trace.instant(o.replica, sub.id(), SpanKind::Admitted);
                        o.queue_wait.record(sub.submitted.elapsed_secs());
                    }
                    self.begin_prefill(sub, None, None, out)
                }
                None => break,
            }
        }
        self.peak_batch = self.peak_batch.max(self.active.len() + self.prefilling.len());
        if self.active.is_empty() {
            return;
        }
        // Retire sequences that already satisfied their budget (including
        // single-token generations) before spending a decode step on them.
        self.retire(out);
        // Reserve next-position pages for the whole batch up front
        // (shrinking it if the pool cannot cover everyone).
        self.ensure_decode_pages();
        if self.active.is_empty() {
            return;
        }

        if self.policy.spec.enabled {
            self.spec_decode();
            self.retire(out);
            return;
        }

        self.tok_buf.clear();
        self.tok_buf.extend(self.active.iter().map(|a| a.next_token));
        let decode_t0 = self.obs.as_ref().map(|o| o.trace.now_us());
        // Caches are decoded in place through `Active: AsKvStore` — no
        // per-step cache extraction/replacement.
        let logits = self
            .model
            .forward_batch_with(&self.tok_buf, &mut self.active, &mut self.scratch);
        self.steps_executed += 1;
        self.batched_tokens += self.tok_buf.len() as u64;
        for (i, a) in self.active.iter_mut().enumerate() {
            let t = a.sub.req.sampler.sample(logits.row(i), &mut self.rng);
            a.generated.push(t);
            a.next_token = t;
            a.steps += 1;
            a.sub.emit(Event::Token {
                id: a.sub.id(),
                token: t,
                index: a.generated.len() - 1,
            });
        }
        // One DecodeStep span per sequence that decoded (before retire,
        // so finishing sequences get their last span too).
        if let (Some(o), Some(t0)) = (&self.obs, decode_t0) {
            for a in &self.active {
                o.trace.span(o.replica, a.sub.id(), SpanKind::DecodeStep, t0);
            }
        }
        self.retire(out);
    }

    /// Speculative decode step: one draft→verify→accept round per
    /// greedy sequence ([`Controller::round`]); non-greedy samplers
    /// fall back to plain batched decode ([`Self::decode_plain_rest`])
    /// because the round's token identity only holds under argmax.
    ///
    /// Each round's draft depth is the sequence's adaptive depth capped
    /// by its remaining token budget, the context room and KV page
    /// availability — and the round's pages are reserved up front, so
    /// draft row writes cannot fail mid-round. `ensure_decode_pages`
    /// already guaranteed one position per sequence, so a round always
    /// runs at `k ≥ 1` even with the pool drained.
    fn spec_decode(&mut self) {
        let fp = Arc::clone(&self.failpoints);
        let tag = self.fp_tag;
        let eos = self.policy.eos;
        let spec_policy = self.policy.spec;
        // Cloned out of `self` so the timing hooks can live alongside the
        // `&mut self.spec` borrow inside `round`.
        let obs = self.obs.clone();
        let mut emitted_total = 0u64;
        let mut plain_rest = false;
        for idx in 0..self.active.len() {
            if !self.active[idx].sub.req.sampler.is_greedy() {
                plain_rest = true;
                continue;
            }
            let (len, mut k) = {
                let a = &self.active[idx];
                let budget = a.sub.req.max_new_tokens.saturating_sub(a.generated.len());
                let len = a.cache.len();
                let room = self.model.cfg.max_seq.saturating_sub(len);
                (len, a.spec.depth().min(budget).min(room))
            };
            if k == 0 {
                continue; // retired by the next retire() pass
            }
            // Depth is capped by what this sequence's tenant may still
            // allocate (quota and pool), so the reserve cannot fail.
            while k > 1
                && self.active[idx].cache.pages_needed(len + k)
                    > self.pool.tenant_available(self.active[idx].cache.tenant())
            {
                k -= 1;
            }
            let a = &mut self.active[idx];
            a.cache.reserve(len + k).expect("pages available after ensure");
            let sampler = a.sub.req.sampler;
            let rng = &mut self.rng;
            let start = a.generated.len();
            let round_t0 = obs.as_ref().map(|o| o.trace.now_us());
            // Stamped by the before-verify hook; splits the round into
            // its draft and verify phases.
            let draft_end = Cell::new(u64::MAX);
            let stats = self.spec.round(
                &self.model,
                &mut a.cache,
                &mut self.scratch,
                a.next_token,
                k,
                eos,
                &mut |row| sampler.sample(row, rng),
                &mut || {
                    if let Some(o) = &obs {
                        draft_end.set(o.trace.now_us());
                    }
                    fp.hit(failpoint::VERIFY, tag);
                },
                &mut a.generated,
            );
            if let (Some(o), Some(t0)) = (&obs, round_t0) {
                let now = o.trace.now_us();
                o.trace.span(o.replica, a.sub.id(), SpanKind::SpecRound, t0);
                o.spec_round.record(now.saturating_sub(t0) as f64 / 1e6);
                let de = draft_end.get();
                if de != u64::MAX {
                    o.spec_draft.record(de.saturating_sub(t0) as f64 / 1e6);
                    o.spec_verify.record(now.saturating_sub(de) as f64 / 1e6);
                }
            }
            a.next_token = *a.generated.last().expect("round emits at least one token");
            a.steps += 1;
            for (j, &t) in a.generated[start..].iter().enumerate() {
                a.sub.emit(Event::Token {
                    id: a.sub.id(),
                    token: t,
                    index: start + j,
                });
            }
            a.spec.observe(&stats, &spec_policy);
            emitted_total += stats.emitted as u64;
        }
        let rest = if plain_rest { self.decode_plain_rest() } else { 0 };
        if emitted_total > 0 || rest > 0 {
            self.steps_executed += 1;
            self.batched_tokens += emitted_total;
        }
    }

    /// Plain batched decode over the non-greedy residue of the batch in
    /// spec mode. Returns the number of sequences decoded.
    fn decode_plain_rest(&mut self) -> u64 {
        self.tok_buf.clear();
        self.tok_buf.extend(
            self.active
                .iter()
                .filter(|a| !a.sub.req.sampler.is_greedy())
                .map(|a| a.next_token),
        );
        if self.tok_buf.is_empty() {
            return 0;
        }
        let mut rest: Vec<&mut Active> = self
            .active
            .iter_mut()
            .filter(|a| !a.sub.req.sampler.is_greedy())
            .collect();
        let logits = self
            .model
            .forward_batch_with(&self.tok_buf, &mut rest, &mut self.scratch);
        for (i, a) in rest.iter_mut().enumerate() {
            let t = a.sub.req.sampler.sample(logits.row(i), &mut self.rng);
            a.generated.push(t);
            a.next_token = t;
            a.steps += 1;
            a.sub.emit(Event::Token {
                id: a.sub.id(),
                token: t,
                index: a.generated.len() - 1,
            });
        }
        let n = self.tok_buf.len() as u64;
        self.batched_tokens += n;
        n
    }

    fn retire(&mut self, out: &mut Vec<Outcome>) {
        let eos = self.policy.eos;
        let cfg_max = self.model.cfg.max_seq;
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let hit_eos = eos.map(|e| a.generated.last() == Some(&e)).unwrap_or(false);
            let budget = a.generated.len() >= a.sub.req.max_new_tokens;
            let ctx_full = a.sub.req.prompt.len() + a.generated.len() >= cfg_max;
            if hit_eos || budget || ctx_full {
                let a = self.active.swap_remove(i);
                let resp = GenResponse {
                    id: a.sub.id(),
                    tokens: a.generated,
                    ttft_s: a.ttft_s,
                    total_s: a.sub.submitted.elapsed_secs(),
                    steps: a.steps,
                    tenant: a.sub.req.tenant,
                };
                a.sub.emit_with(|| Event::Done(resp.clone()));
                out.push(Outcome::Done(resp));
            } else {
                i += 1;
            }
        }
    }

    /// Drive to completion, returning the completed responses (cancelled
    /// and timed-out requests are swept but not returned — stream their
    /// terminal events instead).
    pub fn run_to_completion(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step().into_iter().filter_map(Outcome::into_done));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::failpoint::FailSpec;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;
    use crate::util::proptest::{run_prop, USize};
    use std::time::Duration;

    fn sched(max_batch: usize) -> Scheduler {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 21);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        Scheduler::new(
            model,
            BatchPolicy {
                max_batch,
                ..BatchPolicy::default()
            },
            7,
        )
    }

    #[test]
    fn single_request_completes() {
        let mut s = sched(4);
        s.admit(GenRequest::greedy(1, vec![1, 2, 3], 5));
        let out = s.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].tokens.len(), 5);
    }

    #[test]
    fn all_requests_finish_exactly_once() {
        let mut s = sched(3);
        for id in 0..10u64 {
            s.admit(GenRequest::greedy(id, vec![(id % 60) as u32 + 1], 3 + (id as usize % 4)));
        }
        let out = s.run_to_completion();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        for r in &out {
            let want = 3 + (r.id as usize % 4);
            assert_eq!(r.tokens.len(), want, "req {}", r.id);
        }
    }

    #[test]
    fn batch_occupancy_bounded() {
        let mut s = sched(2);
        for id in 0..6u64 {
            s.admit(GenRequest::greedy(id, vec![1, 2], 4));
        }
        while s.pending() > 0 {
            s.step();
            assert!(s.active.len() <= 2);
        }
    }

    #[test]
    fn batched_equals_sequential_greedy() {
        // Greedy decoding must be identical whether requests are served
        // alone or continuously batched.
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 22);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8], vec![4], vec![5, 6, 7, 8]];

        let mut solo_out = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let mut s = Scheduler::new(model.clone(), BatchPolicy::default(), 1);
            s.admit(GenRequest::greedy(i as u64, p.clone(), 6));
            solo_out.push(s.run_to_completion().pop().unwrap().tokens);
        }

        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 4, ..BatchPolicy::default() },
            1,
        );
        for (i, p) in prompts.iter().enumerate() {
            s.admit(GenRequest::greedy(i as u64, p.clone(), 6));
        }
        let mut batched = s.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, solo_out[i], "req {i}");
        }
    }

    #[test]
    fn eos_stops_early() {
        // With eos = the greedy first token, generation stops at length 1.
        let mut s = sched(1);
        s.admit(GenRequest::greedy(0, vec![1, 2], 10));
        let tok = s.run_to_completion()[0].tokens[0];
        let mut s2 = sched(1);
        s2.policy.eos = Some(tok);
        s2.admit(GenRequest::greedy(0, vec![1, 2], 10));
        let out = s2.run_to_completion();
        assert_eq!(out[0].tokens.len(), 1);
    }

    #[test]
    fn prop_random_loads_complete() {
        run_prop(
            "scheduler-completes",
            0xC0DE,
            8,
            &USize { lo: 1, hi: 12 },
            |&n| {
                let mut s = sched(3);
                for id in 0..n as u64 {
                    s.admit(GenRequest::greedy(
                        id,
                        vec![(id as u32 % 50) + 1, 2],
                        1 + (id as usize % 5),
                    ));
                }
                let out = s.run_to_completion();
                if out.len() != n {
                    return Err(format!("{n} admitted, {} finished", out.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stats_track_occupancy() {
        let mut s = sched(4);
        for id in 0..4u64 {
            s.admit(GenRequest::greedy(id, vec![1], 4));
        }
        s.run_to_completion();
        assert!(s.steps_executed > 0);
        let occ = s.batched_tokens as f64 / s.steps_executed as f64;
        assert!(occ > 1.0, "occupancy {occ} should exceed 1 with 4 concurrent requests");
    }

    /// Satellite regression: the TTFT stopwatch starts at submission, so
    /// queue wait is part of TTFT (the old code started it inside
    /// `start`, under-reporting TTFT by the whole queue delay).
    #[test]
    fn ttft_includes_queue_wait() {
        let mut s = sched(1);
        let sub = Submission::new(GenRequest::greedy(0, vec![1, 2], 2));
        std::thread::sleep(std::time::Duration::from_millis(15));
        s.admit_submission(sub);
        let out = s.run_to_completion();
        assert!(
            out[0].ttft_s >= 0.015,
            "ttft {} must include the 15ms pre-admission wait",
            out[0].ttft_s
        );

        // Saturated batch: with max_batch = 1, later requests wait for
        // every earlier generation, so TTFT grows with queue position (it
        // would be flat at ~prefill time under the old accounting).
        let mut s = sched(1);
        for id in 0..4u64 {
            s.admit(GenRequest::greedy(id, vec![1, 2, 3], 6));
        }
        let mut out = s.run_to_completion();
        out.sort_by_key(|r| r.id);
        for w in out.windows(2) {
            assert!(
                w[1].ttft_s >= w[0].ttft_s,
                "ttft must be monotone in queue position: {} then {}",
                w[0].ttft_s,
                w[1].ttft_s
            );
        }
        assert!(
            out[3].ttft_s > out[0].total_s * 0.5,
            "last ttft {} must reflect waiting behind earlier generations ({})",
            out[3].ttft_s,
            out[0].total_s
        );
    }

    /// Satellite: the prefill chunk cap changes *scheduling*, not
    /// results — greedy tokens are identical whether a prompt prefills
    /// in one pass or in 3-position chunks.
    #[test]
    fn chunked_prefill_matches_unchunked_tokens() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 24);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let prompts: Vec<Vec<u32>> = vec![
            (0..37u32).map(|i| i % 60).collect(),
            vec![9, 8, 7],
            (0..20u32).map(|i| (i * 3) % 60).collect(),
        ];
        let run = |chunk: usize| -> Vec<Vec<u32>> {
            let mut s = Scheduler::new(
                model.clone(),
                BatchPolicy { max_batch: 2, prefill_chunk: chunk, ..BatchPolicy::default() },
                1,
            );
            for (i, p) in prompts.iter().enumerate() {
                s.admit(GenRequest::greedy(i as u64, p.clone(), 5));
            }
            let mut out = s.run_to_completion();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect()
        };
        assert_eq!(run(3), run(1000), "chunk cap must not change tokens");
    }

    /// Satellite: a long prompt no longer stalls a co-batched decode —
    /// the short request finishes while the long prompt is still
    /// prefilling chunk by chunk.
    #[test]
    fn long_prefill_interleaves_with_decode() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 25);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 2, prefill_chunk: 2, ..BatchPolicy::default() },
            1,
        );
        // Short request first so it occupies a decode slot, then a
        // 40-position prompt that needs 20 chunks.
        s.admit(GenRequest::greedy(0, vec![1, 2], 3));
        let long: Vec<u32> = (0..40u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(1, long, 2));
        let mut short_done_while_long_prefilling = false;
        while s.pending() > 0 {
            let outs = s.step();
            if outs.iter().any(|o| o.id() == 0) && s.prefilling_ids().contains(&1) {
                short_done_while_long_prefilling = true;
            }
            // A prefilling sequence owns a batch slot but never a decode
            // slot.
            assert!(!s.active_ids().contains(&1) || s.prefilling_ids().is_empty());
        }
        assert!(
            short_done_while_long_prefilling,
            "the short decode must complete while the long prompt is still prefilling"
        );
    }

    /// Cancelling a sequence mid-prefill releases its slot and settles
    /// it with no generated tokens.
    #[test]
    fn cancel_during_prefill_settles_empty() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 26);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 1, prefill_chunk: 2, ..BatchPolicy::default() },
            1,
        );
        let long: Vec<u32> = (0..30u32).map(|i| i % 60).collect();
        let sub = Submission::new(GenRequest::greedy(0, long, 5));
        let flag = sub.cancel_flag();
        s.admit_submission(sub);
        s.step(); // first chunk ran; still prefilling
        assert_eq!(s.prefilling_ids(), vec![0]);
        flag.store(true, Ordering::SeqCst);
        let mut saw = false;
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::Cancelled { id, tokens } => {
                        assert_eq!(id, 0);
                        assert!(tokens.is_empty(), "no tokens were generated");
                        saw = true;
                    }
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
        }
        assert!(saw, "prefilling cancel must settle exactly once");
    }

    #[test]
    fn cancelled_active_leaves_batch() {
        let mut s = sched(2);
        let sub = Submission::new(GenRequest::greedy(0, vec![1, 2], 50));
        let flag = sub.cancel_flag();
        s.admit_submission(sub);
        s.admit(GenRequest::greedy(1, vec![3], 4));
        let first = s.step(); // both admitted + one decode step
        assert!(first.is_empty(), "nothing terminal yet: {first:?}");
        flag.store(true, Ordering::SeqCst);
        let mut cancelled = 0;
        let mut done = Vec::new();
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::Done(r) => done.push(r),
                    Outcome::Cancelled { id, tokens } => {
                        cancelled += 1;
                        assert_eq!(id, 0);
                        assert!(!tokens.is_empty(), "one step ran before the cancel");
                    }
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
            // Never occupies a batch slot after the boundary sweep.
            assert!(!s.active_ids().contains(&0));
        }
        assert_eq!(cancelled, 1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].tokens.len(), 4, "survivor unaffected by the cancel");
    }

    #[test]
    fn cancelled_queued_never_prefills() {
        let mut s = sched(1);
        s.admit(GenRequest::greedy(0, vec![1], 30)); // holds the only slot
        let sub = Submission::new(GenRequest::greedy(1, vec![2], 30));
        let flag = sub.cancel_flag();
        s.admit_submission(sub);
        s.step();
        flag.store(true, Ordering::SeqCst);
        let mut saw = false;
        while s.pending() > 0 {
            for o in s.step() {
                if let Outcome::Cancelled { id, tokens } = o {
                    assert_eq!(id, 1);
                    assert!(tokens.is_empty(), "queued cancel must not generate");
                    saw = true;
                }
            }
        }
        assert!(saw, "queued request must still emit its terminal outcome");
    }

    /// Property: under random loads with random cancellations, every
    /// submitted request yields exactly one terminal outcome.
    #[test]
    fn prop_cancels_terminate_exactly_once() {
        run_prop(
            "cancel-terminates-once",
            0xCAFE,
            6,
            &USize { lo: 1, hi: 10 },
            |&n| {
                let mut s = sched(3);
                let mut flags = Vec::new();
                for id in 0..n as u64 {
                    let sub = Submission::new(GenRequest::greedy(
                        id,
                        vec![(id as u32 % 50) + 1],
                        2 + (id as usize % 4),
                    ));
                    flags.push(sub.cancel_flag());
                    s.admit_submission(sub);
                }
                let mut terminals = vec![0usize; n];
                for o in s.step() {
                    terminals[o.id() as usize] += 1;
                }
                // Cancel every third request after the first step — some
                // will be active, some queued, some already done.
                for (id, f) in flags.iter().enumerate() {
                    if id % 3 == 0 {
                        f.store(true, Ordering::SeqCst);
                    }
                }
                while s.pending() > 0 {
                    for o in s.step() {
                        terminals[o.id() as usize] += 1;
                    }
                }
                for (id, &c) in terminals.iter().enumerate() {
                    if c != 1 {
                        return Err(format!("req {id} got {c} terminal outcomes"));
                    }
                }
                Ok(())
            },
        );
    }

    /// A queued request whose queue deadline expires settles TimedOut
    /// with no tokens and never touches the model.
    #[test]
    fn queue_deadline_times_out_queued_request() {
        let mut s = sched(1);
        s.admit(GenRequest::greedy(0, vec![1], 30)); // holds the only slot
        s.admit_submission(Submission::new(
            GenRequest::greedy(1, vec![2], 30).with_queue_deadline(Duration::from_millis(5)),
        ));
        s.step();
        std::thread::sleep(Duration::from_millis(10));
        let mut saw = false;
        while s.pending() > 0 {
            for o in s.step() {
                if let Outcome::TimedOut { id, tokens } = o {
                    assert_eq!(id, 1);
                    assert!(tokens.is_empty(), "never admitted, so no tokens");
                    saw = true;
                }
            }
        }
        assert!(saw, "expired queued request must settle TimedOut");
        assert_eq!(s.timed_out, 1);
    }

    /// A total deadline expiring mid-generation evicts the sequence and
    /// returns the tokens generated so far.
    #[test]
    fn total_deadline_evicts_active_sequence() {
        let mut s = sched(2);
        s.admit_submission(Submission::new(
            GenRequest::greedy(0, vec![1, 2], 10_000)
                .with_total_deadline(Duration::from_millis(20)),
        ));
        let mut tokens_at_timeout = None;
        let t = Timer::start();
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::TimedOut { id, tokens } => {
                        assert_eq!(id, 0);
                        tokens_at_timeout = Some(tokens);
                    }
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
            assert!(
                t.elapsed() < Duration::from_secs(30),
                "deadline must evict the sequence long before the token budget"
            );
        }
        let toks = tokens_at_timeout.expect("sequence must settle TimedOut");
        assert!(!toks.is_empty(), "generation had started before expiry");
        assert!(s.active_ids().is_empty());
    }

    /// A step failpoint panic unwinds through `step`; `take_inflight`
    /// then reclaims every in-flight submission with its partial tokens,
    /// leaving the scheduler empty (KV caches released).
    #[test]
    fn panic_unwinds_and_take_inflight_reclaims() {
        let fp = FailPoints::new();
        let mut s = sched(4).with_failpoints(Arc::clone(&fp), 0);
        for id in 0..3u64 {
            s.admit(GenRequest::greedy(id, vec![(id as u32) + 1], 20));
        }
        s.step(); // all three admitted + first decode
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::panic_on_hit(1));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| s.step()));
        assert!(r.is_err(), "armed step failpoint must panic");
        let inflight = s.take_inflight();
        let mut ids: Vec<u64> = inflight.iter().map(|(sub, _)| sub.id()).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2]);
        for (_, tokens) in &inflight {
            assert!(!tokens.is_empty(), "one decode step ran before the panic");
        }
        assert_eq!(s.pending(), 0, "scheduler fully drained after reclaim");
    }

    /// Tentpole: a second identical prompt adopts the committed prefix
    /// pages (no recompute, counted in `prefix_hits`) and still produces
    /// identical greedy tokens.
    #[test]
    fn identical_prompts_share_prefix_pages() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 27);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 1, kv_page_size: 4, ..BatchPolicy::default() },
            1,
        );
        let prompt: Vec<u32> = (0..10u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(0, prompt.clone(), 4));
        let first = s.run_to_completion().pop().unwrap().tokens;
        assert_eq!(s.prefix_hits, 0, "nothing committed before the first prefill");
        s.admit(GenRequest::greedy(1, prompt, 4));
        let second = s.run_to_completion().pop().unwrap().tokens;
        assert_eq!(s.prefix_hits, 2, "a 10-token prompt shares two 4-position pages");
        assert_eq!(first, second, "adopted prefix pages must not change tokens");
    }

    /// Tentpole: with a pool too small for two sequences, admission
    /// preempts the youngest bulk decode instead of stalling, and the
    /// parked sequence later resumes — both finish with full budgets.
    #[test]
    fn tiny_pool_preempts_bulk_and_completes() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 28);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy {
                max_batch: 2,
                kv_page_size: 4,
                kv_pool_pages: 3,
                ..BatchPolicy::default()
            },
            1,
        );
        s.admit(GenRequest::greedy(0, vec![1, 2, 3, 4, 5], 6).with_priority(Priority::Bulk));
        s.admit(GenRequest::greedy(1, vec![9, 8, 7, 6, 5], 6));
        let mut out = s.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2, "both must finish despite pool pressure");
        assert_eq!(out[0].tokens.len(), 6);
        assert_eq!(out[1].tokens.len(), 6);
        assert!(
            s.preemptions > 0,
            "a 3-page pool cannot hold two 5-token prompts at once"
        );
    }

    /// Preemption changes scheduling, not results: greedy tokens after a
    /// park/resume cycle are identical to an undisturbed run.
    #[test]
    fn pool_failpoint_forces_preemption_and_resume_is_exact() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 29);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut undisturbed = Scheduler::new(model.clone(), BatchPolicy::default(), 1);
        undisturbed.admit(GenRequest::greedy(0, vec![1, 2], 8).with_priority(Priority::Bulk));
        let want = undisturbed.run_to_completion().pop().unwrap().tokens;

        let fp = FailPoints::new();
        let mut s =
            Scheduler::new(model, BatchPolicy::default(), 1).with_failpoints(Arc::clone(&fp), 0);
        s.admit(GenRequest::greedy(0, vec![1, 2], 8).with_priority(Priority::Bulk));
        s.step(); // admitted; prefill + first decode ran
        fp.arm_tagged(failpoint::POOL, 0, FailSpec::deny(1));
        s.step(); // deny fires: the only (bulk) sequence parks
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.preempted_ids(), vec![0]);
        let out = s.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, want, "park/resume must not change tokens");
    }

    /// A request whose KV footprint cannot fit even an empty pool
    /// settles `Failed` (exactly once) instead of spinning forever.
    #[test]
    fn oversized_request_fails_terminally() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 30);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy {
                max_batch: 2,
                kv_page_size: 4,
                kv_pool_pages: 2,
                ..BatchPolicy::default()
            },
            1,
        );
        // 12 positions = 3 pages > the whole 2-page pool.
        let long: Vec<u32> = (0..12u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(0, long, 4));
        let mut failed = 0;
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::Failed { id, error } => {
                        assert_eq!(id, 0);
                        assert!(error.contains("pool exhausted"), "{error}");
                        failed += 1;
                    }
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
        }
        assert_eq!(failed, 1, "oversized request settles Failed exactly once");
        assert_eq!(s.kv_pool().used(), 0, "no pages leak from the failed prefill");
    }

    /// Tentpole: prefix tries are tenant-scoped — an identical prompt
    /// from a different tenant adopts nothing (no cross-tenant page
    /// sharing, no `prefix_hits` timing leak), while a same-tenant
    /// repeat still hits.
    #[test]
    fn cross_tenant_prompts_never_share_prefix_pages() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 32);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 1, kv_page_size: 4, ..BatchPolicy::default() },
            1,
        );
        let prompt: Vec<u32> = (0..10u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(0, prompt.clone(), 4).with_tenant(1));
        let first = s.run_to_completion().pop().unwrap().tokens;
        s.admit(GenRequest::greedy(1, prompt.clone(), 4).with_tenant(2));
        let second = s.run_to_completion().pop().unwrap().tokens;
        assert_eq!(s.prefix_hits, 0, "tenant 2 must not adopt tenant 1's pages");
        assert_eq!(first, second, "isolation must not change tokens");
        s.admit(GenRequest::greedy(2, prompt, 4).with_tenant(1));
        s.run_to_completion();
        assert_eq!(s.prefix_hits, 2, "a same-tenant repeat still shares two pages");
    }

    /// Tentpole: a per-tenant quota binds before pool capacity — the
    /// offending tenant's oversized request fails terminally while
    /// another tenant's request sails through, and no pages leak.
    #[test]
    fn tenant_quota_fails_only_offending_tenant() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 33);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy {
                max_batch: 2,
                kv_page_size: 4,
                kv_pool_pages: 8,
                tenant_quota_pages: 2,
                ..BatchPolicy::default()
            },
            1,
        );
        // 12 positions = 3 pages > the 2-page tenant quota (the pool
        // itself has room for 8).
        let long: Vec<u32> = (0..12u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(0, long, 4).with_tenant(1));
        s.admit(GenRequest::greedy(1, vec![5, 6, 7], 4).with_tenant(2));
        let mut failed = 0;
        let mut done = Vec::new();
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::Failed { id, error } => {
                        assert_eq!(id, 0);
                        assert!(error.contains("quota"), "{error}");
                        failed += 1;
                    }
                    Outcome::Done(r) => done.push(r),
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
        }
        assert_eq!(failed, 1, "over-quota request settles Failed exactly once");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].tenant, Some(2));
        assert_eq!(s.kv_pool().used_by(1), 0, "the failed prefill returned its pages");
        assert_eq!(s.kv_pool().used(), 0, "nothing leaks after the drain");
    }

    /// Tentpole: forced preemption parks the youngest bulk sequence of
    /// the *heaviest* tenant (fair share), not the globally youngest —
    /// the light tenant's newer sequence survives.
    #[test]
    fn preemption_is_fair_share_across_tenants() {
        let fp = FailPoints::new();
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 34);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s = Scheduler::new(
            model,
            BatchPolicy { max_batch: 2, kv_page_size: 4, ..BatchPolicy::default() },
            1,
        )
        .with_failpoints(Arc::clone(&fp), 0);
        // Tenant 1 holds three pages (9-token prompt), tenant 2 one —
        // and tenant 2's sequence is the younger of the two.
        let long: Vec<u32> = (0..9u32).map(|i| i % 60).collect();
        s.admit(GenRequest::greedy(0, long, 20).with_tenant(1).with_priority(Priority::Bulk));
        s.admit(GenRequest::greedy(1, vec![1, 2], 20).with_tenant(2).with_priority(Priority::Bulk));
        s.step(); // both admitted and decoding
        assert_eq!(s.active_ids().len(), 2);
        fp.arm_tagged(failpoint::POOL, 0, FailSpec::deny(1));
        s.step(); // synthetic pressure: one fair-share preemption round
        assert_eq!(
            s.preempted_ids(),
            vec![0],
            "the heavy tenant's sequence parks, not the globally youngest"
        );
    }

    /// Cancelling a parked sequence settles it with the tokens it had
    /// generated before preemption.
    #[test]
    fn cancel_while_preempted_settles_with_partial_tokens() {
        let fp = FailPoints::new();
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 31);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut s =
            Scheduler::new(model, BatchPolicy::default(), 1).with_failpoints(Arc::clone(&fp), 0);
        let sub = Submission::new(
            GenRequest::greedy(0, vec![1, 2], 20).with_priority(Priority::Bulk),
        );
        let flag = sub.cancel_flag();
        s.admit_submission(sub);
        s.step();
        fp.arm_tagged(failpoint::POOL, 0, FailSpec::deny(1));
        s.step();
        assert_eq!(s.preempted_ids(), vec![0]);
        flag.store(true, Ordering::SeqCst);
        let mut saw = false;
        while s.pending() > 0 {
            for o in s.step() {
                match o {
                    Outcome::Cancelled { id, tokens } => {
                        assert_eq!(id, 0);
                        assert!(!tokens.is_empty(), "tokens from before the park survive");
                        saw = true;
                    }
                    other => panic!("unexpected outcome {other:?}"),
                }
            }
        }
        assert!(saw, "parked cancel must settle exactly once");
    }
}
