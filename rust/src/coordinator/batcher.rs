//! Continuous dynamic batching scheduler.
//!
//! Pure state machine (no threads) so it is unit-testable: the server
//! drives it with `admit` / `step`. Invariants (property-tested):
//! every admitted request finishes exactly once, no token is generated
//! after `max_new_tokens`, and the running batch never exceeds `max_batch`.

use super::{GenRequest, GenResponse};
use crate::model::transformer::{ForwardScratch, KvCache, Transformer};
use crate::util::prng::Rng;
use crate::util::timer::Timer;
use std::borrow::BorrowMut;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum sequences decoded together.
    pub max_batch: usize,
    /// Optional token id that terminates a sequence early.
    pub eos: Option<u32>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            eos: None,
        }
    }
}

struct Active {
    req: GenRequest,
    cache: KvCache,
    generated: Vec<u32>,
    next_token: u32,
    admitted: Timer,
    ttft_s: Option<f64>,
    steps: usize,
}

impl BorrowMut<KvCache> for Active {
    fn borrow_mut(&mut self) -> &mut KvCache {
        &mut self.cache
    }
}

impl std::borrow::Borrow<KvCache> for Active {
    fn borrow(&self) -> &KvCache {
        &self.cache
    }
}

/// Continuous-batching scheduler bound to one model replica. Owns one
/// [`ForwardScratch`], so steady-state decode steps perform no heap
/// allocation (caches are decoded in place — no per-step cache churn).
pub struct Scheduler {
    model: Transformer,
    policy: BatchPolicy,
    queue: VecDeque<GenRequest>,
    active: Vec<Active>,
    rng: Rng,
    scratch: ForwardScratch,
    /// Reused per-step token staging buffer.
    tok_buf: Vec<u32>,
    pub steps_executed: u64,
    pub batched_tokens: u64,
}

impl Scheduler {
    pub fn new(model: Transformer, policy: BatchPolicy, seed: u64) -> Scheduler {
        Scheduler {
            model,
            policy,
            queue: VecDeque::new(),
            active: Vec::new(),
            rng: Rng::new(seed),
            scratch: ForwardScratch::new(),
            tok_buf: Vec::new(),
            steps_executed: 0,
            batched_tokens: 0,
        }
    }

    pub fn model(&self) -> &Transformer {
        &self.model
    }

    /// Enqueue a request (admission happens at the next step boundary).
    pub fn admit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Prefill a request's prompt and move it into the running batch.
    /// Prompt tokens run through the single-token path (a serving system
    /// would use a chunked prefill; our prompts are short).
    fn start(&mut self, req: GenRequest) {
        let mut cache = self.model.new_cache();
        let timer = Timer::start();
        assert!(
            !req.prompt.is_empty(),
            "empty prompt: nothing to condition on"
        );
        let mut logits: &[f32] = &[];
        for (pos, &t) in req.prompt.iter().enumerate() {
            logits = self.model.forward_with(t, pos, &mut cache, &mut self.scratch);
        }
        let first = req.sampler.sample(logits, &mut self.rng);
        self.active.push(Active {
            req,
            cache,
            generated: vec![first],
            next_token: first,
            admitted: timer,
            ttft_s: None,
            steps: 1,
        });
        let a = self.active.last_mut().unwrap();
        a.ttft_s = Some(a.admitted.elapsed_secs());
    }

    /// One scheduler iteration: admit up to capacity, run one batched
    /// decode step, retire finished sequences. Returns responses finished
    /// in this step.
    pub fn step(&mut self) -> Vec<GenResponse> {
        // Admission.
        while self.active.len() < self.policy.max_batch {
            match self.queue.pop_front() {
                Some(r) => self.start(r),
                None => break,
            }
        }
        let mut done = Vec::new();
        if self.active.is_empty() {
            return done;
        }
        // Retire sequences that already satisfied their budget (including
        // single-token generations) before spending a decode step on them.
        self.retire(&mut done);
        if self.active.is_empty() {
            return done;
        }

        self.tok_buf.clear();
        self.tok_buf.extend(self.active.iter().map(|a| a.next_token));
        // Caches are decoded in place through `Active: BorrowMut<KvCache>`
        // — no per-step cache extraction/replacement (the old path
        // allocated two full KV caches per sequence per step).
        let logits = self
            .model
            .forward_batch_with(&self.tok_buf, &mut self.active, &mut self.scratch);
        self.steps_executed += 1;
        self.batched_tokens += self.tok_buf.len() as u64;
        for (i, a) in self.active.iter_mut().enumerate() {
            let t = a.req.sampler.sample(logits.row(i), &mut self.rng);
            a.generated.push(t);
            a.next_token = t;
            a.steps += 1;
        }
        self.retire(&mut done);
        done
    }

    fn retire(&mut self, done: &mut Vec<GenResponse>) {
        let eos = self.policy.eos;
        let cfg_max = self.model.cfg.max_seq;
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let hit_eos = eos.map(|e| a.generated.last() == Some(&e)).unwrap_or(false);
            let budget = a.generated.len() >= a.req.max_new_tokens;
            let ctx_full = a.req.prompt.len() + a.generated.len() >= cfg_max;
            if hit_eos || budget || ctx_full {
                let a = self.active.swap_remove(i);
                done.push(GenResponse {
                    id: a.req.id,
                    tokens: a.generated,
                    ttft_s: a.ttft_s.unwrap_or(0.0),
                    total_s: a.admitted.elapsed_secs(),
                    steps: a.steps,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Drive to completion, returning all responses.
    pub fn run_to_completion(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;
    use crate::util::proptest::{run_prop, USize};

    fn sched(max_batch: usize) -> Scheduler {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 21);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        Scheduler::new(
            model,
            BatchPolicy {
                max_batch,
                eos: None,
            },
            7,
        )
    }

    #[test]
    fn single_request_completes() {
        let mut s = sched(4);
        s.admit(GenRequest::greedy(1, vec![1, 2, 3], 5));
        let out = s.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].tokens.len(), 5);
    }

    #[test]
    fn all_requests_finish_exactly_once() {
        let mut s = sched(3);
        for id in 0..10u64 {
            s.admit(GenRequest::greedy(id, vec![(id % 60) as u32 + 1], 3 + (id as usize % 4)));
        }
        let out = s.run_to_completion();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        for r in &out {
            let want = 3 + (r.id as usize % 4);
            assert_eq!(r.tokens.len(), want, "req {}", r.id);
        }
    }

    #[test]
    fn batch_occupancy_bounded() {
        let mut s = sched(2);
        for id in 0..6u64 {
            s.admit(GenRequest::greedy(id, vec![1, 2], 4));
        }
        while s.pending() > 0 {
            s.step();
            assert!(s.active.len() <= 2);
        }
    }

    #[test]
    fn batched_equals_sequential_greedy() {
        // Greedy decoding must be identical whether requests are served
        // alone or continuously batched.
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 22);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8], vec![4], vec![5, 6, 7, 8]];

        let mut solo_out = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let mut s = Scheduler::new(model.clone(), BatchPolicy::default(), 1);
            s.admit(GenRequest::greedy(i as u64, p.clone(), 6));
            solo_out.push(s.run_to_completion().pop().unwrap().tokens);
        }

        let mut s = Scheduler::new(model, BatchPolicy { max_batch: 4, eos: None }, 1);
        for (i, p) in prompts.iter().enumerate() {
            s.admit(GenRequest::greedy(i as u64, p.clone(), 6));
        }
        let mut batched = s.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, solo_out[i], "req {i}");
        }
    }

    #[test]
    fn eos_stops_early() {
        // With eos = the greedy first token, generation stops at length 1.
        let mut s = sched(1);
        s.admit(GenRequest::greedy(0, vec![1, 2], 10));
        let tok = s.run_to_completion()[0].tokens[0];
        let mut s2 = sched(1);
        s2.policy.eos = Some(tok);
        s2.admit(GenRequest::greedy(0, vec![1, 2], 10));
        let out = s2.run_to_completion();
        assert_eq!(out[0].tokens.len(), 1);
    }

    #[test]
    fn prop_random_loads_complete() {
        run_prop(
            "scheduler-completes",
            0xC0DE,
            8,
            &USize { lo: 1, hi: 12 },
            |&n| {
                let mut s = sched(3);
                for id in 0..n as u64 {
                    s.admit(GenRequest::greedy(
                        id,
                        vec![(id as u32 % 50) + 1, 2],
                        1 + (id as usize % 5),
                    ));
                }
                let out = s.run_to_completion();
                if out.len() != n {
                    return Err(format!("{n} admitted, {} finished", out.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stats_track_occupancy() {
        let mut s = sched(4);
        for id in 0..4u64 {
            s.admit(GenRequest::greedy(id, vec![1], 4));
        }
        s.run_to_completion();
        assert!(s.steps_executed > 0);
        let occ = s.batched_tokens as f64 / s.steps_executed as f64;
        assert!(occ > 1.0, "occupancy {occ} should exceed 1 with 4 concurrent requests");
    }
}
