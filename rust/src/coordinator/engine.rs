//! The [`Engine`] serving facade: bounded admission, replica dispatch,
//! streaming per-request handles, cancellation, and fault tolerance.
//!
//! One worker thread per replica owns a [`Scheduler`] and drains a
//! *bounded* request channel: [`Engine::submit`] blocks when the queue is
//! full (admission control), [`Engine::try_submit`] surfaces
//! [`EngineError::QueueFull`] so callers can shed load instead. Every
//! accepted request gets a [`RequestHandle`] streaming [`Event`]s over its
//! own channel; `cancel()` flips a shared flag the scheduler observes at
//! the next step boundary (the sequence leaves the batch, its KV cache is
//! freed) and the cancel-aware [`AdmissionQueue`] observes on its next
//! touch (a cancelled-but-still-queued request releases its capacity
//! slot immediately instead of squatting until dequeue). Replica choice
//! is an internal [`DispatchPolicy`] — least-outstanding (the
//! vllm-router default) or round-robin — and both route around
//! unhealthy replicas.
//!
//! **Supervision.** Each worker's serve loop runs under `catch_unwind`.
//! On a panic the supervisor marks the replica unhealthy, reclaims every
//! in-flight submission from the unwound scheduler
//! ([`Scheduler::take_inflight`]) and settles each with a terminal
//! event: cancelled requests settle `Cancelled`, idempotent requests
//! (zero tokens emitted, never retried before) are re-dispatched once to
//! a healthy replica, and everything else settles [`Event::Failed`].
//! The worker then restarts with capped exponential backoff and marks
//! itself healthy again. The exactly-one-terminal-event invariant holds
//! across the unwind: outcomes emitted before the panic had already left
//! scheduler state, so they cannot be settled twice.

use super::batcher::{BatchPolicy, Outcome, OutstandingGuard, SchedObs, Scheduler, Submission};
use super::failpoint::FailPoints;
use super::queue::{AdmissionQueue, TryPushError};
use super::{Event, GenRequest, GenResponse, ServeStats};
use crate::kv::KvGauges;
use crate::model::transformer::Transformer;
use crate::obs::{
    kernels, names, FaultSection, HistStat, Histogram, KvSection, MetricsRegistry,
    MetricsSnapshot, ServeSection, SpanKind, SpecSection, TraceSection, TraceSink,
    DEFAULT_RING_CAP,
};
use crate::util::metrics::{FaultCounters, FaultMeter};
use crate::util::timer::Timer;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// Errors surfaced by the submission paths. Every variant hands the
/// request back so the caller can retry, re-route or drop it.
#[derive(Debug)]
pub enum EngineError {
    /// The selected replica's bounded queue is full (backpressure).
    QueueFull(GenRequest),
    /// A bulk request was shed to keep the interactive reserve free
    /// (priority-aware load shedding; interactive submissions may still
    /// be accepted).
    Overloaded(GenRequest),
    /// The engine is shutting down; no replica accepts work.
    Shutdown(GenRequest),
    /// The request can never be served (e.g. empty prompt) — rejected at
    /// submission rather than poisoning a replica worker.
    InvalidRequest(GenRequest, &'static str),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::QueueFull(r) => write!(f, "queue full (request {})", r.id),
            EngineError::Overloaded(r) => {
                write!(f, "overloaded: bulk request {} shed", r.id)
            }
            EngineError::Shutdown(r) => write!(f, "engine shut down (request {})", r.id),
            EngineError::InvalidRequest(r, why) => {
                write!(f, "invalid request {}: {why}", r.id)
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// How [`Engine::submit`] picks a replica. Both policies skip unhealthy
/// replicas (a replica is unhealthy between a panic and the completion
/// of its restart); if every replica is unhealthy they fall back to the
/// plain choice — queues stay open during a restart, so the request is
/// served once the worker is back.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Fewest outstanding requests, ties broken by replica index.
    #[default]
    LeastOutstanding,
    /// Strict rotation, ignoring load.
    RoundRobin,
}

/// State shared between the engine facade, one replica worker, and the
/// request handles it issued.
struct ReplicaShared {
    queue: AdmissionQueue,
    /// Requests dispatched here and not yet settled (guard-counted, so
    /// exact across every settle path including panics).
    outstanding: Arc<AtomicUsize>,
    /// False between a worker panic and the completion of its restart;
    /// dispatch routes around unhealthy replicas.
    healthy: AtomicBool,
}

/// Streaming handle to one submitted request.
///
/// Events arrive in order: `Queued`, `FirstToken`, then `Token`s, ending
/// with exactly one terminal event (`Done`, `Cancelled`, `TimedOut` or
/// `Failed`). Dropping the handle detaches the stream but does **not**
/// cancel the request — call [`RequestHandle::cancel`], or opt in to
/// [`RequestHandle::cancel_on_drop`] so abandoned streams reclaim their
/// batch slot and KV cache automatically.
pub struct RequestHandle {
    id: u64,
    rx: mpsc::Receiver<Event>,
    cancel: Arc<AtomicBool>,
    /// The replica this request was dispatched to; its admission queue
    /// is nudged on cancel so a cancelled still-queued request frees its
    /// capacity slot for blocked producers immediately.
    shared: Arc<ReplicaShared>,
    finished: bool,
    cancel_on_drop: bool,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Opt in to drop-cancellation: if this handle is dropped before the
    /// request settles, the request is cancelled as if
    /// [`RequestHandle::cancel`] had been called — the scheduler drops
    /// the sequence at its next step boundary and frees its KV cache, so
    /// abandoned streams (client went away, timeout paths, early `?`
    /// returns) never keep decoding. Consuming builder style:
    /// `engine.submit(req)?.cancel_on_drop()`.
    pub fn cancel_on_drop(mut self) -> Self {
        self.cancel_on_drop = true;
        self
    }

    /// Ask the scheduler to drop this request at its next step boundary.
    /// The stream still ends with a terminal event (`Cancelled`, or `Done`
    /// if the request won the race by finishing first). A request still
    /// waiting in the bounded admission queue releases its capacity slot
    /// as soon as the queue is next touched (it settles as `Cancelled`
    /// without ever prefilling).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
        // Release a still-queued request's capacity slot right away and
        // wake any producer blocked on the full queue.
        self.shared.queue.nudge();
    }

    /// Blocking receive of the next lifecycle event. Returns `None` after
    /// the terminal event has been delivered (or if the engine vanished).
    pub fn next_event(&mut self) -> Option<Event> {
        if self.finished {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                self.finished = ev.is_terminal();
                Some(ev)
            }
            Err(_) => {
                self.finished = true;
                None
            }
        }
    }

    /// Bounded-wait variant of [`RequestHandle::next_event`]: blocks at
    /// most `timeout`, so a caller never hangs on a wedged stream (e.g.
    /// a replica stalled mid-forward). `None` can mean "nothing within
    /// the timeout" or "stream over" — check
    /// [`RequestHandle::is_finished`] to tell them apart.
    pub fn next_event_timeout(&mut self, timeout: Duration) -> Option<Event> {
        if self.finished {
            return None;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.finished = ev.is_terminal();
                Some(ev)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.finished = true;
                None
            }
        }
    }

    /// Non-blocking variant of [`RequestHandle::next_event`]. A `None`
    /// can mean "no event yet" or "stream over" — check
    /// [`RequestHandle::is_finished`] to tell them apart.
    pub fn try_next_event(&mut self) -> Option<Event> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                self.finished = ev.is_terminal();
                Some(ev)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.finished = true;
                None
            }
        }
    }

    /// True once the terminal event has been delivered (or the stream
    /// disconnected) — no further events will ever arrive.
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Drain the stream to its terminal event. `Some(response)` when the
    /// request completed, `None` when it was cancelled, timed out,
    /// failed, or the engine disappeared mid-flight.
    pub fn wait(mut self) -> Option<GenResponse> {
        while let Some(ev) = self.next_event() {
            if let Event::Done(r) = ev {
                return Some(r);
            }
        }
        None
    }

    /// Bounded [`RequestHandle::wait`]: drain toward the terminal event
    /// for at most `timeout` overall. `Ok` carries the usual wait result;
    /// `Err` hands the handle back un-finished so the caller can keep
    /// waiting, cancel, or abandon it.
    pub fn wait_timeout(mut self, timeout: Duration) -> Result<Option<GenResponse>, RequestHandle> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            match self.next_event_timeout(remaining) {
                Some(Event::Done(r)) => return Ok(Some(r)),
                Some(ev) if ev.is_terminal() => return Ok(None),
                Some(_) => {}
                None if self.finished => return Ok(None),
                None => return Err(self),
            }
        }
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        // `finished` is only set once the terminal event was delivered,
        // so an opted-in drop before that point requests cancellation
        // (a no-op race if the request wins by completing first).
        if self.cancel_on_drop && !self.finished {
            self.cancel.store(true, Ordering::SeqCst);
            self.shared.queue.nudge();
        }
    }
}

/// Configures and builds an [`Engine`].
pub struct EngineBuilder {
    replicas: usize,
    batch: BatchPolicy,
    dispatch: DispatchPolicy,
    queue_capacity: usize,
    interactive_reserve: Option<usize>,
    seed: u64,
    retry_idempotent: bool,
    backoff_base: Duration,
    backoff_cap: Duration,
    failpoints: Arc<FailPoints>,
    trace_ring_cap: usize,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            replicas: 1,
            batch: BatchPolicy::default(),
            dispatch: DispatchPolicy::default(),
            queue_capacity: 64,
            interactive_reserve: None,
            seed: 0,
            retry_idempotent: true,
            backoff_base: Duration::from_millis(20),
            backoff_cap: Duration::from_millis(500),
            failpoints: FailPoints::new(),
            trace_ring_cap: DEFAULT_RING_CAP,
        }
    }
}

impl EngineBuilder {
    /// Number of model replicas (worker threads); all share one
    /// `Arc`-held copy of the weights (read-only at serve time), so
    /// N-replica memory is ~1× the model. Default 1.
    pub fn replicas(mut self, n: usize) -> Self {
        assert!(n > 0, "at least one replica");
        self.replicas = n;
        self
    }

    /// Full batch policy for every replica's scheduler.
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.batch = policy;
        self
    }

    /// Maximum sequences decoded together per replica (default 8).
    pub fn max_batch(mut self, n: usize) -> Self {
        assert!(n > 0, "max_batch must be positive");
        self.batch.max_batch = n;
        self
    }

    /// Token id that terminates a sequence early.
    pub fn eos(mut self, token: u32) -> Self {
        self.batch.eos = Some(token);
        self
    }

    /// Prefill chunk cap in positions (default 128): longer prompts
    /// prefill one chunk per scheduler step, interleaved with the
    /// running batch's decode steps, so a long prompt cannot stall
    /// co-batched decodes.
    pub fn prefill_chunk(mut self, n: usize) -> Self {
        assert!(n > 0, "prefill chunk must be positive");
        self.batch.prefill_chunk = n;
        self
    }

    /// KV page size in token positions (default 16): the granularity of
    /// paged cache growth, copy-on-write forks, and prefix sharing
    /// (only whole-page prompt chunks are ever shared).
    pub fn kv_page_size(mut self, n: usize) -> Self {
        assert!(n > 0, "kv page size must be positive");
        self.batch.kv_page_size = n;
        self
    }

    /// Capacity of each replica's KV page pool. `0` (the default) sizes
    /// the pool for the worst case — `max_batch` sequences at full
    /// context — so nothing ever preempts. A smaller explicit value
    /// over-commits memory and relies on continuous batching: admission
    /// proceeds whenever pages are actually free, and exhaustion
    /// preempts the youngest bulk sequence instead of stalling
    /// interactive traffic.
    pub fn kv_pool_pages(mut self, n: usize) -> Self {
        self.batch.kv_pool_pages = n;
        self
    }

    /// Per-tenant KV page quota (default 0 = unlimited). With a quota
    /// set, each tenant's live pages — sequences plus cached prefix
    /// pages — are capped on every replica, so one tenant cannot starve
    /// the pool for the rest; quota-bound pressure only ever parks the
    /// offending tenant's own sequences.
    pub fn tenant_quota_pages(mut self, n: usize) -> Self {
        self.batch.tenant_quota_pages = n;
        self
    }

    /// Enable self-speculative decoding: greedy sequences draft tokens
    /// from the hi mantissa stream and verify them in one full-precision
    /// batched pass per round (token-identical to plain greedy decode;
    /// see [`crate::spec`]). Non-greedy samplers keep the plain path.
    pub fn speculative(mut self, yes: bool) -> Self {
        self.batch.spec.enabled = yes;
        self
    }

    /// Baseline speculative draft depth `k` (default 4). The adaptive
    /// controller floats each sequence's depth in `[1, 2k]` from its
    /// running acceptance rate.
    pub fn draft_depth(mut self, k: usize) -> Self {
        assert!(k > 0, "draft depth must be positive");
        self.batch.spec.draft_depth = k;
        self
    }

    /// Replica dispatch policy (default least-outstanding).
    pub fn dispatch(mut self, policy: DispatchPolicy) -> Self {
        self.dispatch = policy;
        self
    }

    /// Bound of each replica's pending-request queue (default 64):
    /// `submit` blocks and `try_submit` returns
    /// [`EngineError::QueueFull`] once a replica holds this many
    /// un-admitted requests.
    pub fn queue_capacity(mut self, n: usize) -> Self {
        assert!(n > 0, "queue capacity must be positive");
        self.queue_capacity = n;
        self
    }

    /// Queue slots reserved for interactive traffic: bulk submissions
    /// are shed ([`EngineError::Overloaded`]) once a replica's queue
    /// occupancy reaches `capacity - reserve`. Defaults to 1/8 of the
    /// capacity (at least one slot, when capacity permits).
    pub fn interactive_reserve(mut self, n: usize) -> Self {
        self.interactive_reserve = Some(n);
        self
    }

    /// Sampler seed; replica `i` uses `seed + i` so multi-replica runs
    /// stay deterministic per replica.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Whether a replica panic re-dispatches idempotent in-flight
    /// requests (zero tokens emitted, never retried before) to a
    /// healthy replica instead of failing them (default true). Each
    /// request is retried at most once, so a poison-pill request cannot
    /// crash-loop the fleet.
    pub fn retry_idempotent(mut self, yes: bool) -> Self {
        self.retry_idempotent = yes;
        self
    }

    /// Restart backoff after a worker panic: the n-th consecutive panic
    /// sleeps `base * 2^(n-1)`, capped at `cap`. Defaults 20 ms / 500 ms.
    pub fn restart_backoff(mut self, base: Duration, cap: Duration) -> Self {
        self.backoff_base = base;
        self.backoff_cap = cap;
        self
    }

    /// Wire a fault-injection registry through the engine: every
    /// replica's scheduler and admission queue hit its sites, tagged by
    /// replica index. Inert unless schedules are armed (and compiled to
    /// nothing without `cfg(any(test, feature = "failpoints"))`).
    pub fn failpoints(mut self, fp: Arc<FailPoints>) -> Self {
        self.failpoints = fp;
        self
    }

    /// Span-trace ring capacity per replica, in events (default
    /// [`DEFAULT_RING_CAP`]). When a ring fills, the oldest events are
    /// dropped and counted; the export degrades instead of growing
    /// without bound.
    pub fn trace_ring_cap(mut self, n: usize) -> Self {
        self.trace_ring_cap = n.max(1);
        self
    }

    /// Spawn the replica workers and return the engine. The model moves
    /// behind one `Arc`; every replica scheduler reads the same weights.
    pub fn build(self, model: Transformer) -> Engine {
        let registry = MetricsRegistry::new();
        let trace = TraceSink::new(self.replicas, self.trace_ring_cap);
        // TTFT/latency record through the registry's streaming
        // histograms — bounded memory, and one snapshot surface for the
        // CLI report, METRICS.json and the bench probes.
        let latency = registry.histogram(names::LATENCY);
        let ttft = registry.histogram(names::TTFT);
        let meter = Arc::new(FaultMeter::new());
        let kv_gauges = Arc::new(KvGauges::default());
        let max_seq = model.cfg.max_seq;
        let model = Arc::new(model);
        let reserve = self
            .interactive_reserve
            .unwrap_or_else(|| (self.queue_capacity / 8).max(1))
            // capacity 1 leaves no room for a reserve
            .min(self.queue_capacity.saturating_sub(1));
        let shared: Arc<Vec<Arc<ReplicaShared>>> = Arc::new(
            (0..self.replicas)
                .map(|i| {
                    Arc::new(ReplicaShared {
                        queue: AdmissionQueue::with_policy(
                            self.queue_capacity,
                            reserve,
                            Arc::clone(&self.failpoints),
                            i as u64,
                        ),
                        outstanding: Arc::new(AtomicUsize::new(0)),
                        healthy: AtomicBool::new(true),
                    })
                })
                .collect(),
        );
        let mut handles = Vec::with_capacity(self.replicas);
        for i in 0..self.replicas {
            let ctx = WorkerCtx {
                shared: Arc::clone(&shared),
                index: i,
                model: Arc::clone(&model),
                policy: self.batch,
                seed: self.seed.wrapping_add(i as u64),
                latency: Arc::clone(&latency),
                ttft: Arc::clone(&ttft),
                registry: Arc::clone(&registry),
                trace: Arc::clone(&trace),
                meter: Arc::clone(&meter),
                kv_gauges: Arc::clone(&kv_gauges),
                failpoints: Arc::clone(&self.failpoints),
                retry_idempotent: self.retry_idempotent,
                backoff_base: self.backoff_base,
                backoff_cap: self.backoff_cap,
            };
            let handle = thread::Builder::new()
                .name(format!("ams-engine-{i}"))
                .spawn(move || replica_main(ctx))
                .expect("spawn engine replica");
            handles.push(Some(handle));
        }
        Engine {
            shared,
            handles,
            dispatch: self.dispatch,
            rr: AtomicUsize::new(0),
            max_seq,
            kv_page_size: self.batch.kv_page_size,
            latency,
            ttft,
            registry,
            trace,
            started: Timer::start(),
            meter,
            kv_gauges,
        }
    }
}

/// Everything a replica worker thread needs; owned by the thread.
struct WorkerCtx {
    shared: Arc<Vec<Arc<ReplicaShared>>>,
    index: usize,
    model: Arc<Transformer>,
    policy: BatchPolicy,
    seed: u64,
    latency: Arc<Histogram>,
    ttft: Arc<Histogram>,
    registry: Arc<MetricsRegistry>,
    trace: Arc<TraceSink>,
    meter: Arc<FaultMeter>,
    kv_gauges: Arc<KvGauges>,
    failpoints: Arc<FailPoints>,
    retry_idempotent: bool,
    backoff_base: Duration,
    backoff_cap: Duration,
}

/// Extract a human-readable message from a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "replica worker panicked".to_string()
    }
}

/// Re-dispatch an idempotent request to the least-loaded healthy
/// replica other than `ctx.index`; hands the submission back when no
/// target exists or the target's queue refuses it.
fn redispatch(ctx: &WorkerCtx, mut sub: Submission) -> Result<(), Submission> {
    let target = ctx
        .shared
        .iter()
        .enumerate()
        .filter(|(i, r)| *i != ctx.index && r.healthy.load(Ordering::SeqCst))
        .min_by_key(|(i, r)| (r.outstanding.load(Ordering::SeqCst), *i))
        .map(|(i, _)| i);
    let Some(t) = target else {
        return Err(sub);
    };
    sub.mark_retried();
    // Move the outstanding share to the target replica so drain() and
    // least-outstanding dispatch see the request where it now lives.
    sub.retarget(&ctx.shared[t].outstanding);
    ctx.shared[t]
        .queue
        .try_push(sub)
        .map_err(TryPushError::into_submission)
}

/// Replica worker: supervise the serve loop under `catch_unwind`. A
/// clean exit (queue closed and drained) ends the thread; a panic
/// settles the in-flight work, backs off, and restarts the loop with a
/// fresh scheduler (the old one's KV caches died with the unwind).
fn replica_main(ctx: WorkerCtx) -> ServeStats {
    let me = Arc::clone(&ctx.shared[ctx.index]);
    let mut stats = ServeStats::default();
    let wall = Timer::start();
    let mut consecutive_panics: u32 = 0;
    loop {
        let mut sched = Scheduler::new(Arc::clone(&ctx.model), ctx.policy, ctx.seed)
            .with_failpoints(Arc::clone(&ctx.failpoints), ctx.index as u64)
            .with_kv_gauges(Arc::clone(&ctx.kv_gauges))
            .with_obs(SchedObs::new(&ctx.registry, Arc::clone(&ctx.trace), ctx.index));
        let run = catch_unwind(AssertUnwindSafe(|| {
            serve_loop(&mut sched, &me, &ctx, &mut stats)
        }));
        // Scheduler counters survive the unwind; fold them in before the
        // scheduler (and its caches) is dropped or rebuilt.
        stats.decode_steps += sched.steps_executed;
        stats.batched_tokens += sched.batched_tokens;
        stats.timed_out += sched.timed_out;
        stats.prefix_hits += sched.prefix_hits;
        stats.preemptions += sched.preemptions;
        stats.peak_concurrency = stats.peak_concurrency.max(sched.peak_batch);
        stats.drafted += sched.spec.drafted;
        stats.accepted += sched.spec.accepted;
        match run {
            Ok(()) => break, // queue closed and drained
            Err(payload) => {
                me.healthy.store(false, Ordering::SeqCst);
                stats.panics_recovered += 1;
                ctx.meter.panics_recovered.inc();
                consecutive_panics += 1;
                let msg = panic_message(payload.as_ref());
                // Settle everything the dead scheduler still held.
                // Outcomes emitted before the panic already left its
                // state, so nothing here settles twice.
                for (sub, tokens) in sched.take_inflight() {
                    if sub.cancelled() {
                        stats.cancelled += 1;
                        ctx.registry.counter(names::CANCELLED).inc();
                        // The unwound scheduler never returned these
                        // outcomes through `step`, so the terminal span
                        // is emitted here — the invariant's only other
                        // source.
                        ctx.trace.instant(ctx.index, sub.id(), SpanKind::Cancelled);
                        sub.settle_cancelled(tokens);
                    } else if ctx.retry_idempotent && tokens.is_empty() && sub.retries() == 0 {
                        match redispatch(&ctx, sub) {
                            Ok(()) => {
                                stats.retries += 1;
                                ctx.meter.retries.inc();
                            }
                            Err(sub) => {
                                stats.failed += 1;
                                ctx.registry.counter(names::FAILED).inc();
                                ctx.trace.instant(ctx.index, sub.id(), SpanKind::Failed);
                                sub.settle_failed(&msg);
                            }
                        }
                    } else {
                        stats.failed += 1;
                        ctx.registry.counter(names::FAILED).inc();
                        ctx.trace.instant(ctx.index, sub.id(), SpanKind::Failed);
                        sub.settle_failed(&msg);
                    }
                }
                let exp = consecutive_panics.saturating_sub(1).min(16);
                let delay = ctx.backoff_base.saturating_mul(1 << exp).min(ctx.backoff_cap);
                thread::sleep(delay);
                stats.restarts += 1;
                ctx.meter.restarts.inc();
                me.healthy.store(true, Ordering::SeqCst);
            }
        }
    }
    stats.wall_s = wall.elapsed_secs();
    stats
}

/// The supervised inner loop: drain the bounded queue into the
/// scheduler, step it, settle outcomes. Returns once the engine closes
/// the queue *and* all in-flight work has finished.
fn serve_loop(
    sched: &mut Scheduler,
    me: &ReplicaShared,
    ctx: &WorkerCtx,
    stats: &mut ServeStats,
) {
    // Live registry counters, ticked as outcomes settle so a
    // `metrics_snapshot` taken mid-run is current (the per-worker
    // `ServeStats` only merges at shutdown).
    let c_requests = ctx.registry.counter(names::REQUESTS);
    let c_cancelled = ctx.registry.counter(names::CANCELLED);
    let c_timed_out = ctx.registry.counter(names::TIMED_OUT);
    let c_failed = ctx.registry.counter(names::FAILED);
    let c_tokens = ctx.registry.counter(names::TOKENS_GENERATED);
    let h_latency = &ctx.latency;
    let h_ttft = &ctx.ttft;
    loop {
        // Reaped entries (cancelled or expired while queued) need no
        // batch slot, only their terminal settle — drain them even when
        // the batch is full so they never wait behind running sequences.
        while let Some(sub) = me.queue.pop_reaped() {
            sched.admit_submission(sub);
        }
        // Block for work only when idle; otherwise pull between decode
        // steps — but only enough to fill the free batch slots, so the
        // *bounded queue* stays the real admission queue and
        // `queue_capacity` is an honest backpressure bound (draining
        // eagerly would just relocate the backlog into the scheduler's
        // unbounded queue).
        if sched.pending() == 0 {
            match me.queue.pop_blocking() {
                Some(sub) => sched.admit_submission(sub),
                None => break, // closed and idle: done
            }
        }
        while sched.pending() < ctx.policy.max_batch {
            match me.queue.try_pop() {
                Some(sub) => sched.admit_submission(sub),
                None => break,
            }
        }
        for o in sched.step() {
            match o {
                Outcome::Done(r) => {
                    stats.requests += 1;
                    stats.tokens_generated += r.tokens.len() as u64;
                    c_requests.inc();
                    c_tokens.add(r.tokens.len() as u64);
                    h_latency.record(r.total_s);
                    h_ttft.record(r.ttft_s);
                    // Per-tenant latency attribution: labeled siblings
                    // of the fleet histograms. Requests that never set a
                    // tenant stay unlabeled, so single-tenant runs add
                    // zero new metrics.
                    if let Some(t) = r.tenant {
                        ctx.registry
                            .histogram_labeled(names::LATENCY, "tenant", t)
                            .record(r.total_s);
                        ctx.registry
                            .histogram_labeled(names::TTFT, "tenant", t)
                            .record(r.ttft_s);
                        ctx.registry.counter_labeled(names::REQUESTS, "tenant", t).inc();
                    }
                }
                Outcome::Cancelled { .. } => {
                    stats.cancelled += 1;
                    c_cancelled.inc();
                }
                // `stats.timed_out` is folded from the scheduler counter
                // by the supervisor; only the live telemetry ticks here.
                Outcome::TimedOut { .. } => {
                    ctx.meter.timeouts.inc();
                    c_timed_out.inc();
                }
                // Scheduler-originated terminal failure (an oversized
                // request the pool can never hold).
                Outcome::Failed { .. } => {
                    stats.failed += 1;
                    c_failed.inc();
                }
            }
        }
    }
}

/// The serving engine: the only public entry point for batched
/// generation. See the [module docs](self) for the lifecycle.
pub struct Engine {
    shared: Arc<Vec<Arc<ReplicaShared>>>,
    handles: Vec<Option<thread::JoinHandle<ServeStats>>>,
    dispatch: DispatchPolicy,
    rr: AtomicUsize,
    /// Model context bound, for request validation at submit.
    max_seq: usize,
    /// KV page size in positions (snapshot reporting).
    kv_page_size: usize,
    latency: Arc<Histogram>,
    ttft: Arc<Histogram>,
    registry: Arc<MetricsRegistry>,
    trace: Arc<TraceSink>,
    /// Engine lifetime stopwatch: `wall_s` for live snapshots.
    started: Timer,
    meter: Arc<FaultMeter>,
    kv_gauges: Arc<KvGauges>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    pub fn replica_count(&self) -> usize {
        self.shared.len()
    }

    /// Requests accepted but not yet settled, across all replicas.
    pub fn outstanding(&self) -> usize {
        self.shared
            .iter()
            .map(|r| r.outstanding.load(Ordering::SeqCst))
            .sum()
    }

    /// Replicas currently accepting dispatch (healthy). A replica is
    /// unhealthy only between a panic and the completion of its restart.
    pub fn healthy_replicas(&self) -> usize {
        self.shared
            .iter()
            .filter(|r| r.healthy.load(Ordering::SeqCst))
            .count()
    }

    /// Live occupancy of each replica's bounded admission queue — the
    /// capacity probe used by the chaos suite (all zeros once drained).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared.iter().map(|r| r.queue.depth()).collect()
    }

    /// Point-in-time fault counters: panics recovered, restarts,
    /// timeouts, sheds, retries.
    pub fn faults(&self) -> FaultCounters {
        self.meter.snapshot()
    }

    /// KV page-pool gauges shared by every replica. Cloning the `Arc`
    /// lets the chaos suite audit the pool *after* shutdown (used and
    /// leaked must both read zero once all schedulers have dropped).
    pub fn kv_gauges(&self) -> Arc<KvGauges> {
        Arc::clone(&self.kv_gauges)
    }

    /// KV pages currently in use, summed over replicas.
    pub fn kv_pages_used(&self) -> u64 {
        self.kv_gauges.pages_used.load(Ordering::Relaxed)
    }

    /// KV pages currently free, summed over replicas.
    pub fn kv_pages_free(&self) -> u64 {
        self.kv_gauges
            .pages_capacity
            .load(Ordering::Relaxed)
            .saturating_sub(self.kv_pages_used())
    }

    /// High-water mark of concurrent KV page usage.
    pub fn kv_pages_peak(&self) -> u64 {
        self.kv_gauges.pages_peak.load(Ordering::Relaxed)
    }

    /// Prompt-prefix pages adopted from the trie instead of prefilled.
    pub fn prefix_hits(&self) -> u64 {
        self.kv_gauges.prefix_hits.load(Ordering::Relaxed)
    }

    /// Sequences preempted (parked) on pool pressure.
    pub fn preemptions(&self) -> u64 {
        self.kv_gauges.preemptions.load(Ordering::Relaxed)
    }

    /// Pages a dropped pool could not account for (drop-audit; must
    /// stay zero).
    pub fn pages_leaked(&self) -> u64 {
        self.kv_gauges.leaked.load(Ordering::Relaxed)
    }

    /// Block until every accepted request has settled. Workers record a
    /// request's metrics *before* decrementing its outstanding count, so
    /// [`Engine::latency`]/[`Engine::ttft`] snapshots taken after this
    /// are complete. (Callers normally await their handles first, making
    /// this a microsecond formality.)
    pub fn drain(&self) {
        // Poll with a short sleep rather than a hot spin, so a long tail
        // generation is not taxed by a burning core while it decodes.
        while self.outstanding() > 0 {
            thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    /// End-to-end latency distribution (completed requests only):
    /// exact count/sum/mean/min/max, bounded-relative-error p50/p90/p99
    /// from the streaming histogram.
    pub fn latency(&self) -> HistStat {
        self.latency.stat()
    }

    /// Time-to-first-token distribution, measured from submission.
    pub fn ttft(&self) -> HistStat {
        self.ttft.stat()
    }

    /// The span-trace sink shared by every replica. Export with
    /// [`TraceSink::to_chrome_json`] (`serve --trace-out`).
    pub fn trace(&self) -> Arc<TraceSink> {
        Arc::clone(&self.trace)
    }

    /// The metrics registry every replica records through. Exposed so
    /// harnesses can register their own counters alongside the engine's.
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.registry)
    }

    /// Point-in-time typed snapshot of every serving metric: request
    /// and throughput scalars, fault counters, KV page-pool gauges,
    /// span-trace health, and every streaming histogram (TTFT, queue
    /// wait, step time, prefill chunk, spec rounds, per-path kernel
    /// timings) as bounded-error [`HistStat`]s. Callable mid-run — the
    /// workers tick the registry live — and after `close`; see
    /// [`MetricsSnapshot`] for the JSON/row renders.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        // Refresh facade-owned gauges before copying the registry.
        self.kv_gauges.export(&self.registry);
        let depth: usize = self.shared.iter().map(|r| r.queue.depth()).sum();
        let peak = self
            .shared
            .iter()
            .map(|r| r.queue.peak_depth())
            .max()
            .unwrap_or(0);
        self.registry.set_gauge(names::QUEUE_DEPTH, depth as u64);
        self.registry.set_gauge(names::QUEUE_DEPTH_PEAK, peak as u64);
        self.registry.set_gauge(names::TRACE_DROPPED, self.trace.dropped());
        let reg = self.registry.snapshot();
        let c = |n: &str| reg.counters.get(n).copied().unwrap_or(0);
        let g = |n: &str| reg.gauges.get(n).copied().unwrap_or(0);
        let faults = self.meter.snapshot();
        let wall_s = self.started.elapsed_secs();
        let tokens_generated = c(names::TOKENS_GENERATED);
        let decode_steps = c(names::DECODE_STEPS);
        let batched_tokens = c(names::BATCHED_TOKENS);
        let serve = ServeSection {
            requests: c(names::REQUESTS),
            cancelled: c(names::CANCELLED),
            timed_out: c(names::TIMED_OUT),
            failed: c(names::FAILED),
            shed: faults.sheds,
            retries: faults.retries,
            tokens_generated,
            decode_steps,
            batched_tokens,
            wall_s,
            throughput_tps: if wall_s > 0.0 {
                tokens_generated as f64 / wall_s
            } else {
                0.0
            },
            mean_batch_occupancy: if decode_steps > 0 {
                batched_tokens as f64 / decode_steps as f64
            } else {
                0.0
            },
            peak_concurrency: g(names::PEAK_CONCURRENCY) as usize,
        };
        let drafted = c(names::SPEC_DRAFTED);
        let accepted = c(names::SPEC_ACCEPTED);
        let spec = SpecSection {
            drafted,
            accepted,
            acceptance_rate: if drafted > 0 {
                accepted as f64 / drafted as f64
            } else {
                0.0
            },
        };
        let kv = KvSection {
            page_size: self.kv_page_size as u64,
            pages_capacity: g(names::KV_PAGES_CAPACITY),
            pages_used: g(names::KV_PAGES_USED),
            pages_peak: g(names::KV_PAGES_PEAK),
            pages_leaked: g(names::KV_LEAKED),
            prefix_hits: self.prefix_hits(),
            preemptions: self.preemptions(),
        };
        let trace = TraceSection {
            events_retained: self.trace.len() as u64,
            events_dropped: self.trace.dropped(),
        };
        let mut hists = reg.hists;
        // The kernel sink is process-global (see `obs::kernels`); fold
        // its per-path timings into the same snapshot map.
        for (name, stat) in kernels::stats() {
            hists.insert(name.to_string(), stat);
        }
        MetricsSnapshot {
            serve,
            spec,
            faults: FaultSection {
                panics_recovered: faults.panics_recovered,
                restarts: faults.restarts,
                timeouts: faults.timeouts,
                sheds: faults.sheds,
                retries: faults.retries,
            },
            kv,
            trace,
            counters: reg.counters,
            gauges: reg.gauges,
            hists,
        }
    }

    fn pick_replica(&self) -> usize {
        let healthy = |r: &ReplicaShared| r.healthy.load(Ordering::SeqCst);
        match self.dispatch {
            DispatchPolicy::LeastOutstanding => self
                .shared
                .iter()
                .enumerate()
                .filter(|(_, r)| healthy(r))
                .min_by_key(|(i, r)| (r.outstanding.load(Ordering::SeqCst), *i))
                .map(|(i, _)| i)
                .unwrap_or_else(|| {
                    // Every replica is mid-restart: queues stay open, so
                    // fall back to the least-loaded one regardless.
                    self.shared
                        .iter()
                        .enumerate()
                        .min_by_key(|(i, r)| (r.outstanding.load(Ordering::SeqCst), *i))
                        .map(|(i, _)| i)
                        .expect("at least one replica")
                }),
            DispatchPolicy::RoundRobin => {
                let n = self.shared.len();
                for _ in 0..n {
                    let idx = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                    if healthy(&self.shared[idx]) {
                        return idx;
                    }
                }
                self.rr.fetch_add(1, Ordering::Relaxed) % n
            }
        }
    }

    fn dispatch_to(
        &self,
        idx: usize,
        req: GenRequest,
        block: bool,
    ) -> Result<RequestHandle, EngineError> {
        // The scheduler/model assert on these; reject here so a bad
        // request can never panic a replica worker.
        if req.prompt.is_empty() {
            return Err(EngineError::InvalidRequest(req, "empty prompt"));
        }
        if req.prompt.len() > self.max_seq {
            return Err(EngineError::InvalidRequest(
                req,
                "prompt exceeds the model context",
            ));
        }
        let replica = &self.shared[idx];
        let (tx_ev, rx_ev) = mpsc::channel::<Event>();
        // The TTFT stopwatch starts inside `Submission` — before any
        // queue wait, including a blocking push on a full queue.
        let mut sub = Submission::with_events(req, tx_ev.clone());
        let id = sub.id();
        let cancel = sub.cancel_flag();
        let _ = tx_ev.send(Event::Queued { id });
        // Guard-held outstanding count: released wherever the submission
        // dies — normal settle, push failure below, or a worker panic.
        sub.attach_guard(OutstandingGuard::acquire(&replica.outstanding));
        // A closed engine surfaces the typed `Shutdown` error with the
        // request handed back — never a panic on user input.
        let send_result = if block {
            replica
                .queue
                .push(sub)
                .map_err(|s| EngineError::Shutdown(s.into_request()))
        } else {
            replica.queue.try_push(sub).map_err(|e| match e {
                TryPushError::Full(s) => EngineError::QueueFull(s.into_request()),
                TryPushError::Shed(s) => {
                    self.meter.sheds.inc();
                    EngineError::Overloaded(s.into_request())
                }
                TryPushError::Closed(s) => EngineError::Shutdown(s.into_request()),
            })
        };
        send_result.map(|()| {
            // Span timeline starts here — only for requests that actually
            // entered a replica queue (a refused push never ran).
            self.trace.instant(idx, id, SpanKind::Queued);
            RequestHandle {
                id,
                rx: rx_ev,
                cancel,
                shared: Arc::clone(replica),
                finished: false,
                cancel_on_drop: false,
            }
        })
    }

    /// Submit a request, blocking while the chosen replica's queue is
    /// full (bounded admission). Returns the streaming handle.
    pub fn submit(&self, req: GenRequest) -> Result<RequestHandle, EngineError> {
        let idx = self.pick_replica();
        self.dispatch_to(idx, req, true)
    }

    /// Non-blocking submit: [`EngineError::QueueFull`] when the chosen
    /// replica's queue is at capacity (handing the request back to the
    /// caller — shed, retry or spill to another engine), and
    /// [`EngineError::Overloaded`] when a bulk request is shed to keep
    /// the interactive reserve free.
    pub fn try_submit(&self, req: GenRequest) -> Result<RequestHandle, EngineError> {
        let idx = self.pick_replica();
        self.dispatch_to(idx, req, false)
    }

    /// Stop accepting new work without joining the replicas: every
    /// queue is closed, in-flight requests keep decoding to completion,
    /// any submitter *parked* on a full queue wakes with
    /// [`EngineError::Shutdown`], and any later `submit`/`try_submit`
    /// returns the same error with the request handed back. Takes
    /// `&self` so it can race concurrent submitters by design — that is
    /// the point. Call [`Engine::shutdown`] afterwards to join and
    /// collect statistics.
    pub fn close(&self) {
        for r in self.shared.iter() {
            r.queue.close();
        }
    }

    /// Stop accepting work, finish everything in flight, join the
    /// replicas and return merged statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> ServeStats {
        // Close every queue first so replicas drain concurrently.
        self.close();
        let mut total = ServeStats::default();
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                total.merge(&h.join().unwrap_or_default());
            }
        }
        // Sheds happen on the dispatch path, not in any worker.
        total.shed += self.meter.sheds.get();
        total
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::failpoint::{self, FailSpec};
    use crate::coordinator::Priority;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;
    use crate::util::proptest::{run_prop, USize};

    fn model() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 33);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    /// A model with a long context bound, for tests that need a request
    /// to keep decoding for hundreds of steps (test_tiny's max_seq of 64
    /// would retire it via ctx_full).
    fn long_ctx_model() -> Transformer {
        let cfg = ModelConfig {
            max_seq: 2048,
            ..ModelConfig::test_tiny()
        };
        let ck = synthetic_checkpoint(&cfg, 33);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    fn engine(replicas: usize, max_batch: usize) -> Engine {
        Engine::builder()
            .replicas(replicas)
            .max_batch(max_batch)
            .seed(1)
            .build(model())
    }

    #[test]
    fn serves_and_shuts_down() {
        let eng = engine(1, 8);
        let handles: Vec<RequestHandle> = (0..5u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1, 2], 3)).unwrap())
            .collect();
        let out: Vec<GenResponse> = handles.into_iter().filter_map(|h| h.wait()).collect();
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| r.tokens.len() == 3));
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.tokens_generated, 15);
        assert!(stats.wall_s > 0.0);
        assert_eq!(stats.panics_recovered, 0);
        assert_eq!(stats.failed, 0);
    }

    #[test]
    fn event_stream_orders_and_matches_response() {
        let eng = engine(1, 4);
        let mut h = eng.submit(GenRequest::greedy(7, vec![1, 2, 3], 5)).unwrap();
        let mut streamed = Vec::new();
        let mut saw_queued = false;
        let mut done: Option<GenResponse> = None;
        while let Some(ev) = h.next_event() {
            match ev {
                Event::Queued { id } => {
                    assert_eq!(id, 7);
                    assert!(streamed.is_empty(), "Queued must precede tokens");
                    saw_queued = true;
                }
                Event::FirstToken { id, token, ttft_s } => {
                    assert_eq!(id, 7);
                    assert!(streamed.is_empty(), "FirstToken must be the first token");
                    assert!(ttft_s >= 0.0);
                    streamed.push(token);
                }
                Event::Token { id, token, index } => {
                    assert_eq!(id, 7);
                    assert_eq!(index, streamed.len(), "tokens must arrive in order");
                    streamed.push(token);
                }
                Event::Done(r) => done = Some(r),
                Event::Cancelled { .. } | Event::TimedOut { .. } | Event::Failed { .. } => {
                    panic!("unexpected terminal: {ev:?}")
                }
            }
        }
        assert!(saw_queued);
        let done = done.expect("terminal Done");
        // Streaming satellite: greedy streamed tokens == the final result.
        assert_eq!(streamed, done.tokens);
        assert_eq!(streamed.len(), 5);
        eng.shutdown();
    }

    #[test]
    fn streaming_equals_non_streaming_greedy() {
        // The engine path (chunked prefill + streaming) must produce the
        // same greedy tokens as a bare scheduler fed the same requests.
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8], vec![4], vec![5, 6, 7, 8]];
        let mut sched = Scheduler::new(
            model(),
            BatchPolicy { max_batch: 4, ..BatchPolicy::default() },
            1,
        );
        for (i, p) in prompts.iter().enumerate() {
            sched.admit(GenRequest::greedy(i as u64, p.clone(), 6));
        }
        let mut reference = sched.run_to_completion();
        reference.sort_by_key(|r| r.id);

        let eng = Engine::builder().max_batch(4).seed(1).build(model());
        let handles: Vec<RequestHandle> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| eng.submit(GenRequest::greedy(i as u64, p.clone(), 6)).unwrap())
            .collect();
        let mut out: Vec<GenResponse> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        out.sort_by_key(|r| r.id);
        for (a, b) in out.iter().zip(&reference) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        eng.shutdown();
    }

    #[test]
    fn cancel_mid_generation() {
        let eng = engine(1, 2);
        // A long request we cancel and a short one that must be unaffected.
        let long = eng.submit(GenRequest::greedy(0, vec![1, 2], 400)).unwrap();
        let short = eng.submit(GenRequest::greedy(1, vec![3], 4)).unwrap();
        long.cancel();
        assert!(long.wait().is_none(), "cancelled requests yield no response");
        let r = short.wait().expect("survivor completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn cancelled_stream_ends_with_terminal_event() {
        let eng = engine(1, 2);
        let mut h = eng.submit(GenRequest::greedy(0, vec![1, 2], 400)).unwrap();
        h.cancel();
        let mut terminal = 0;
        let mut after_terminal = 0;
        while let Some(ev) = h.next_event() {
            if terminal > 0 {
                after_terminal += 1;
            }
            if ev.is_terminal() {
                assert!(matches!(ev, Event::Cancelled { .. }));
                terminal += 1;
            }
        }
        assert_eq!(terminal, 1);
        assert_eq!(after_terminal, 0, "nothing may follow the terminal event");
        eng.shutdown();
    }

    /// Property (satellite): every submitted request yields exactly one
    /// terminal event, whether it completes or is cancelled at a random
    /// point in its lifecycle.
    #[test]
    fn prop_exactly_one_terminal_event() {
        run_prop(
            "one-terminal-event",
            0xE7E7,
            5,
            &USize { lo: 1, hi: 9 },
            |&n| {
                let eng = Engine::builder().max_batch(3).seed(2).build(model());
                let mut handles = Vec::new();
                for id in 0..n as u64 {
                    let h = eng
                        .submit(GenRequest::greedy(
                            id,
                            vec![(id as u32 % 50) + 1],
                            2 + (id as usize % 5),
                        ))
                        .unwrap();
                    if id % 3 == 1 {
                        h.cancel();
                    }
                    handles.push(h);
                }
                for mut h in handles {
                    let mut terminals = 0;
                    while let Some(ev) = h.next_event() {
                        if ev.is_terminal() {
                            terminals += 1;
                        }
                    }
                    if terminals != 1 {
                        return Err(format!("request {} saw {terminals} terminal events", h.id()));
                    }
                }
                eng.shutdown();
                Ok(())
            },
        );
    }

    #[test]
    fn try_submit_surfaces_queue_full() {
        // Capacity 1 and a slow long-running request: the queue must fill
        // and try_submit must hand the request back instead of panicking.
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(1)
            .seed(3)
            .build(model());
        let first = eng.submit(GenRequest::greedy(0, vec![1, 2], 60)).unwrap();
        let mut full_seen = false;
        let mut accepted = Vec::new();
        // Push until the bounded queue rejects one (the worker may admit
        // the first request before the queue fills, hence the loop).
        for id in 1..50u64 {
            match eng.try_submit(GenRequest::greedy(id, vec![2], 60)) {
                Ok(h) => accepted.push(h),
                Err(EngineError::QueueFull(req)) => {
                    assert_eq!(req.id, id, "rejected request handed back intact");
                    full_seen = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(full_seen, "bounded queue never reported QueueFull");
        // Unblock the system: cancel everything and drain (a request may
        // legitimately win the race and complete before its cancel).
        first.cancel();
        for h in &accepted {
            h.cancel();
        }
        let _ = first.wait();
        for h in accepted {
            h.wait();
        }
        eng.shutdown();
    }

    /// Satellite regression: a request cancelled while still in the
    /// bounded admission queue releases its capacity slot immediately —
    /// a subsequent try_submit succeeds with no dequeue in between —
    /// and the cancelled request still settles exactly once, without
    /// ever prefilling.
    #[test]
    fn cancelled_queued_request_frees_queue_slot() {
        // max_batch 1 + a long-running active request: the worker never
        // touches the queue while request 0 decodes, so the queue state
        // is fully deterministic. Steps pinned at >= 1ms (and a long
        // context so ctx_full cannot retire it) keep request 0 active
        // for the whole test window on any machine.
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::stall_ms(1).times(100_000));
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(1)
            .seed(6)
            .failpoints(Arc::clone(&fp))
            .build(long_ctx_model());
        let active = eng.submit(GenRequest::greedy(0, vec![1, 2], 1500)).unwrap();
        // Wait for the worker to admit request 0 so the queue is empty.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let queued = loop {
            match eng.try_submit(GenRequest::greedy(1, vec![3], 400)) {
                Ok(h) => break h,
                Err(EngineError::QueueFull(_)) => {
                    assert!(std::time::Instant::now() < deadline, "worker never admitted");
                    thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        // The queue now holds request 1; capacity 1 ⇒ full.
        match eng.try_submit(GenRequest::greedy(2, vec![4], 400)) {
            Err(EngineError::QueueFull(req)) => assert_eq!(req.id, 2),
            other => panic!("queue must be full: {:?}", other.map(|h| h.id())),
        }
        // Cancel the queued request: its slot frees without any dequeue
        // (the worker is still busy decoding request 0).
        queued.cancel();
        let third = eng
            .try_submit(GenRequest::greedy(3, vec![5], 4))
            .expect("cancelled queued request released its capacity slot");
        // Everyone settles exactly once: 1 was cancelled in-queue (no
        // tokens, never prefilled), 3 completes once 0 is cancelled.
        active.cancel();
        assert!(active.wait().is_none());
        assert!(queued.wait().is_none(), "queued cancel yields no response");
        let r = third.wait().expect("replacement request completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 2);
    }

    /// Satellite: submitting to a closed engine surfaces the typed
    /// `Shutdown` error (request handed back) instead of panicking.
    #[test]
    fn submit_after_close_returns_shutdown_error() {
        let eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![1], 2)).unwrap();
        eng.close();
        match eng.submit(GenRequest::greedy(1, vec![2], 2)) {
            Err(EngineError::Shutdown(req)) => assert_eq!(req.id, 1, "request handed back"),
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("closed engine must reject submissions"),
        }
        match eng.try_submit(GenRequest::greedy(2, vec![3], 2)) {
            Err(EngineError::Shutdown(req)) => assert_eq!(req.id, 2),
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("closed engine must reject try_submit too"),
        }
        // In-flight work before the close still completes.
        assert!(h.wait().is_some());
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
    }

    /// Satellite regression: `close()` must wake a submitter *parked*
    /// on a full queue with `Shutdown` instead of leaving it parked
    /// forever. (The old `close(&mut self)` could not even be called
    /// while another thread was blocked inside `submit(&self)`.)
    #[test]
    fn close_wakes_parked_submitter() {
        // Same pinning as above: request 0 must still be decoding when
        // the parked submitter is woken by close().
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::stall_ms(1).times(100_000));
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(1)
            .seed(6)
            .failpoints(Arc::clone(&fp))
            .build(long_ctx_model());
        let active = eng.submit(GenRequest::greedy(0, vec![1, 2], 1500)).unwrap();
        // Fill the queue deterministically (wait for the worker to admit
        // request 0 first).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let queued = loop {
            match eng.try_submit(GenRequest::greedy(1, vec![3], 400)) {
                Ok(h) => break h,
                Err(EngineError::QueueFull(_)) => {
                    assert!(std::time::Instant::now() < deadline, "worker never admitted");
                    thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        thread::scope(|s| {
            let parked = s.spawn(|| eng.submit(GenRequest::greedy(2, vec![4], 4)));
            // Give the submitter time to park on the full queue.
            thread::sleep(std::time::Duration::from_millis(30));
            eng.close();
            match parked.join().expect("parked submitter must return") {
                Err(EngineError::Shutdown(req)) => {
                    assert_eq!(req.id, 2, "request handed back to the woken submitter")
                }
                Err(e) => panic!("wrong error for parked submitter: {e}"),
                Ok(_) => panic!("queue was full and closing; submit cannot succeed"),
            }
        });
        // In-flight and queued work still settles after the close.
        active.cancel();
        queued.cancel();
        assert!(active.wait().is_none());
        assert!(queued.wait().is_none());
        let stats = eng.shutdown();
        assert_eq!(stats.cancelled, 2);
        assert_eq!(stats.requests, 0);
    }

    /// Satellite: an abandoned handle with cancel_on_drop reclaims its
    /// sequence — the request settles as cancelled, the survivor is
    /// unaffected.
    #[test]
    fn cancel_on_drop_reclaims_abandoned_stream() {
        let eng = engine(1, 2);
        let long = eng
            .submit(GenRequest::greedy(0, vec![1, 2], 400))
            .unwrap()
            .cancel_on_drop();
        let short = eng.submit(GenRequest::greedy(1, vec![3], 4)).unwrap();
        drop(long); // client went away — the stream is abandoned
        let r = short.wait().expect("survivor completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.cancelled, 1, "dropped handle cancelled its request");
        assert_eq!(stats.requests, 1);
    }

    /// Without the opt-in, dropping a handle only detaches the stream;
    /// the request still runs to completion (the documented default).
    #[test]
    fn plain_drop_does_not_cancel() {
        let eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![1, 2], 5)).unwrap();
        drop(h);
        let stats = eng.shutdown(); // waits for in-flight work
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 0);
    }

    /// A handle consumed by `wait()` (terminal event delivered) must not
    /// flip the cancel flag on drop even with cancel_on_drop set.
    #[test]
    fn cancel_on_drop_noop_after_completion() {
        let eng = engine(1, 2);
        let h = eng
            .submit(GenRequest::greedy(0, vec![1], 3))
            .unwrap()
            .cancel_on_drop();
        let r = h.wait().expect("completes normally");
        assert_eq!(r.tokens.len(), 3);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 0);
    }

    #[test]
    fn empty_prompt_rejected_at_submit() {
        let eng = engine(1, 2);
        match eng.submit(GenRequest::greedy(0, vec![], 4)) {
            Err(EngineError::InvalidRequest(req, why)) => {
                assert_eq!(req.id, 0, "request handed back intact");
                assert!(why.contains("empty"));
            }
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("empty prompt must be rejected, not panic a worker"),
        }
        // Prompts beyond the model context are rejected up front too.
        let too_long = vec![1u32; ModelConfig::test_tiny().max_seq + 1];
        match eng.submit(GenRequest::greedy(2, too_long, 2)) {
            Err(EngineError::InvalidRequest(req, why)) => {
                assert_eq!(req.id, 2);
                assert!(why.contains("context"));
            }
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("over-long prompt must be rejected, not panic a worker"),
        }
        // The engine stays healthy afterwards.
        let h = eng.submit(GenRequest::greedy(1, vec![1], 2)).unwrap();
        assert_eq!(h.wait().expect("serves normally").tokens.len(), 2);
        eng.shutdown();
    }

    #[test]
    fn round_robin_rotates_replicas() {
        let eng = Engine::builder()
            .replicas(3)
            .dispatch(DispatchPolicy::RoundRobin)
            .seed(4)
            .build(model());
        assert_eq!(eng.replica_count(), 3);
        assert_eq!(eng.pick_replica(), 0);
        assert_eq!(eng.pick_replica(), 1);
        assert_eq!(eng.pick_replica(), 2);
        assert_eq!(eng.pick_replica(), 0);
        eng.shutdown();
    }

    #[test]
    fn least_outstanding_spreads_load() {
        let eng = Engine::builder().replicas(3).seed(5).build(model());
        // Long generations keep requests outstanding, so the three
        // dispatch decisions must fan out across replicas.
        let handles: Vec<RequestHandle> = (0..3u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1, 2, 3, 4], 24)).unwrap())
            .collect();
        let out: Vec<GenResponse> = handles.into_iter().filter_map(|h| h.wait()).collect();
        assert_eq!(out.len(), 3);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        eng_stats_sane(&stats);
    }

    fn eng_stats_sane(stats: &ServeStats) {
        assert!(stats.wall_s > 0.0);
        assert!(stats.decode_steps > 0);
    }

    #[test]
    fn shutdown_completes_inflight() {
        let eng = engine(1, 4);
        let handles: Vec<RequestHandle> = (0..3u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1], 2)).unwrap())
            .collect();
        // Immediate shutdown: responses must still be produced.
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        for h in handles {
            assert!(h.wait().is_some(), "in-flight work finishes before join");
        }
    }

    #[test]
    fn latency_and_ttft_recorded() {
        let eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![3], 2)).unwrap();
        let r = h.wait().unwrap();
        assert!(r.ttft_s > 0.0);
        assert!(r.total_s >= r.ttft_s);
        eng.drain();
        assert_eq!(eng.latency().count, 1);
        assert_eq!(eng.ttft().count, 1);
        // The typed snapshot agrees with the accessor histograms and
        // carries the request-lifecycle counters live (pre-shutdown).
        let snap = eng.metrics_snapshot();
        assert_eq!(snap.serve.requests, 1);
        assert_eq!(snap.hist(crate::obs::names::TTFT).count, 1);
        assert_eq!(snap.hist(crate::obs::names::LATENCY).count, 1);
        assert!(snap.hist(crate::obs::names::STEP_TIME).count > 0);
        assert!(snap.serve.wall_s > 0.0);
        eng.shutdown();
    }

    /// Tentpole: tenants flow end to end — labeled TTFT/latency
    /// histograms and per-tenant request counters appear in the
    /// snapshot, responses carry their tenant, untenanted requests add
    /// zero labeled metrics, and the pool conserves pages exactly with
    /// a quota active.
    #[test]
    fn multi_tenant_requests_label_metrics_and_conserve_pages() {
        let eng = Engine::builder()
            .max_batch(4)
            .kv_page_size(4)
            .tenant_quota_pages(64)
            .seed(15)
            .build(model());
        let a = eng.submit(GenRequest::greedy(0, vec![1, 2], 3).with_tenant(1)).unwrap();
        let b = eng.submit(GenRequest::greedy(1, vec![3, 4], 3).with_tenant(2)).unwrap();
        let c = eng.submit(GenRequest::greedy(2, vec![5], 3)).unwrap();
        let ra = a.wait().expect("tenant 1 completes");
        assert_eq!(ra.tenant, Some(1));
        assert!(b.wait().is_some());
        let rc = c.wait().expect("untenanted request completes");
        assert_eq!(rc.tenant, None);
        eng.drain();
        let snap = eng.metrics_snapshot();
        assert_eq!(snap.hist("serve.ttft_s{tenant=1}").count, 1);
        assert_eq!(snap.hist("serve.latency_s{tenant=2}").count, 1);
        assert_eq!(snap.counters["serve.requests{tenant=1}"], 1);
        assert!(
            !snap.counters.contains_key("serve.requests{tenant=0}"),
            "untenanted requests stay unlabeled"
        );
        // The unlabeled fleet histograms aggregate all three requests.
        assert_eq!(snap.hist(crate::obs::names::TTFT).count, 3);
        let gauges = eng.kv_gauges();
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(gauges.pages_used.load(Ordering::Relaxed), 0, "exact conservation");
        assert_eq!(gauges.leaked.load(Ordering::Relaxed), 0, "no pages leaked");
    }

    // ---- fault tolerance -------------------------------------------------

    /// Tentpole: a replica panic settles every in-flight request with a
    /// terminal event, the worker restarts, and the engine keeps
    /// serving. Requests that had emitted tokens settle `Failed`; the
    /// conservation law done + failed + cancelled + timed_out ==
    /// submitted holds; outstanding() returns to 0.
    #[test]
    fn panic_recovery_settles_and_restarts() {
        let fp = FailPoints::new();
        // Panic on the 3rd step of replica 0's scheduler.
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::panic_on_hit(3));
        let eng = Engine::builder()
            .replicas(2)
            .dispatch(DispatchPolicy::RoundRobin)
            .max_batch(4)
            .seed(7)
            .restart_backoff(Duration::from_millis(1), Duration::from_millis(10))
            .failpoints(Arc::clone(&fp))
            .build(model());
        let handles: Vec<RequestHandle> = (0..8u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![(id as u32) + 1], 12)).unwrap())
            .collect();
        let mut terminals = 0;
        let mut done = 0;
        let mut failed = 0;
        for mut h in handles {
            let mut mine = 0;
            while let Some(ev) = h.next_event() {
                if ev.is_terminal() {
                    mine += 1;
                    match ev {
                        Event::Done(_) => done += 1,
                        Event::Failed { error, .. } => {
                            assert!(error.contains("failpoint"), "panic message propagated");
                            failed += 1;
                        }
                        other => panic!("unexpected terminal {other:?}"),
                    }
                }
            }
            assert_eq!(mine, 1, "exactly one terminal event per request");
            terminals += mine;
        }
        assert_eq!(terminals, 8);
        eng.drain();
        assert_eq!(eng.outstanding(), 0, "guards released on every settle path");
        assert_eq!(eng.queue_depths(), vec![0, 0], "no queue slots leaked");
        assert_eq!(fp.fired(failpoint::STEP), 1, "the fault was injected");
        // The restart (backoff included) races the handle drain; poll
        // briefly instead of asserting an instantaneous recovery.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while eng.healthy_replicas() < 2 || eng.faults().restarts < 1 {
            assert!(
                std::time::Instant::now() < deadline,
                "panicked replica never recovered"
            );
            thread::sleep(Duration::from_millis(2));
        }
        let faults = eng.faults();
        assert_eq!(faults.panics_recovered, 1);
        assert_eq!(faults.restarts, 1);
        let stats = eng.shutdown();
        assert_eq!(stats.panics_recovered, 1);
        assert_eq!(stats.restarts, 1);
        assert_eq!(stats.requests, done as u64);
        assert_eq!(stats.failed, failed as u64);
        assert_eq!(
            stats.requests + stats.failed + stats.cancelled + stats.timed_out,
            8,
            "conservation: every request settled exactly once"
        );
    }

    /// A panicked replica restarts and serves again — even with a single
    /// replica (no retry target), the next request completes.
    #[test]
    fn single_replica_restarts_and_serves_again() {
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::panic_on_hit(2));
        let eng = Engine::builder()
            .seed(8)
            .failpoints(Arc::clone(&fp))
            .restart_backoff(Duration::from_millis(1), Duration::from_millis(10))
            .build(model());
        let mut victim = eng.submit(GenRequest::greedy(0, vec![1, 2], 30)).unwrap();
        let mut saw_failed = false;
        while let Some(ev) = victim.next_event() {
            if let Event::Failed { id, .. } = ev {
                assert_eq!(id, 0);
                saw_failed = true;
            }
        }
        assert!(saw_failed, "no retry target exists, so the request fails");
        // The supervisor restarted the worker; the engine serves again.
        let h = eng.submit(GenRequest::greedy(1, vec![3], 4)).unwrap();
        assert_eq!(h.wait().expect("served after restart").tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.restarts, 1);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.requests, 1);
    }

    /// Idempotent requests (zero tokens emitted — e.g. still prefilling
    /// a chunked prompt) are re-dispatched to a healthy replica after a
    /// panic and complete as Done; nothing fails.
    #[test]
    fn panic_mid_prefill_retries_idempotent() {
        let fp = FailPoints::new();
        // Panic on step 2: with prefill_chunk 2 and 10-token prompts, the
        // admitted sequence is still prefilling (zero tokens emitted).
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::panic_on_hit(2));
        let eng = Engine::builder()
            .replicas(2)
            .max_batch(2)
            .prefill_chunk(2)
            .seed(9)
            .failpoints(Arc::clone(&fp))
            .build(model());
        let prompt: Vec<u32> = (1..11u32).collect();
        let a = eng.dispatch_to(0, GenRequest::greedy(0, prompt.clone(), 3), true).unwrap();
        let b = eng.dispatch_to(0, GenRequest::greedy(1, prompt, 3), true).unwrap();
        let ra = a.wait().expect("retried on the healthy replica");
        let rb = b.wait().expect("retried or served after restart");
        assert_eq!(ra.tokens.len(), 3);
        assert_eq!(rb.tokens.len(), 3);
        let stats = eng.shutdown();
        assert_eq!(stats.panics_recovered, 1);
        assert_eq!(stats.failed, 0, "zero-token requests never fail, they retry");
        assert!(stats.retries >= 1, "at least the in-flight prefill was retried");
        assert_eq!(stats.requests, 2);
    }

    /// With retry disabled, the same panic fails the in-flight prefill
    /// instead of re-dispatching it.
    #[test]
    fn retry_disabled_fails_idempotent_requests() {
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::panic_on_hit(2));
        let eng = Engine::builder()
            .replicas(2)
            .max_batch(2)
            .prefill_chunk(2)
            .seed(10)
            .retry_idempotent(false)
            .failpoints(Arc::clone(&fp))
            .build(model());
        let prompt: Vec<u32> = (1..11u32).collect();
        // Request 0 is deterministically in-flight (its 10-token prompt
        // needs 5 chunks) when step 2 panics.
        let a = eng.dispatch_to(0, GenRequest::greedy(0, prompt, 3), true).unwrap();
        assert!(a.wait().is_none(), "failed request yields no response");
        let stats = eng.shutdown();
        assert_eq!(stats.panics_recovered, 1);
        assert!(stats.failed >= 1);
        assert_eq!(stats.retries, 0);
    }

    /// Deadline satellite: a queue deadline expires while the request
    /// waits behind a saturated batch — terminal TimedOut, empty tokens,
    /// queue slot restored.
    #[test]
    fn queue_deadline_times_out_with_terminal_event() {
        // Pin each scheduler step at >= 1ms so request 0 provably holds
        // the only batch slot past the 60ms queue deadline regardless of
        // machine speed.
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::stall_ms(1).times(100_000));
        let eng = Engine::builder()
            .max_batch(1)
            .seed(11)
            .failpoints(Arc::clone(&fp))
            .build(long_ctx_model());
        let active = eng.submit(GenRequest::greedy(0, vec![1, 2], 1500)).unwrap();
        let mut h = eng
            .submit(
                GenRequest::greedy(1, vec![3], 50)
                    .with_queue_deadline(Duration::from_millis(60)),
            )
            .unwrap();
        let mut saw = false;
        while let Some(ev) = h.next_event() {
            if let Event::TimedOut { id, tokens } = ev {
                assert_eq!(id, 1);
                assert!(tokens.is_empty(), "never admitted, so no tokens");
                saw = true;
            }
        }
        assert!(saw, "queue deadline must settle TimedOut");
        active.cancel();
        assert!(active.wait().is_none());
        eng.drain();
        assert_eq!(eng.queue_depths(), vec![0]);
        assert!(eng.faults().timeouts >= 1);
        let stats = eng.shutdown();
        assert_eq!(stats.timed_out, 1);
    }

    /// A total deadline expiring mid-generation evicts the sequence with
    /// the tokens generated so far.
    #[test]
    fn total_deadline_times_out_mid_generation() {
        // Pin steps at >= 3ms: the first token lands well inside the
        // 120ms budget (step 1), and the 1500-token request provably
        // outlives it (would need 4.5s) — no dependence on machine speed.
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::stall_ms(3).times(100_000));
        let eng = Engine::builder()
            .max_batch(2)
            .seed(12)
            .failpoints(Arc::clone(&fp))
            .build(long_ctx_model());
        let mut h = eng
            .submit(
                GenRequest::greedy(0, vec![1, 2], 1500)
                    .with_total_deadline(Duration::from_millis(120)),
            )
            .unwrap();
        let mut timed_out_tokens = None;
        while let Some(ev) = h.next_event() {
            if let Event::TimedOut { id, tokens } = ev {
                assert_eq!(id, 0);
                timed_out_tokens = Some(tokens);
            }
        }
        let toks = timed_out_tokens.expect("must settle TimedOut");
        assert!(!toks.is_empty(), "generation had started before expiry");
        let stats = eng.shutdown();
        assert_eq!(stats.timed_out, 1);
        assert_eq!(stats.requests, 0);
    }

    /// Priority satellite: bulk requests shed with `Overloaded` once the
    /// interactive reserve is all that remains; interactive requests
    /// still get in.
    #[test]
    fn bulk_sheds_before_interactive_under_overload() {
        // capacity 4, reserve 2 ⇒ bulk ceiling 2. Steps pinned at >= 1ms
        // so request 0 occupies the only batch slot for the whole test
        // body and queue occupancy stays deterministic.
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::STEP, 0, FailSpec::stall_ms(1).times(100_000));
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(4)
            .interactive_reserve(2)
            .seed(13)
            .failpoints(Arc::clone(&fp))
            .build(long_ctx_model());
        let active = eng.submit(GenRequest::greedy(0, vec![1, 2], 1500)).unwrap();
        // Wait until the worker admits request 0 (queue empty again).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let b1 = loop {
            match eng.try_submit(
                GenRequest::greedy(1, vec![3], 400).with_priority(Priority::Bulk),
            ) {
                Ok(h) => break h,
                Err(EngineError::QueueFull(_)) | Err(EngineError::Overloaded(_)) => {
                    assert!(std::time::Instant::now() < deadline, "worker never admitted");
                    thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        let b2 = eng
            .try_submit(GenRequest::greedy(2, vec![4], 400).with_priority(Priority::Bulk))
            .expect("second bulk fits under the ceiling");
        match eng.try_submit(GenRequest::greedy(3, vec![5], 400).with_priority(Priority::Bulk)) {
            Err(EngineError::Overloaded(req)) => assert_eq!(req.id, 3, "bulk shed, handed back"),
            other => panic!("expected Overloaded: {:?}", other.map(|h| h.id())),
        }
        // The reserve still admits interactive traffic...
        let i1 = eng
            .try_submit(GenRequest::greedy(4, vec![6], 400))
            .expect("interactive uses the reserve");
        let i2 = eng
            .try_submit(GenRequest::greedy(5, vec![7], 400))
            .expect("interactive fills to the brim");
        // ...until the queue is truly full, which is QueueFull even for
        // interactive.
        match eng.try_submit(GenRequest::greedy(6, vec![8], 400)) {
            Err(EngineError::QueueFull(req)) => assert_eq!(req.id, 6),
            other => panic!("expected QueueFull: {:?}", other.map(|h| h.id())),
        }
        assert!(eng.faults().sheds >= 1);
        for h in [&active, &b1, &b2, &i1, &i2] {
            h.cancel();
        }
        for h in [active, b1, b2, i1, i2] {
            let _ = h.wait();
        }
        let stats = eng.shutdown();
        assert!(stats.shed >= 1, "sheds observable in merged stats");
    }

    /// Timeout-API satellite: against a replica stalled in prefill, the
    /// bounded-wait accessors return instead of hanging, and the handle
    /// survives to be waited again.
    #[test]
    fn next_event_timeout_against_stalled_replica() {
        let fp = FailPoints::new();
        fp.arm_tagged(failpoint::PREFILL, 0, FailSpec::stall_ms(250));
        let eng = Engine::builder()
            .seed(14)
            .failpoints(Arc::clone(&fp))
            .build(model());
        let mut h = eng.submit(GenRequest::greedy(0, vec![1, 2], 3)).unwrap();
        // Queued is sent on the dispatch path, before the stall.
        match h.next_event_timeout(Duration::from_secs(2)) {
            Some(Event::Queued { id }) => assert_eq!(id, 0),
            other => panic!("expected Queued, got {other:?}"),
        }
        // The replica is stalled: a bounded wait returns None quickly
        // with the stream still open.
        let t = Timer::start();
        assert!(h.next_event_timeout(Duration::from_millis(10)).is_none());
        assert!(!h.is_finished(), "timeout is not a terminal state");
        assert!(t.elapsed_secs() < 1.0, "must not block past the timeout");
        // wait_timeout hands the un-finished handle back on expiry...
        let h = match h.wait_timeout(Duration::from_millis(10)) {
            Err(h) => h,
            Ok(r) => panic!("stalled stream cannot settle this fast: {r:?}"),
        };
        // ...and a generous retry drains to completion once the stall
        // clears.
        let r = h
            .wait_timeout(Duration::from_secs(30))
            .expect("stall cleared well within 30s")
            .expect("request completes");
        assert_eq!(r.tokens.len(), 3);
        assert_eq!(fp.fired(failpoint::PREFILL), 1);
        eng.shutdown();
    }
}
