//! The [`Engine`] serving facade: bounded admission, replica dispatch,
//! streaming per-request handles, and cancellation.
//!
//! One worker thread per replica owns a [`Scheduler`] and drains a
//! *bounded* request channel: [`Engine::submit`] blocks when the queue is
//! full (admission control), [`Engine::try_submit`] surfaces
//! [`EngineError::QueueFull`] so callers can shed load instead. Every
//! accepted request gets a [`RequestHandle`] streaming [`Event`]s over its
//! own channel; `cancel()` flips a shared flag the scheduler observes at
//! the next step boundary (the sequence leaves the batch, its KV cache is
//! freed) and the cancel-aware [`AdmissionQueue`] observes on its next
//! touch (a cancelled-but-still-queued request releases its capacity
//! slot immediately instead of squatting until dequeue). Replica choice
//! is an internal [`DispatchPolicy`] — least-outstanding (the
//! vllm-router default) or round-robin.

use super::batcher::{BatchPolicy, Outcome, Scheduler, Submission};
use super::queue::{AdmissionQueue, TryPushError};
use super::{Event, GenRequest, GenResponse, ServeStats};
use crate::model::transformer::Transformer;
use crate::util::metrics::{LatencyRecorder, Summary};
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

/// Errors surfaced by the submission paths. Every variant hands the
/// request back so the caller can retry, re-route or drop it.
#[derive(Debug)]
pub enum EngineError {
    /// The selected replica's bounded queue is full (backpressure).
    QueueFull(GenRequest),
    /// The engine is shutting down; no replica accepts work.
    Shutdown(GenRequest),
    /// The request can never be served (e.g. empty prompt) — rejected at
    /// submission rather than poisoning a replica worker.
    InvalidRequest(GenRequest, &'static str),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::QueueFull(r) => write!(f, "queue full (request {})", r.id),
            EngineError::Shutdown(r) => write!(f, "engine shut down (request {})", r.id),
            EngineError::InvalidRequest(r, why) => {
                write!(f, "invalid request {}: {why}", r.id)
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// How [`Engine::submit`] picks a replica.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Fewest outstanding requests, ties broken by replica index.
    #[default]
    LeastOutstanding,
    /// Strict rotation, ignoring load.
    RoundRobin,
}

/// Streaming handle to one submitted request.
///
/// Events arrive in order: `Queued`, `FirstToken`, then `Token`s, ending
/// with exactly one terminal event (`Done` or `Cancelled`). Dropping the
/// handle detaches the stream but does **not** cancel the request — call
/// [`RequestHandle::cancel`], or opt in to
/// [`RequestHandle::cancel_on_drop`] so abandoned streams reclaim their
/// batch slot and KV cache automatically.
pub struct RequestHandle {
    id: u64,
    rx: mpsc::Receiver<Event>,
    cancel: Arc<AtomicBool>,
    /// The replica's admission queue, nudged on cancel so a cancelled
    /// still-queued request frees its capacity slot for blocked
    /// producers immediately.
    queue: Arc<AdmissionQueue>,
    finished: bool,
    cancel_on_drop: bool,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Opt in to drop-cancellation: if this handle is dropped before the
    /// request settles, the request is cancelled as if
    /// [`RequestHandle::cancel`] had been called — the scheduler drops
    /// the sequence at its next step boundary and frees its KV cache, so
    /// abandoned streams (client went away, timeout paths, early `?`
    /// returns) never keep decoding. Consuming builder style:
    /// `engine.submit(req)?.cancel_on_drop()`.
    pub fn cancel_on_drop(mut self) -> Self {
        self.cancel_on_drop = true;
        self
    }

    /// Ask the scheduler to drop this request at its next step boundary.
    /// The stream still ends with a terminal event (`Cancelled`, or `Done`
    /// if the request won the race by finishing first). A request still
    /// waiting in the bounded admission queue releases its capacity slot
    /// as soon as the queue is next touched (it settles as `Cancelled`
    /// without ever prefilling).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
        // Release a still-queued request's capacity slot right away and
        // wake any producer blocked on the full queue.
        self.queue.nudge();
    }

    /// Blocking receive of the next lifecycle event. Returns `None` after
    /// the terminal event has been delivered (or if the engine vanished).
    pub fn next_event(&mut self) -> Option<Event> {
        if self.finished {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                self.finished = ev.is_terminal();
                Some(ev)
            }
            Err(_) => {
                self.finished = true;
                None
            }
        }
    }

    /// Non-blocking variant of [`RequestHandle::next_event`]. A `None`
    /// can mean "no event yet" or "stream over" — check
    /// [`RequestHandle::is_finished`] to tell them apart.
    pub fn try_next_event(&mut self) -> Option<Event> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                self.finished = ev.is_terminal();
                Some(ev)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.finished = true;
                None
            }
        }
    }

    /// True once the terminal event has been delivered (or the stream
    /// disconnected) — no further events will ever arrive.
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Drain the stream to its terminal event. `Some(response)` when the
    /// request completed, `None` when it was cancelled (or the engine
    /// disappeared mid-flight).
    pub fn wait(mut self) -> Option<GenResponse> {
        while let Some(ev) = self.next_event() {
            match ev {
                Event::Done(r) => return Some(r),
                Event::Cancelled { .. } => return None,
                _ => {}
            }
        }
        None
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        // `finished` is only set once the terminal event was delivered,
        // so an opted-in drop before that point requests cancellation
        // (a no-op race if the request wins by completing first).
        if self.cancel_on_drop && !self.finished {
            self.cancel.store(true, Ordering::SeqCst);
            self.queue.nudge();
        }
    }
}

struct Replica {
    queue: Arc<AdmissionQueue>,
    handle: Option<thread::JoinHandle<ServeStats>>,
    outstanding: Arc<AtomicUsize>,
}

/// Configures and builds an [`Engine`].
pub struct EngineBuilder {
    replicas: usize,
    batch: BatchPolicy,
    dispatch: DispatchPolicy,
    queue_capacity: usize,
    seed: u64,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            replicas: 1,
            batch: BatchPolicy::default(),
            dispatch: DispatchPolicy::default(),
            queue_capacity: 64,
            seed: 0,
        }
    }
}

impl EngineBuilder {
    /// Number of model replicas (worker threads); all share one
    /// `Arc`-held copy of the weights (read-only at serve time), so
    /// N-replica memory is ~1× the model. Default 1.
    pub fn replicas(mut self, n: usize) -> Self {
        assert!(n > 0, "at least one replica");
        self.replicas = n;
        self
    }

    /// Full batch policy for every replica's scheduler.
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.batch = policy;
        self
    }

    /// Maximum sequences decoded together per replica (default 8).
    pub fn max_batch(mut self, n: usize) -> Self {
        assert!(n > 0, "max_batch must be positive");
        self.batch.max_batch = n;
        self
    }

    /// Token id that terminates a sequence early.
    pub fn eos(mut self, token: u32) -> Self {
        self.batch.eos = Some(token);
        self
    }

    /// Prefill chunk cap in positions (default 128): longer prompts
    /// prefill one chunk per scheduler step, interleaved with the
    /// running batch's decode steps, so a long prompt cannot stall
    /// co-batched decodes.
    pub fn prefill_chunk(mut self, n: usize) -> Self {
        assert!(n > 0, "prefill chunk must be positive");
        self.batch.prefill_chunk = n;
        self
    }

    /// Replica dispatch policy (default least-outstanding).
    pub fn dispatch(mut self, policy: DispatchPolicy) -> Self {
        self.dispatch = policy;
        self
    }

    /// Bound of each replica's pending-request queue (default 64):
    /// `submit` blocks and `try_submit` returns
    /// [`EngineError::QueueFull`] once a replica holds this many
    /// un-admitted requests.
    pub fn queue_capacity(mut self, n: usize) -> Self {
        assert!(n > 0, "queue capacity must be positive");
        self.queue_capacity = n;
        self
    }

    /// Sampler seed; replica `i` uses `seed + i` so multi-replica runs
    /// stay deterministic per replica.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Spawn the replica workers and return the engine. The model moves
    /// behind one `Arc`; every replica scheduler reads the same weights.
    pub fn build(self, model: Transformer) -> Engine {
        let latency = Arc::new(LatencyRecorder::new());
        let ttft = Arc::new(LatencyRecorder::new());
        let max_seq = model.cfg.max_seq;
        let mut replicas = Vec::with_capacity(self.replicas);
        let model = Arc::new(model);
        for i in 0..self.replicas {
            let m = Arc::clone(&model);
            let queue = Arc::new(AdmissionQueue::new(self.queue_capacity));
            let q = Arc::clone(&queue);
            let outstanding = Arc::new(AtomicUsize::new(0));
            let out_ctr = Arc::clone(&outstanding);
            let lat = Arc::clone(&latency);
            let ttf = Arc::clone(&ttft);
            let policy = self.batch;
            let seed = self.seed.wrapping_add(i as u64);
            let handle = thread::Builder::new()
                .name(format!("ams-engine-{i}"))
                .spawn(move || replica_main(q, m, policy, seed, out_ctr, lat, ttf))
                .expect("spawn engine replica");
            replicas.push(Replica {
                queue,
                handle: Some(handle),
                outstanding,
            });
        }
        Engine {
            replicas,
            dispatch: self.dispatch,
            rr: AtomicUsize::new(0),
            max_seq,
            latency,
            ttft,
        }
    }
}

/// Replica worker: drain the bounded queue into the scheduler, step it,
/// settle outcomes. Exits once the engine closes the queue *and* all
/// in-flight work has finished.
fn replica_main(
    queue: Arc<AdmissionQueue>,
    model: Arc<Transformer>,
    policy: BatchPolicy,
    seed: u64,
    outstanding: Arc<AtomicUsize>,
    latency: Arc<LatencyRecorder>,
    ttft: Arc<LatencyRecorder>,
) -> ServeStats {
    let mut sched = Scheduler::new(model, policy, seed);
    let mut stats = ServeStats::default();
    let wall = Timer::start();
    loop {
        // Block for work only when idle; otherwise pull between decode
        // steps — but only enough to fill the free batch slots, so the
        // *bounded queue* stays the real admission queue and
        // `queue_capacity` is an honest backpressure bound (draining
        // eagerly would just relocate the backlog into the scheduler's
        // unbounded queue). Cancelled-while-queued submissions drain
        // here too — the scheduler's sweep settles their terminal
        // `Cancelled` event without ever prefilling them.
        if sched.pending() == 0 {
            match queue.pop_blocking() {
                Some(sub) => sched.admit_submission(sub),
                None => break, // closed and idle: done
            }
        }
        while sched.pending() < policy.max_batch {
            match queue.try_pop() {
                Some(sub) => sched.admit_submission(sub),
                None => break,
            }
        }
        for o in sched.step() {
            match o {
                Outcome::Done(r) => {
                    stats.requests += 1;
                    stats.tokens_generated += r.tokens.len() as u64;
                    latency.record(r.total_s);
                    ttft.record(r.ttft_s);
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                }
                Outcome::Cancelled { .. } => {
                    stats.cancelled += 1;
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
    }
    stats.decode_steps = sched.steps_executed;
    stats.batched_tokens = sched.batched_tokens;
    stats.wall_s = wall.elapsed_secs();
    stats
}

/// The serving engine: the only public entry point for batched
/// generation. See the [module docs](self) for the lifecycle.
pub struct Engine {
    replicas: Vec<Replica>,
    dispatch: DispatchPolicy,
    rr: AtomicUsize,
    /// Model context bound, for request validation at submit.
    max_seq: usize,
    latency: Arc<LatencyRecorder>,
    ttft: Arc<LatencyRecorder>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Requests accepted but not yet settled, across all replicas.
    pub fn outstanding(&self) -> usize {
        self.replicas
            .iter()
            .map(|r| r.outstanding.load(Ordering::SeqCst))
            .sum()
    }

    /// Block until every accepted request has settled. Workers record a
    /// request's metrics *before* decrementing its outstanding count, so
    /// [`Engine::latency`]/[`Engine::ttft`] snapshots taken after this
    /// are complete. (Callers normally await their handles first, making
    /// this a microsecond formality.)
    pub fn drain(&self) {
        // Poll with a short sleep rather than a hot spin, so a long tail
        // generation is not taxed by a burning core while it decodes.
        while self.outstanding() > 0 {
            thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    /// End-to-end latency samples (completed requests only).
    pub fn latency(&self) -> Summary {
        self.latency.snapshot()
    }

    /// Time-to-first-token samples, measured from submission.
    pub fn ttft(&self) -> Summary {
        self.ttft.snapshot()
    }

    fn pick_replica(&self) -> usize {
        match self.dispatch {
            DispatchPolicy::LeastOutstanding => {
                self.replicas
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, r)| (r.outstanding.load(Ordering::SeqCst), *i))
                    .map(|(i, _)| i)
                    .expect("at least one replica")
            }
            DispatchPolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % self.replicas.len()
            }
        }
    }

    fn dispatch_to(
        &self,
        idx: usize,
        req: GenRequest,
        block: bool,
    ) -> Result<RequestHandle, EngineError> {
        // The scheduler/model assert on these; reject here so a bad
        // request can never panic a replica worker.
        if req.prompt.is_empty() {
            return Err(EngineError::InvalidRequest(req, "empty prompt"));
        }
        if req.prompt.len() > self.max_seq {
            return Err(EngineError::InvalidRequest(
                req,
                "prompt exceeds the model context",
            ));
        }
        let replica = &self.replicas[idx];
        let (tx_ev, rx_ev) = mpsc::channel::<Event>();
        // The TTFT stopwatch starts inside `Submission` — before any
        // queue wait, including a blocking push on a full queue.
        let sub = Submission::with_events(req, tx_ev.clone());
        let id = sub.id();
        let cancel = sub.cancel_flag();
        let _ = tx_ev.send(Event::Queued { id });
        replica.outstanding.fetch_add(1, Ordering::SeqCst);
        // A closed engine surfaces the typed `Shutdown` error with the
        // request handed back — never a panic on user input.
        let send_result = if block {
            replica
                .queue
                .push(sub)
                .map_err(|s| EngineError::Shutdown(s.into_request()))
        } else {
            replica.queue.try_push(sub).map_err(|e| match e {
                TryPushError::Full(s) => EngineError::QueueFull(s.into_request()),
                TryPushError::Closed(s) => EngineError::Shutdown(s.into_request()),
            })
        };
        match send_result {
            Ok(()) => Ok(RequestHandle {
                id,
                rx: rx_ev,
                cancel,
                queue: Arc::clone(&replica.queue),
                finished: false,
                cancel_on_drop: false,
            }),
            Err(err) => {
                replica.outstanding.fetch_sub(1, Ordering::SeqCst);
                Err(err)
            }
        }
    }

    /// Submit a request, blocking while the chosen replica's queue is
    /// full (bounded admission). Returns the streaming handle.
    pub fn submit(&self, req: GenRequest) -> Result<RequestHandle, EngineError> {
        let idx = self.pick_replica();
        self.dispatch_to(idx, req, true)
    }

    /// Non-blocking submit: [`EngineError::QueueFull`] when the chosen
    /// replica's queue is at capacity, handing the request back to the
    /// caller (shed, retry or spill to another engine).
    pub fn try_submit(&self, req: GenRequest) -> Result<RequestHandle, EngineError> {
        let idx = self.pick_replica();
        self.dispatch_to(idx, req, false)
    }

    /// Stop accepting new work without joining the replicas: every
    /// queue is closed, in-flight requests keep decoding to
    /// completion, and any later `submit`/`try_submit` returns
    /// [`EngineError::Shutdown`] with the request handed back. Call
    /// [`Engine::shutdown`] afterwards to join and collect statistics.
    pub fn close(&mut self) {
        for r in &self.replicas {
            r.queue.close();
        }
    }

    /// Stop accepting work, finish everything in flight, join the
    /// replicas and return merged statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> ServeStats {
        // Close every queue first so replicas drain concurrently.
        for r in &self.replicas {
            r.queue.close();
        }
        let mut total = ServeStats::default();
        for r in &mut self.replicas {
            if let Some(h) = r.handle.take() {
                total.merge(&h.join().unwrap_or_default());
            }
        }
        total
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;
    use crate::util::proptest::{run_prop, USize};

    fn model() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 33);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    fn engine(replicas: usize, max_batch: usize) -> Engine {
        Engine::builder()
            .replicas(replicas)
            .max_batch(max_batch)
            .seed(1)
            .build(model())
    }

    #[test]
    fn serves_and_shuts_down() {
        let eng = engine(1, 8);
        let handles: Vec<RequestHandle> = (0..5u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1, 2], 3)).unwrap())
            .collect();
        let out: Vec<GenResponse> = handles.into_iter().filter_map(|h| h.wait()).collect();
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| r.tokens.len() == 3));
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.tokens_generated, 15);
        assert!(stats.wall_s > 0.0);
    }

    #[test]
    fn event_stream_orders_and_matches_response() {
        let eng = engine(1, 4);
        let mut h = eng.submit(GenRequest::greedy(7, vec![1, 2, 3], 5)).unwrap();
        let mut streamed = Vec::new();
        let mut saw_queued = false;
        let mut done: Option<GenResponse> = None;
        while let Some(ev) = h.next_event() {
            match ev {
                Event::Queued { id } => {
                    assert_eq!(id, 7);
                    assert!(streamed.is_empty(), "Queued must precede tokens");
                    saw_queued = true;
                }
                Event::FirstToken { id, token, ttft_s } => {
                    assert_eq!(id, 7);
                    assert!(streamed.is_empty(), "FirstToken must be the first token");
                    assert!(ttft_s >= 0.0);
                    streamed.push(token);
                }
                Event::Token { id, token, index } => {
                    assert_eq!(id, 7);
                    assert_eq!(index, streamed.len(), "tokens must arrive in order");
                    streamed.push(token);
                }
                Event::Done(r) => done = Some(r),
                Event::Cancelled { .. } => panic!("never cancelled"),
            }
        }
        assert!(saw_queued);
        let done = done.expect("terminal Done");
        // Streaming satellite: greedy streamed tokens == the final result.
        assert_eq!(streamed, done.tokens);
        assert_eq!(streamed.len(), 5);
        eng.shutdown();
    }

    #[test]
    fn streaming_equals_non_streaming_greedy() {
        // The engine path (chunked prefill + streaming) must produce the
        // same greedy tokens as a bare scheduler fed the same requests.
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8], vec![4], vec![5, 6, 7, 8]];
        let mut sched = Scheduler::new(
            model(),
            BatchPolicy { max_batch: 4, ..BatchPolicy::default() },
            1,
        );
        for (i, p) in prompts.iter().enumerate() {
            sched.admit(GenRequest::greedy(i as u64, p.clone(), 6));
        }
        let mut reference = sched.run_to_completion();
        reference.sort_by_key(|r| r.id);

        let eng = Engine::builder().max_batch(4).seed(1).build(model());
        let handles: Vec<RequestHandle> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| eng.submit(GenRequest::greedy(i as u64, p.clone(), 6)).unwrap())
            .collect();
        let mut out: Vec<GenResponse> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        out.sort_by_key(|r| r.id);
        for (a, b) in out.iter().zip(&reference) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
        eng.shutdown();
    }

    #[test]
    fn cancel_mid_generation() {
        let eng = engine(1, 2);
        // A long request we cancel and a short one that must be unaffected.
        let long = eng.submit(GenRequest::greedy(0, vec![1, 2], 400)).unwrap();
        let short = eng.submit(GenRequest::greedy(1, vec![3], 4)).unwrap();
        long.cancel();
        assert!(long.wait().is_none(), "cancelled requests yield no response");
        let r = short.wait().expect("survivor completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn cancelled_stream_ends_with_terminal_event() {
        let eng = engine(1, 2);
        let mut h = eng.submit(GenRequest::greedy(0, vec![1, 2], 400)).unwrap();
        h.cancel();
        let mut terminal = 0;
        let mut after_terminal = 0;
        while let Some(ev) = h.next_event() {
            if terminal > 0 {
                after_terminal += 1;
            }
            if ev.is_terminal() {
                assert!(matches!(ev, Event::Cancelled { .. }));
                terminal += 1;
            }
        }
        assert_eq!(terminal, 1);
        assert_eq!(after_terminal, 0, "nothing may follow the terminal event");
        eng.shutdown();
    }

    /// Property (satellite): every submitted request yields exactly one
    /// terminal event, whether it completes or is cancelled at a random
    /// point in its lifecycle.
    #[test]
    fn prop_exactly_one_terminal_event() {
        run_prop(
            "one-terminal-event",
            0xE7E7,
            5,
            &USize { lo: 1, hi: 9 },
            |&n| {
                let eng = Engine::builder().max_batch(3).seed(2).build(model());
                let mut handles = Vec::new();
                for id in 0..n as u64 {
                    let h = eng
                        .submit(GenRequest::greedy(
                            id,
                            vec![(id as u32 % 50) + 1],
                            2 + (id as usize % 5),
                        ))
                        .unwrap();
                    if id % 3 == 1 {
                        h.cancel();
                    }
                    handles.push(h);
                }
                for mut h in handles {
                    let mut terminals = 0;
                    while let Some(ev) = h.next_event() {
                        if ev.is_terminal() {
                            terminals += 1;
                        }
                    }
                    if terminals != 1 {
                        return Err(format!("request {} saw {terminals} terminal events", h.id()));
                    }
                }
                eng.shutdown();
                Ok(())
            },
        );
    }

    #[test]
    fn try_submit_surfaces_queue_full() {
        // Capacity 1 and a slow long-running request: the queue must fill
        // and try_submit must hand the request back instead of panicking.
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(1)
            .seed(3)
            .build(model());
        let first = eng.submit(GenRequest::greedy(0, vec![1, 2], 60)).unwrap();
        let mut full_seen = false;
        let mut accepted = Vec::new();
        // Push until the bounded queue rejects one (the worker may admit
        // the first request before the queue fills, hence the loop).
        for id in 1..50u64 {
            match eng.try_submit(GenRequest::greedy(id, vec![2], 60)) {
                Ok(h) => accepted.push(h),
                Err(EngineError::QueueFull(req)) => {
                    assert_eq!(req.id, id, "rejected request handed back intact");
                    full_seen = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(full_seen, "bounded queue never reported QueueFull");
        // Unblock the system: cancel everything and drain (a request may
        // legitimately win the race and complete before its cancel).
        first.cancel();
        for h in &accepted {
            h.cancel();
        }
        let _ = first.wait();
        for h in accepted {
            h.wait();
        }
        eng.shutdown();
    }

    /// Satellite regression: a request cancelled while still in the
    /// bounded admission queue releases its capacity slot immediately —
    /// a subsequent try_submit succeeds with no dequeue in between —
    /// and the cancelled request still settles exactly once, without
    /// ever prefilling.
    #[test]
    fn cancelled_queued_request_frees_queue_slot() {
        // max_batch 1 + a long-running active request: the worker never
        // touches the queue while request 0 decodes, so the queue state
        // is fully deterministic. A long context keeps request 0
        // decoding for 1500 steps — ctx_full cannot retire it inside
        // the test window (test_tiny's max_seq of 64 would).
        let cfg = ModelConfig {
            max_seq: 2048,
            ..ModelConfig::test_tiny()
        };
        let ck = synthetic_checkpoint(&cfg, 33);
        let long_ctx = Transformer::from_checkpoint(&ck).unwrap();
        let eng = Engine::builder()
            .max_batch(1)
            .queue_capacity(1)
            .seed(6)
            .build(long_ctx);
        let active = eng.submit(GenRequest::greedy(0, vec![1, 2], 1500)).unwrap();
        // Wait for the worker to admit request 0 so the queue is empty.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let queued = loop {
            match eng.try_submit(GenRequest::greedy(1, vec![3], 400)) {
                Ok(h) => break h,
                Err(EngineError::QueueFull(_)) => {
                    assert!(std::time::Instant::now() < deadline, "worker never admitted");
                    thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        // The queue now holds request 1; capacity 1 ⇒ full.
        match eng.try_submit(GenRequest::greedy(2, vec![4], 400)) {
            Err(EngineError::QueueFull(req)) => assert_eq!(req.id, 2),
            other => panic!("queue must be full: {:?}", other.map(|h| h.id())),
        }
        // Cancel the queued request: its slot frees without any dequeue
        // (the worker is still busy decoding request 0).
        queued.cancel();
        let third = eng
            .try_submit(GenRequest::greedy(3, vec![5], 4))
            .expect("cancelled queued request released its capacity slot");
        // Everyone settles exactly once: 1 was cancelled in-queue (no
        // tokens, never prefilled), 3 completes once 0 is cancelled.
        active.cancel();
        assert!(active.wait().is_none());
        assert!(queued.wait().is_none(), "queued cancel yields no response");
        let r = third.wait().expect("replacement request completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 2);
    }

    /// Satellite: submitting to a closed engine surfaces the typed
    /// `Shutdown` error (request handed back) instead of panicking.
    #[test]
    fn submit_after_close_returns_shutdown_error() {
        let mut eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![1], 2)).unwrap();
        eng.close();
        match eng.submit(GenRequest::greedy(1, vec![2], 2)) {
            Err(EngineError::Shutdown(req)) => assert_eq!(req.id, 1, "request handed back"),
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("closed engine must reject submissions"),
        }
        match eng.try_submit(GenRequest::greedy(2, vec![3], 2)) {
            Err(EngineError::Shutdown(req)) => assert_eq!(req.id, 2),
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("closed engine must reject try_submit too"),
        }
        // In-flight work before the close still completes.
        assert!(h.wait().is_some());
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
    }

    /// Satellite: an abandoned handle with cancel_on_drop reclaims its
    /// sequence — the request settles as cancelled, the survivor is
    /// unaffected.
    #[test]
    fn cancel_on_drop_reclaims_abandoned_stream() {
        let eng = engine(1, 2);
        let long = eng
            .submit(GenRequest::greedy(0, vec![1, 2], 400))
            .unwrap()
            .cancel_on_drop();
        let short = eng.submit(GenRequest::greedy(1, vec![3], 4)).unwrap();
        drop(long); // client went away — the stream is abandoned
        let r = short.wait().expect("survivor completes");
        assert_eq!(r.tokens.len(), 4);
        let stats = eng.shutdown();
        assert_eq!(stats.cancelled, 1, "dropped handle cancelled its request");
        assert_eq!(stats.requests, 1);
    }

    /// Without the opt-in, dropping a handle only detaches the stream;
    /// the request still runs to completion (the documented default).
    #[test]
    fn plain_drop_does_not_cancel() {
        let eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![1, 2], 5)).unwrap();
        drop(h);
        let stats = eng.shutdown(); // waits for in-flight work
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 0);
    }

    /// A handle consumed by `wait()` (terminal event delivered) must not
    /// flip the cancel flag on drop even with cancel_on_drop set.
    #[test]
    fn cancel_on_drop_noop_after_completion() {
        let eng = engine(1, 2);
        let h = eng
            .submit(GenRequest::greedy(0, vec![1], 3))
            .unwrap()
            .cancel_on_drop();
        let r = h.wait().expect("completes normally");
        assert_eq!(r.tokens.len(), 3);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 0);
    }

    #[test]
    fn empty_prompt_rejected_at_submit() {
        let eng = engine(1, 2);
        match eng.submit(GenRequest::greedy(0, vec![], 4)) {
            Err(EngineError::InvalidRequest(req, why)) => {
                assert_eq!(req.id, 0, "request handed back intact");
                assert!(why.contains("empty"));
            }
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("empty prompt must be rejected, not panic a worker"),
        }
        // Prompts beyond the model context are rejected up front too.
        let too_long = vec![1u32; ModelConfig::test_tiny().max_seq + 1];
        match eng.submit(GenRequest::greedy(2, too_long, 2)) {
            Err(EngineError::InvalidRequest(req, why)) => {
                assert_eq!(req.id, 2);
                assert!(why.contains("context"));
            }
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("over-long prompt must be rejected, not panic a worker"),
        }
        // The engine stays healthy afterwards.
        let h = eng.submit(GenRequest::greedy(1, vec![1], 2)).unwrap();
        assert_eq!(h.wait().expect("serves normally").tokens.len(), 2);
        eng.shutdown();
    }

    #[test]
    fn round_robin_rotates_replicas() {
        let eng = Engine::builder()
            .replicas(3)
            .dispatch(DispatchPolicy::RoundRobin)
            .seed(4)
            .build(model());
        assert_eq!(eng.replica_count(), 3);
        assert_eq!(eng.pick_replica(), 0);
        assert_eq!(eng.pick_replica(), 1);
        assert_eq!(eng.pick_replica(), 2);
        assert_eq!(eng.pick_replica(), 0);
        eng.shutdown();
    }

    #[test]
    fn least_outstanding_spreads_load() {
        let eng = Engine::builder().replicas(3).seed(5).build(model());
        // Long generations keep requests outstanding, so the three
        // dispatch decisions must fan out across replicas.
        let handles: Vec<RequestHandle> = (0..3u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1, 2, 3, 4], 24)).unwrap())
            .collect();
        let out: Vec<GenResponse> = handles.into_iter().filter_map(|h| h.wait()).collect();
        assert_eq!(out.len(), 3);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        eng_stats_sane(&stats);
    }

    fn eng_stats_sane(stats: &ServeStats) {
        assert!(stats.wall_s > 0.0);
        assert!(stats.decode_steps > 0);
    }

    #[test]
    fn shutdown_completes_inflight() {
        let eng = engine(1, 4);
        let handles: Vec<RequestHandle> = (0..3u64)
            .map(|id| eng.submit(GenRequest::greedy(id, vec![1], 2)).unwrap())
            .collect();
        // Immediate shutdown: responses must still be produced.
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        for h in handles {
            assert!(h.wait().is_some(), "in-flight work finishes before join");
        }
    }

    #[test]
    fn latency_and_ttft_recorded() {
        let eng = engine(1, 2);
        let h = eng.submit(GenRequest::greedy(0, vec![3], 2)).unwrap();
        let r = h.wait().unwrap();
        assert!(r.ttft_s > 0.0);
        assert!(r.total_s >= r.ttft_s);
        eng.drain();
        assert_eq!(eng.latency().count(), 1);
        assert_eq!(eng.ttft().count(), 1);
        eng.shutdown();
    }
}
