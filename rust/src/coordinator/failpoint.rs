//! Deterministic fault injection for the serving coordinator.
//!
//! A [`FailPoints`] registry is a set of *armed* fault schedules keyed by
//! site name (see the `SITE` constants) and optionally scoped to one
//! replica via a numeric tag. Instrumented sites in the engine worker,
//! scheduler and admission queue call [`FailPoints::hit`]; an armed entry
//! whose skip/times window covers that hit fires its [`FailAction`]:
//! panic the calling thread (a replica crash), stall it (a wedged
//! forward), or report denial to the call site (a synthetic queue-full
//! burst). Schedules are deterministic — trigger steps are fixed at arm
//! time, and the registry's own randomness ([`FailPoints::seeded`] +
//! [`FailPoints::arm_random_panic`]) derives from an explicit seed — so
//! a chaos run is reproducible from its seed alone.
//!
//! The registry is process-external state *injected* through
//! [`EngineBuilder::failpoints`](super::engine::EngineBuilder::failpoints)
//! (never a global), so concurrent tests cannot interfere with each
//! other. The real implementation is compiled only under
//! `cfg(any(test, feature = "failpoints"))`; production builds get
//! inert zero-sized stubs, and every call site compiles away.

/// Site name: hit at the top of every [`Scheduler::step`]
/// (tag = replica index). Arm with a panic action to crash a replica at
/// a chosen decode step.
pub const STEP: &str = "replica-step";

/// Site name: hit before every prefill chunk (tag = replica index). Arm
/// with a stall action to wedge a replica mid-prefill.
pub const PREFILL: &str = "prefill-chunk";

/// Site name: hit on every non-blocking admission-queue push
/// (tag = replica index). Arm with a deny action for a synthetic
/// queue-full burst.
pub const QUEUE_PUSH: &str = "queue-push";

/// Site name: hit at the top of every [`Scheduler::step`] with the KV
/// page pool live (tag = replica index). Arm with a deny action to
/// force one preempt-youngest-bulk round per fire, simulating pool
/// exhaustion without actually shrinking the pool.
pub const POOL: &str = "kv-pool";

/// Site name: hit after a speculative round's draft pass and before its
/// verify forward (tag = replica index). Arm with a panic action to
/// crash a replica mid-round, with draft-quality KV rows written and
/// the frontier rewound — the chaos suite asserts no page leaks and
/// exactly one terminal event per request through this window.
pub const VERIFY: &str = "spec-verify";

/// Site name: hit at the end of every [`Scheduler::step`] when an
/// observability sink is attached (tag = replica index). Arm with a
/// deny action to force a span-ring wraparound mid-run — the oldest
/// half of the replica's trace ring is dropped, and the chaos suite
/// asserts export degrades gracefully (drop counters tick, retained
/// requests keep exactly one terminal event, no panic).
pub const TRACE_BUF: &str = "trace-buffer";

#[cfg(any(test, feature = "failpoints"))]
mod imp {
    use crate::util::prng::Rng;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    /// What an armed failpoint does when its schedule triggers.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FailAction {
        /// Panic the calling thread — simulates a replica worker crash.
        Panic,
        /// Sleep for the given milliseconds — simulates a stalled or
        /// wedged step.
        StallMs(u64),
        /// Report denial to the call site — the admission queue treats
        /// the push as refused (synthetic queue-full burst).
        Deny,
    }

    /// One armed schedule: ignore the first `skip` matching hits, fire
    /// on each of the next `times`, then stay inert.
    #[derive(Clone, Copy, Debug)]
    pub struct FailSpec {
        pub action: FailAction,
        pub skip: u64,
        pub times: u64,
    }

    impl FailSpec {
        /// Panic on the `n`-th matching hit (1-based).
        pub fn panic_on_hit(n: u64) -> FailSpec {
            FailSpec { action: FailAction::Panic, skip: n.saturating_sub(1), times: 1 }
        }

        /// Stall `ms` milliseconds on the first matching hit.
        pub fn stall_ms(ms: u64) -> FailSpec {
            FailSpec { action: FailAction::StallMs(ms), skip: 0, times: 1 }
        }

        /// Deny the next `times` matching hits.
        pub fn deny(times: u64) -> FailSpec {
            FailSpec { action: FailAction::Deny, skip: 0, times }
        }

        /// Shift the schedule: ignore the first `skip` hits.
        pub fn after(mut self, skip: u64) -> FailSpec {
            self.skip = skip;
            self
        }

        /// Fire on `times` consecutive hits instead of one.
        pub fn times(mut self, times: u64) -> FailSpec {
            self.times = times;
            self
        }
    }

    struct Armed {
        tag: Option<u64>,
        spec: FailSpec,
        hits: u64,
        fired: u64,
    }

    #[derive(Default)]
    struct Registry {
        points: HashMap<String, Vec<Armed>>,
        fired: HashMap<String, u64>,
    }

    /// See the [module docs](super) for the model.
    pub struct FailPoints {
        state: Mutex<Registry>,
        rng: Mutex<Rng>,
    }

    enum Fire {
        No,
        Panic,
        Stall(u64),
        Deny,
    }

    impl FailPoints {
        /// An inert registry (seed 0); arm sites to make it dangerous.
        pub fn new() -> Arc<FailPoints> {
            FailPoints::seeded(0)
        }

        /// A registry whose random schedules derive from `seed`.
        pub fn seeded(seed: u64) -> Arc<FailPoints> {
            Arc::new(FailPoints {
                state: Mutex::new(Registry::default()),
                rng: Mutex::new(Rng::new(seed)),
            })
        }

        /// Arm `name` for hits from every tag.
        pub fn arm(&self, name: &str, spec: FailSpec) {
            self.arm_entry(name, None, spec);
        }

        /// Arm `name` for hits from one tag (replica) only.
        pub fn arm_tagged(&self, name: &str, tag: u64, spec: FailSpec) {
            self.arm_entry(name, Some(tag), spec);
        }

        /// Arm a panic for `tag` on a hit drawn uniformly from
        /// `[lo, hi)` with the registry's seeded rng; returns the chosen
        /// 1-based hit index so the schedule can be logged/reproduced.
        pub fn arm_random_panic(&self, name: &str, tag: u64, lo: u64, hi: u64) -> u64 {
            debug_assert!(lo >= 1 && hi > lo, "hit indices are 1-based");
            let n = lo + self.rng.lock().expect("failpoint rng").below(hi - lo);
            self.arm_tagged(name, tag, FailSpec::panic_on_hit(n));
            n
        }

        fn arm_entry(&self, name: &str, tag: Option<u64>, spec: FailSpec) {
            let mut st = self.state.lock().expect("failpoint registry");
            st.points
                .entry(name.to_string())
                .or_default()
                .push(Armed { tag, spec, hits: 0, fired: 0 });
        }

        /// Remove every schedule armed under `name`.
        pub fn disarm(&self, name: &str) {
            let mut st = self.state.lock().expect("failpoint registry");
            st.points.remove(name);
        }

        /// Total fires recorded for `name` (across tags, including
        /// schedules since disarmed) — lets tests assert a fault was
        /// actually injected.
        pub fn fired(&self, name: &str) -> u64 {
            let st = self.state.lock().expect("failpoint registry");
            st.fired.get(name).copied().unwrap_or(0)
        }

        /// Record a hit at site `name` from replica `tag`. Returns true
        /// when a deny action fired; panic/stall actions take effect
        /// directly (the panic is raised *after* the registry lock is
        /// released, so the registry survives its own faults).
        pub fn hit(&self, name: &str, tag: u64) -> bool {
            let fire = {
                let mut st = self.state.lock().expect("failpoint registry");
                let mut fire = Fire::No;
                if let Some(list) = st.points.get_mut(name) {
                    for a in list.iter_mut() {
                        if a.tag.map_or(true, |t| t == tag) {
                            a.hits += 1;
                            if a.hits > a.spec.skip && a.fired < a.spec.times {
                                a.fired += 1;
                                fire = match a.spec.action {
                                    FailAction::Panic => Fire::Panic,
                                    FailAction::StallMs(ms) => Fire::Stall(ms),
                                    FailAction::Deny => Fire::Deny,
                                };
                                break;
                            }
                        }
                    }
                }
                if !matches!(fire, Fire::No) {
                    *st.fired.entry(name.to_string()).or_insert(0) += 1;
                }
                fire
            };
            match fire {
                Fire::No => false,
                Fire::Panic => panic!("failpoint '{name}' fired (tag {tag})"),
                Fire::Stall(ms) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                    false
                }
                Fire::Deny => true,
            }
        }
    }
}

#[cfg(not(any(test, feature = "failpoints")))]
mod imp {
    //! Inert production stubs: the same API surface with no state; every
    //! call compiles away.
    use std::sync::Arc;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FailAction {
        Panic,
        StallMs(u64),
        Deny,
    }

    #[derive(Clone, Copy, Debug)]
    pub struct FailSpec {
        pub action: FailAction,
        pub skip: u64,
        pub times: u64,
    }

    impl FailSpec {
        pub fn panic_on_hit(n: u64) -> FailSpec {
            FailSpec { action: FailAction::Panic, skip: n.saturating_sub(1), times: 1 }
        }
        pub fn stall_ms(ms: u64) -> FailSpec {
            FailSpec { action: FailAction::StallMs(ms), skip: 0, times: 1 }
        }
        pub fn deny(times: u64) -> FailSpec {
            FailSpec { action: FailAction::Deny, skip: 0, times }
        }
        pub fn after(mut self, skip: u64) -> FailSpec {
            self.skip = skip;
            self
        }
        pub fn times(mut self, times: u64) -> FailSpec {
            self.times = times;
            self
        }
    }

    /// Inert registry stub (build without `--features failpoints`).
    pub struct FailPoints;

    impl FailPoints {
        pub fn new() -> Arc<FailPoints> {
            Arc::new(FailPoints)
        }
        pub fn seeded(_seed: u64) -> Arc<FailPoints> {
            Arc::new(FailPoints)
        }
        pub fn arm(&self, _name: &str, _spec: FailSpec) {}
        pub fn arm_tagged(&self, _name: &str, _tag: u64, _spec: FailSpec) {}
        pub fn arm_random_panic(&self, _name: &str, _tag: u64, _lo: u64, _hi: u64) -> u64 {
            0
        }
        pub fn disarm(&self, _name: &str) {}
        pub fn fired(&self, _name: &str) -> u64 {
            0
        }
        #[inline(always)]
        pub fn hit(&self, _name: &str, _tag: u64) -> bool {
            false
        }
    }
}

pub use imp::{FailAction, FailPoints, FailSpec};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_on_nth_hit_only() {
        let fp = FailPoints::new();
        fp.arm_tagged(STEP, 0, FailSpec::panic_on_hit(3));
        assert!(!fp.hit(STEP, 0));
        assert!(!fp.hit(STEP, 0));
        assert!(!fp.hit(STEP, 1), "other tags never match a tagged arm");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| fp.hit(STEP, 0)));
        assert!(r.is_err(), "third matching hit must panic");
        assert_eq!(fp.fired(STEP), 1);
        assert!(!fp.hit(STEP, 0), "one-shot schedule stays inert after firing");
    }

    #[test]
    fn deny_burst_then_inert() {
        let fp = FailPoints::new();
        fp.arm(QUEUE_PUSH, FailSpec::deny(2));
        assert!(fp.hit(QUEUE_PUSH, 5));
        assert!(fp.hit(QUEUE_PUSH, 6));
        assert!(!fp.hit(QUEUE_PUSH, 5));
        assert_eq!(fp.fired(QUEUE_PUSH), 2);
    }

    #[test]
    fn seeded_random_schedule_is_reproducible() {
        let a = FailPoints::seeded(42).arm_random_panic(STEP, 0, 1, 50);
        let b = FailPoints::seeded(42).arm_random_panic(STEP, 0, 1, 50);
        assert_eq!(a, b, "same seed, same schedule");
        assert!((1..50).contains(&a));
    }

    #[test]
    fn disarm_clears() {
        let fp = FailPoints::new();
        fp.arm(STEP, FailSpec::panic_on_hit(1));
        fp.disarm(STEP);
        assert!(!fp.hit(STEP, 0));
    }

    #[test]
    fn skip_window_with_times() {
        let fp = FailPoints::new();
        fp.arm(QUEUE_PUSH, FailSpec::deny(2).after(1));
        assert!(!fp.hit(QUEUE_PUSH, 0), "first hit skipped");
        assert!(fp.hit(QUEUE_PUSH, 0));
        assert!(fp.hit(QUEUE_PUSH, 0));
        assert!(!fp.hit(QUEUE_PUSH, 0));
    }
}
