//! L3 serving coordinator: request types, continuous dynamic batcher,
//! engine workers and a replica router.
//!
//! The paper's contribution is the numeric format + kernels, so the
//! coordinator is deliberately vLLM-router-shaped but lean: requests enter
//! a queue, a scheduler admits them into the running batch (continuous
//! batching up to `max_batch`), every step runs one batched decode through
//! the packed kernels, finished sequences leave the batch immediately.

pub mod batcher;
pub mod router;
pub mod server;

use crate::model::sampler::Sampler;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
}

impl GenRequest {
    pub fn greedy(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u32>,
    /// Seconds from admission to first generated token.
    pub ttft_s: f64,
    /// Seconds from admission to completion.
    pub total_s: f64,
    /// Decode steps executed on behalf of this request.
    pub steps: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub batched_tokens: u64,
    pub wall_s: f64,
}

impl ServeStats {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_steps > 0 {
            self.batched_tokens as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }
}
