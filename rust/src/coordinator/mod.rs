//! L3 serving coordinator: the [`Engine`] facade over request queues,
//! continuous dynamic batching, chunked prefill, streaming responses and
//! replica dispatch.
//!
//! The paper's contribution is the numeric format + kernels, so the
//! coordinator is deliberately vLLM-router-shaped but lean. The request
//! lifecycle API is what real quantized-serving systems expose:
//!
//! ```text
//! Engine::builder().replicas(2).max_batch(8).queue_capacity(64).build(model)
//!   └─ submit(req)      -> RequestHandle (blocking when the queue is full)
//!   └─ try_submit(req)  -> Err(EngineError::QueueFull | Overloaded)
//! RequestHandle
//!   └─ next_event()     -> Queued | FirstToken | Token | Done | Cancelled
//!                          | TimedOut | Failed
//!   └─ cancel()         -> sequence dropped at the next step boundary
//!                          once admitted (queued requests settle when
//!                          dequeued), KV cache freed, terminal
//!                          Cancelled event
//!   └─ wait()           -> drain to the terminal event
//! ```
//!
//! Under the facade each replica worker owns a [`batcher::Scheduler`]:
//! requests enter a bounded queue, the scheduler admits them into the
//! running batch (continuous batching up to `max_batch`) with a *chunked
//! prefill* — the whole prompt runs as one `[prompt_len, ·]` GEMM per
//! projection through the tiled fused kernels — then every step runs one
//! batched decode, finished sequences leave the batch immediately, and
//! per-token events stream back over the per-request channel. Replica
//! dispatch (least-outstanding or round-robin) is an internal policy of
//! the engine, not a second user-facing type.
//!
//! **Fault tolerance.** Replica workers run under `catch_unwind`
//! supervision: a panic settles every in-flight sequence on that replica
//! with a terminal [`Event::Failed`] (idempotent requests — zero tokens
//! emitted — may be retried on a healthy replica instead), marks the
//! replica unhealthy so dispatch routes around it, and restarts the
//! worker with capped exponential backoff. Requests carry optional
//! [`GenRequest::queue_deadline`] / [`GenRequest::total_deadline`]
//! budgets that settle with [`Event::TimedOut`] on expiry, and a
//! [`Priority`] class: interactive requests overtake bulk in the
//! admission queue, and under overload bulk is shed first
//! ([`engine::EngineError::Overloaded`]). The [`failpoint`] registry
//! injects deterministic faults (panics, stalls, queue-full bursts) for
//! the chaos test suite.
//!
//! All request timing measures from **submission**: `ttft_s` and
//! `total_s` include queue wait.

pub mod batcher;
pub mod engine;
pub mod failpoint;
mod queue;

pub use engine::{DispatchPolicy, Engine, EngineBuilder, EngineError, RequestHandle};
pub use failpoint::{FailPoints, FailSpec};

pub use crate::kv::{TenantId, DEFAULT_TENANT};

use crate::model::sampler::Sampler;
use std::time::Duration;

/// Scheduling class of a request. Interactive requests overtake bulk
/// jobs in the admission queue, and under overload bulk is shed first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive: dequeued first, admitted up to full queue
    /// capacity.
    #[default]
    Interactive,
    /// Throughput traffic: dequeued after interactive, and refused
    /// ([`engine::EngineError::Overloaded`]) once the queue's bulk share
    /// is exhausted.
    Bulk,
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Scheduling class (default [`Priority::Interactive`]).
    pub priority: Priority,
    /// Max time the request may sit queued before admission; on expiry
    /// it settles with [`Event::TimedOut`] without touching the model.
    pub queue_deadline: Option<Duration>,
    /// Max time from submission to completion; on expiry mid-generation
    /// the sequence is evicted and settles with [`Event::TimedOut`]
    /// carrying the tokens generated so far.
    pub total_deadline: Option<Duration>,
    /// Tenant namespace for KV pages, quotas, prefix sharing and
    /// labeled metrics. `None` (the default) joins the shared
    /// [`DEFAULT_TENANT`], which preserves single-tenant behavior
    /// bit for bit.
    pub tenant: Option<TenantId>,
}

impl GenRequest {
    pub fn greedy(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
            priority: Priority::Interactive,
            queue_deadline: None,
            total_deadline: None,
            tenant: None,
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> GenRequest {
        self.priority = priority;
        self
    }

    pub fn with_tenant(mut self, tenant: TenantId) -> GenRequest {
        self.tenant = Some(tenant);
        self
    }

    /// The tenant this request bills against ([`DEFAULT_TENANT`] when
    /// none was set).
    pub fn effective_tenant(&self) -> TenantId {
        self.tenant.unwrap_or(DEFAULT_TENANT)
    }

    pub fn with_queue_deadline(mut self, d: Duration) -> GenRequest {
        self.queue_deadline = Some(d);
        self
    }

    pub fn with_total_deadline(mut self, d: Duration) -> GenRequest {
        self.total_deadline = Some(d);
        self
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u32>,
    /// Seconds from submission to first generated token (queue wait
    /// included).
    pub ttft_s: f64,
    /// Seconds from submission to completion.
    pub total_s: f64,
    /// Decode steps executed on behalf of this request (prefill counts as
    /// one).
    pub steps: usize,
    /// Tenant the request billed against (`None` when it never set
    /// one) — the engine labels per-tenant latency metrics with this.
    pub tenant: Option<TenantId>,
}

/// Per-request lifecycle event streamed over a [`RequestHandle`].
///
/// Exactly one terminal event ([`Event::Done`], [`Event::Cancelled`],
/// [`Event::TimedOut`] or [`Event::Failed`]) is emitted per submitted
/// request — under replica panics and injected faults included.
#[derive(Clone, Debug)]
pub enum Event {
    /// Accepted into the engine queue.
    Queued { id: u64 },
    /// First generated token (end of prefill). `ttft_s` measures from
    /// submission, queue wait included.
    FirstToken { id: u64, token: u32, ttft_s: f64 },
    /// A subsequent generated token; `index` is its position in the
    /// generated sequence (the first token has index 0).
    Token { id: u64, token: u32, index: usize },
    /// Terminal: the request finished (budget, EOS or context bound).
    Done(GenResponse),
    /// Terminal: the request was cancelled; carries whatever tokens were
    /// generated before the cut.
    Cancelled { id: u64, tokens: Vec<u32> },
    /// Terminal: a deadline expired; carries whatever tokens were
    /// generated before eviction (empty when it never left the queue).
    TimedOut { id: u64, tokens: Vec<u32> },
    /// Terminal: the replica serving the request panicked and the
    /// request could not be (or was not eligible to be) retried — or
    /// the KV page pool cannot hold the request even with the replica
    /// otherwise idle.
    Failed { id: u64, error: String },
}

impl Event {
    pub fn id(&self) -> u64 {
        match self {
            Event::Queued { id }
            | Event::FirstToken { id, .. }
            | Event::Token { id, .. }
            | Event::Cancelled { id, .. }
            | Event::TimedOut { id, .. }
            | Event::Failed { id, .. } => *id,
            Event::Done(r) => r.id,
        }
    }

    /// Done, Cancelled, TimedOut or Failed — the last event a request
    /// ever emits.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Event::Done(_)
                | Event::Cancelled { .. }
                | Event::TimedOut { .. }
                | Event::Failed { .. }
        )
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub cancelled: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub batched_tokens: u64,
    pub wall_s: f64,
    /// Requests that settled [`Event::TimedOut`] on a deadline.
    pub timed_out: u64,
    /// Requests that settled [`Event::Failed`] — after a replica panic,
    /// or because the KV page pool can never hold the request even with
    /// the replica otherwise idle.
    pub failed: u64,
    /// Bulk requests refused under overload (`EngineError::Overloaded`).
    pub shed: u64,
    /// Idempotent requests re-dispatched to a healthy replica after a
    /// panic.
    pub retries: u64,
    /// Worker panics caught by the supervisor.
    pub panics_recovered: u64,
    /// Worker restarts performed by the supervisor.
    pub restarts: u64,
    /// Prompt-prefix pages adopted from the KV trie instead of
    /// prefilled (each unit is one whole page of skipped prefill).
    pub prefix_hits: u64,
    /// Sequences preempted (parked) to relieve KV page-pool pressure.
    pub preemptions: u64,
    /// High-water mark of sequences concurrently admitted (active +
    /// prefilling) on any single replica.
    pub peak_concurrency: usize,
    /// Tokens drafted by speculative hi-stream rounds (0 unless
    /// speculative decoding is enabled).
    pub drafted: u64,
    /// Drafted tokens the full-precision verify pass accepted.
    pub accepted: u64,
}

impl ServeStats {
    /// Fraction of drafted tokens accepted by verify (0.0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted > 0 {
            self.accepted as f64 / self.drafted as f64
        } else {
            0.0
        }
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_steps > 0 {
            self.batched_tokens as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    /// Fold another replica's stats into this one (counters add, wall
    /// clocks overlap so the max is the fleet wall time).
    pub fn merge(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.cancelled += other.cancelled;
        self.tokens_generated += other.tokens_generated;
        self.decode_steps += other.decode_steps;
        self.batched_tokens += other.batched_tokens;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.timed_out += other.timed_out;
        self.failed += other.failed;
        self.shed += other.shed;
        self.retries += other.retries;
        self.panics_recovered += other.panics_recovered;
        self.restarts += other.restarts;
        self.prefix_hits += other.prefix_hits;
        self.preemptions += other.preemptions;
        self.peak_concurrency = self.peak_concurrency.max(other.peak_concurrency);
        self.drafted += other.drafted;
        self.accepted += other.accepted;
    }
}
