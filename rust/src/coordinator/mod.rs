//! L3 serving coordinator: the [`Engine`] facade over request queues,
//! continuous dynamic batching, chunked prefill, streaming responses and
//! replica dispatch.
//!
//! The paper's contribution is the numeric format + kernels, so the
//! coordinator is deliberately vLLM-router-shaped but lean. The request
//! lifecycle API is what real quantized-serving systems expose:
//!
//! ```text
//! Engine::builder().replicas(2).max_batch(8).queue_capacity(64).build(model)
//!   └─ submit(req)      -> RequestHandle (blocking when the queue is full)
//!   └─ try_submit(req)  -> Err(EngineError::QueueFull) for backpressure
//! RequestHandle
//!   └─ next_event()     -> Queued | FirstToken | Token | Done | Cancelled
//!   └─ cancel()         -> sequence dropped at the next step boundary
//!                          once admitted (queued requests settle when
//!                          dequeued), KV cache freed, terminal
//!                          Cancelled event
//!   └─ wait()           -> drain to the terminal event
//! ```
//!
//! Under the facade each replica worker owns a [`batcher::Scheduler`]:
//! requests enter a bounded queue, the scheduler admits them into the
//! running batch (continuous batching up to `max_batch`) with a *chunked
//! prefill* — the whole prompt runs as one `[prompt_len, ·]` GEMM per
//! projection through the tiled fused kernels — then every step runs one
//! batched decode, finished sequences leave the batch immediately, and
//! per-token events stream back over the per-request channel. Replica
//! dispatch (least-outstanding or round-robin) is an internal policy of
//! the engine, not a second user-facing type.
//!
//! All request timing measures from **submission**: `ttft_s` and
//! `total_s` include queue wait.

pub mod batcher;
pub mod engine;
mod queue;

pub use engine::{DispatchPolicy, Engine, EngineBuilder, EngineError, RequestHandle};

use crate::model::sampler::Sampler;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
}

impl GenRequest {
    pub fn greedy(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u32>,
    /// Seconds from submission to first generated token (queue wait
    /// included).
    pub ttft_s: f64,
    /// Seconds from submission to completion.
    pub total_s: f64,
    /// Decode steps executed on behalf of this request (prefill counts as
    /// one).
    pub steps: usize,
}

/// Per-request lifecycle event streamed over a [`RequestHandle`].
///
/// Exactly one terminal event ([`Event::Done`] or [`Event::Cancelled`]) is
/// emitted per submitted request.
#[derive(Clone, Debug)]
pub enum Event {
    /// Accepted into the engine queue.
    Queued { id: u64 },
    /// First generated token (end of prefill). `ttft_s` measures from
    /// submission, queue wait included.
    FirstToken { id: u64, token: u32, ttft_s: f64 },
    /// A subsequent generated token; `index` is its position in the
    /// generated sequence (the first token has index 0).
    Token { id: u64, token: u32, index: usize },
    /// Terminal: the request finished (budget, EOS or context bound).
    Done(GenResponse),
    /// Terminal: the request was cancelled; carries whatever tokens were
    /// generated before the cut.
    Cancelled { id: u64, tokens: Vec<u32> },
}

impl Event {
    pub fn id(&self) -> u64 {
        match self {
            Event::Queued { id }
            | Event::FirstToken { id, .. }
            | Event::Token { id, .. }
            | Event::Cancelled { id, .. } => *id,
            Event::Done(r) => r.id,
        }
    }

    /// Done or Cancelled — the last event a request ever emits.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done(_) | Event::Cancelled { .. })
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub cancelled: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub batched_tokens: u64,
    pub wall_s: f64,
}

impl ServeStats {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_steps > 0 {
            self.batched_tokens as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    /// Fold another replica's stats into this one (counters add, wall
    /// clocks overlap so the max is the fleet wall time).
    pub fn merge(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.cancelled += other.cancelled;
        self.tokens_generated += other.tokens_generated;
        self.decode_steps += other.decode_steps;
        self.batched_tokens += other.batched_tokens;
        self.wall_s = self.wall_s.max(other.wall_s);
    }
}
