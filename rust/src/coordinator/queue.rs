//! Cancel-aware bounded admission queue.
//!
//! The engine's replica queue used to be an `mpsc::sync_channel`, which
//! made a cancelled-but-still-queued request hold its capacity slot
//! until the replica happened to dequeue it — under backpressure a
//! client could cancel its way out of a full queue and still be told
//! `QueueFull`. This queue observes each [`Submission`]'s cancel flag:
//! every push/pop first *purges* cancelled entries out of the live
//! window (releasing their capacity slots immediately) into a reaped
//! side-list. Reaped submissions are still handed to the consumer — the
//! scheduler settles them with their terminal `Cancelled` event on its
//! normal sweep path, so the exactly-one-terminal-event invariant is
//! untouched; they just stop counting against `capacity` the moment the
//! queue is next touched.

use super::batcher::Submission;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a non-blocking push was refused; both variants hand the
/// submission back.
pub(crate) enum TryPushError {
    Full(Submission),
    Closed(Submission),
}

struct State {
    /// Un-cancelled submissions; only these count against `capacity`.
    live: VecDeque<Submission>,
    /// Cancelled-while-queued submissions awaiting their terminal
    /// settle; drained ahead of live entries.
    reaped: VecDeque<Submission>,
    closed: bool,
}

impl State {
    /// Move cancelled submissions out of the live window, releasing
    /// their capacity slots.
    fn purge(&mut self) {
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].cancelled() {
                let s = self.live.remove(i).expect("index in bounds");
                self.reaped.push_back(s);
            } else {
                i += 1;
            }
        }
    }
}

pub(crate) struct AdmissionQueue {
    capacity: usize,
    state: Mutex<State>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        assert!(capacity > 0, "queue capacity must be positive");
        AdmissionQueue {
            capacity,
            state: Mutex::new(State {
                live: VecDeque::new(),
                reaped: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Blocking push: waits while the live window is at capacity.
    /// Returns the submission when the queue is closed.
    pub fn push(&self, sub: Submission) -> Result<(), Submission> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            if st.closed {
                return Err(sub);
            }
            st.purge();
            if st.live.len() < self.capacity {
                st.live.push_back(sub);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).expect("queue lock");
        }
    }

    /// Non-blocking push; a full live window (after purging cancelled
    /// entries) refuses with [`TryPushError::Full`].
    pub fn try_push(&self, sub: Submission) -> Result<(), TryPushError> {
        let mut st = self.state.lock().expect("queue lock");
        if st.closed {
            return Err(TryPushError::Closed(sub));
        }
        st.purge();
        if st.live.len() >= self.capacity {
            return Err(TryPushError::Full(sub));
        }
        st.live.push_back(sub);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained
    /// (reaped entries included — they still need their terminal event).
    pub fn pop_blocking(&self) -> Option<Submission> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            st.purge();
            if let Some(s) = st.reaped.pop_front() {
                self.not_full.notify_one();
                return Some(s);
            }
            if let Some(s) = st.live.pop_front() {
                self.not_full.notify_one();
                return Some(s);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("queue lock");
        }
    }

    /// Non-blocking pop (`None` = nothing available right now).
    pub fn try_pop(&self) -> Option<Submission> {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        let s = st.reaped.pop_front().or_else(|| st.live.pop_front());
        if s.is_some() {
            self.not_full.notify_one();
        }
        s
    }

    /// Stop accepting work; wakes every blocked producer and consumer.
    /// Entries already queued (live or reaped) still drain.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("queue lock");
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Re-examine the queue after a cancel flag flipped: purge cancelled
    /// entries out of the live window and wake blocked producers. Called
    /// from [`RequestHandle::cancel`](super::engine::RequestHandle::cancel)
    /// so a *blocking* `submit` parked on a full queue benefits from the
    /// freed slot immediately — not only the next `try_push`/pop.
    pub fn nudge(&self) {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenRequest;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    fn sub(id: u64) -> Submission {
        Submission::new(GenRequest::greedy(id, vec![1], 4))
    }

    #[test]
    fn fifo_within_capacity() {
        let q = AdmissionQueue::new(4);
        assert!(q.try_push(sub(0)).is_ok());
        assert!(q.try_push(sub(1)).is_ok());
        assert_eq!(q.try_pop().unwrap().id(), 0);
        assert_eq!(q.try_pop().unwrap().id(), 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn full_refuses_and_hands_back() {
        let q = AdmissionQueue::new(1);
        assert!(q.try_push(sub(0)).is_ok());
        match q.try_push(sub(1)) {
            Err(TryPushError::Full(s)) => assert_eq!(s.id(), 1),
            _ => panic!("expected Full"),
        }
    }

    /// Satellite regression: cancelling a queued submission releases its
    /// capacity slot immediately — the next push succeeds without any
    /// dequeue — and the cancelled submission still comes out (ahead of
    /// live entries) so it can settle its terminal event.
    #[test]
    fn cancel_releases_capacity_immediately() {
        let q = AdmissionQueue::new(1);
        let s = sub(7);
        let flag = s.cancel_flag();
        assert!(q.try_push(s).is_ok());
        match q.try_push(sub(8)) {
            Err(TryPushError::Full(s)) => assert_eq!(s.id(), 8),
            _ => panic!("queue must be full before the cancel"),
        }
        flag.store(true, Ordering::SeqCst);
        assert!(q.try_push(sub(8)).is_ok(), "cancel freed the slot");
        // The cancelled submission is reaped, not lost: it drains first.
        assert_eq!(q.try_pop().unwrap().id(), 7);
        assert_eq!(q.try_pop().unwrap().id(), 8);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(AdmissionQueue::new(1));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert!(t.join().unwrap().is_none());
        // Closed queue refuses new work, handing the submission back.
        assert!(q.push(sub(1)).is_err());
        assert!(matches!(q.try_push(sub(2)), Err(TryPushError::Closed(_))));
    }

    #[test]
    fn close_drains_remaining_entries() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(sub(0)).is_ok());
        let s = sub(1);
        s.cancel_flag().store(true, Ordering::SeqCst);
        assert!(q.try_push(s).is_ok());
        q.close();
        // Reaped-first drain, then live, then None.
        assert_eq!(q.pop_blocking().unwrap().id(), 1);
        assert_eq!(q.pop_blocking().unwrap().id(), 0);
        assert!(q.pop_blocking().is_none());
    }
}
