//! Cancel-aware, deadline-aware, priority-ordered bounded admission
//! queue.
//!
//! The engine's replica queue used to be an `mpsc::sync_channel`, which
//! made a cancelled-but-still-queued request hold its capacity slot
//! until the replica happened to dequeue it — under backpressure a
//! client could cancel its way out of a full queue and still be told
//! `QueueFull`. This queue observes each [`Submission`]'s cancel flag
//! *and* queue deadline: every push/pop first *purges* cancelled or
//! expired entries out of the live window (releasing their capacity
//! slots immediately) into a reaped side-list. Reaped submissions are
//! still handed to the consumer — the scheduler settles them with their
//! terminal `Cancelled`/`TimedOut` event on its normal sweep path, so
//! the exactly-one-terminal-event invariant is untouched; they just stop
//! counting against `capacity` the moment the queue is next touched.
//!
//! **Priority.** The live window is two lanes: interactive entries are
//! always dequeued before bulk, so short latency-sensitive requests
//! overtake batch jobs that arrived earlier. Overload sheds
//! lowest-priority-first: bulk pushes are refused
//! ([`TryPushError::Shed`]) once occupancy reaches
//! `capacity - interactive_reserve`, keeping the reserve for interactive
//! traffic (which may fill the queue to the brim).

use super::batcher::Submission;
use super::failpoint::{self, FailPoints};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Why a non-blocking push was refused; every variant hands the
/// submission back.
pub(crate) enum TryPushError {
    /// Live window at full capacity (even an interactive push would be
    /// refused).
    Full(Submission),
    /// Bulk push refused to keep the interactive reserve free; the
    /// engine surfaces this as `EngineError::Overloaded`.
    Shed(Submission),
    Closed(Submission),
}

impl TryPushError {
    pub fn into_submission(self) -> Submission {
        match self {
            TryPushError::Full(s) | TryPushError::Shed(s) | TryPushError::Closed(s) => s,
        }
    }
}

struct State {
    /// Un-cancelled, un-expired interactive submissions.
    interactive: VecDeque<Submission>,
    /// Un-cancelled, un-expired bulk submissions; dequeued after every
    /// interactive entry.
    bulk: VecDeque<Submission>,
    /// Cancelled- or expired-while-queued submissions awaiting their
    /// terminal settle; drained ahead of live entries and free of
    /// capacity accounting.
    reaped: VecDeque<Submission>,
    closed: bool,
}

impl State {
    fn live_len(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    /// Move cancelled or queue-expired submissions out of the live
    /// window, releasing their capacity slots.
    fn purge(&mut self) {
        for lane in [&mut self.interactive, &mut self.bulk] {
            let mut i = 0;
            while i < lane.len() {
                if lane[i].cancelled() || lane[i].queue_expired() {
                    let s = lane.remove(i).expect("index in bounds");
                    self.reaped.push_back(s);
                } else {
                    i += 1;
                }
            }
        }
    }
}

pub(crate) struct AdmissionQueue {
    capacity: usize,
    /// Occupancy ceiling for bulk admission (`capacity` minus the
    /// interactive reserve).
    bulk_capacity: usize,
    state: Mutex<State>,
    not_full: Condvar,
    not_empty: Condvar,
    failpoints: Arc<FailPoints>,
    fp_tag: u64,
    /// Deepest live occupancy ever held — the `queue.depth_peak` gauge
    /// (backlog high-water mark, never reset).
    peak: AtomicUsize,
}

impl AdmissionQueue {
    /// A queue with no interactive reserve and inert failpoints.
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue::with_policy(capacity, 0, FailPoints::new(), 0)
    }

    /// `interactive_reserve` slots are admitted only to interactive
    /// submissions; `failpoints`/`tag` wire the queue into a fault
    /// registry (tag = owning replica index).
    pub fn with_policy(
        capacity: usize,
        interactive_reserve: usize,
        failpoints: Arc<FailPoints>,
        tag: u64,
    ) -> AdmissionQueue {
        assert!(capacity > 0, "queue capacity must be positive");
        assert!(
            interactive_reserve < capacity,
            "interactive reserve must leave room for bulk"
        );
        AdmissionQueue {
            capacity,
            bulk_capacity: capacity - interactive_reserve,
            state: Mutex::new(State {
                interactive: VecDeque::new(),
                bulk: VecDeque::new(),
                reaped: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            failpoints,
            fp_tag: tag,
            peak: AtomicUsize::new(0),
        }
    }

    /// Raise the high-water mark to `depth` if it exceeds the current
    /// peak (called with the state lock held, so plain max is racefree).
    fn note_depth(&self, depth: usize) {
        self.peak.fetch_max(depth, Ordering::Relaxed);
    }

    fn is_bulk(sub: &Submission) -> bool {
        sub.priority() == super::Priority::Bulk
    }

    /// Occupancy ceiling that applies to `sub`'s priority class.
    fn cap_for(&self, sub: &Submission) -> usize {
        if Self::is_bulk(sub) {
            self.bulk_capacity
        } else {
            self.capacity
        }
    }

    /// Blocking push: waits while the submission's priority class is at
    /// its occupancy ceiling. Returns the submission when the queue is
    /// closed (including when closed *while parked* — close wakes every
    /// blocked producer).
    pub fn push(&self, sub: Submission) -> Result<(), Submission> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            if st.closed {
                return Err(sub);
            }
            st.purge();
            if st.live_len() < self.cap_for(&sub) {
                let lane = if Self::is_bulk(&sub) {
                    &mut st.bulk
                } else {
                    &mut st.interactive
                };
                lane.push_back(sub);
                self.note_depth(st.live_len());
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).expect("queue lock");
        }
    }

    /// Non-blocking push. A full live window (after purging dead
    /// entries) refuses with [`TryPushError::Full`]; a bulk push over
    /// the bulk ceiling (but under total capacity) sheds with
    /// [`TryPushError::Shed`]. A `queue-push` failpoint deny reads as
    /// `Full` — a synthetic queue-full burst — but never masks
    /// `Closed`: a closed queue reports the real shutdown signal.
    pub fn try_push(&self, sub: Submission) -> Result<(), TryPushError> {
        // The failpoint fires before the lock is taken so an injected
        // panic can never poison the queue mutex. Its verdict is only
        // honored *after* the closed check below — a deny on a closed
        // queue must still read as `Closed`, not `Full`.
        let denied = self.failpoints.hit(failpoint::QUEUE_PUSH, self.fp_tag);
        let mut st = self.state.lock().expect("queue lock");
        if st.closed {
            return Err(TryPushError::Closed(sub));
        }
        if denied {
            return Err(TryPushError::Full(sub));
        }
        st.purge();
        if st.live_len() >= self.capacity {
            return Err(TryPushError::Full(sub));
        }
        if Self::is_bulk(&sub) && st.live_len() >= self.bulk_capacity {
            return Err(TryPushError::Shed(sub));
        }
        let lane = if Self::is_bulk(&sub) {
            &mut st.bulk
        } else {
            &mut st.interactive
        };
        lane.push_back(sub);
        self.note_depth(st.live_len());
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained
    /// (reaped entries included — they still need their terminal event).
    /// Order: reaped, then interactive, then bulk.
    pub fn pop_blocking(&self) -> Option<Submission> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            st.purge();
            if let Some(s) = st
                .reaped
                .pop_front()
                .or_else(|| st.interactive.pop_front())
                .or_else(|| st.bulk.pop_front())
            {
                // Parked producers wait on *heterogeneous* predicates
                // (bulk ceiling vs full capacity): notify_one could wake
                // a bulk producer still at its ceiling — which re-parks —
                // while an admissible interactive producer sleeps
                // forever. Wake them all and let the predicates decide.
                self.not_full.notify_all();
                return Some(s);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("queue lock");
        }
    }

    /// Non-blocking pop (`None` = nothing available right now).
    pub fn try_pop(&self) -> Option<Submission> {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        let s = st
            .reaped
            .pop_front()
            .or_else(|| st.interactive.pop_front())
            .or_else(|| st.bulk.pop_front());
        if s.is_some() {
            // See pop_blocking: heterogeneous wait predicates require
            // waking every parked producer.
            self.not_full.notify_all();
        }
        s
    }

    /// Non-blocking pop of *reaped* entries only — submissions that need
    /// nothing but their terminal settle. The worker drains these even
    /// when its batch is full, so cancelled/expired requests never wait
    /// behind running sequences for their terminal event.
    pub fn pop_reaped(&self) -> Option<Submission> {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        let s = st.reaped.pop_front();
        if s.is_some() {
            // See pop_blocking: heterogeneous wait predicates require
            // waking every parked producer.
            self.not_full.notify_all();
        }
        s
    }

    /// Live occupancy (capacity slots currently held) after a purge.
    /// A drained queue reports 0 — the capacity-restoration probe used
    /// by the chaos suite.
    pub fn depth(&self) -> usize {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        st.live_len()
    }

    /// Deepest live occupancy this queue ever held (never reset; purged
    /// entries counted while they were live).
    pub fn peak_depth(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Stop accepting work; wakes every blocked producer and consumer.
    /// Entries already queued (live or reaped) still drain.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("queue lock");
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Re-examine the queue after a cancel flag flipped: purge dead
    /// entries out of the live window and wake blocked producers. Called
    /// from [`RequestHandle::cancel`](super::engine::RequestHandle::cancel)
    /// so a *blocking* `submit` parked on a full queue benefits from the
    /// freed slot immediately — not only the next `try_push`/pop.
    pub fn nudge(&self) {
        let mut st = self.state.lock().expect("queue lock");
        st.purge();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::failpoint::FailSpec;
    use crate::coordinator::{GenRequest, Priority};
    use std::sync::atomic::Ordering;
    use std::time::Duration;

    fn sub(id: u64) -> Submission {
        Submission::new(GenRequest::greedy(id, vec![1], 4))
    }

    fn bulk(id: u64) -> Submission {
        Submission::new(GenRequest::greedy(id, vec![1], 4).with_priority(Priority::Bulk))
    }

    #[test]
    fn fifo_within_capacity() {
        let q = AdmissionQueue::new(4);
        assert!(q.try_push(sub(0)).is_ok());
        assert!(q.try_push(sub(1)).is_ok());
        assert_eq!(q.try_pop().unwrap().id(), 0);
        assert_eq!(q.try_pop().unwrap().id(), 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn full_refuses_and_hands_back() {
        let q = AdmissionQueue::new(1);
        assert!(q.try_push(sub(0)).is_ok());
        match q.try_push(sub(1)) {
            Err(TryPushError::Full(s)) => assert_eq!(s.id(), 1),
            _ => panic!("expected Full"),
        }
    }

    /// Satellite regression: cancelling a queued submission releases its
    /// capacity slot immediately — the next push succeeds without any
    /// dequeue — and the cancelled submission still comes out (ahead of
    /// live entries) so it can settle its terminal event.
    #[test]
    fn cancel_releases_capacity_immediately() {
        let q = AdmissionQueue::new(1);
        let s = sub(7);
        let flag = s.cancel_flag();
        assert!(q.try_push(s).is_ok());
        match q.try_push(sub(8)) {
            Err(TryPushError::Full(s)) => assert_eq!(s.id(), 8),
            _ => panic!("queue must be full before the cancel"),
        }
        flag.store(true, Ordering::SeqCst);
        assert!(q.try_push(sub(8)).is_ok(), "cancel freed the slot");
        // The cancelled submission is reaped, not lost: it drains first.
        assert_eq!(q.try_pop().unwrap().id(), 7);
        assert_eq!(q.try_pop().unwrap().id(), 8);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = std::sync::Arc::new(AdmissionQueue::new(1));
        let q2 = std::sync::Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert!(t.join().unwrap().is_none());
        // Closed queue refuses new work, handing the submission back.
        assert!(q.push(sub(1)).is_err());
        assert!(matches!(q.try_push(sub(2)), Err(TryPushError::Closed(_))));
    }

    #[test]
    fn close_drains_remaining_entries() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(sub(0)).is_ok());
        let s = sub(1);
        s.cancel_flag().store(true, Ordering::SeqCst);
        assert!(q.try_push(s).is_ok());
        q.close();
        // Reaped-first drain, then live, then None.
        assert_eq!(q.pop_blocking().unwrap().id(), 1);
        assert_eq!(q.pop_blocking().unwrap().id(), 0);
        assert!(q.pop_blocking().is_none());
    }

    #[test]
    fn interactive_overtakes_bulk() {
        let q = AdmissionQueue::new(4);
        assert!(q.try_push(bulk(0)).is_ok());
        assert!(q.try_push(bulk(1)).is_ok());
        assert!(q.try_push(sub(2)).is_ok());
        // Interactive dequeues first despite arriving last; bulk keeps
        // FIFO order among itself.
        assert_eq!(q.try_pop().unwrap().id(), 2);
        assert_eq!(q.try_pop().unwrap().id(), 0);
        assert_eq!(q.try_pop().unwrap().id(), 1);
    }

    #[test]
    fn bulk_sheds_at_reserve_interactive_fills_to_brim() {
        // capacity 3, reserve 1 => bulk ceiling 2.
        let q = AdmissionQueue::with_policy(3, 1, FailPoints::new(), 0);
        assert!(q.try_push(bulk(0)).is_ok());
        assert!(q.try_push(bulk(1)).is_ok());
        match q.try_push(bulk(2)) {
            Err(TryPushError::Shed(s)) => assert_eq!(s.id(), 2),
            _ => panic!("expected Shed at the bulk ceiling"),
        }
        // The reserved slot is still open to interactive traffic...
        assert!(q.try_push(sub(3)).is_ok());
        // ...and a full queue refuses even interactive with Full.
        assert!(matches!(q.try_push(sub(4)), Err(TryPushError::Full(_))));
    }

    #[test]
    fn queue_deadline_expiry_frees_slot_and_reaps() {
        let q = AdmissionQueue::new(1);
        let s = Submission::new(
            GenRequest::greedy(9, vec![1], 4).with_queue_deadline(Duration::from_millis(5)),
        );
        assert!(q.try_push(s).is_ok());
        assert_eq!(q.depth(), 1);
        std::thread::sleep(Duration::from_millis(10));
        // Expiry released the capacity slot; the expired entry is still
        // delivered (via the reaped lane) for its terminal settle.
        assert_eq!(q.depth(), 0);
        assert!(q.try_push(sub(10)).is_ok());
        assert_eq!(q.pop_reaped().unwrap().id(), 9);
        assert!(q.pop_reaped().is_none(), "live entries are not reaped");
        assert_eq!(q.try_pop().unwrap().id(), 10);
    }

    #[test]
    fn peak_depth_is_a_highwater_mark() {
        let q = AdmissionQueue::new(4);
        assert_eq!(q.peak_depth(), 0);
        assert!(q.try_push(sub(0)).is_ok());
        assert!(q.try_push(sub(1)).is_ok());
        assert_eq!(q.peak_depth(), 2);
        q.try_pop();
        q.try_pop();
        assert_eq!(q.depth(), 0);
        assert_eq!(q.peak_depth(), 2, "peak never resets");
    }

    /// Regression (lost wakeup): with a bulk producer parked at its
    /// ceiling and an interactive producer parked at full capacity, a
    /// freed slot must wake *both* — under notify_one the single wakeup
    /// could land on the bulk producer (still over its ceiling, so it
    /// re-parks and swallows the signal) while the admissible
    /// interactive producer sleeps forever.
    #[test]
    fn pop_wakes_all_parked_producer_classes() {
        use std::sync::Arc;
        // capacity 2, reserve 1 => bulk ceiling 1.
        let q = Arc::new(AdmissionQueue::with_policy(2, 1, FailPoints::new(), 0));
        assert!(q.try_push(bulk(0)).is_ok()); // bulk at its ceiling
        assert!(q.try_push(sub(1)).is_ok()); // queue at full capacity
        let qb = Arc::clone(&q);
        let bulk_prod = std::thread::spawn(move || qb.push(bulk(2)));
        let qi = Arc::clone(&q);
        let inter_prod = std::thread::spawn(move || qi.push(sub(3)));
        // Let both producers park on the full queue.
        std::thread::sleep(Duration::from_millis(30));
        // Pop the interactive entry: occupancy drops to 1 == bulk
        // ceiling, so only the interactive producer is admissible. The
        // wakeup must reach it even if a bulk producer is woken first
        // and re-parks.
        assert_eq!(q.try_pop().unwrap().id(), 1);
        inter_prod
            .join()
            .unwrap()
            .unwrap_or_else(|_| panic!("interactive producer must be admitted"));
        // Drain until the queue is empty so the parked bulk producer
        // finally fits under its ceiling of 1 (interactive lane drains
        // first, then bulk).
        assert_eq!(q.try_pop().unwrap().id(), 3);
        assert_eq!(q.try_pop().unwrap().id(), 0);
        bulk_prod
            .join()
            .unwrap()
            .unwrap_or_else(|_| panic!("bulk producer must be admitted"));
        assert_eq!(q.depth(), 1);
    }

    /// Regression (failpoint ordering): an armed `queue-push` deny on a
    /// *closed* queue must report `Closed`, not `Full` — chaos schedules
    /// that close mid-burst must not mask the real shutdown signal.
    #[test]
    fn closed_queue_reports_closed_even_under_failpoint_deny() {
        let fp = FailPoints::new();
        let q = AdmissionQueue::with_policy(4, 0, std::sync::Arc::clone(&fp), 5);
        fp.arm_tagged(crate::coordinator::failpoint::QUEUE_PUSH, 5, FailSpec::deny(10));
        q.close();
        assert!(
            matches!(q.try_push(sub(0)), Err(TryPushError::Closed(_))),
            "closed wins over an injected deny"
        );
    }

    #[test]
    fn failpoint_deny_reads_as_full_burst() {
        let fp = FailPoints::new();
        let q = AdmissionQueue::with_policy(4, 0, std::sync::Arc::clone(&fp), 3);
        fp.arm_tagged(crate::coordinator::failpoint::QUEUE_PUSH, 3, FailSpec::deny(2));
        assert!(matches!(q.try_push(sub(0)), Err(TryPushError::Full(_))));
        assert!(matches!(q.try_push(sub(0)), Err(TryPushError::Full(_))));
        assert!(q.try_push(sub(0)).is_ok(), "burst over, queue admits again");
        assert_eq!(q.depth(), 1);
    }
}
