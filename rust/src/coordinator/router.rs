//! Replica router: least-outstanding-requests dispatch over N server
//! replicas (the vllm-router pattern scaled down to threads).

use super::server::Server;
use super::{GenRequest, GenResponse, ServeStats};

pub struct Router {
    replicas: Vec<Server>,
    /// Responses owed per replica (incremented on submit, settled on
    /// collect).
    owed: Vec<usize>,
}

impl Router {
    pub fn new(replicas: Vec<Server>) -> Router {
        assert!(!replicas.is_empty());
        let owed = vec![0; replicas.len()];
        Router { replicas, owed }
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Dispatch to the replica with the fewest outstanding requests
    /// (ties broken by index).
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let (idx, _) = self
            .replicas
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.outstanding(), *i))
            .unwrap();
        self.replicas[idx].submit(req);
        self.owed[idx] += 1;
        idx
    }

    /// Collect all responses for everything submitted so far (blocking).
    /// Replicas decode concurrently; draining them one at a time only
    /// serializes the *receives*, not the work.
    pub fn collect_all(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        for (i, s) in self.replicas.iter().enumerate() {
            for _ in 0..self.owed[i] {
                if let Some(r) = s.recv() {
                    out.push(r);
                }
            }
            self.owed[i] = 0;
        }
        out
    }

    pub fn shutdown(self) -> Vec<ServeStats> {
        self.replicas.into_iter().map(|s| s.shutdown()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::transformer::Transformer;
    use crate::model::ModelConfig;

    fn router(n: usize) -> Router {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 44);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        Router::new(
            (0..n)
                .map(|i| Server::spawn(model.clone(), BatchPolicy::default(), i as u64))
                .collect(),
        )
    }

    #[test]
    fn spreads_load() {
        // Use longer generations so requests stay outstanding while the
        // next ones are dispatched — least-loaded must then fan out.
        let mut r = router(3);
        let mut hit = [0usize; 3];
        for id in 0..3u64 {
            hit[r.submit(GenRequest::greedy(id, vec![1, 2, 3, 4], 24))] += 1;
        }
        let out = r.collect_all();
        assert_eq!(out.len(), 3);
        // With three simultaneously-outstanding requests the three dispatch
        // decisions must not all collapse onto one replica unless the
        // earlier ones already finished (possible but then hits are valid
        // too) — assert the common case softly and totals strictly.
        assert_eq!(hit.iter().sum::<usize>(), 3, "{hit:?}");
        r.shutdown();
    }

    #[test]
    fn all_ids_come_back() {
        let mut r = router(2);
        for id in 0..8u64 {
            r.submit(GenRequest::greedy(id, vec![2, 3], 3));
        }
        let mut ids: Vec<u64> = r.collect_all().iter().map(|x| x.id).collect();
        ids.sort();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let stats = r.shutdown();
        let total: u64 = stats.iter().map(|s| s.requests).sum();
        assert_eq!(total, 8);
    }
}
