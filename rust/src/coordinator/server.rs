//! Threaded serving front: a worker thread owns a [`Scheduler`] and drains
//! an mpsc request channel; responses flow back over a response channel.
//! Latency percentiles and throughput are recorded per server.

use super::batcher::{BatchPolicy, Scheduler};
use super::{GenRequest, GenResponse, ServeStats};
use crate::model::transformer::Transformer;
use crate::util::metrics::LatencyRecorder;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

enum Msg {
    Req(GenRequest),
    Shutdown,
}

/// Handle to a single-replica serving worker.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    rx_out: mpsc::Receiver<GenResponse>,
    handle: Option<thread::JoinHandle<ServeStats>>,
    outstanding: Arc<AtomicUsize>,
    pub latency: Arc<LatencyRecorder>,
}

impl Server {
    pub fn spawn(model: Transformer, policy: BatchPolicy, seed: u64) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (tx_out, rx_out) = mpsc::channel::<GenResponse>();
        let outstanding = Arc::new(AtomicUsize::new(0));
        let latency = Arc::new(LatencyRecorder::new());
        let out_ctr = Arc::clone(&outstanding);
        let lat = Arc::clone(&latency);
        let handle = thread::Builder::new()
            .name("ams-server".into())
            .spawn(move || {
                let mut sched = Scheduler::new(model, policy, seed);
                let mut stats = ServeStats::default();
                let wall = Timer::start();
                loop {
                    // Drain whatever is queued; block only when idle.
                    if sched.pending() == 0 {
                        match rx.recv() {
                            Ok(Msg::Req(r)) => sched.admit(r),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    }
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            Msg::Req(r) => sched.admit(r),
                            Msg::Shutdown => {
                                // Finish in-flight work, then exit.
                                for r in sched.run_to_completion() {
                                    stats.requests += 1;
                                    stats.tokens_generated += r.tokens.len() as u64;
                                    lat.record(r.total_s);
                                    out_ctr.fetch_sub(1, Ordering::SeqCst);
                                    let _ = tx_out.send(r);
                                }
                                stats.decode_steps = sched.steps_executed;
                                stats.batched_tokens = sched.batched_tokens;
                                stats.wall_s = wall.elapsed_secs();
                                return stats;
                            }
                        }
                    }
                    for r in sched.step() {
                        stats.requests += 1;
                        stats.tokens_generated += r.tokens.len() as u64;
                        lat.record(r.total_s);
                        out_ctr.fetch_sub(1, Ordering::SeqCst);
                        let _ = tx_out.send(r);
                    }
                }
                stats.decode_steps = sched.steps_executed;
                stats.batched_tokens = sched.batched_tokens;
                stats.wall_s = wall.elapsed_secs();
                stats
            })
            .expect("spawn server");
        Server {
            tx,
            rx_out,
            handle: Some(handle),
            outstanding,
            latency,
        }
    }

    pub fn submit(&self, req: GenRequest) {
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Req(req)).expect("server send");
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// Blocking receive of the next finished response.
    pub fn recv(&self) -> Option<GenResponse> {
        self.rx_out.recv().ok()
    }

    /// Collect exactly `n` responses (blocking).
    pub fn collect(&self, n: usize) -> Vec<GenResponse> {
        (0..n).filter_map(|_| self.recv()).collect()
    }

    /// Stop the worker and return its stats.
    pub fn shutdown(mut self) -> ServeStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;

    fn model() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 33);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    #[test]
    fn serves_and_shuts_down() {
        let srv = Server::spawn(model(), BatchPolicy::default(), 1);
        for id in 0..5u64 {
            srv.submit(GenRequest::greedy(id, vec![1, 2], 3));
        }
        let out = srv.collect(5);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| r.tokens.len() == 3));
        let stats = srv.shutdown();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.tokens_generated, 15);
        assert!(stats.wall_s > 0.0);
    }

    #[test]
    fn latency_recorded() {
        let srv = Server::spawn(model(), BatchPolicy::default(), 2);
        srv.submit(GenRequest::greedy(0, vec![3], 2));
        let _ = srv.collect(1);
        assert_eq!(srv.latency.snapshot().count(), 1);
        srv.shutdown();
    }

    #[test]
    fn shutdown_completes_inflight() {
        let srv = Server::spawn(model(), BatchPolicy::default(), 3);
        for id in 0..3u64 {
            srv.submit(GenRequest::greedy(id, vec![1], 2));
        }
        // Immediate shutdown: responses must still be produced.
        let stats = srv.shutdown();
        assert_eq!(stats.requests, 3);
    }
}
