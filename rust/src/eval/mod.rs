//! Accuracy evaluation harness — the Table 2 / Figure 3 / Figure 5 proxies.
//!
//! The paper scores quantized LLMs on MMLU / GSM8k / IFEval via
//! OpenCompass; those benchmarks need multi-billion-parameter models. Our
//! substitution (DESIGN.md §2) evaluates the build-time-trained tiny char
//! LM on three tasks with the same role: any task whose score degrades
//! monotonically with weight perturbation reproduces the *format ordering*
//! that Table 2 establishes:
//!
//! - **perplexity** on a held-out corpus slice (↓ better — reported as the
//!   normalized inverse so higher = better, like the paper's accuracies);
//! - **next-token top-1 accuracy** on the same slice;
//! - **pattern-completion accuracy**: greedy continuation of periodic
//!   strings the training grammar contains (an IFEval-like exact-match).

pub mod tasks;

use crate::model::transformer::{KvCache, Transformer};

/// Teacher-forced negative log-likelihood over a token stream.
/// Returns (mean NLL in nats, perplexity, top-1 accuracy).
pub fn evaluate_stream(model: &Transformer, tokens: &[u32]) -> (f64, f64, f64) {
    assert!(tokens.len() >= 2, "need at least two tokens");
    let n = tokens.len().min(model.cfg.max_seq);
    let mut cache: KvCache = model.new_cache();
    let mut nll = 0.0f64;
    let mut hits = 0usize;
    for pos in 0..n - 1 {
        let logits = model.forward(tokens[pos], pos, &mut cache);
        let target = tokens[pos + 1] as usize;
        nll += -log_softmax_at(&logits, target);
        if crate::model::sampler::argmax(&logits) == target {
            hits += 1;
        }
    }
    let steps = (n - 1) as f64;
    let mean_nll = nll / steps;
    (mean_nll, mean_nll.exp(), hits as f64 / steps)
}

/// Mean NLL over multiple independent streams (resets cache between them).
pub fn evaluate_corpus(model: &Transformer, corpus: &[u32], window: usize) -> EvalResult {
    let window = window.min(model.cfg.max_seq);
    let mut total_nll = 0.0;
    let mut total_hits = 0.0;
    let mut chunks = 0.0;
    for chunk in corpus.chunks(window) {
        if chunk.len() < 2 {
            continue;
        }
        let (nll, _, acc) = evaluate_stream(model, chunk);
        total_nll += nll;
        total_hits += acc;
        chunks += 1.0;
    }
    assert!(chunks > 0.0, "corpus too small");
    let nll = total_nll / chunks;
    EvalResult {
        nll,
        ppl: nll.exp(),
        top1: total_hits / chunks,
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub nll: f64,
    pub ppl: f64,
    pub top1: f64,
}

/// Reference trace: per-position log-softmax distributions and argmax of
/// the FP16 model, reused to score quantized variants against it.
pub struct ReferenceTrace {
    /// Chunked evaluation windows (token slices of the corpus).
    pub windows: Vec<Vec<u32>>,
    /// Per window, per position: argmax token of the reference model.
    pub argmax: Vec<Vec<u32>>,
    /// Per window, per position: reference log-probs (full vocab).
    pub logprobs: Vec<Vec<Vec<f32>>>,
}

/// Build the reference trace from the FP16 model.
pub fn reference_trace(model: &Transformer, corpus: &[u32], window: usize) -> ReferenceTrace {
    let window = window.min(model.cfg.max_seq);
    let mut tr = ReferenceTrace {
        windows: Vec::new(),
        argmax: Vec::new(),
        logprobs: Vec::new(),
    };
    for chunk in corpus.chunks(window) {
        if chunk.len() < 2 {
            continue;
        }
        let mut cache = model.new_cache();
        let mut am = Vec::new();
        let mut lps = Vec::new();
        for pos in 0..chunk.len() - 1 {
            let logits = model.forward(chunk[pos], pos, &mut cache);
            am.push(crate::model::sampler::argmax(&logits) as u32);
            lps.push(log_softmax(&logits));
        }
        tr.windows.push(chunk.to_vec());
        tr.argmax.push(am);
        tr.logprobs.push(lps);
    }
    tr
}

/// Metrics of a (quantized) model against the FP16 reference trace:
/// (agreement = greedy-match rate vs reference, mean KL(ref ‖ model) nats).
pub fn evaluate_against_reference(model: &Transformer, tr: &ReferenceTrace) -> (f64, f64) {
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut kl_sum = 0.0f64;
    for (wi, chunk) in tr.windows.iter().enumerate() {
        let mut cache = model.new_cache();
        for pos in 0..chunk.len() - 1 {
            let logits = model.forward(chunk[pos], pos, &mut cache);
            let lp = log_softmax(&logits);
            let rlp = &tr.logprobs[wi][pos];
            if crate::model::sampler::argmax(&logits) as u32 == tr.argmax[wi][pos] {
                agree += 1;
            }
            total += 1;
            // KL(ref || model) = Σ p_ref (log p_ref - log p_model).
            let mut kl = 0.0f64;
            for (r, m) in rlp.iter().zip(&lp) {
                let p = (*r as f64).exp();
                kl += p * ((*r as f64) - (*m as f64));
            }
            kl_sum += kl.max(0.0);
        }
    }
    (agree as f64 / total.max(1) as f64, kl_sum / total.max(1) as f64)
}

fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits
        .iter()
        .map(|&l| ((l as f64) - m).exp())
        .sum::<f64>()
        .ln()
        + m;
    logits.iter().map(|&l| (l as f64 - lse) as f32).collect()
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&l| ((l as f64) - m).exp()).sum::<f64>().ln() + m;
    logits[idx] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;

    fn model() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 11);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    #[test]
    fn nll_positive_and_bounded() {
        let m = model();
        let tokens: Vec<u32> = (0..32).map(|i| (i * 7 % 64) as u32).collect();
        let (nll, ppl, acc) = evaluate_stream(&m, &tokens);
        assert!(nll > 0.0 && nll.is_finite());
        assert!(ppl >= 1.0);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        // An untrained model's perplexity should be within a factor of ~3
        // of uniform (vocab=64).
        let m = model();
        let tokens: Vec<u32> = (0..60).map(|i| (i * 13 % 64) as u32).collect();
        let r = evaluate_corpus(&m, &tokens, 30);
        assert!(r.ppl > 20.0 && r.ppl < 200.0, "ppl={}", r.ppl);
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
