//! Pattern-completion task: the synthetic-corpus grammar (shared with
//! python/compile/corpus.py) embeds periodic key-value "sentences"; the
//! model is prompted with a prefix whose continuation is deterministic
//! under the grammar, and scored on greedy exact-match — our stand-in for
//! instruction-following exact-match metrics (IFEval's strict accuracy).

use crate::model::sampler::argmax;
use crate::model::transformer::Transformer;
use crate::util::prng::Rng;

/// A single prompt/continuation pair.
#[derive(Clone, Debug)]
pub struct PatternCase {
    pub prompt: Vec<u32>,
    pub target: Vec<u32>,
}

/// Build cases of the form "abcabcabc..." — after seeing two periods the
/// continuation is deterministic for a model that learned the structure.
pub fn periodic_cases(n_cases: usize, period: usize, reps: usize, tail: usize, seed: u64) -> Vec<PatternCase> {
    let mut rng = Rng::new(seed);
    let alphabet: Vec<u32> = ('a'..='z').map(|c| c as u32).collect();
    (0..n_cases)
        .map(|_| {
            let motif: Vec<u32> = (0..period)
                .map(|_| alphabet[rng.range(0, alphabet.len())])
                .collect();
            let mut seq = Vec::new();
            for _ in 0..reps {
                seq.extend_from_slice(&motif);
            }
            let target: Vec<u32> = (0..tail).map(|i| motif[i % period]).collect();
            PatternCase {
                prompt: seq,
                target,
            }
        })
        .collect()
}

/// Greedy-decode each case and report exact-match rate over target tokens.
pub fn pattern_accuracy(model: &Transformer, cases: &[PatternCase]) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for case in cases {
        let mut cache = model.new_cache();
        let mut logits = vec![];
        for (pos, &t) in case.prompt.iter().enumerate() {
            logits = model.forward(t, pos, &mut cache);
        }
        let mut pos = case.prompt.len();
        for &want in &case.target {
            let got = argmax(&logits) as u32;
            if got == want {
                correct += 1;
            }
            total += 1;
            // Teacher-force the *expected* token so one miss does not
            // cascade (per-token scoring, like prompt-level-strict split
            // into token events).
            logits = model.forward(want, pos, &mut cache);
            pos += 1;
        }
    }
    correct as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;
    use crate::model::ModelConfig;

    #[test]
    fn cases_are_periodic() {
        let cases = periodic_cases(5, 3, 4, 6, 1);
        for c in &cases {
            assert_eq!(c.prompt.len(), 12);
            assert_eq!(c.target.len(), 6);
            // Continuation continues the motif.
            for (i, &t) in c.target.iter().enumerate() {
                assert_eq!(t, c.prompt[i % 3]);
            }
        }
    }

    #[test]
    fn accuracy_in_unit_interval() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 5);
        let m = crate::model::transformer::Transformer::from_checkpoint(&ck).unwrap();
        // test_tiny vocab is 64 — map case tokens into range.
        let mut cases = periodic_cases(3, 2, 3, 4, 2);
        for c in &mut cases {
            for t in c.prompt.iter_mut().chain(c.target.iter_mut()) {
                *t %= 64;
            }
        }
        let acc = pattern_accuracy(&m, &cases);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn deterministic_cases() {
        let a = periodic_cases(4, 3, 3, 5, 9);
        let b = periodic_cases(4, 3, 3, 5, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.target, y.target);
        }
    }
}
