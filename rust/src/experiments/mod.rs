//! Experiment drivers shared by the CLI, examples and benches — one
//! function per paper table/figure (DESIGN.md §6 index).

use crate::eval::tasks::{pattern_accuracy, periodic_cases};
use crate::eval::{evaluate_against_reference, evaluate_corpus, reference_trace, EvalResult};
use crate::formats::registry::Scheme;
use crate::formats::FpFormat;
use crate::gemm::QuantLinear;
use crate::model::checkpoint::Checkpoint;
use crate::model::synthetic::{llm_weight, synthetic_checkpoint, WeightProfile};
use crate::model::transformer::Transformer;
use crate::model::{tokenizer, ModelConfig};
use crate::quant::QuantConfig;
use crate::report::{f, Table};
use crate::sim::{self, Device, Workload};
use crate::tensor::Tensor;
use crate::util::bench::{bench_with_units, BenchConfig};
use crate::util::prng::Rng;
use anyhow::Result;
use std::path::Path;

/// Load the build-time-trained tiny LM; fall back to a synthetic model of
/// the same architecture when artifacts are absent (CI without `make
/// artifacts`). Returns (model, heldout tokens, kind) where kind is
/// "trained" / "trained, synthetic heldout" / "synthetic". A missing
/// held-out corpus never downgrades existing trained *weights* — only
/// the evaluation/calibration text falls back to the synthetic grammar.
pub fn load_model(artifacts: &Path) -> Result<(Transformer, Vec<u32>, &'static str)> {
    let ckpt_path = artifacts.join("tiny_lm.amsz");
    let held_path = artifacts.join("corpus_heldout.txt");
    if ckpt_path.exists() {
        let ck = Checkpoint::load(&ckpt_path)?;
        let model = Transformer::from_checkpoint(&ck)?;
        return match std::fs::read_to_string(&held_path) {
            Ok(text) => Ok((model, tokenizer::encode(&text), "trained")),
            Err(_) => Ok((
                model,
                tokenizer::encode(&crate::model::synthetic_eval_text()),
                "trained, synthetic heldout",
            )),
        };
    }
    let ck = synthetic_checkpoint(&ModelConfig::tiny_lm(), 0xA11CE);
    let model = Transformer::from_checkpoint(&ck)?;
    // Synthetic "heldout": periodic + template text (untrained model
    // still produces a valid ordering signal via logit degradation).
    let text = crate::model::synthetic_eval_text();
    Ok((model, tokenizer::encode(&text), "synthetic"))
}

/// One row of the accuracy suite (Table 2 proxy).
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub scheme: String,
    pub bits: f64,
    pub ppl: f64,
    pub top1_pct: f64,
    pub pattern_pct: f64,
    /// Greedy-decode agreement with the FP16 model (%) — the direct proxy
    /// for "retains the same accuracy level as FP16".
    pub agree_pct: f64,
    /// Mean KL(fp16 ‖ quantized) in nats — strictly monotone in
    /// perturbation, the most sensitive ordering signal.
    pub kl: f64,
    /// Paper-style "Avg.": mean of top-1, pattern and agreement scores.
    pub avg: f64,
    pub eval: EvalResult,
}

/// E5 (Table 2 / Fig 5): evaluate the model under every scheme.
pub fn accuracy_suite(
    base: &Transformer,
    heldout: &[u32],
    schemes: &[Scheme],
    eval_tokens: usize,
) -> Vec<AccuracyRow> {
    let held = &heldout[..heldout.len().min(eval_tokens)];
    let window = base.cfg.max_seq.min(192);
    let mut cases = periodic_cases(12, 3, 4, 8, 99);
    for c in &mut cases {
        for t in c.prompt.iter_mut().chain(c.target.iter_mut()) {
            *t %= base.cfg.vocab_size as u32;
        }
    }
    let trace = reference_trace(base, held, window);
    let mut rows = Vec::new();
    for &scheme in schemes {
        let model = if scheme == Scheme::Fp16 {
            base.clone()
        } else {
            base.quantized(&QuantConfig::paper(scheme))
                .expect("paper config is always packable")
        };
        let ev = evaluate_corpus(&model, held, window);
        let pat = pattern_accuracy(&model, &cases);
        let (agree, kl) = evaluate_against_reference(&model, &trace);
        let top1 = ev.top1 * 100.0;
        let patp = pat * 100.0;
        let agp = agree * 100.0;
        rows.push(AccuracyRow {
            scheme: scheme.label(),
            bits: scheme.bits_per_weight(),
            ppl: ev.ppl,
            top1_pct: top1,
            pattern_pct: patp,
            agree_pct: agp,
            kl,
            avg: (top1 + patp + agp) / 3.0,
            eval: ev,
        });
    }
    rows
}

pub fn accuracy_table(rows: &[AccuracyRow], title: &str) -> Table {
    let mut t = Table::new(
        title,
        &["Scheme", "bits/w", "PPL", "Top-1 %", "Pattern %", "FP16-agree %", "KL (nats)", "Avg."],
    );
    for r in rows {
        t.row(vec![
            r.scheme.clone(),
            f(r.bits, 2),
            f(r.ppl, 3),
            f(r.top1_pct, 2),
            f(r.pattern_pct, 2),
            f(r.agree_pct, 2),
            format!("{:.2e}", r.kl),
            f(r.avg, 2),
        ]);
    }
    t
}

/// E6 (Table 3, simulated): paper-device speedup grid.
pub fn table3_sim() -> Vec<Table> {
    let dev = Device::paper();
    let mut out = Vec::new();
    for (name, rows, cols) in sim::table3_shapes() {
        let mut t = Table::new(
            &format!("Table 3 (simulated) — {name}"),
            &["Scheme", "b=1", "b=2", "b=4", "b=8", "b=16", "b=32"],
        );
        for scheme in Scheme::table3_set() {
            let sp = sim::speedup_row(&dev, rows, cols, scheme, &sim::TABLE3_BATCHES);
            let mut cells = vec![scheme.label()];
            cells.extend(sp.iter().map(|&v| f(v, 2)));
            t.row(cells);
        }
        out.push(t);
    }
    out
}

/// E6/E7 measured: wall-clock GEMM speedups of the packed CPU kernels vs
/// the fp16-storage baseline at (scaled) paper shapes.
pub fn table3_measured(
    shapes: &[(String, usize, usize)],
    schemes: &[Scheme],
    batches: &[usize],
    cfg: &BenchConfig,
    threads: usize,
) -> Vec<Table> {
    let entries: Vec<(String, QuantConfig)> = schemes
        .iter()
        .map(|&s| (s.label(), QuantConfig::paper(s)))
        .collect();
    table3_measured_configs(shapes, &entries, batches, cfg, threads)
}

/// [`table3_measured`] over full `(label, QuantConfig)` entries, so
/// grouped-scale variants (`PerGroup(g)`, served stream-direct at
/// aligned g) ride the same harness and baseline as the per-channel
/// schemes (used by `benches/bench_gemv.rs`).
pub fn table3_measured_configs(
    shapes: &[(String, usize, usize)],
    entries: &[(String, QuantConfig)],
    batches: &[usize],
    cfg: &BenchConfig,
    threads: usize,
) -> Vec<Table> {
    let mut rng = Rng::new(0xBEEF);
    let mut out = Vec::new();
    for (name, rows, cols) in shapes {
        let (rows, cols) = (*rows, *cols);
        let w = llm_weight(rows, cols, &WeightProfile::default(), &mut rng);
        let mut header = vec!["Scheme".to_string()];
        header.extend(batches.iter().map(|b| format!("b={b}")));
        let mut t = Table::new(
            &format!("Table 3 (measured CPU) — {name} [{rows}x{cols}]"),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        // Baseline fp16 latency per batch.
        let base = make_linear(&w, Scheme::Fp16);
        let mut base_lat = Vec::new();
        for &b in batches {
            let x = random_acts(b, cols, &mut rng);
            let mut fcall = || {
                let y = if threads > 1 {
                    base.gemm_parallel(&x, threads)
                } else {
                    base.gemm(&x)
                };
                crate::util::bench::black_box(y.len());
            };
            let r = bench_with_units("fp16", cfg, (rows * cols) as f64, &mut fcall);
            base_lat.push(r.median_secs);
        }
        for (label, qcfg) in entries {
            let lin = make_linear_with(&w, qcfg);
            let mut cells = vec![label.clone()];
            for (bi, &b) in batches.iter().enumerate() {
                let x = random_acts(b, cols, &mut rng);
                let mut fcall = || {
                    let y = if threads > 1 {
                        lin.gemm_parallel(&x, threads)
                    } else {
                        lin.gemm(&x)
                    };
                    crate::util::bench::black_box(y.len());
                };
                let r = bench_with_units(&qcfg.scheme.id(), cfg, (rows * cols) as f64, &mut fcall);
                cells.push(f(base_lat[bi] / r.median_secs, 2));
            }
            t.row(cells);
        }
        out.push(t);
    }
    out
}

/// Build a QuantLinear for any scheme (shared with benches/examples) —
/// one `Quantizer` pipeline call regardless of scheme family.
pub fn make_linear(w: &Tensor, scheme: Scheme) -> QuantLinear {
    make_linear_with(w, &QuantConfig::paper(scheme))
}

/// Build a QuantLinear under any full config (granularity, policies).
pub fn make_linear_with(w: &Tensor, cfg: &QuantConfig) -> QuantLinear {
    QuantLinear::new(
        crate::quant::pipeline::quantize_packed(w, cfg).expect("bench config must be packable"),
    )
}

pub fn random_acts(batch: usize, cols: usize, rng: &mut Rng) -> Tensor {
    crate::tensor::init::gaussian(&[batch, cols], 0.0, 1.0, rng)
}

/// E2 (Fig 2a): CSV of representable values per format.
pub fn fig2a_csv() -> String {
    let mut out = String::from("format,value\n");
    for fmt in [FpFormat::E2M1, FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2, FpFormat::E4M3] {
        for v in fmt.all_values() {
            out.push_str(&format!("{},{v}\n", fmt.name()));
        }
    }
    out
}

/// E3 (Fig 2b): CSV histogram of weights for four layers (trained model if
/// available, synthetic otherwise) — normalized per layer.
pub fn fig2b_csv(model: &Transformer) -> String {
    let mut out = String::from("layer,bin_center,density\n");
    let picks = [
        (0usize, "wq"),
        (model.cfg.n_layers / 2, "w_gate"),
        (model.cfg.n_layers / 2, "w_down"),
        (model.cfg.n_layers - 1, "wo"),
    ];
    for (li, name) in picks {
        let layer = &model.layers[li];
        let w = match name {
            "wq" => &layer.wq,
            "w_gate" => &layer.w_gate,
            "w_down" => &layer.w_down,
            _ => &layer.wo,
        };
        let data = match w {
            crate::model::transformer::Linear::Dense(t) => t.data().to_vec(),
            crate::model::transformer::Linear::Quant(_) => continue,
        };
        let std = (data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / data.len() as f64)
            .sqrt()
            .max(1e-12) as f32;
        let bins = 61;
        let range = 4.0 * std;
        let mut hist = vec![0usize; bins];
        for &x in &data {
            let t = ((x + range) / (2.0 * range) * bins as f32).floor();
            let idx = (t as isize).clamp(0, bins as isize - 1) as usize;
            hist[idx] += 1;
        }
        for (i, &h) in hist.iter().enumerate() {
            let center = -range + (i as f32 + 0.5) * 2.0 * range / bins as f32;
            out.push_str(&format!(
                "layers.{li}.{name},{center},{}\n",
                h as f64 / data.len() as f64
            ));
        }
    }
    out
}

/// A3 (k sweep): bits/weight vs MSE frontier for a base format.
pub fn k_sweep(base: FpFormat, ks: &[usize], seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let w = llm_weight(64, 768, &WeightProfile::default(), &mut rng);
    let mut t = Table::new(
        &format!("k-sweep over {} (A3)", base.name()),
        &["k", "bits/w", "MSE", "SQNR dB"],
    );
    // k=1: plain FPx.
    let q0 = crate::quant::sharing::quantize(&w, &QuantConfig::paper(Scheme::Fp(base))).unwrap();
    let d0 = q0.dequantize();
    t.row(vec![
        "1 (no sharing)".into(),
        f(base.bits() as f64, 2),
        format!("{:.3e}", w.mse(&d0)),
        f(crate::quant::metrics::sqnr_db(&w, &d0), 2),
    ]);
    for &k in ks {
        let scheme = Scheme::Ams { base, k };
        let q = crate::quant::sharing::quantize(&w, &QuantConfig::paper(scheme)).unwrap();
        let d = q.dequantize();
        t.row(vec![
            k.to_string(),
            f(scheme.bits_per_weight(), 3),
            format!("{:.3e}", w.mse(&d)),
            f(crate::quant::metrics::sqnr_db(&w, &d), 2),
        ]);
    }
    t
}

/// E6 workload scaled to CPU budgets: same aspect ratios as the paper's
/// shapes, divided by `shrink`.
pub fn scaled_table3_shapes(shrink: usize) -> Vec<(String, usize, usize)> {
    sim::table3_shapes()
        .into_iter()
        .map(|(n, r, c)| {
            (
                format!("{n} /{shrink}"),
                (r / shrink).max(64),
                ((c / shrink).max(64) + 15) / 16 * 16,
            )
        })
        .collect()
}

/// Roofline estimate table used in §Perf: bytes moved per scheme for one
/// GEMV and the ideal memory-bound speedup.
pub fn roofline_table(rows: usize, cols: usize) -> Table {
    let mut t = Table::new(
        &format!("Ideal memory-bound speedups at [{rows}x{cols}]"),
        &["Scheme", "bits/w", "weight MB", "ideal speedup"],
    );
    for scheme in Scheme::table3_set() {
        let bpw = scheme.bits_per_weight();
        let mb = rows as f64 * cols as f64 * bpw / 8.0 / 1e6;
        t.row(vec![
            scheme.label(),
            f(bpw, 2),
            f(mb, 2),
            f(16.0 / bpw, 2),
        ]);
    }
    t
}

/// Simulator latency detail for one workload (used by `ams-quant sim`).
pub fn sim_latency_table(rows: usize, cols: usize, batches: &[usize]) -> Table {
    let dev = Device::paper();
    let mut header = vec!["Scheme".to_string()];
    header.extend(batches.iter().map(|b| format!("µs @b={b}")));
    let mut t = Table::new(
        &format!("Simulated kernel latency — [{rows}x{cols}] on 22TF/290GBs"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for scheme in Scheme::table3_set() {
        let mut cells = vec![scheme.label()];
        for &b in batches {
            cells.push(f(
                sim::latency_us(&dev, &Workload { rows, cols, batch: b }, scheme),
                1,
            ));
        }
        t.row(cells);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2a_has_all_formats() {
        let csv = fig2a_csv();
        for name in ["e2m1", "e2m2", "e2m3", "e3m2", "e4m3"] {
            assert!(csv.contains(name));
        }
    }

    #[test]
    fn k_sweep_monotone_bits() {
        let t = k_sweep(FpFormat::E2M2, &[2, 3, 4, 8], 1);
        assert_eq!(t.rows.len(), 5);
    }

    #[test]
    fn table3_sim_shapes() {
        let ts = table3_sim();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].rows.len(), 6);
    }

    #[test]
    fn accuracy_suite_small() {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 5);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let held: Vec<u32> = (0..200).map(|i| (i * 7 % 64) as u32).collect();
        let schemes = [Scheme::Fp16, Scheme::parse("fp4").unwrap()];
        let rows = accuracy_suite(&model, &held, &schemes, 120);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].ppl > 1.0);
    }

    #[test]
    fn scaled_shapes_nonzero() {
        for (_, r, c) in scaled_table3_shapes(16) {
            assert!(r >= 64 && c >= 64);
            assert_eq!(c % 16, 0);
        }
    }
}
