//! IEEE-754 binary16 (half) conversion — the dequantization *target* of the
//! paper's restoration kernels. Bit-exact f32 ↔ u16 with round-to-nearest-
//! even, subnormals, infinities and NaN.

/// Convert IEEE half bits to f32.
pub fn fp16_to_f32(h: u16) -> f32 {
    let sign = u32::from(h >> 15) << 31;
    let exp = u32::from((h >> 10) & 0x1F);
    let man = u32::from(h & 0x3FF);
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: value = man * 2^-24. Normalize: shift the leading
            // one of the 10-bit mantissa up to the implicit-bit position.
            let shift = man.leading_zeros() - 21; // = 10 - msb_index(man)
            let man_norm = (man << shift) & 0x3FF;
            let exp_f32 = 113 - shift; // 127 - 15 + 1 - shift
            sign | (exp_f32 << 23) | (man_norm << 13)
        }
    } else if exp == 0x1F {
        if man == 0 {
            sign | 0x7F80_0000 // ±inf
        } else {
            sign | 0x7FC0_0000 | (man << 13) // NaN (payload preserved-ish)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Convert f32 to IEEE half bits with round-to-nearest-even.
pub fn f32_to_fp16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf or NaN.
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 | ((man >> 13) as u16 & 0x3FF) | u16::from(man >> 13 == 0)
        };
    }

    let e = exp - 127 + 15; // rebias
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal half (or zero).
        if e < -10 {
            return sign; // too small -> ±0
        }
        // Add implicit bit, shift right by (1 - e) extra places.
        let man_full = man | 0x80_0000;
        let shift = (14 - e) as u32; // 23 - 10 + (1 - e)
        let half_man = man_full >> shift;
        // Round to nearest even on the dropped bits.
        let dropped = man_full & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = match dropped.cmp(&halfway) {
            std::cmp::Ordering::Greater => half_man + 1,
            std::cmp::Ordering::Equal => half_man + (half_man & 1),
            std::cmp::Ordering::Less => half_man,
        };
        return sign | rounded as u16; // may carry into exp=1: that is correct
    }

    // Normal half.
    let half_man = man >> 13;
    let dropped = man & 0x1FFF;
    let mut out = sign as u32 | ((e as u32) << 10) | half_man;
    match dropped.cmp(&0x1000) {
        std::cmp::Ordering::Greater => out += 1,
        std::cmp::Ordering::Equal => out += out & 1,
        std::cmp::Ordering::Less => {}
    }
    // Carry may roll into the exponent (and to inf) — both are correct.
    out as u16
}

/// Round-trip helper: nearest representable half value of x, as f32.
pub fn fp16_rtn(x: f32) -> f32 {
    fp16_to_f32(f32_to_fp16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(f32_to_fp16(0.0), 0x0000);
        assert_eq!(f32_to_fp16(-0.0), 0x8000);
        assert_eq!(f32_to_fp16(1.0), 0x3C00);
        assert_eq!(f32_to_fp16(-2.0), 0xC000);
        assert_eq!(f32_to_fp16(65504.0), 0x7BFF); // max half
        assert_eq!(f32_to_fp16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_fp16(6.1035156e-5), 0x0400); // min normal
        assert_eq!(f32_to_fp16(5.9604645e-8), 0x0001); // min subnormal
    }

    #[test]
    fn decode_known() {
        assert_eq!(fp16_to_f32(0x3C00), 1.0);
        assert_eq!(fp16_to_f32(0xC000), -2.0);
        assert_eq!(fp16_to_f32(0x7BFF), 65504.0);
        assert_eq!(fp16_to_f32(0x0001), 5.9604645e-8);
        assert!(fp16_to_f32(0x7C00).is_infinite());
        assert!(fp16_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn exhaustive_roundtrip_half_to_f32_to_half() {
        // Every finite half survives a round trip exactly.
        for h in 0..=0xFFFFu16 {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan handled separately
            }
            let x = fp16_to_f32(h);
            let back = f32_to_fp16(x);
            // ±0 distinction is preserved by our impl.
            assert_eq!(back, h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn rounding_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> even (1.0).
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_fp16(x), 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> even (1+2^-9... code LSB 0).
        let y = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_fp16(y), 0x3C02);
        // Slightly above halfway rounds up.
        let z = 1.0 + 2f32.powi(-11) + 2f32.powi(-20);
        assert_eq!(f32_to_fp16(z), 0x3C01);
    }

    #[test]
    fn overflow_and_underflow() {
        assert_eq!(f32_to_fp16(1e6), 0x7C00); // -> inf
        assert_eq!(f32_to_fp16(-1e6), 0xFC00);
        assert_eq!(f32_to_fp16(1e-10), 0x0000); // -> 0
        assert_eq!(f32_to_fp16(2e-8), 0x0000); // below half of min subnormal? 2e-8 < 2.98e-8 -> 0
        assert_eq!(f32_to_fp16(4e-8), 0x0001); // rounds to min subnormal
    }

    #[test]
    fn subnormal_rounding_carry() {
        // Just below min normal rounds into the normal range.
        let x = 6.097e-5; // slightly above max subnormal 6.0976e-5? keep below min normal
        let h = f32_to_fp16(x);
        let back = fp16_to_f32(h);
        assert!((back - x).abs() <= 6.0e-8 + x * 1e-3);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(fp16_to_f32(f32_to_fp16(f32::NAN)).is_nan());
    }
}
