//! Low-bit floating-point format algebra (the FPx of the paper).
//!
//! A format is `sign(1) | exponent(e) | mantissa(m)` with IEEE-754
//! semantics *minus* infinities and NaN: following the paper (§2.2) and the
//! OCP MicroScaling convention, all-ones exponents encode regular values,
//! because quantized weights are always dequantized back to FP16 where the
//! whole range is representable. Bias is the IEEE `2^(e-1) - 1`.
//!
//! `decode` is exact; `encode_rtn` implements round-to-nearest with
//! ties-to-even on the mantissa LSB — the `Round()` of Eqn. (1).

pub mod fp16;
pub mod registry;

/// A small floating-point format, e.g. e2m3 (FP6) or e2m2 (FP5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FpFormat {
    pub ebits: u32,
    pub mbits: u32,
}

impl FpFormat {
    pub const E2M1: FpFormat = FpFormat { ebits: 2, mbits: 1 }; // FP4
    pub const E2M2: FpFormat = FpFormat { ebits: 2, mbits: 2 }; // FP5
    pub const E2M3: FpFormat = FpFormat { ebits: 2, mbits: 3 }; // FP6
    pub const E3M2: FpFormat = FpFormat { ebits: 3, mbits: 2 }; // FP6 alt
    pub const E4M3: FpFormat = FpFormat { ebits: 4, mbits: 3 }; // FP8
    pub const E5M2: FpFormat = FpFormat { ebits: 5, mbits: 2 }; // FP8 alt
    pub const E5M10: FpFormat = FpFormat {
        ebits: 5,
        mbits: 10,
    }; // FP16 (no inf/nan variant used for analysis)

    pub const fn new(ebits: u32, mbits: u32) -> FpFormat {
        FpFormat { ebits, mbits }
    }

    /// Total bits including sign.
    pub const fn bits(&self) -> u32 {
        1 + self.ebits + self.mbits
    }

    /// Number of distinct code words.
    pub const fn code_count(&self) -> usize {
        1 << self.bits()
    }

    /// IEEE exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }

    pub fn name(&self) -> String {
        format!("e{}m{}", self.ebits, self.mbits)
    }

    // --- Code field accessors -------------------------------------------

    #[inline]
    pub const fn sign_of(&self, code: u16) -> u16 {
        (code >> (self.ebits + self.mbits)) & 1
    }

    #[inline]
    pub const fn exp_of(&self, code: u16) -> u16 {
        (code >> self.mbits) & ((1 << self.ebits) - 1)
    }

    #[inline]
    pub const fn man_of(&self, code: u16) -> u16 {
        code & ((1 << self.mbits) - 1)
    }

    #[inline]
    pub const fn make_code(&self, sign: u16, exp: u16, man: u16) -> u16 {
        (sign << (self.ebits + self.mbits)) | (exp << self.mbits) | man
    }

    /// Mask of valid code bits.
    pub const fn code_mask(&self) -> u16 {
        ((1u32 << self.bits()) - 1) as u16
    }

    // --- Decode ----------------------------------------------------------

    /// Exact value of a code word.
    pub fn decode(&self, code: u16) -> f32 {
        let s = self.sign_of(code);
        let e = self.exp_of(code) as i32;
        let man = self.man_of(code) as f64;
        let scale = f64::from(2.0f32).powi(-(self.mbits as i32));
        let mag = if e != 0 {
            (1.0 + man * scale) * 2f64.powi(e - self.bias())
        } else {
            // Subnormal: exponent 1-bias, no implicit leading one.
            (man * scale) * 2f64.powi(1 - self.bias())
        };
        let v = if s == 1 { -mag } else { mag } as f32;
        v
    }

    /// Largest representable magnitude (all-ones exponent and mantissa — no
    /// inf/nan in this system). This is the `M` of Eqn. (1).
    pub fn max_normal(&self) -> f32 {
        self.decode(self.make_code(0, ((1 << self.ebits) - 1) as u16, ((1 << self.mbits) - 1) as u16))
    }

    pub fn min_normal(&self) -> f32 {
        self.decode(self.make_code(0, 1, 0))
    }

    pub fn max_subnormal(&self) -> f32 {
        self.decode(self.make_code(0, 0, ((1 << self.mbits) - 1) as u16))
    }

    pub fn min_subnormal(&self) -> f32 {
        self.decode(self.make_code(0, 0, 1))
    }

    // --- Encode (round to nearest, ties to even) -------------------------

    /// Round `x` to the nearest representable value; returns the code.
    /// Values beyond ±max_normal saturate. Ties round to even mantissa LSB.
    /// `Round(w) = argmin_α |w - α|` from the paper, with IEEE tie-breaking.
    pub fn encode_rtn(&self, x: f32) -> u16 {
        if x.is_nan() {
            return 0;
        }
        let sign: u16 = if x.is_sign_negative() { 1 } else { 0 };
        let mag = x.abs();
        let maxn = self.max_normal();
        if mag >= maxn {
            return self.make_code(
                sign,
                ((1 << self.ebits) - 1) as u16,
                ((1 << self.mbits) - 1) as u16,
            );
        }
        // Positive magnitude codes are monotone in (exp, man); binary-search
        // over the unsigned code space [0, 2^(e+m)).
        let n_mag = 1u32 << (self.ebits + self.mbits);
        let (mut lo, mut hi) = (0u32, n_mag - 1);
        // Invariant: decode(lo) <= mag <= decode(hi) after the first check.
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.decode(mid as u16) <= mag {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (vlo, vhi) = (self.decode(lo as u16), self.decode(hi as u16));
        let code = if mag - vlo < vhi - mag {
            lo
        } else if mag - vlo > vhi - mag {
            hi
        } else {
            // Tie: pick the code with even LSB (IEEE round-half-to-even).
            if lo & 1 == 0 {
                lo
            } else {
                hi
            }
        };
        self.make_code(sign, 0, 0) | code as u16
    }

    /// Quantize then dequantize (no scaling) — the raw RTN of a value.
    pub fn rtn(&self, x: f32) -> f32 {
        self.decode(self.encode_rtn(x))
    }

    /// All representable values, sign included, ascending. `-0` collapses
    /// next to `+0` (both decode to 0.0).
    pub fn all_values(&self) -> Vec<f32> {
        let mut v: Vec<f32> = (0..self.code_count() as u16)
            .map(|c| self.decode(c))
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Non-negative representable magnitudes, ascending, one entry per code.
    pub fn positive_values(&self) -> Vec<f32> {
        (0..(1u32 << (self.ebits + self.mbits)) as u16)
            .map(|c| self.decode(c))
            .collect()
    }

    /// The worst-case relative quantization step around 1.0-magnitude
    /// normals: 2^-mbits (analysis helper for DESIGN §9 roofline notes).
    pub fn ulp_rel(&self) -> f32 {
        2f32.powi(-(self.mbits as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, VecF32};

    /// Table 1 of the paper, exactly.
    #[test]
    fn table1_e2m3() {
        let f = FpFormat::E2M3;
        assert_eq!(f.bias(), 1);
        assert_eq!(f.max_normal(), 7.5);
        assert_eq!(f.min_normal(), 1.0);
        assert_eq!(f.max_subnormal(), 0.875);
        assert_eq!(f.min_subnormal(), 0.125);
    }

    #[test]
    fn table1_e3m2() {
        let f = FpFormat::E3M2;
        assert_eq!(f.bias(), 3);
        assert_eq!(f.max_normal(), 28.0);
        assert_eq!(f.min_normal(), 0.25);
        assert_eq!(f.max_subnormal(), 0.1875);
        assert_eq!(f.min_subnormal(), 0.0625);
    }

    #[test]
    fn e2m1_values() {
        // FP4-e2m1: ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}
        let vals = FpFormat::E2M1.positive_values();
        assert_eq!(vals, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn e2m2_values() {
        let vals = FpFormat::E2M2.positive_values();
        assert_eq!(
            vals,
            vec![0.0, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0, 7.0]
        );
    }

    #[test]
    fn positive_codes_monotone() {
        for f in [
            FpFormat::E2M1,
            FpFormat::E2M2,
            FpFormat::E2M3,
            FpFormat::E3M2,
            FpFormat::E4M3,
            FpFormat::E5M2,
        ] {
            let vals = f.positive_values();
            for w in vals.windows(2) {
                assert!(w[0] < w[1], "{}: {} !< {}", f.name(), w[0], w[1]);
            }
        }
    }

    #[test]
    fn decode_encode_roundtrip_all_codes() {
        for f in [
            FpFormat::E2M1,
            FpFormat::E2M2,
            FpFormat::E2M3,
            FpFormat::E3M2,
            FpFormat::E4M3,
            FpFormat::E5M2,
        ] {
            for code in 0..f.code_count() as u16 {
                let v = f.decode(code);
                let back = f.encode_rtn(v);
                // -0 and +0 collapse; otherwise exact.
                if v == 0.0 {
                    assert_eq!(f.decode(back), 0.0);
                } else {
                    assert_eq!(
                        back,
                        code,
                        "{}: code {code} -> {v} -> {back}",
                        f.name()
                    );
                }
            }
        }
    }

    #[test]
    fn rtn_is_nearest() {
        // Property: for random x within range, |rtn(x) - x| <= |v - x| for
        // every representable v (argmin definition from the paper).
        for f in [FpFormat::E2M1, FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2] {
            let vals = f.all_values();
            run_prop(
                "rtn-nearest",
                0xA5A5 ^ (f.bits() as u64),
                300,
                &VecF32 {
                    min_len: 1,
                    max_len: 16,
                    scale: f.max_normal() / 2.0,
                },
                |xs| {
                    for &x in xs {
                        let q = f.rtn(x);
                        let dq = (q - x).abs();
                        for &v in &vals {
                            if (v - x).abs() + 1e-7 < dq {
                                return Err(format!(
                                    "{}: rtn({x})={q} but {v} closer",
                                    f.name()
                                ));
                            }
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn rtn_saturates() {
        let f = FpFormat::E2M3;
        assert_eq!(f.rtn(100.0), 7.5);
        assert_eq!(f.rtn(-100.0), -7.5);
        assert_eq!(f.rtn(f32::INFINITY), 7.5);
    }

    #[test]
    fn rtn_ties_to_even() {
        let f = FpFormat::E2M1; // values 2.0 (code 0b0100) and 3.0 (0b0101)
        // 2.5 is equidistant; even mantissa LSB -> 2.0.
        assert_eq!(f.rtn(2.5), 2.0);
        // 1.25 between 1.0 (0b0010) and 1.5 (0b0011) -> even -> 1.0.
        assert_eq!(f.rtn(1.25), 1.0);
    }

    #[test]
    fn zero_and_signs() {
        let f = FpFormat::E2M3;
        assert_eq!(f.decode(f.encode_rtn(0.0)), 0.0);
        assert_eq!(f.rtn(-0.3), -f.rtn(0.3));
        assert!(f.rtn(-1.2) < 0.0);
    }

    #[test]
    fn no_inf_nan_in_values() {
        for f in [FpFormat::E2M3, FpFormat::E3M2, FpFormat::E4M3, FpFormat::E5M2] {
            assert!(f.all_values().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn code_fields() {
        let f = FpFormat::E2M3;
        let c = f.make_code(1, 0b10, 0b101);
        assert_eq!(f.sign_of(c), 1);
        assert_eq!(f.exp_of(c), 0b10);
        assert_eq!(f.man_of(c), 0b101);
        assert_eq!(c & !f.code_mask(), 0);
    }
}
