//! Named quantization schemes: the paper's format vocabulary
//! (`fp16`, `fp6-e2m3`, `fp5.33`, `fp4.25`, `int4`, ...) parsed from CLI
//! strings and mapped to storage bit-widths.

use super::FpFormat;

/// Everything the repo can quantize to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// FP16 passthrough — the W16A16 baseline.
    Fp16,
    /// Plain FPx round-to-nearest (channel-wise scale).
    Fp(FpFormat),
    /// AMS: FPx RTN + groups of `k` sharing the mantissa LSB
    /// → (bits-1) + 1/k bits per weight.
    Ams { base: FpFormat, k: usize },
    /// Integer RTN baseline (int4 / int8), symmetric, channel-wise scale.
    Int { bits: u32 },
}

impl Scheme {
    /// Effective storage bits per weight (excluding per-channel scales,
    /// which are identical across schemes and amortized over the channel).
    pub fn bits_per_weight(&self) -> f64 {
        match self {
            Scheme::Fp16 => 16.0,
            Scheme::Fp(f) => f.bits() as f64,
            Scheme::Ams { base, k } => (base.bits() - 1) as f64 + 1.0 / *k as f64,
            Scheme::Int { bits } => *bits as f64,
        }
    }

    /// The underlying element format, if floating-point.
    pub fn fp_format(&self) -> Option<FpFormat> {
        match self {
            Scheme::Fp(f) => Some(*f),
            Scheme::Ams { base, .. } => Some(*base),
            _ => None,
        }
    }

    /// Sharing group size (1 when no sharing).
    pub fn group_k(&self) -> usize {
        match self {
            Scheme::Ams { k, .. } => *k,
            _ => 1,
        }
    }

    /// Paper-style display name.
    pub fn label(&self) -> String {
        match self {
            Scheme::Fp16 => "FP16".into(),
            Scheme::Fp(f) => format!("FP{} ({})", f.bits(), f.name()),
            Scheme::Ams { base, k } => {
                let bits = self.bits_per_weight();
                let _ = k;
                format!("FP{:.4} ({})", trim_bits(bits), base.name())
            }
            Scheme::Int { bits } => format!("INT{bits}"),
        }
    }

    /// Canonical parseable id (inverse of `parse`).
    pub fn id(&self) -> String {
        match self {
            Scheme::Fp16 => "fp16".into(),
            Scheme::Fp(f) => format!("fp{}-{}", f.bits(), f.name()),
            Scheme::Ams { base, k } => format!("ams-{}-k{}", base.name(), k),
            Scheme::Int { bits } => format!("int{bits}"),
        }
    }

    /// Parse a scheme name. Accepts paper spellings (`fp5.33`, `fp4.25`,
    /// `fp5.3`, `fp4.3`), explicit formats (`fp6-e2m3`, `fp8-e4m3`),
    /// defaults (`fp6`→e2m3, `fp5`→e2m2, `fp4`→e2m1, `fp8`→e4m3), generic
    /// AMS ids (`ams-e2m2-k4`), and `int4`/`int8`.
    pub fn parse(name: &str) -> Result<Scheme, String> {
        let n = name.trim().to_ascii_lowercase();
        match n.as_str() {
            "fp16" | "fp16-e5m10" | "half" | "w16a16" => return Ok(Scheme::Fp16),
            "fp8" | "fp8-e4m3" | "w8a16-fp" => return Ok(Scheme::Fp(FpFormat::E4M3)),
            "fp8-e5m2" => return Ok(Scheme::Fp(FpFormat::E5M2)),
            "fp6" | "fp6-e2m3" => return Ok(Scheme::Fp(FpFormat::E2M3)),
            "fp6-e3m2" => return Ok(Scheme::Fp(FpFormat::E3M2)),
            "fp5" | "fp5-e2m2" => return Ok(Scheme::Fp(FpFormat::E2M2)),
            "fp4" | "fp4-e2m1" => return Ok(Scheme::Fp(FpFormat::E2M1)),
            // Paper's AMS spellings: FP(x-1).y with y = 1/k over base FPx.
            "fp5.33" | "fp5.3" | "fp5.33-e2m3" | "fp5.3-e2m3" => {
                return Ok(Scheme::Ams {
                    base: FpFormat::E2M3,
                    k: 3,
                })
            }
            "fp4.5" | "fp4.5-e2m2" => {
                return Ok(Scheme::Ams {
                    base: FpFormat::E2M2,
                    k: 2,
                })
            }
            "fp4.33" | "fp4.3" | "fp4.33-e2m2" | "fp4.3-e2m2" => {
                return Ok(Scheme::Ams {
                    base: FpFormat::E2M2,
                    k: 3,
                })
            }
            "fp4.25" | "fp4.25-e2m2" => {
                return Ok(Scheme::Ams {
                    base: FpFormat::E2M2,
                    k: 4,
                })
            }
            "int4" => return Ok(Scheme::Int { bits: 4 }),
            "int8" | "w8a16" => return Ok(Scheme::Int { bits: 8 }),
            _ => {}
        }
        // Generic: ams-eXmY-kZ
        if let Some(rest) = n.strip_prefix("ams-") {
            let parts: Vec<&str> = rest.split('-').collect();
            if parts.len() == 2 {
                if let (Some(fmt), Some(k)) = (parse_fmt(parts[0]), parse_k(parts[1])) {
                    if fmt.mbits == 0 {
                        return Err(format!("'{name}': cannot share mantissa of m0 format"));
                    }
                    return Ok(Scheme::Ams { base: fmt, k });
                }
            }
        }
        // Generic: fpN-eXmY
        if let Some(rest) = n.strip_prefix("fp") {
            if let Some((_, fmt)) = rest.split_once('-') {
                if let Some(f) = parse_fmt(fmt) {
                    return Ok(Scheme::Fp(f));
                }
            }
        }
        Err(format!("unknown scheme '{name}'"))
    }

    /// The set evaluated in Table 2 / Figure 5, top (high-bit) to bottom.
    pub fn table2_set() -> Vec<Scheme> {
        ["fp16", "fp6-e2m3", "fp5.33", "fp5", "fp4.5", "fp4.33", "fp4.25", "fp4"]
            .iter()
            .map(|s| Scheme::parse(s).unwrap())
            .collect()
    }

    /// The set evaluated in Table 3 / Figure 6.
    pub fn table3_set() -> Vec<Scheme> {
        ["fp16", "fp8", "fp6-e2m3", "fp5.33", "fp5", "fp4.25"]
            .iter()
            .map(|s| Scheme::parse(s).unwrap())
            .collect()
    }

    /// The preliminary-study set of Figure 3.
    pub fn fig3_set() -> Vec<Scheme> {
        ["fp16", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4-e2m1"]
            .iter()
            .map(|s| Scheme::parse(s).unwrap())
            .collect()
    }
}

fn parse_fmt(s: &str) -> Option<FpFormat> {
    let s = s.strip_prefix('e')?;
    let (e, m) = s.split_once('m')?;
    Some(FpFormat::new(e.parse().ok()?, m.parse().ok()?))
}

fn parse_k(s: &str) -> Option<usize> {
    let k: usize = s.strip_prefix('k')?.parse().ok()?;
    (k >= 2).then_some(k)
}

fn trim_bits(b: f64) -> String {
    // 5.3333 -> "5.33", 4.25 -> "4.25", 4.5 -> "4.5"
    let s = format!("{b:.2}");
    s.trim_end_matches('0').trim_end_matches('.').to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_spellings() {
        assert_eq!(
            Scheme::parse("fp5.33").unwrap(),
            Scheme::Ams {
                base: FpFormat::E2M3,
                k: 3
            }
        );
        assert_eq!(
            Scheme::parse("FP4.25").unwrap(),
            Scheme::Ams {
                base: FpFormat::E2M2,
                k: 4
            }
        );
        assert_eq!(Scheme::parse("fp4.5").unwrap().group_k(), 2);
        assert_eq!(Scheme::parse("fp4.3").unwrap().group_k(), 3);
        assert_eq!(Scheme::parse("fp6").unwrap(), Scheme::Fp(FpFormat::E2M3));
        assert_eq!(Scheme::parse("fp6-e3m2").unwrap(), Scheme::Fp(FpFormat::E3M2));
        assert_eq!(Scheme::parse("int8").unwrap(), Scheme::Int { bits: 8 });
    }

    #[test]
    fn bits_per_weight_match_paper() {
        assert_eq!(Scheme::parse("fp16").unwrap().bits_per_weight(), 16.0);
        assert!((Scheme::parse("fp5.33").unwrap().bits_per_weight() - (5.0 + 1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(Scheme::parse("fp4.25").unwrap().bits_per_weight(), 4.25);
        assert_eq!(Scheme::parse("fp4.5").unwrap().bits_per_weight(), 4.5);
        assert_eq!(Scheme::parse("fp6").unwrap().bits_per_weight(), 6.0);
    }

    #[test]
    fn generic_ams() {
        let s = Scheme::parse("ams-e3m2-k4").unwrap();
        assert_eq!(
            s,
            Scheme::Ams {
                base: FpFormat::E3M2,
                k: 4
            }
        );
        assert_eq!(s.bits_per_weight(), 5.25);
    }

    #[test]
    fn rejects_bad() {
        assert!(Scheme::parse("fp7.77").is_err());
        assert!(Scheme::parse("ams-e2m0-k2").is_err());
        assert!(Scheme::parse("ams-e2m2-k1").is_err());
        assert!(Scheme::parse("nonsense").is_err());
    }

    #[test]
    fn id_roundtrip() {
        for name in ["fp16", "fp6-e2m3", "fp5.33", "fp4.25", "int4", "ams-e3m2-k4"] {
            let s = Scheme::parse(name).unwrap();
            assert_eq!(Scheme::parse(&s.id()).unwrap(), s, "{name}");
        }
    }

    #[test]
    fn labels() {
        assert_eq!(Scheme::parse("fp5.33").unwrap().label(), "FP5.33 (e2m3)");
        assert_eq!(Scheme::parse("fp4.25").unwrap().label(), "FP4.25 (e2m2)");
        assert_eq!(Scheme::parse("fp6").unwrap().label(), "FP6 (e2m3)");
    }

    #[test]
    fn experiment_sets() {
        assert_eq!(Scheme::table2_set().len(), 8);
        assert_eq!(Scheme::table3_set().len(), 6);
        assert_eq!(Scheme::fig3_set().len(), 5);
    }
}
