//! Scheme-specialized scalar row kernels. Each streams a packed row's
//! words and either fuses dequant+dot (`row_dot`, the table-served GEMV
//! path) or materializes the dequantized row (`row_values`, kept as the
//! bit-exact oracle for layout tests). The batched hot path lives in
//! [`super::simd`] (`dotn_*` tile kernels) — rows are no longer decoded
//! to dense f32 there.

use crate::formats::registry::Scheme;
use crate::formats::FpFormat;

/// Fused dequant–dot for one packed row (pre-scale).
pub fn row_dot(scheme: Scheme, words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    match scheme {
        Scheme::Fp16 => dot_fp16(words, cols, table, x),
        Scheme::Fp(f) if f.bits() == 8 => dot_fixed::<8>(words, cols, table, x),
        Scheme::Int { bits: 8 } => dot_fixed::<8>(words, cols, table, x),
        Scheme::Int { bits: 4 } => dot_fixed::<4>(words, cols, table, x),
        Scheme::Fp(f) if f.bits() == 6 => dot_fp6(words, cols, table, x),
        Scheme::Fp(f) if f.bits() == 5 => dot_fp5(words, cols, table, x),
        Scheme::Fp(f) if f.bits() == 4 => dot_fixed::<4>(words, cols, table, x),
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => {
            dot_fp533(words, cols, table, x)
        }
        Scheme::Ams { base, k } if base.bits() == 5 => dot_ams_e2m2(words, cols, k, table, x),
        _ => {
            // Generic fallback: unpack into a stack-ish scratch then dot.
            let mut codes = vec![0u16; cols];
            crate::pack::unpack_row(scheme, words, cols, &mut codes);
            codes
                .iter()
                .zip(x)
                .map(|(&c, &xv)| table[c as usize] * xv)
                .sum()
        }
    }
}

/// Materialize the dequantized (pre-scale) row values.
pub fn row_values(scheme: Scheme, words: &[u16], cols: usize, table: &[f32], out: &mut [f32]) {
    debug_assert!(out.len() >= cols);
    match scheme {
        Scheme::Fp16 => {
            for (o, &w) in out.iter_mut().zip(words).take(cols) {
                *o = table[w as usize];
            }
        }
        Scheme::Fp(f) if f.bits() == 8 => vals_fixed::<8>(words, cols, table, out),
        Scheme::Int { bits: 8 } => vals_fixed::<8>(words, cols, table, out),
        Scheme::Int { bits: 4 } => vals_fixed::<4>(words, cols, table, out),
        Scheme::Fp(f) if f.bits() == 6 => vals_fp6(words, cols, table, out),
        Scheme::Fp(f) if f.bits() == 5 => vals_fp5(words, cols, table, out),
        Scheme::Fp(f) if f.bits() == 4 => vals_fixed::<4>(words, cols, table, out),
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => {
            vals_fp533(words, cols, table, out)
        }
        Scheme::Ams { base, k } if base.bits() == 5 => vals_ams_e2m2(words, cols, k, table, out),
        _ => {
            let mut codes = vec![0u16; cols];
            crate::pack::unpack_row(scheme, words, cols, &mut codes);
            for (o, &c) in out.iter_mut().zip(&codes) {
                *o = table[c as usize];
            }
        }
    }
}

// --- specialized kernels -------------------------------------------------

#[inline]
fn dot_fp16(words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    let mut acc = 0f32;
    for i in 0..cols {
        acc += table[words[i] as usize] * x[i];
    }
    acc
}

/// B-bit fixed packing (4 or 8 bits, 16/B codes per word).
#[inline]
fn dot_fixed<const B: usize>(words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    let per = 16 / B;
    let mask = ((1u32 << B) - 1) as u16;
    let mut acc = 0f32;
    let full = cols / per;
    for w in 0..full {
        let word = words[w];
        let base = w * per;
        for j in 0..per {
            acc += table[((word >> (B * j)) & mask) as usize] * x[base + j];
        }
    }
    for i in full * per..cols {
        let code = (words[i / per] >> (B * (i % per))) & mask;
        acc += table[code as usize] * x[i];
    }
    acc
}

#[inline]
fn vals_fixed<const B: usize>(words: &[u16], cols: usize, table: &[f32], out: &mut [f32]) {
    let per = 16 / B;
    let mask = ((1u32 << B) - 1) as u16;
    for i in 0..cols {
        out[i] = table[((words[i / per] >> (B * (i % per))) & mask) as usize];
    }
}

/// TC-FPx FP6 (4+2): high-4 stream then low-2 stream.
#[inline]
fn dot_fp6(words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    let hi_words = cols.div_ceil(4);
    let (hi, lo) = words.split_at(hi_words);
    let mut acc = 0f32;
    let full = cols / 8;
    for blk in 0..full {
        // One lo word covers 8 codes = 2 hi words.
        let l = lo[blk];
        let h0 = hi[2 * blk];
        let h1 = hi[2 * blk + 1];
        let base = blk * 8;
        for j in 0..4 {
            let code = (((h0 >> (4 * j)) & 0xF) << 2) | ((l >> (2 * j)) & 0x3);
            acc += table[code as usize] * x[base + j];
        }
        for j in 0..4 {
            let code = (((h1 >> (4 * j)) & 0xF) << 2) | ((l >> (2 * (j + 4))) & 0x3);
            acc += table[code as usize] * x[base + 4 + j];
        }
    }
    for i in full * 8..cols {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let l = (lo[i / 8] >> (2 * (i % 8))) & 0x3;
        acc += table[((h << 2) | l) as usize] * x[i];
    }
    acc
}

#[inline]
fn vals_fp6(words: &[u16], cols: usize, table: &[f32], out: &mut [f32]) {
    let hi_words = cols.div_ceil(4);
    let (hi, lo) = words.split_at(hi_words);
    for (i, o) in out.iter_mut().enumerate().take(cols) {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let l = (lo[i / 8] >> (2 * (i % 8))) & 0x3;
        *o = table[((h << 2) | l) as usize];
    }
}

/// FP5 (4+1): high-4 stream + LSB stream.
#[inline]
fn dot_fp5(words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    let hi_words = cols.div_ceil(4);
    let (hi, lsb) = words.split_at(hi_words);
    let mut acc = 0f32;
    let full = cols / 16;
    for blk in 0..full {
        let bits = lsb[blk];
        let base = blk * 16;
        for w in 0..4 {
            let h = hi[4 * blk + w];
            for j in 0..4 {
                let idx = w * 4 + j;
                let code = (((h >> (4 * j)) & 0xF) << 1) | ((bits >> idx) & 1);
                acc += table[code as usize] * x[base + idx];
            }
        }
    }
    for i in full * 16..cols {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let b = (lsb[i / 16] >> (i % 16)) & 1;
        acc += table[((h << 1) | b) as usize] * x[i];
    }
    acc
}

#[inline]
fn vals_fp5(words: &[u16], cols: usize, table: &[f32], out: &mut [f32]) {
    let hi_words = cols.div_ceil(4);
    let (hi, lsb) = words.split_at(hi_words);
    for (i, o) in out.iter_mut().enumerate().take(cols) {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let b = (lsb[i / 16] >> (i % 16)) & 1;
        *o = table[((h << 1) | b) as usize];
    }
}

/// FP5.33: one u16 per 3 codes + shared LSB (continuous packing).
#[inline]
fn dot_fp533(words: &[u16], cols: usize, table: &[f32], x: &[f32]) -> f32 {
    let mut acc = 0f32;
    let full = cols / 3;
    for (g, &w) in words.iter().enumerate().take(full) {
        let shared = (w >> 15) & 1;
        let base = g * 3;
        let c0 = (((w) & 0x1F) << 1) | shared;
        let c1 = (((w >> 5) & 0x1F) << 1) | shared;
        let c2 = (((w >> 10) & 0x1F) << 1) | shared;
        acc += table[c0 as usize] * x[base]
            + table[c1 as usize] * x[base + 1]
            + table[c2 as usize] * x[base + 2];
    }
    for i in full * 3..cols {
        let w = words[i / 3];
        let shared = (w >> 15) & 1;
        let code = (((w >> (5 * (i % 3))) & 0x1F) << 1) | shared;
        acc += table[code as usize] * x[i];
    }
    acc
}

#[inline]
fn vals_fp533(words: &[u16], cols: usize, table: &[f32], out: &mut [f32]) {
    for (i, o) in out.iter_mut().enumerate().take(cols) {
        let w = words[i / 3];
        let shared = (w >> 15) & 1;
        *o = table[((((w >> (5 * (i % 3))) & 0x1F) << 1) | shared) as usize];
    }
}

/// AMS e2m2 (FP4.5 / FP4.33 / FP4.25): high-4 stream + shared-bit stream.
#[inline]
fn dot_ams_e2m2(words: &[u16], cols: usize, k: usize, table: &[f32], x: &[f32]) -> f32 {
    let hi_words = cols.div_ceil(4);
    let (hi, shared) = words.split_at(hi_words);
    let mut acc = 0f32;
    for i in 0..cols {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let g = i / k;
        let s = (shared[g / 16] >> (g % 16)) & 1;
        acc += table[((h << 1) | s) as usize] * x[i];
    }
    acc
}

#[inline]
fn vals_ams_e2m2(words: &[u16], cols: usize, k: usize, table: &[f32], out: &mut [f32]) {
    let hi_words = cols.div_ceil(4);
    let (hi, shared) = words.split_at(hi_words);
    for (i, o) in out.iter_mut().enumerate().take(cols) {
        let h = (hi[i / 4] >> (4 * (i % 4))) & 0xF;
        let g = i / k;
        let s = (shared[g / 16] >> (g % 16)) & 1;
        *o = table[((h << 1) | s) as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dequant_table;
    use crate::pack::{pack, row_stride, unpack_row};
    use crate::quant::sharing::quantize;
    use crate::quant::QuantConfig;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    /// row_values must agree with unpack_row + table for every scheme and
    /// ragged column counts.
    #[test]
    fn row_values_matches_unpack() {
        let schemes = [
            "fp8", "int8", "int4", "fp6-e2m3", "fp5-e2m2", "fp4-e2m1", "fp5.33", "fp4.5",
            "fp4.25", "ams-e3m2-k4",
        ];
        for name in schemes {
            let scheme = Scheme::parse(name).unwrap();
            for cols in [1usize, 3, 4, 15, 16, 17, 47, 48, 64, 96, 100] {
                let mut rng = Rng::new(cols as u64);
                let w = init::gaussian(&[1, cols], 0.0, 0.02, &mut rng);
                let p = if matches!(scheme, Scheme::Int { .. }) {
                    crate::baselines::quantize_int(&w, scheme)
                } else {
                    pack(&quantize(&w, &QuantConfig::paper(scheme)).unwrap()).unwrap()
                };
                let table = dequant_table(scheme);
                let mut vals = vec![0f32; cols];
                row_values(scheme, p.row_words(0), cols, &table, &mut vals);
                let mut codes = vec![0u16; cols];
                unpack_row(scheme, p.row_words(0), cols, &mut codes);
                for i in 0..cols {
                    assert_eq!(
                        vals[i], table[codes[i] as usize],
                        "{name} cols={cols} i={i}"
                    );
                }
                // And row_dot agrees with the scalar dot of row_values.
                let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.37).sin()).collect();
                let fused = row_dot(scheme, p.row_words(0), cols, &table, &x);
                let scalar: f32 = vals.iter().zip(&x).map(|(&v, &xv)| v * xv).sum();
                assert!(
                    (fused - scalar).abs() <= 1e-4 * (1.0 + scalar.abs()),
                    "{name} cols={cols}: {fused} vs {scalar}"
                );
            }
        }
        // Silence unused warning for row_stride import used in docs.
        let _ = row_stride(Scheme::Fp16, 4);
    }
}
