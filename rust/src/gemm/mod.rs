//! Fused unpack–dequant GEMV/GEMM — the CPU analog of the paper's CUDA
//! linear kernels (§3.3).
//!
//! The weight matrix stays packed in memory; each row kernel streams the
//! row's words, reconstructs values through a ≤256-entry dequant table
//! (see [`crate::restore::lut`]), and fuses the multiply–accumulate. The
//! per-channel scale is applied once per output element, so the inner loop
//! is exactly: load word → shift/and → table gather → FMA, mirroring the
//! paper's load → bit-op restore → MMA pipeline.
//!
//! `y = W · x` with `W: [rows, cols]` packed, `x: [cols]`, `y: [rows]`.
//! The batched path computes `Y = X · Wᵀ` for `X: [batch, cols]`.

pub mod kernels;
pub mod parallel;
pub mod simd;

use crate::formats::fp16::fp16_to_f32;
use crate::formats::registry::Scheme;
use crate::pack::PackedTensor;
use crate::tensor::Tensor;

/// Dequant table for a scheme: code → f32 (pre-scale). FP16 uses the
/// global half table; INT uses offset-binary.
pub fn dequant_table(scheme: Scheme) -> Vec<f32> {
    match scheme {
        Scheme::Fp16 => (0..=u16::MAX).map(fp16_to_f32).collect(),
        Scheme::Fp(f) => crate::restore::F32Lut::new(f).table,
        Scheme::Ams { base, .. } => crate::restore::F32Lut::new(base).table,
        Scheme::Int { bits } => {
            let n = 1usize << bits;
            let offset = (n / 2) as f32;
            (0..n).map(|c| c as f32 - offset).collect()
        }
    }
}

/// A packed linear layer with its dequant table resolved — the unit the
/// coordinator serves.
#[derive(Clone, Debug)]
pub struct QuantLinear {
    pub packed: PackedTensor,
    table: Vec<f32>,

}

impl QuantLinear {
    pub fn new(packed: PackedTensor) -> QuantLinear {
        let table = dequant_table(packed.scheme);
        QuantLinear { packed, table }
    }

    pub fn rows(&self) -> usize {
        self.packed.rows
    }

    pub fn cols(&self) -> usize {
        self.packed.cols
    }

    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// Single-vector product: `y[r] = scale_r * Σ_c deq(W[r,c]) x[c]`.
    ///
    /// Two-phase hot path for FP schemes (§Perf): (1) unpack the row's
    /// codes into a reusable buffer, (2) vectorized bit-placement decode +
    /// FMA (`simd::dot_codes`), with the exponent rebias folded into the
    /// channel scale. FP16 uses VCVTPH2PS. Integer schemes keep the
    /// table kernels.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.packed.cols);
        assert_eq!(y.len(), self.packed.rows);
        self.gemv_rows(0, self.packed.rows, x, y);
    }

    /// GEMV over a row range `[start, end)`; `y` has `end - start` slots.
    /// Shared by the serial and parallel paths.
    pub(crate) fn gemv_rows(&self, start: usize, end: usize, x: &[f32], y: &mut [f32]) {
        let cols = self.packed.cols;
        match self.packed.scheme {
            Scheme::Fp16 => {
                for (i, r) in (start..end).enumerate() {
                    y[i] = simd::dot_fp16_bits(&self.packed.row_words(r)[..cols], x, &self.table)
                        * self.packed.scales[r];
                }
            }
            Scheme::Fp(fmt) | Scheme::Ams { base: fmt, .. } => {
                // Fully-fused SIMD paths per layout family; fall back to
                // unpack + vectorized decode-dot where none applies.
                let is_fp533 = matches!(
                    self.packed.scheme,
                    Scheme::Ams { base, k } if base == crate::formats::FpFormat::E2M3 && k == 3
                );
                let seg = match self.packed.scheme {
                    Scheme::Fp(f) if f.bits() == 6 => Some(simd::LowBits::PerCode2),
                    Scheme::Fp(f) if f.bits() == 5 => Some(simd::LowBits::PerCode1),
                    Scheme::Ams { base, k } if base.bits() == 5 => Some(simd::LowBits::Group(k)),
                    _ => None,
                };
                let is_bytes = matches!(self.packed.scheme, Scheme::Fp(f) if f.bits() == 8);
                let hi_len = cols.div_ceil(4);
                // Stride-3 de-interleaved activations for FP5.33 (amortized
                // over all rows).
                let (mut x0, mut x1, mut x2) = (Vec::new(), Vec::new(), Vec::new());
                if is_fp533 {
                    simd::deinterleave3(x, &mut x0, &mut x1, &mut x2);
                }
                let mut codes = vec![0u16; cols];
                for (i, r) in (start..end).enumerate() {
                    let words = self.packed.row_words(r);
                    if is_fp533 {
                        if let Some(dot) = simd::dot_fp533(words, cols, &x0, &x1, &x2, x) {
                            y[i] = dot * self.packed.scales[r];
                            continue;
                        }
                    } else if is_bytes {
                        if let Some(dot) = simd::dot_bytes(words, cols, x, fmt) {
                            y[i] = dot * self.packed.scales[r];
                            continue;
                        }
                    } else if let Some(low) = seg {
                        let (hi, lo) = words.split_at(hi_len);
                        if let Some(dot) = simd::dot_segmented(hi, lo, cols, x, fmt, low) {
                            y[i] = dot * self.packed.scales[r];
                            continue;
                        }
                    }
                    crate::pack::unpack_row(self.packed.scheme, words, cols, &mut codes);
                    y[i] = simd::dot_codes(&codes, x, fmt) * self.packed.scales[r];
                }
            }
            _ => {
                for (i, r) in (start..end).enumerate() {
                    y[i] = kernels::row_dot(
                        self.packed.scheme,
                        self.packed.row_words(r),
                        cols,
                        &self.table,
                        x,
                    ) * self.packed.scales[r];
                }
            }
        }
    }

    /// Batched product: `X: [batch, cols]` row-major → `Y: [batch, rows]`.
    /// Internally transposes X once so the inner loop reads a contiguous
    /// per-column activation block (the CPU analog of coalesced loads).
    pub fn gemm(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.cols(), self.packed.cols);
        let batch = x.rows();
        let xt = x.transpose(); // [cols, batch]
        let mut y = Tensor::zeros(&[batch, self.packed.rows]);
        let mut acc = vec![0f32; batch];
        let mut vals = vec![0f32; self.packed.cols];
        let mut codes = vec![0u16; self.packed.cols];
        for r in 0..self.packed.rows {
            acc.fill(0.0);
            self.row_values_fast(r, &mut codes, &mut vals);
            kernels::batch_fma(&vals, xt.data(), batch, &mut acc);
            // The fold factor is baked into `vals` only on the table path;
            // apply scale (and fold for the decode path) at the end.
            let s = self.packed.scales[r];
            for b in 0..batch {
                y.set2(b, r, acc[b] * s);
            }
        }
        y
    }

    /// Decode one packed row into pre-scale (fold-applied) values.
    fn row_values_fast(&self, r: usize, codes: &mut [u16], vals: &mut [f32]) {
        let cols = self.packed.cols;
        match self.packed.scheme {
            Scheme::Fp(fmt) | Scheme::Ams { base: fmt, .. } => {
                crate::pack::unpack_row(self.packed.scheme, self.packed.row_words(r), cols, codes);
                simd::decode_codes(codes, vals, fmt);
            }
            _ => kernels::row_values(
                self.packed.scheme,
                self.packed.row_words(r),
                cols,
                &self.table,
                vals,
            ),
        }
    }


    /// Reference implementation: unpack codes row by row, dequantize
    /// through the table, dense dot. Independent of the fused kernels.
    pub fn gemv_reference(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.packed.rows];
        let mut codes = vec![0u16; self.packed.cols];
        for r in 0..self.packed.rows {
            crate::pack::unpack_row(
                self.packed.scheme,
                self.packed.row_words(r),
                self.packed.cols,
                &mut codes,
            );
            y[r] = codes
                .iter()
                .zip(x)
                .map(|(&c, &xv)| self.table[c as usize] * xv)
                .sum::<f32>()
                * self.packed.scales[r];
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sharing::quantize;
    use crate::quant::QuantConfig;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    pub(crate) fn make_linear(name: &str, rows: usize, cols: usize, seed: u64) -> QuantLinear {
        let mut rng = Rng::new(seed);
        let w = init::gaussian(&[rows, cols], 0.0, 0.02, &mut rng);
        let scheme = Scheme::parse(name).unwrap();
        let packed = if scheme == Scheme::Fp16 {
            crate::baselines::pack_fp16(&w)
        } else if matches!(scheme, Scheme::Int { .. }) {
            crate::baselines::quantize_int(&w, scheme)
        } else {
            crate::pack::pack(&quantize(&w, &QuantConfig::paper(scheme)))
        };
        QuantLinear::new(packed)
    }

    const SCHEMES: &[&str] = &[
        "fp16", "fp8", "int8", "int4", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4-e2m1",
        "fp5.33", "fp4.5", "fp4.3", "fp4.25", "ams-e3m2-k4",
    ];

    #[test]
    fn gemv_matches_reference_all_schemes() {
        let mut rng = Rng::new(100);
        for name in SCHEMES {
            let lin = make_linear(name, 7, 61, 1);
            let x: Vec<f32> = (0..61).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y = vec![0f32; 7];
            lin.gemv(&x, &mut y);
            let yref = lin.gemv_reference(&x);
            for (a, b) in y.iter().zip(&yref) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{name}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn gemm_matches_gemv_per_row() {
        let mut rng = Rng::new(101);
        for name in ["fp16", "fp5.33", "fp4.25", "fp6-e2m3", "int8"] {
            let lin = make_linear(name, 9, 48, 2);
            let x = init::gaussian(&[5, 48], 0.0, 1.0, &mut rng);
            let y = lin.gemm(&x);
            assert_eq!(y.shape(), &[5, 9]);
            for b in 0..5 {
                let mut yr = vec![0f32; 9];
                lin.gemv(x.row(b), &mut yr);
                for r in 0..9 {
                    assert!(
                        (y.at2(b, r) - yr[r]).abs() <= 1e-4 * (1.0 + yr[r].abs()),
                        "{name} b={b} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn dequant_table_int() {
        let t = dequant_table(Scheme::Int { bits: 4 });
        assert_eq!(t.len(), 16);
        assert_eq!(t[8], 0.0);
        assert_eq!(t[0], -8.0);
        assert_eq!(t[15], 7.0);
    }

    #[test]
    fn dequant_table_fp16_spot() {
        let t = dequant_table(Scheme::Fp16);
        assert_eq!(t[0x3C00], 1.0);
        assert_eq!(t[0xC000], -2.0);
    }

    #[test]
    fn empty_like_shapes() {
        let lin = make_linear("fp4.25", 1, 4, 3);
        let x = vec![1.0f32; 4];
        let mut y = vec![0f32; 1];
        lin.gemv(&x, &mut y);
        let yref = lin.gemv_reference(&x);
        assert!((y[0] - yref[0]).abs() < 1e-5);
    }
}
