//! Fused unpack–dequant GEMV/GEMM — the CPU analog of the paper's CUDA
//! linear kernels (§3.3).
//!
//! The weight matrix stays packed in memory end to end. The single-vector
//! path streams each row's words, reconstructs values arithmetically (or
//! through a ≤256-entry dequant table), and fuses the multiply–accumulate;
//! the per-channel scale is applied once per output element, so the inner
//! loop is exactly: load word → shift/and → decode → FMA, mirroring the
//! paper's load → bit-op restore → MMA pipeline.
//!
//! **Tiled batched layout (§Perf).** `gemm` no longer dequantizes rows to
//! dense f32: it streams each packed row once per *tile* of up to
//! [`simd::NTILE`] activation rows (taken contiguously from row-major `X`,
//! so no transpose is built), decoding every code exactly once per
//! row-tile and fanning the value into per-column register accumulators
//! (`simd::dotn_*`). Results are produced in a transposed
//! `[rows, batch]` staging buffer — so parallel workers own disjoint
//! contiguous row-range slices — and transposed once into `Y: [batch,
//! rows]` at the end.
//!
//! **Stream-direct per-group decode (§Perf, PR 5).** Group-wise tensors
//! (`Granularity::PerGroup(g)`, the FineQuant/M-ANT axis) serve through
//! one dot per group *segment* with the group scale folded into the
//! accumulation. When every group boundary is segment-addressable in the
//! scheme's packed streams (`g % 16 == 0` on the byte/segmented layouts,
//! plus `g % k == 0` for the AMS shared-bit families — see
//! [`crate::pack::group_segments_aligned`]), the segments decode
//! *straight from the packed words*: no codes unpack, no values staging,
//! zero scratch — the CPU analog of the paper's decode-in-kernel CUDA
//! path. Ragged `g` and codes/table/FP5.33 layouts keep a buffered
//! fallback (unpack → unscaled decode → dense segment dots) whose
//! reduction structure matches segment for segment, so the two paths are
//! bit-identical wherever both apply (locked by `tests/kernels.rs`
//! golden vectors and the three-way property suite).
//!
//! **Scratch ownership.** All intermediate buffers (unpacked codes, the
//! FP5.33 de-interleaved activation streams, the transposed staging
//! buffer) live in a caller-owned [`GemmScratch`], created once per
//! `Transformer`/worker and borrowed per call; the steady-state decode
//! loop performs zero heap allocation — and the stream-direct grouped
//! path touches no scratch at all. Parallel workers use a thread-local
//! scratch (see [`parallel`]).
//!
//! `y = W · x` with `W: [rows, cols]` packed, `x: [cols]`, `y: [rows]`.
//! The batched path computes `Y = X · Wᵀ` for `X: [batch, cols]`.

pub mod kernels;
pub mod parallel;
pub mod simd;

use crate::formats::fp16::fp16_to_f32;
use crate::formats::registry::Scheme;
use crate::formats::FpFormat;
use crate::pack::PackedTensor;
use crate::tensor::Tensor;

/// Dequant table for a scheme: code → f32 (pre-scale). FP16 uses the
/// global half table; INT uses offset-binary.
pub fn dequant_table(scheme: Scheme) -> Vec<f32> {
    match scheme {
        Scheme::Fp16 => (0..=u16::MAX).map(fp16_to_f32).collect(),
        Scheme::Fp(f) => crate::restore::F32Lut::new(f).table,
        Scheme::Ams { base, .. } => crate::restore::F32Lut::new(base).table,
        Scheme::Int { bits } => {
            let n = 1usize << bits;
            let offset = (n / 2) as f32;
            (0..n).map(|c| c as f32 - offset).collect()
        }
    }
}

/// Reusable workspace for the GEMV/GEMM hot path. Create once per
/// `Transformer`/worker; buffers grow to the high-water mark on first use
/// and are reused allocation-free afterwards.
#[derive(Clone, Debug, Default)]
pub struct GemmScratch {
    /// Unpacked row codes (code-buffer kernel families).
    codes: Vec<u16>,
    /// Unscaled decoded row values — only the *buffered* grouped path
    /// (ragged `g` / codes-family layouts) stages through here; the
    /// stream-direct grouped path decodes straight from the packed words
    /// and leaves this buffer untouched.
    vals: Vec<f32>,
    /// FP5.33 stride-3 de-interleaved activation streams, `[batch][groups]`.
    x0: Vec<f32>,
    x1: Vec<f32>,
    x2: Vec<f32>,
    /// Transposed staging output `[rows, batch]`.
    yt: Vec<f32>,
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }
}

/// Which precision the decode streams at — the draft/verify axis of the
/// self-speculative path (see [`crate::spec`]). `HiOnly` reads only the
/// high-nibble stream of a segmented layout, zero-filling the low
/// mantissa bits and folding the least-squares [`QuantLinear::hi_rescale`]
/// correction into the scale; layouts without a hi/lo split (FP16, bytes,
/// FP5.33, codes, tables) fall back to `Full` decode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DecodePrecision {
    /// Full-precision decode through both word streams (the verify path).
    #[default]
    Full,
    /// Hi-stream-only truncated decode (the draft path): ~half the weight
    /// traffic on the segmented layouts, no lo-stream reads at all.
    HiOnly,
}

/// How the kernels fold a tensor's per-group scales into the decode —
/// resolved once at [`QuantLinear`] construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupDecodePath {
    /// Segment-addressable `g` (see [`crate::pack::group_segments_aligned`]) on
    /// a byte/segmented kernel family: each group segment decodes
    /// straight from the packed hi/lo streams with the group scale
    /// folded into the accumulation — no codes unpack, no values
    /// staging, zero scratch. The CPU analog of the paper's
    /// decode-in-kernel CUDA path.
    StreamDirect,
    /// Ragged `g`, or a layout without segment-addressable streams:
    /// unpack the row once, decode *unscaled* values, then run the same
    /// per-segment dense dots. The reduction structure matches the
    /// stream-direct path segment for segment, so where both apply they
    /// produce bit-identical results (pinned by the golden-vector and
    /// three-way property suites).
    Buffered,
}

/// Decode one unpacked row of codes into *unscaled* f32 values — the
/// buffered grouped path's staging step (the group scale is folded into
/// the per-segment accumulation, mirroring the stream-direct path).
#[inline]
fn decode_codes_table(codes: &[u16], table: &[f32], vals: &mut [f32]) {
    debug_assert_eq!(codes.len(), vals.len());
    for (v, &c) in vals.iter_mut().zip(codes) {
        *v = table[c as usize];
    }
}

/// Grouped dot of one decoded row against `T` activation rows: one dense
/// dot per group segment, group scale folded into the accumulation.
/// The buffered twin of [`QuantLinear::stream_grouped_dot`] — identical
/// segment reduction order, so the two are bit-identical.
#[inline]
fn dense_grouped_dot<const T: usize>(
    vals: &[f32],
    gscales: &[f32],
    g: usize,
    xs: &[&[f32]; T],
) -> [f32; T] {
    debug_assert_eq!(gscales.len(), vals.len().div_ceil(g));
    let mut acc = [0f32; T];
    for (gi, &s) in gscales.iter().enumerate() {
        let c0 = gi * g;
        let len = g.min(vals.len() - c0);
        let seg: [&[f32]; T] = core::array::from_fn(|j| &xs[j][c0..c0 + len]);
        let d = simd::dotn_dense(&vals[c0..c0 + len], &seg);
        for j in 0..T {
            acc[j] += d[j] * s;
        }
    }
    acc
}

/// Which fused row kernel serves a scheme (resolved once at construction).
#[derive(Clone, Copy, Debug)]
pub(crate) enum RowKernel {
    /// Native half words through VCVTPH2PS / the half table.
    Fp16Bits,
    /// Contiguous 8-bit codes (FP8-e4m3).
    Bytes(FpFormat),
    /// High-nibble stream + low-bit stream (FP6, FP5, FP4.x).
    Segmented(FpFormat, simd::LowBits),
    /// FP5.33 continuous half-word groups (e2m3, k=3).
    Fp533,
    /// Unpack to a code buffer, then arithmetic decode+dot.
    Codes(FpFormat),
    /// Unpack/stream through the dequant table (INT schemes).
    Table,
}

impl RowKernel {
    fn for_scheme(scheme: Scheme) -> RowKernel {
        match scheme {
            Scheme::Fp16 => RowKernel::Fp16Bits,
            Scheme::Fp(f) if f.bits() == 8 => RowKernel::Bytes(f),
            Scheme::Fp(f) if f.bits() == 6 => RowKernel::Segmented(f, simd::LowBits::PerCode2),
            Scheme::Fp(f) if f.bits() == 5 => RowKernel::Segmented(f, simd::LowBits::PerCode1),
            Scheme::Fp(f) => RowKernel::Codes(f),
            Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => RowKernel::Fp533,
            Scheme::Ams { base, k } if base.bits() == 5 => {
                RowKernel::Segmented(base, simd::LowBits::Group(k))
            }
            Scheme::Ams { base, .. } => RowKernel::Codes(base),
            Scheme::Int { .. } => RowKernel::Table,
        }
    }
}

/// Whether the stream-direct grouped path serves this (kernel, scheme,
/// group size): the packed layout must segment at every group boundary
/// ([`crate::pack::group_segments_aligned`]) *and* the kernel family must
/// decode straight from the word streams. Codes/table/FP5.33 families
/// keep the buffered fallback; AMS shared-bit layouts additionally need
/// an AVX-lane-servable k so the stream and buffered paths share one
/// SIMD/scalar gating and stay bit-identical.
fn stream_direct_serves(kernel: RowKernel, scheme: Scheme, g: usize) -> bool {
    if !crate::pack::group_segments_aligned(scheme, g) {
        return false;
    }
    match kernel {
        RowKernel::Bytes(_) => true,
        RowKernel::Segmented(_, simd::LowBits::PerCode1 | simd::LowBits::PerCode2) => true,
        RowKernel::Segmented(_, simd::LowBits::Group(k)) => k == 2 || k == 4,
        _ => false,
    }
}

/// Bits the low stream contributes to each code of a segmented layout.
#[inline]
fn low_width_of(low: simd::LowBits) -> u32 {
    match low {
        simd::LowBits::PerCode2 => 2,
        _ => 1,
    }
}

/// Least-squares scalar correction for the hi-only truncated decode:
/// over a uniform code prior, the `a` minimizing
/// `Σ_c (table[c] - a · table[(c >> w) << w])²` is
/// `Σ full·trunc / Σ trunc²`. Mantissa truncation always rounds toward
/// zero, so `a` is slightly above 1 — it recenters the truncated values
/// on the full-precision ones, which measurably lifts draft acceptance.
fn hi_rescale_for(table: &[f32], low_width: u32) -> f32 {
    let (mut num, mut den) = (0f64, 0f64);
    for (c, &full) in table.iter().enumerate() {
        let trunc = f64::from(table[(c >> low_width) << low_width]);
        num += f64::from(full) * trunc;
        den += trunc * trunc;
    }
    if den > 0.0 {
        (num / den) as f32
    } else {
        1.0
    }
}

/// De-interleave every row of `x` into the stride-3 streams used by the
/// FP5.33 kernels, laid out `[batch][groups]`. Returns the group count.
fn deinterleave3_batch(
    x: &Tensor,
    x0: &mut Vec<f32>,
    x1: &mut Vec<f32>,
    x2: &mut Vec<f32>,
) -> usize {
    let groups = x.cols().div_ceil(3);
    let batch = x.rows();
    for v in [&mut *x0, &mut *x1, &mut *x2] {
        v.clear();
        v.resize(batch * groups, 0.0);
    }
    for b in 0..batch {
        let base = b * groups;
        for (j, chunk) in x.row(b).chunks(3).enumerate() {
            x0[base + j] = chunk[0];
            if chunk.len() > 1 {
                x1[base + j] = chunk[1];
            }
            if chunk.len() > 2 {
                x2[base + j] = chunk[2];
            }
        }
    }
    groups
}

/// `yt: [rows, batch]` → `y: [batch, rows]`.
pub(crate) fn transpose_into(yt: &[f32], rows: usize, batch: usize, y: &mut [f32]) {
    debug_assert_eq!(yt.len(), rows * batch);
    debug_assert_eq!(y.len(), rows * batch);
    for r in 0..rows {
        let src = &yt[r * batch..(r + 1) * batch];
        for (b, &v) in src.iter().enumerate() {
            y[b * rows + r] = v;
        }
    }
}

/// Dense f32 batched product through the same tile micro-kernels:
/// `Y[batch, rows] = X[batch, cols] · Wᵀ`. Serves the FP16-reference
/// baseline so speedup comparisons measure the format, not kernel quality.
pub fn dense_gemm_into(w: &Tensor, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
    let (rows, cols) = (w.rows(), w.cols());
    let batch = x.rows();
    assert_eq!(x.cols(), cols);
    assert_eq!(y.shape(), &[batch, rows]);
    let yt = &mut scratch.yt;
    yt.clear();
    yt.resize(rows * batch, 0.0);
    dense_rows_t(w, 0, rows, x, yt);
    transpose_into(yt, rows, batch, y.data_mut());
}

/// Dense tiled kernel over rows `[r0, r1)` writing the transposed block
/// `out[(r - r0) * batch + b]`. Shared by the serial and pool-sharded
/// dense paths (per-row math is identical at any worker count).
pub(crate) fn dense_rows_t(w: &Tensor, r0: usize, r1: usize, x: &Tensor, out: &mut [f32]) {
    let batch = x.rows();
    debug_assert_eq!(out.len(), (r1 - r0) * batch);
    for r in r0..r1 {
        let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
        dense_row_ladder(w.row(r), x, orow);
    }
}

/// Run one f32 row through the 8/4/2/1 dense tile ladder against the
/// whole batch, writing `orow[b]`. One copy of the ladder shared by the
/// dense-reference path and the per-group folded-values path, so tile
/// tuning moves both together.
#[inline]
fn dense_row_ladder(wr: &[f32], x: &Tensor, orow: &mut [f32]) {
    let batch = x.rows();
    debug_assert_eq!(orow.len(), batch);
    let mut b = 0usize;
    while b < batch {
        let rem = batch - b;
        if rem >= 8 {
            dense_tile::<8>(wr, x, b, &mut orow[b..b + 8]);
            b += 8;
        } else if rem >= 4 {
            dense_tile::<4>(wr, x, b, &mut orow[b..b + 4]);
            b += 4;
        } else if rem >= 2 {
            dense_tile::<2>(wr, x, b, &mut orow[b..b + 2]);
            b += 2;
        } else {
            dense_tile::<1>(wr, x, b, &mut orow[b..b + 1]);
            b += 1;
        }
    }
}

#[inline]
fn dense_tile<const T: usize>(wr: &[f32], x: &Tensor, b0: usize, out: &mut [f32]) {
    let xs: [&[f32]; T] = core::array::from_fn(|j| x.row(b0 + j));
    let d = simd::dotn_dense(wr, &xs);
    out[..T].copy_from_slice(&d);
}

/// Worker count for a `[rows, cols] × batch` product (1 = stay serial):
/// consult the shared pool only above the size floor so small models
/// never spin it up. One policy for the packed *and* dense-reference
/// paths — a tuning change here moves both together, keeping baseline
/// comparisons fair.
pub(crate) fn auto_threads(rows: usize, cols: usize, batch: usize) -> usize {
    let macs = rows * cols * batch.max(1);
    if macs < PAR_MIN_MACS {
        return 1;
    }
    let t = crate::util::threadpool::shared_pool().size();
    if t <= 1 || rows < 4 * t {
        1
    } else {
        t
    }
}

/// Dense GEMV that self-selects serial vs pool-parallel execution — the
/// FP16-reference baseline analog of [`QuantLinear::gemv_auto`], so
/// baseline numbers at high thread counts stay fair.
pub fn dense_gemv_auto(w: &Tensor, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.cols());
    assert_eq!(y.len(), w.rows());
    let threads = auto_threads(w.rows(), w.cols(), 1);
    if threads > 1 {
        parallel::dense_gemv_parallel(w, x, y, threads);
    } else {
        for r in 0..w.rows() {
            y[r] = simd::dot_dense(w.row(r), x);
        }
    }
}

/// Dense batched product that self-selects serial vs pool-parallel
/// execution (analog of [`QuantLinear::gemm_auto_into`]).
pub fn dense_gemm_auto_into(w: &Tensor, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
    let threads = auto_threads(w.rows(), w.cols(), x.rows());
    if threads > 1 {
        parallel::dense_gemm_parallel_into(w, x, y, threads, scratch);
    } else {
        dense_gemm_into(w, x, y, scratch);
    }
}

/// Scheme names the kernel tests must cover — shared by the unit tests
/// here and the fused-GEMM property test in `util::proptest` so the two
/// cannot drift.
#[cfg(test)]
pub(crate) const TEST_SCHEMES: &[&str] = &[
    "fp16", "fp8", "int8", "int4", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4-e2m1",
    "fp5.33", "fp4.5", "fp4.3", "fp4.25", "ams-e3m2-k4",
];

/// Schemes that support per-group scales (everything but the FP16
/// passthrough baseline) — shared with the per-group property test.
#[cfg(test)]
pub(crate) const GROUPED_TEST_SCHEMES: &[&str] = &[
    "fp8", "int8", "int4", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4-e2m1",
    "fp5.33", "fp4.5", "fp4.3", "fp4.25", "ams-e3m2-k4",
];

/// A packed linear layer with its dequant table, kernel family and
/// grouped decode path resolved — the unit the coordinator serves.
#[derive(Clone, Debug)]
pub struct QuantLinear {
    pub packed: PackedTensor,
    table: Vec<f32>,
    kernel: RowKernel,
    /// `Some` iff the tensor carries per-group scales.
    group_path: Option<GroupDecodePath>,
    /// Least-squares multiplicative correction for the hi-only truncated
    /// decode, computed once from the dequant table: the `a` minimizing
    /// `Σ_codes (full(c) - a · trunc(c))²`. Folded into the row/group
    /// scale on the `HiOnly` path; 1.0 for layouts without a hi/lo split.
    hi_rescale: f32,
}

/// MACs below which parallel dispatch is not worth the pool hand-off.
const PAR_MIN_MACS: usize = 1 << 18;

impl QuantLinear {
    pub fn new(packed: PackedTensor) -> QuantLinear {
        let table = dequant_table(packed.scheme);
        let kernel = RowKernel::for_scheme(packed.scheme);
        let group_path = packed.group_scales.as_ref().map(|gs| {
            if stream_direct_serves(kernel, packed.scheme, gs.group_size) {
                GroupDecodePath::StreamDirect
            } else {
                GroupDecodePath::Buffered
            }
        });
        let hi_rescale = match kernel {
            RowKernel::Segmented(_, low) => hi_rescale_for(&table, low_width_of(low)),
            _ => 1.0,
        };
        QuantLinear {
            packed,
            table,
            kernel,
            group_path,
            hi_rescale,
        }
    }

    /// The decode path serving this tensor's per-group scales (`None`
    /// for per-channel/per-tensor scales).
    pub fn group_decode_path(&self) -> Option<GroupDecodePath> {
        self.group_path
    }

    /// Force the buffered grouped path on a stream-direct-eligible
    /// tensor. Test/bench hook: the golden-vector and three-way property
    /// suites compare the two paths bit for bit, and `bench_gemm`
    /// records the stream-direct vs buffered throughput delta. No-op for
    /// per-channel tensors.
    pub fn force_buffered_group_decode(&mut self) {
        if self.group_path.is_some() {
            self.group_path = Some(GroupDecodePath::Buffered);
        }
    }

    /// Whether the hi-only truncated decode serves this tensor: the
    /// kernel must be a two-stream segmented family, and per-group
    /// tensors additionally need `g % 16 == 0` so every group's first
    /// code starts word-aligned in the hi-nibble stream. Unlike the
    /// stream-direct gate there is no shared-bit lane constraint — the
    /// hi path reads no shared bits, so k=3 layouts serve too.
    pub fn hi_only_serves(&self) -> bool {
        matches!(self.kernel, RowKernel::Segmented(..))
            && self
                .packed
                .group_scales
                .as_ref()
                .map_or(true, |gs| gs.group_size % 16 == 0)
    }

    /// The least-squares hi-only scale correction (1.0 when
    /// [`QuantLinear::hi_only_serves`] is false).
    pub fn hi_rescale(&self) -> f32 {
        self.hi_rescale
    }

    /// Reference dequantization through the hi-only truncated decode —
    /// the effective weights the speculative draft forward multiplies
    /// by: low mantissa bits zero-filled, [`QuantLinear::hi_rescale`]
    /// folded into the scale. `None` when the layout has no hi/lo split
    /// ([`QuantLinear::hi_only_serves`] is false).
    pub fn hi_dequantize(&self) -> Option<Tensor> {
        if !self.hi_only_serves() {
            return None;
        }
        let low = match self.kernel {
            RowKernel::Segmented(_, low) => low_width_of(low),
            _ => unreachable!("hi_only_serves implies a segmented kernel"),
        };
        let p = &self.packed;
        let mut out = Tensor::zeros(&[p.rows, p.cols]);
        let mut codes = vec![0u16; p.cols];
        for r in 0..p.rows {
            crate::pack::unpack_row(p.scheme, p.row_words(r), p.cols, &mut codes);
            let orow = out.row_mut(r);
            for (c, o) in orow.iter_mut().enumerate() {
                let trunc = self.table[((codes[c] >> low) << low) as usize];
                *o = trunc * self.hi_rescale * p.scale_for(r, c);
            }
        }
        Some(out)
    }

    pub fn rows(&self) -> usize {
        self.packed.rows
    }

    pub fn cols(&self) -> usize {
        self.packed.cols
    }

    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// Single-vector product: `y[r] = scale_r * Σ_c deq(W[r,c]) x[c]`.
    /// Allocates a transient scratch; hot paths use [`QuantLinear::gemv_with`].
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        let mut scratch = GemmScratch::new();
        self.gemv_with(x, y, &mut scratch);
    }

    /// Zero-alloc GEMV against a caller-owned scratch.
    ///
    /// Two-phase hot path for FP schemes (§Perf): fully-fused SIMD decode
    /// per layout family (`simd::dotn_*`), with the exponent rebias folded
    /// into the channel scale; FP16 uses VCVTPH2PS; integer schemes keep
    /// the table kernels.
    pub fn gemv_with(&self, x: &[f32], y: &mut [f32], scratch: &mut GemmScratch) {
        assert_eq!(x.len(), self.packed.cols);
        assert_eq!(y.len(), self.packed.rows);
        self.gemv_rows(0, self.packed.rows, x, y, scratch);
    }

    /// GEMV over a row range `[start, end)`; `y` has `end - start` slots.
    /// Shared by the serial and parallel paths.
    pub(crate) fn gemv_rows(
        &self,
        start: usize,
        end: usize,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut GemmScratch,
    ) {
        let cols = self.packed.cols;
        let GemmScratch {
            codes,
            vals,
            x0,
            x1,
            x2,
            ..
        } = scratch;
        if let Some(gs) = &self.packed.group_scales {
            // Per-group path: one dot per group segment with the scale
            // folded into the accumulation. No trailing per-row scale —
            // the group scales are the whole scale.
            match self.group_path {
                Some(GroupDecodePath::StreamDirect) => {
                    // Decode straight from the packed hi/lo streams:
                    // no codes unpack, no values staging, zero scratch.
                    for (i, r) in (start..end).enumerate() {
                        y[i] = self.stream_grouped_dot::<1>(r, gs.row(r), gs.group_size, &[x])[0];
                    }
                }
                _ => {
                    // Buffered fallback (ragged g / codes-family
                    // layouts): unpack once, decode unscaled values,
                    // same per-segment dense dots.
                    codes.clear();
                    codes.resize(cols, 0);
                    vals.clear();
                    vals.resize(cols, 0.0);
                    for (i, r) in (start..end).enumerate() {
                        crate::pack::unpack_row(
                            self.packed.scheme,
                            self.packed.row_words(r),
                            cols,
                            codes,
                        );
                        decode_codes_table(codes, &self.table, vals);
                        y[i] = dense_grouped_dot::<1>(vals, gs.row(r), gs.group_size, &[x])[0];
                    }
                }
            }
            return;
        }
        match self.kernel {
            RowKernel::Fp16Bits => {
                for (i, r) in (start..end).enumerate() {
                    y[i] = simd::dot_fp16_bits(&self.packed.row_words(r)[..cols], x, &self.table)
                        * self.packed.scales[r];
                }
            }
            RowKernel::Bytes(fmt) => {
                for (i, r) in (start..end).enumerate() {
                    y[i] = simd::dotn_bytes::<1>(self.packed.row_words(r), cols, &[x], fmt)[0]
                        * self.packed.scales[r];
                }
            }
            RowKernel::Segmented(fmt, low) => {
                let hi_len = cols.div_ceil(4);
                for (i, r) in (start..end).enumerate() {
                    let (hi, lo) = self.packed.row_words(r).split_at(hi_len);
                    y[i] = simd::dotn_segmented::<1>(hi, lo, cols, &[x], fmt, low)[0]
                        * self.packed.scales[r];
                }
            }
            RowKernel::Fp533 => {
                // Stride-3 de-interleaved activations (amortized over
                // rows) — only built when the AVX-512 path will read them.
                let use_deint = simd::fp533_uses_deint(cols);
                if use_deint {
                    simd::deinterleave3(x, x0, x1, x2);
                }
                let (a0, a1, a2): (&[f32], &[f32], &[f32]) = if use_deint {
                    (x0.as_slice(), x1.as_slice(), x2.as_slice())
                } else {
                    (&[], &[], &[])
                };
                for (i, r) in (start..end).enumerate() {
                    let d = simd::dotn_fp533::<1>(
                        self.packed.row_words(r),
                        cols,
                        &[a0],
                        &[a1],
                        &[a2],
                        &[x],
                    );
                    y[i] = d[0] * self.packed.scales[r];
                }
            }
            RowKernel::Codes(fmt) => {
                codes.clear();
                codes.resize(cols, 0);
                for (i, r) in (start..end).enumerate() {
                    crate::pack::unpack_row(
                        self.packed.scheme,
                        self.packed.row_words(r),
                        cols,
                        codes,
                    );
                    y[i] = simd::dot_codes(codes, x, fmt) * self.packed.scales[r];
                }
            }
            RowKernel::Table => {
                for (i, r) in (start..end).enumerate() {
                    y[i] = kernels::row_dot(
                        self.packed.scheme,
                        self.packed.row_words(r),
                        cols,
                        &self.table,
                        x,
                    ) * self.packed.scales[r];
                }
            }
        }
    }

    /// Batched product: `X: [batch, cols]` row-major → `Y: [batch, rows]`.
    /// Allocates the output and a transient scratch; hot paths use
    /// [`QuantLinear::gemm_into`].
    pub fn gemm(&self, x: &Tensor) -> Tensor {
        let mut scratch = GemmScratch::new();
        self.gemm_with(x, &mut scratch)
    }

    /// Batched product against a caller-owned scratch (output allocated).
    pub fn gemm_with(&self, x: &Tensor, scratch: &mut GemmScratch) -> Tensor {
        let mut y = Tensor::zeros(&[x.rows(), self.packed.rows]);
        self.gemm_into(x, &mut y, scratch);
        y
    }

    /// Zero-alloc batched product into a pre-shaped `y: [batch, rows]`.
    pub fn gemm_into(&self, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.cols(), self.packed.cols);
        let batch = x.rows();
        let rows = self.packed.rows;
        assert_eq!(y.shape(), &[batch, rows]);
        let GemmScratch {
            codes,
            vals,
            x0,
            x1,
            x2,
            yt,
        } = scratch;
        let deint = if self.packed.group_scales.is_none()
            && matches!(self.kernel, RowKernel::Fp533)
            && simd::fp533_uses_deint(self.packed.cols)
        {
            let groups = deinterleave3_batch(x, x0, x1, x2);
            Some((x0.as_slice(), x1.as_slice(), x2.as_slice(), groups))
        } else {
            None
        };
        yt.clear();
        yt.resize(rows * batch, 0.0);
        self.gemm_rows_t(0, rows, x, deint, codes, vals, yt);
        transpose_into(yt, rows, batch, y.data_mut());
    }

    /// Pick a worker count for this matrix (1 = stay serial) — the shared
    /// policy of [`auto_threads`], so the packed and dense-reference paths
    /// can never diverge in when they go parallel.
    pub(crate) fn auto_threads(&self, batch: usize) -> usize {
        auto_threads(self.packed.rows, self.packed.cols, batch)
    }

    /// GEMV that self-selects serial vs pool-parallel execution.
    pub fn gemv_auto(&self, x: &[f32], y: &mut [f32], scratch: &mut GemmScratch) {
        let threads = self.auto_threads(1);
        if threads > 1 {
            self.gemv_parallel(x, y, threads);
        } else {
            self.gemv_with(x, y, scratch);
        }
    }

    /// Batched product that self-selects serial vs pool-parallel execution.
    pub fn gemm_auto_into(&self, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
        let threads = self.auto_threads(x.rows());
        if threads > 1 {
            self.gemm_parallel_into(x, y, threads, scratch);
        } else {
            self.gemm_into(x, y, scratch);
        }
    }

    /// Precision-dispatched GEMV: `Full` takes the normal auto path;
    /// `HiOnly` streams only the hi-nibble words where the layout has a
    /// hi/lo split ([`QuantLinear::hi_only_serves`]) and silently falls
    /// back to full decode everywhere else — so a mixed-scheme model can
    /// run a draft forward end to end.
    /// The observability path label for a call at `prec` — `None` when
    /// the tensor's layout has no grouped decode path and no hi/lo split
    /// (per-channel full decode; not a tracked family).
    fn timing_path(&self, prec: DecodePrecision) -> Option<crate::obs::KernelPath> {
        if prec == DecodePrecision::HiOnly && self.hi_only_serves() {
            return Some(crate::obs::KernelPath::HiOnly);
        }
        match self.group_path {
            Some(GroupDecodePath::StreamDirect) => Some(crate::obs::KernelPath::StreamDirect),
            Some(GroupDecodePath::Buffered) => Some(crate::obs::KernelPath::Buffered),
            None => None,
        }
    }

    pub fn gemv_prec(
        &self,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut GemmScratch,
        prec: DecodePrecision,
    ) {
        // Sampled per-path timing (every Nth call; see `obs::kernels`).
        // Measurement only — never alters which kernel runs.
        let path = self.timing_path(prec);
        let t0 = (path.is_some() && crate::obs::kernels::should_sample())
            .then(std::time::Instant::now);
        if prec == DecodePrecision::HiOnly && self.hi_only_serves() {
            self.gemv_hi(x, y);
        } else {
            self.gemv_auto(x, y, scratch);
        }
        if let (Some(p), Some(t0)) = (path, t0) {
            crate::obs::kernels::record(p, t0.elapsed().as_secs_f64());
        }
    }

    /// Precision-dispatched batched product (see [`QuantLinear::gemv_prec`]).
    pub fn gemm_prec_into(
        &self,
        x: &Tensor,
        y: &mut Tensor,
        scratch: &mut GemmScratch,
        prec: DecodePrecision,
    ) {
        let path = self.timing_path(prec);
        let t0 = (path.is_some() && crate::obs::kernels::should_sample())
            .then(std::time::Instant::now);
        if prec == DecodePrecision::HiOnly && self.hi_only_serves() {
            self.gemm_hi_into(x, y, scratch);
        } else {
            self.gemm_auto_into(x, y, scratch);
        }
        if let (Some(p), Some(t0)) = (path, t0) {
            crate::obs::kernels::record(p, t0.elapsed().as_secs_f64());
        }
    }

    /// Hi-only GEMV: truncated decode from the hi-nibble stream alone,
    /// `hi_rescale` folded into the row/group scale. Reads no lo-stream
    /// words (the segment kernel takes none).
    fn gemv_hi(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.packed.cols);
        assert_eq!(y.len(), self.packed.rows);
        for r in 0..self.packed.rows {
            y[r] = self.hi_row_tile::<1>(r, &[x])[0];
        }
    }

    /// Hi-only batched product across the same 8/4/2/1 tile ladder as the
    /// full path.
    fn gemm_hi_into(&self, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.cols(), self.packed.cols);
        let batch = x.rows();
        let rows = self.packed.rows;
        assert_eq!(y.shape(), &[batch, rows]);
        let yt = &mut scratch.yt;
        yt.clear();
        yt.resize(rows * batch, 0.0);
        for r in 0..rows {
            let orow = &mut yt[r * batch..(r + 1) * batch];
            let mut b = 0usize;
            while b < batch {
                let rem = batch - b;
                let take = if rem >= 8 {
                    8
                } else if rem >= 4 {
                    4
                } else if rem >= 2 {
                    2
                } else {
                    1
                };
                match take {
                    8 => self.hi_tile_into::<8>(r, x, b, &mut orow[b..b + 8]),
                    4 => self.hi_tile_into::<4>(r, x, b, &mut orow[b..b + 4]),
                    2 => self.hi_tile_into::<2>(r, x, b, &mut orow[b..b + 2]),
                    _ => self.hi_tile_into::<1>(r, x, b, &mut orow[b..b + 1]),
                }
                b += take;
            }
        }
        transpose_into(yt, rows, batch, y.data_mut());
    }

    #[inline]
    fn hi_tile_into<const T: usize>(&self, r: usize, x: &Tensor, b0: usize, out: &mut [f32]) {
        let xs: [&[f32]; T] = core::array::from_fn(|j| x.row(b0 + j));
        let d = self.hi_row_tile::<T>(r, &xs);
        out[..T].copy_from_slice(&d);
    }

    /// One hi-only row × T-column tile: per-channel rows in one segment
    /// dot, per-group rows one segment per group with the group scale
    /// folded in — mirroring [`QuantLinear::stream_grouped_dot`], but
    /// sliced only through the hi stream (group starts are word-aligned
    /// by the `g % 16 == 0` serve gate).
    #[inline]
    fn hi_row_tile<const T: usize>(&self, r: usize, xs: &[&[f32]; T]) -> [f32; T] {
        let cols = self.packed.cols;
        let RowKernel::Segmented(fmt, low) = self.kernel else {
            unreachable!("hi-only path admits only segmented kernels");
        };
        let lw = low_width_of(low);
        let (hi, _lo) = self.packed.row_streams(r);
        match &self.packed.group_scales {
            None => {
                let d = simd::dotn_segmented_hi(hi, cols, xs, fmt, lw);
                let s = self.packed.scales[r] * self.hi_rescale;
                core::array::from_fn(|j| d[j] * s)
            }
            Some(gs) => {
                let g = gs.group_size;
                let mut acc = [0f32; T];
                for (gi, &s) in gs.row(r).iter().enumerate() {
                    let c0 = gi * g;
                    let len = g.min(cols - c0);
                    let seg: [&[f32]; T] = core::array::from_fn(|j| &xs[j][c0..c0 + len]);
                    let d = simd::dotn_segmented_hi(&hi[c0 / 4..], len, &seg, fmt, lw);
                    for j in 0..T {
                        acc[j] += d[j] * s;
                    }
                }
                core::array::from_fn(|j| acc[j] * self.hi_rescale)
            }
        }
    }

    /// Tiled batched kernel over rows `[r0, r1)`: writes the transposed
    /// block `out[(r - r0) * batch + b] = scale_r · Σ_c deq(W[r,c])·X[b,c]`.
    /// Each packed row is streamed once per ≤[`simd::NTILE`]-column tile;
    /// `deint` carries the shared FP5.33 activation streams. Per-group
    /// tensors run one segment dot per group with the scale folded into
    /// the accumulation — straight from the packed words on the
    /// stream-direct path, through `codes`/`vals` on the buffered
    /// fallback (see [`GroupDecodePath`]).
    pub(crate) fn gemm_rows_t(
        &self,
        r0: usize,
        r1: usize,
        x: &Tensor,
        deint: Option<(&[f32], &[f32], &[f32], usize)>,
        codes: &mut Vec<u16>,
        vals: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let cols = self.packed.cols;
        let batch = x.rows();
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        if let Some(gs) = &self.packed.group_scales {
            let g = gs.group_size;
            let stream = self.group_path == Some(GroupDecodePath::StreamDirect);
            if !stream {
                codes.clear();
                codes.resize(cols, 0);
                vals.clear();
                vals.resize(cols, 0.0);
            }
            for r in r0..r1 {
                if !stream {
                    crate::pack::unpack_row(
                        self.packed.scheme,
                        self.packed.row_words(r),
                        cols,
                        codes,
                    );
                    decode_codes_table(codes, &self.table, vals);
                }
                let gsr = gs.row(r);
                let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
                let mut b = 0usize;
                while b < batch {
                    let rem = batch - b;
                    if rem >= 8 {
                        self.grouped_tile::<8>(r, vals, gsr, g, stream, x, b, &mut orow[b..b + 8]);
                        b += 8;
                    } else if rem >= 4 {
                        self.grouped_tile::<4>(r, vals, gsr, g, stream, x, b, &mut orow[b..b + 4]);
                        b += 4;
                    } else if rem >= 2 {
                        self.grouped_tile::<2>(r, vals, gsr, g, stream, x, b, &mut orow[b..b + 2]);
                        b += 2;
                    } else {
                        self.grouped_tile::<1>(r, vals, gsr, g, stream, x, b, &mut orow[b..b + 1]);
                        b += 1;
                    }
                }
            }
            return;
        }
        codes.clear();
        codes.resize(cols, 0);
        for r in r0..r1 {
            let words = self.packed.row_words(r);
            // Code-buffer families unpack once per row; the streaming
            // families decode straight from the packed words per tile.
            if matches!(self.kernel, RowKernel::Codes(_) | RowKernel::Table) {
                crate::pack::unpack_row(self.packed.scheme, words, cols, codes);
            }
            let scale = self.packed.scales[r];
            let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
            let mut b = 0usize;
            while b < batch {
                let rem = batch - b;
                if rem >= 8 {
                    self.row_tile::<8>(words, x, deint, codes, b, &mut orow[b..b + 8], scale);
                    b += 8;
                } else if rem >= 4 {
                    self.row_tile::<4>(words, x, deint, codes, b, &mut orow[b..b + 4], scale);
                    b += 4;
                } else if rem >= 2 {
                    self.row_tile::<2>(words, x, deint, codes, b, &mut orow[b..b + 2], scale);
                    b += 2;
                } else {
                    self.row_tile::<1>(words, x, deint, codes, b, &mut orow[b..b + 1], scale);
                    b += 1;
                }
            }
        }
    }

    /// One fused row × T-column tile: decode each code once, fan the value
    /// into T register accumulators.
    #[inline]
    fn row_tile<const T: usize>(
        &self,
        words: &[u16],
        x: &Tensor,
        deint: Option<(&[f32], &[f32], &[f32], usize)>,
        codes: &[u16],
        b0: usize,
        out: &mut [f32],
        scale: f32,
    ) {
        let cols = self.packed.cols;
        let xs: [&[f32]; T] = core::array::from_fn(|j| x.row(b0 + j));
        let d = match self.kernel {
            RowKernel::Fp16Bits => simd::dotn_fp16_bits(&words[..cols], &xs, &self.table),
            RowKernel::Bytes(fmt) => simd::dotn_bytes(words, cols, &xs, fmt),
            RowKernel::Segmented(fmt, low) => {
                let (hi, lo) = words.split_at(cols.div_ceil(4));
                simd::dotn_segmented(hi, lo, cols, &xs, fmt, low)
            }
            RowKernel::Fp533 => match deint {
                Some((x0, x1, x2, groups)) => {
                    let x0s: [&[f32]; T] =
                        core::array::from_fn(|j| &x0[(b0 + j) * groups..(b0 + j + 1) * groups]);
                    let x1s: [&[f32]; T] =
                        core::array::from_fn(|j| &x1[(b0 + j) * groups..(b0 + j + 1) * groups]);
                    let x2s: [&[f32]; T] =
                        core::array::from_fn(|j| &x2[(b0 + j) * groups..(b0 + j + 1) * groups]);
                    simd::dotn_fp533(words, cols, &x0s, &x1s, &x2s, &xs)
                }
                // No streams were built: the kernel's scalar path (the
                // same `fp533_uses_deint` gate) never reads them.
                None => {
                    let empty: [&[f32]; T] = [&[]; T];
                    simd::dotn_fp533(words, cols, &empty, &empty, &empty, &xs)
                }
            },
            RowKernel::Codes(fmt) => simd::dotn_codes(&codes[..cols], &xs, fmt),
            RowKernel::Table => simd::dotn_table(&codes[..cols], &xs, &self.table),
        };
        for j in 0..T {
            out[j] = d[j] * scale;
        }
    }

    /// One grouped row × T-column tile: dispatch to the stream-direct or
    /// buffered segment dot (`vals` holds the decoded row on the
    /// buffered path and is unread on the stream path).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn grouped_tile<const T: usize>(
        &self,
        r: usize,
        vals: &[f32],
        gscales: &[f32],
        g: usize,
        stream: bool,
        x: &Tensor,
        b0: usize,
        out: &mut [f32],
    ) {
        let xs: [&[f32]; T] = core::array::from_fn(|j| x.row(b0 + j));
        let d = if stream {
            self.stream_grouped_dot::<T>(r, gscales, g, &xs)
        } else {
            dense_grouped_dot::<T>(vals, gscales, g, &xs)
        };
        out[..T].copy_from_slice(&d);
    }

    /// Stream-direct grouped dot of packed row `r` against `T`
    /// activation rows: decode each group segment straight from the
    /// hi/lo word streams — no codes unpack, no values staging — with
    /// the group scale folded into the accumulation. Only reachable for
    /// the byte/segmented kernel families at segment-aligned `g` (see
    /// [`stream_direct_serves`]).
    #[inline]
    fn stream_grouped_dot<const T: usize>(
        &self,
        r: usize,
        gscales: &[f32],
        g: usize,
        xs: &[&[f32]; T],
    ) -> [f32; T] {
        let cols = self.packed.cols;
        debug_assert_eq!(gscales.len(), cols.div_ceil(g));
        let (hi, lo) = self.packed.row_streams(r);
        let mut acc = [0f32; T];
        for (gi, &s) in gscales.iter().enumerate() {
            let c0 = gi * g;
            let len = g.min(cols - c0);
            let seg: [&[f32]; T] = core::array::from_fn(|j| &xs[j][c0..c0 + len]);
            let d = match self.kernel {
                RowKernel::Bytes(fmt) => simd::dotn_bytes(&hi[c0 / 2..], len, &seg, fmt),
                RowKernel::Segmented(fmt, low @ simd::LowBits::PerCode1) => {
                    simd::dotn_segmented(&hi[c0 / 4..], &lo[c0 / 16..], len, &seg, fmt, low)
                }
                RowKernel::Segmented(fmt, low @ simd::LowBits::PerCode2) => {
                    simd::dotn_segmented(&hi[c0 / 4..], &lo[c0 / 8..], len, &seg, fmt, low)
                }
                RowKernel::Segmented(fmt, simd::LowBits::Group(k)) => {
                    simd::dotn_segmented_group_at(&hi[c0 / 4..], lo, c0 / k, len, &seg, fmt, k)
                }
                // Unreachable: gated at construction by stream_direct_serves.
                _ => unreachable!("stream-direct path admits only byte/segmented kernels"),
            };
            for j in 0..T {
                acc[j] += d[j] * s;
            }
        }
        acc
    }

    /// Reference implementation: unpack codes row by row, dequantize
    /// through the table, dense dot at the tensor's scale granularity.
    /// Independent of the fused kernels.
    pub fn gemv_reference(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.packed.rows];
        let mut codes = vec![0u16; self.packed.cols];
        for r in 0..self.packed.rows {
            crate::pack::unpack_row(
                self.packed.scheme,
                self.packed.row_words(r),
                self.packed.cols,
                &mut codes,
            );
            y[r] = match &self.packed.group_scales {
                None => {
                    codes
                        .iter()
                        .zip(x)
                        .map(|(&c, &xv)| self.table[c as usize] * xv)
                        .sum::<f32>()
                        * self.packed.scales[r]
                }
                Some(gs) => codes
                    .chunks(gs.group_size)
                    .zip(x.chunks(gs.group_size))
                    .zip(gs.row(r))
                    .map(|((cc, xc), &s)| {
                        cc.iter()
                            .zip(xc)
                            .map(|(&c, &xv)| self.table[c as usize] * xv)
                            .sum::<f32>()
                            * s
                    })
                    .sum(),
            };
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pipeline::quantize_packed;
    use crate::quant::{Granularity, QuantConfig};
    use crate::tensor::init;
    use crate::util::prng::Rng;

    pub(crate) fn make_linear(name: &str, rows: usize, cols: usize, seed: u64) -> QuantLinear {
        let mut rng = Rng::new(seed);
        let w = init::gaussian(&[rows, cols], 0.0, 0.02, &mut rng);
        let scheme = Scheme::parse(name).unwrap();
        QuantLinear::new(quantize_packed(&w, &QuantConfig::paper(scheme)).unwrap())
    }

    pub(crate) fn make_linear_grouped(
        name: &str,
        rows: usize,
        cols: usize,
        g: usize,
        seed: u64,
    ) -> QuantLinear {
        let mut rng = Rng::new(seed);
        let w = init::gaussian(&[rows, cols], 0.0, 0.02, &mut rng);
        let cfg = QuantConfig::paper(Scheme::parse(name).unwrap())
            .with_granularity(Granularity::PerGroup(g));
        QuantLinear::new(quantize_packed(&w, &cfg).unwrap())
    }

    pub(crate) const SCHEMES: &[&str] = super::TEST_SCHEMES;
    pub(crate) const GROUPED_SCHEMES: &[&str] = super::GROUPED_TEST_SCHEMES;

    #[test]
    fn gemv_matches_reference_all_schemes() {
        let mut rng = Rng::new(100);
        for name in SCHEMES {
            let lin = make_linear(name, 7, 61, 1);
            let x: Vec<f32> = (0..61).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y = vec![0f32; 7];
            lin.gemv(&x, &mut y);
            let yref = lin.gemv_reference(&x);
            for (a, b) in y.iter().zip(&yref) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{name}: {a} vs {b}"
                );
            }
        }
    }

    /// The tiled batched path must agree with per-row GEMV for every
    /// scheme, at shapes that are deliberately ragged for every layout:
    /// cols not a multiple of the SIMD lane count (16), the FP5.33 group
    /// width (3/48), or the shared-bit group size k; batch widths that
    /// exercise the 8/4/2/1 tile ladder (1, 3, tile+1, 2·tile+1).
    #[test]
    fn gemm_matches_gemv_per_row() {
        let mut rng = Rng::new(101);
        for name in SCHEMES {
            for cols in [48usize, 61] {
                let lin = make_linear(name, 9, cols, 2);
                let mut scratch = GemmScratch::new();
                for batch in [1usize, 3, 5, 9, 17] {
                    let x = init::gaussian(&[batch, cols], 0.0, 1.0, &mut rng);
                    let y = lin.gemm_with(&x, &mut scratch);
                    assert_eq!(y.shape(), &[batch, 9]);
                    for b in 0..batch {
                        let mut yr = vec![0f32; 9];
                        lin.gemv(x.row(b), &mut yr);
                        for r in 0..9 {
                            assert!(
                                (y.at2(b, r) - yr[r]).abs() <= 1e-4 * (1.0 + yr[r].abs()),
                                "{name} cols={cols} batch={batch} b={b} r={r}: {} vs {}",
                                y.at2(b, r),
                                yr[r]
                            );
                        }
                    }
                }
            }
        }
    }

    /// One scratch reused across shrinking/growing batches stays correct
    /// (buffers are high-water sized, never stale).
    #[test]
    fn scratch_reuse_across_batches() {
        let mut rng = Rng::new(102);
        let lin = make_linear("fp5.33", 11, 51, 3);
        let mut scratch = GemmScratch::new();
        for &batch in &[9usize, 2, 5, 1, 8] {
            let x = init::gaussian(&[batch, 51], 0.0, 1.0, &mut rng);
            let fresh = lin.gemm(&x);
            let reused = lin.gemm_with(&x, &mut scratch);
            assert_eq!(fresh, reused, "batch={batch}");
        }
    }

    #[test]
    fn dense_gemm_matches_matmul() {
        let mut rng = Rng::new(103);
        let w = init::gaussian(&[9, 37], 0.0, 1.0, &mut rng);
        let mut scratch = GemmScratch::new();
        for batch in [1usize, 3, 8, 11] {
            let x = init::gaussian(&[batch, 37], 0.0, 1.0, &mut rng);
            let mut y = Tensor::zeros(&[batch, 9]);
            dense_gemm_into(&w, &x, &mut y, &mut scratch);
            let yref = x.matmul(&w.transpose());
            for b in 0..batch {
                for r in 0..9 {
                    assert!(
                        (y.at2(b, r) - yref.at2(b, r)).abs()
                            <= 1e-4 * (1.0 + yref.at2(b, r).abs()),
                        "batch={batch} b={b} r={r}"
                    );
                }
            }
        }
    }

    /// The auto path (which may engage the shared pool) must match the
    /// serial path bit-for-bit: work is row-sharded, per-row math is
    /// identical.
    #[test]
    fn gemm_auto_matches_serial() {
        let mut rng = Rng::new(104);
        let lin = make_linear("fp4.25", 256, 1024, 4);
        let x = init::gaussian(&[5, 1024], 0.0, 1.0, &mut rng);
        let mut s1 = GemmScratch::new();
        let mut s2 = GemmScratch::new();
        let mut y_auto = Tensor::zeros(&[5, 256]);
        lin.gemm_auto_into(&x, &mut y_auto, &mut s1);
        let mut y_serial = Tensor::zeros(&[5, 256]);
        lin.gemm_into(&x, &mut y_serial, &mut s2);
        assert_eq!(y_auto, y_serial);
    }

    #[test]
    fn dequant_table_int() {
        let t = dequant_table(Scheme::Int { bits: 4 });
        assert_eq!(t.len(), 16);
        assert_eq!(t[8], 0.0);
        assert_eq!(t[0], -8.0);
        assert_eq!(t[15], 7.0);
    }

    #[test]
    fn dequant_table_fp16_spot() {
        let t = dequant_table(Scheme::Fp16);
        assert_eq!(t[0x3C00], 1.0);
        assert_eq!(t[0xC000], -2.0);
    }

    #[test]
    fn empty_like_shapes() {
        let lin = make_linear("fp4.25", 1, 4, 3);
        let x = vec![1.0f32; 4];
        let mut y = vec![0f32; 1];
        lin.gemv(&x, &mut y);
        let yref = lin.gemv_reference(&x);
        assert!((y[0] - yref[0]).abs() < 1e-5);
    }

    /// Acceptance: fused gemv/gemm over a `PerGroup(g)` tensor match the
    /// `dequantize` oracle for every grouped scheme, g ∈ {32, 64, 128},
    /// ragged shapes (cols not a multiple of g, of the SIMD lane count,
    /// or of the sharing k), and batch widths across the tile ladder.
    #[test]
    fn per_group_matches_dequantize_reference() {
        let mut rng = Rng::new(200);
        for name in GROUPED_SCHEMES {
            for g in [32usize, 64, 128] {
                let (rows, cols) = (7usize, 150usize);
                let lin = make_linear_grouped(name, rows, cols, g, g as u64);
                assert!(lin.packed.group_scales.is_some(), "{name}");
                let deq = lin.packed.dequantize();
                let mut scratch = GemmScratch::new();
                // GEMV vs the dequantize oracle.
                let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let mut y = vec![0f32; rows];
                lin.gemv_with(&x, &mut y, &mut scratch);
                for r in 0..rows {
                    let want: f32 = deq.row(r).iter().zip(&x).map(|(&a, &b)| a * b).sum();
                    assert!(
                        (y[r] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "{name} g={g} gemv r={r}: {} vs {want}",
                        y[r]
                    );
                }
                // And the kernel-independent reference agrees too.
                let yref = lin.gemv_reference(&x);
                for r in 0..rows {
                    assert!(
                        (y[r] - yref[r]).abs() <= 1e-4 * (1.0 + yref[r].abs()),
                        "{name} g={g} ref r={r}"
                    );
                }
                // Batched path across the 8/4/2/1 tile ladder.
                for batch in [1usize, 3, 9] {
                    let xb = init::gaussian(&[batch, cols], 0.0, 1.0, &mut rng);
                    let yb = lin.gemm_with(&xb, &mut scratch);
                    for b in 0..batch {
                        for r in 0..rows {
                            let want: f32 =
                                deq.row(r).iter().zip(xb.row(b)).map(|(&a, &v)| a * v).sum();
                            assert!(
                                (yb.at2(b, r) - want).abs() <= 1e-4 * (1.0 + want.abs()),
                                "{name} g={g} gemm batch={batch} b={b} r={r}: {} vs {want}",
                                yb.at2(b, r)
                            );
                        }
                    }
                }
            }
        }
    }

    /// Which grouped tensors resolve to the stream-direct path: the
    /// byte/segmented families at segment-aligned g; everything else
    /// buffered.
    #[test]
    fn stream_direct_path_resolution() {
        let path = |name: &str, g: usize| {
            make_linear_grouped(name, 4, 256, g, 1).group_decode_path()
        };
        use GroupDecodePath::*;
        for g in [32usize, 64, 128] {
            for name in ["fp8", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4.5", "fp4.25"] {
                assert_eq!(path(name, g), Some(StreamDirect), "{name} g={g}");
            }
            // k=3 shared groups straddle segments; codes/table families
            // and the continuous FP5.33 layout have no segment kernels.
            for name in ["fp4.33", "fp5.33", "fp4-e2m1", "int4", "int8", "ams-e3m2-k4"] {
                assert_eq!(path(name, g), Some(Buffered), "{name} g={g}");
            }
        }
        // Ragged g buffers everywhere; per-channel tensors have no path.
        assert_eq!(path("fp4.25", 24), Some(Buffered));
        assert_eq!(make_linear("fp4.25", 4, 256, 1).group_decode_path(), None);
    }

    /// Acceptance (PR 5): the stream-direct grouped path is bit-identical
    /// to the buffered fallback — same segment reduction order, same
    /// SIMD/scalar gating — across every stream-direct scheme, g, ragged
    /// shapes and the whole batch tile ladder.
    #[test]
    fn stream_direct_matches_buffered_bitwise() {
        let mut rng = Rng::new(400);
        for name in ["fp8", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4.5", "fp4.25"] {
            for g in [32usize, 64, 128] {
                for cols in [120usize, 150] {
                    let rows = 7usize;
                    let lin = make_linear_grouped(name, rows, cols, g, g as u64 + 7);
                    assert_eq!(lin.group_decode_path(), Some(GroupDecodePath::StreamDirect));
                    let mut buf = lin.clone();
                    buf.force_buffered_group_decode();
                    assert_eq!(buf.group_decode_path(), Some(GroupDecodePath::Buffered));
                    let mut s1 = GemmScratch::new();
                    let mut s2 = GemmScratch::new();
                    let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let mut ys = vec![0f32; rows];
                    let mut yb = vec![0f32; rows];
                    lin.gemv_with(&x, &mut ys, &mut s1);
                    buf.gemv_with(&x, &mut yb, &mut s2);
                    assert_eq!(ys, yb, "{name} g={g} cols={cols} gemv");
                    for batch in [1usize, 3, 9, 17] {
                        let xb = init::gaussian(&[batch, cols], 0.0, 1.0, &mut rng);
                        let a = lin.gemm_with(&xb, &mut s1);
                        let b = buf.gemm_with(&xb, &mut s2);
                        assert_eq!(a, b, "{name} g={g} cols={cols} batch={batch}");
                    }
                }
            }
        }
    }

    /// The stream-direct path allocates nothing: a fresh scratch stays
    /// untouched (codes/vals never sized) through gemv and gemm.
    #[test]
    fn stream_direct_leaves_scratch_untouched() {
        let mut rng = Rng::new(401);
        let lin = make_linear_grouped("fp4.25", 9, 128, 32, 9);
        assert_eq!(lin.group_decode_path(), Some(GroupDecodePath::StreamDirect));
        let mut scratch = GemmScratch::new();
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y = vec![0f32; 9];
        lin.gemv_with(&x, &mut y, &mut scratch);
        let xb = init::gaussian(&[5, 128], 0.0, 1.0, &mut rng);
        let mut yb = Tensor::zeros(&[5, 9]);
        lin.gemm_into(&xb, &mut yb, &mut scratch);
        assert!(scratch.codes.is_empty(), "no codes unpack on the aligned-g path");
        assert!(scratch.vals.is_empty(), "no values staging on the aligned-g path");
        // (yt is the transposed output staging, not a decode buffer.)
        assert_eq!(scratch.yt.len(), 5 * 9);
    }

    /// One scratch reused across per-group and per-channel tensors and
    /// shrinking/growing batches stays correct (vals/codes buffers are
    /// high-water sized, never stale).
    #[test]
    fn per_group_scratch_reuse() {
        let mut rng = Rng::new(201);
        let grouped = make_linear_grouped("fp4.25", 11, 140, 32, 5);
        let channel = make_linear("fp4.25", 11, 140, 5);
        let mut scratch = GemmScratch::new();
        for &batch in &[9usize, 2, 5, 1, 8] {
            let x = init::gaussian(&[batch, 140], 0.0, 1.0, &mut rng);
            let fresh_g = grouped.gemm(&x);
            let reused_g = grouped.gemm_with(&x, &mut scratch);
            assert_eq!(fresh_g, reused_g, "grouped batch={batch}");
            let fresh_c = channel.gemm(&x);
            let reused_c = channel.gemm_with(&x, &mut scratch);
            assert_eq!(fresh_c, reused_c, "channel batch={batch}");
        }
    }

    /// Which tensors the hi-only draft decode serves: every two-stream
    /// segmented layout (including k=3, which the stream-direct full path
    /// rejects), per-channel or at word-aligned g; single-stream layouts
    /// never.
    #[test]
    fn hi_only_serve_resolution() {
        for name in ["fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4.5", "fp4.33", "fp4.25"] {
            assert!(make_linear(name, 4, 64, 1).hi_only_serves(), "{name} pc");
            assert!(make_linear_grouped(name, 4, 128, 32, 1).hi_only_serves(), "{name} g32");
            assert!(!make_linear_grouped(name, 4, 120, 24, 1).hi_only_serves(), "{name} g24");
        }
        for name in ["fp16", "fp8", "int8", "int4", "fp4-e2m1", "fp5.33", "ams-e3m2-k4"] {
            assert!(!make_linear(name, 4, 64, 1).hi_only_serves(), "{name}");
        }
    }

    /// Truncated-decode oracle: unpack the codes, zero the low mantissa
    /// bits, decode through the table at the tensor's scale granularity,
    /// apply `hi_rescale`. Kernel-independent.
    fn hi_reference(lin: &QuantLinear, x: &[f32]) -> Vec<f32> {
        let w = match lin.kernel {
            RowKernel::Segmented(_, low) => low_width_of(low),
            _ => panic!("hi reference needs a segmented layout"),
        };
        let mut y = vec![0f32; lin.packed.rows];
        let mut codes = vec![0u16; lin.packed.cols];
        for r in 0..lin.packed.rows {
            crate::pack::unpack_row(lin.packed.scheme, lin.packed.row_words(r), lin.packed.cols, &mut codes);
            y[r] = codes
                .iter()
                .enumerate()
                .map(|(c, &code)| {
                    let trunc = (code >> w) << w;
                    lin.table[trunc as usize] * lin.packed.scale_for(r, c) * x[c]
                })
                .sum::<f32>()
                * lin.hi_rescale;
        }
        y
    }

    /// The hi-only path equals the truncated-decode oracle for every
    /// segmented scheme, per-channel and grouped, and the batched hi path
    /// is bit-identical to per-row hi GEMV (same tile reduction order).
    #[test]
    fn hi_only_matches_truncated_oracle() {
        let mut rng = Rng::new(300);
        for name in ["fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4.5", "fp4.33", "fp4.25"] {
            for grouped in [false, true] {
                let (rows, cols) = (7usize, 150usize);
                let lin = if grouped {
                    make_linear_grouped(name, rows, cols, 32, 11)
                } else {
                    make_linear(name, rows, cols, 11)
                };
                assert!(lin.hi_only_serves(), "{name} grouped={grouped}");
                assert!(lin.hi_rescale() >= 1.0, "{name}: truncation rounds toward zero");
                let mut scratch = GemmScratch::new();
                let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let mut y = vec![0f32; rows];
                lin.gemv_prec(&x, &mut y, &mut scratch, DecodePrecision::HiOnly);
                let want = hi_reference(&lin, &x);
                for r in 0..rows {
                    assert!(
                        (y[r] - want[r]).abs() <= 1e-4 * (1.0 + want[r].abs()),
                        "{name} grouped={grouped} r={r}: {} vs {}",
                        y[r],
                        want[r]
                    );
                }
                for batch in [1usize, 3, 9] {
                    let xb = init::gaussian(&[batch, cols], 0.0, 1.0, &mut rng);
                    let mut yb = Tensor::zeros(&[batch, rows]);
                    lin.gemm_prec_into(&xb, &mut yb, &mut scratch, DecodePrecision::HiOnly);
                    for b in 0..batch {
                        let mut yr = vec![0f32; rows];
                        lin.gemv_prec(xb.row(b), &mut yr, &mut scratch, DecodePrecision::HiOnly);
                        for r in 0..rows {
                            assert_eq!(
                                yb.at2(b, r).to_bits(),
                                yr[r].to_bits(),
                                "{name} grouped={grouped} batch={batch} b={b} r={r}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Instrumented gate: flipping every lo-stream word leaves the
    /// hi-only output bit-identical (the draft path reads no lo words)
    /// while the full decode visibly changes.
    #[test]
    fn hi_only_reads_no_lo_words() {
        let mut rng = Rng::new(301);
        for name in ["fp6-e2m3", "fp5-e2m2", "fp4.25"] {
            let (rows, cols) = (5usize, 96usize);
            let lin = make_linear(name, rows, cols, 13);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut scratch = GemmScratch::new();
            let mut hi_before = vec![0f32; rows];
            let mut full_before = vec![0f32; rows];
            lin.gemv_prec(&x, &mut hi_before, &mut scratch, DecodePrecision::HiOnly);
            lin.gemv_prec(&x, &mut full_before, &mut scratch, DecodePrecision::Full);
            let mut poisoned = lin.clone();
            let hi_words = crate::pack::hi_stream_words(poisoned.packed.scheme, cols);
            let stride = poisoned.packed.row_stride;
            for r in 0..rows {
                for w in &mut poisoned.packed.words[r * stride + hi_words..(r + 1) * stride] {
                    *w = !*w;
                }
            }
            let mut hi_after = vec![0f32; rows];
            let mut full_after = vec![0f32; rows];
            poisoned.gemv_prec(&x, &mut hi_after, &mut scratch, DecodePrecision::HiOnly);
            poisoned.gemv_prec(&x, &mut full_after, &mut scratch, DecodePrecision::Full);
            for r in 0..rows {
                assert_eq!(
                    hi_before[r].to_bits(),
                    hi_after[r].to_bits(),
                    "{name} r={r}: hi-only must not read lo words"
                );
            }
            assert_ne!(full_before, full_after, "{name}: full decode must read lo words");
        }
    }

    /// Layouts without a hi/lo split fall back to the full decode —
    /// bit-identically, so a mixed-scheme draft forward stays exact where
    /// no cheaper decode exists.
    #[test]
    fn hi_only_fallback_is_full_decode() {
        let mut rng = Rng::new(302);
        for name in ["fp16", "fp8", "int4", "fp5.33", "ams-e3m2-k4"] {
            let lin = make_linear(name, 6, 80, 17);
            assert!(!lin.hi_only_serves(), "{name}");
            assert_eq!(lin.hi_rescale(), 1.0, "{name}");
            let x: Vec<f32> = (0..80).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut s1 = GemmScratch::new();
            let mut s2 = GemmScratch::new();
            let mut y_hi = vec![0f32; 6];
            let mut y_full = vec![0f32; 6];
            lin.gemv_prec(&x, &mut y_hi, &mut s1, DecodePrecision::HiOnly);
            lin.gemv_prec(&x, &mut y_full, &mut s2, DecodePrecision::Full);
            assert_eq!(y_hi, y_full, "{name}");
        }
    }

    /// The auto path (which may engage the shared pool) must match the
    /// serial per-group path bit-for-bit.
    #[test]
    fn per_group_auto_matches_serial() {
        let mut rng = Rng::new(202);
        let lin = make_linear_grouped("fp4.25", 256, 1024, 64, 6);
        let x = init::gaussian(&[5, 1024], 0.0, 1.0, &mut rng);
        let mut s1 = GemmScratch::new();
        let mut s2 = GemmScratch::new();
        let mut y_auto = Tensor::zeros(&[5, 256]);
        lin.gemm_auto_into(&x, &mut y_auto, &mut s1);
        let mut y_serial = Tensor::zeros(&[5, 256]);
        lin.gemm_into(&x, &mut y_serial, &mut s2);
        assert_eq!(y_auto, y_serial);
        let xv: Vec<f32> = (0..1024).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut yv_auto = vec![0f32; 256];
        lin.gemv_auto(&xv, &mut yv_auto, &mut s1);
        let mut yv_serial = vec![0f32; 256];
        lin.gemv_with(&xv, &mut yv_serial, &mut s2);
        assert_eq!(yv_auto, yv_serial);
    }
}
