//! Row-sharded parallel GEMV/GEMM over scoped threads.
//!
//! Output rows are independent, so the packed matrix is split into
//! contiguous row blocks, one per worker. Used by the serving hot path for
//! the large MLP projections where a single core cannot saturate memory
//! bandwidth.

use super::{kernels, QuantLinear};
use crate::tensor::Tensor;
use crate::util::threadpool::scope_chunks;
use std::sync::Mutex;

impl QuantLinear {
    /// Parallel `gemv` across `threads` row blocks.
    pub fn gemv_parallel(&self, x: &[f32], y: &mut [f32], threads: usize) {
        assert_eq!(x.len(), self.packed.cols);
        assert_eq!(y.len(), self.packed.rows);
        if threads <= 1 || self.packed.rows < 2 * threads {
            self.gemv(x, y);
            return;
        }
        let y_cell = Mutex::new(&mut *y);
        // Each worker owns a disjoint row range; collect into a local buffer
        // then splice under the lock (short critical section). Each worker
        // computes rows through a thread-local gemv on a row-sliced view.
        scope_chunks(self.packed.rows, threads, |_, start, end| {
            let mut local = vec![0f32; end - start];
            self.gemv_rows(start, end, x, &mut local);
            let mut guard = y_cell.lock().unwrap();
            guard[start..end].copy_from_slice(&local);
        });
    }

    /// Parallel batched product (see [`QuantLinear::gemm`]).
    pub fn gemm_parallel(&self, x: &Tensor, threads: usize) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.cols(), self.packed.cols);
        let batch = x.rows();
        if threads <= 1 || self.packed.rows < 2 * threads {
            return self.gemm(x);
        }
        let xt = x.transpose();
        let y = Mutex::new(Tensor::zeros(&[batch, self.packed.rows]));
        scope_chunks(self.packed.rows, threads, |_, start, end| {
            let mut acc = vec![0f32; batch];
            let mut vals = vec![0f32; self.packed.cols];
            let mut codes = vec![0u16; self.packed.cols];
            let mut local = vec![0f32; (end - start) * batch]; // [rows_local, batch]
            for r in start..end {
                acc.fill(0.0);
                self.row_values_fast(r, &mut codes, &mut vals);
                kernels::batch_fma(&vals, xt.data(), batch, &mut acc);
                let s = self.packed.scales[r];
                for b in 0..batch {
                    local[(r - start) * batch + b] = acc[b] * s;
                }
            }
            let mut guard = y.lock().unwrap();
            for r in start..end {
                for b in 0..batch {
                    guard.set2(b, r, local[(r - start) * batch + b]);
                }
            }
        });
        y.into_inner().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::make_linear;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    #[test]
    fn parallel_matches_serial_gemv() {
        let mut rng = Rng::new(7);
        for name in ["fp16", "fp5.33", "fp4.25", "fp6-e2m3"] {
            let lin = make_linear(name, 64, 128, 3);
            let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y1 = vec![0f32; 64];
            let mut y4 = vec![0f32; 64];
            lin.gemv(&x, &mut y1);
            lin.gemv_parallel(&x, &mut y4, 4);
            assert_eq!(y1, y4, "{name}");
        }
    }

    #[test]
    fn parallel_matches_serial_gemm() {
        let mut rng = Rng::new(8);
        let lin = make_linear("fp4.25", 48, 96, 4);
        let x = init::gaussian(&[8, 96], 0.0, 1.0, &mut rng);
        let a = lin.gemm(&x);
        let b = lin.gemm_parallel(&x, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn small_matrix_falls_back() {
        let lin = make_linear("fp16", 3, 8, 5);
        let x = vec![1.0f32; 8];
        let mut y = vec![0f32; 3];
        lin.gemv_parallel(&x, &mut y, 8); // rows < 2*threads -> serial path
        let r = lin.gemv_reference(&x);
        for (a, b) in y.iter().zip(&r) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
