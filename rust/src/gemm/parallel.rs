//! Row-sharded parallel GEMV/GEMM on the shared persistent thread pool.
//!
//! Output rows are independent, so the packed matrix is split into
//! contiguous row blocks, one per worker. Workers write *pre-split
//! disjoint output slices* — the GEMV output directly, the GEMM through
//! the `[rows, batch]` staging buffer whose row-range chunks are
//! contiguous — so there is no lock and no per-element splice on the
//! merge path. Per-worker decode scratch is thread-local to the pool
//! workers (created once per worker thread, reused across calls), keeping
//! the steady-state decode loop allocation-free; grouped tensors on the
//! stream-direct path (segment-aligned `g`) don't touch the worker
//! scratch at all.
//!
//! Used by the serving hot path for the large projections where a single
//! core cannot saturate memory bandwidth; `QuantLinear::{gemv,gemm}_auto*`
//! dispatch here automatically above the size floor.

use super::{GemmScratch, QuantLinear, RowKernel};
use crate::tensor::Tensor;
use crate::util::threadpool::shared_pool;
use std::cell::RefCell;

thread_local! {
    /// Per-thread decode scratch for pool workers (and any other thread
    /// that lands here): one allocation high-water per worker, reused for
    /// every job.
    static WORKER_SCRATCH: RefCell<GemmScratch> = RefCell::new(GemmScratch::new());
}

fn with_worker_scratch<R>(f: impl FnOnce(&mut GemmScratch) -> R) -> R {
    WORKER_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// Split `buf` into per-chunk `(start_row, slice)` parts of `per` rows
/// each (`row_width` elements per row). Disjoint by construction.
fn split_rows<'a>(
    buf: &'a mut [f32],
    rows: usize,
    per: usize,
    row_width: usize,
) -> Vec<(usize, &'a mut [f32])> {
    let mut parts = Vec::with_capacity(rows.div_ceil(per));
    let mut rest = buf;
    let mut start = 0usize;
    while start < rows {
        let take = per.min(rows - start);
        let (head, tail) = rest.split_at_mut(take * row_width);
        parts.push((start, head));
        start += take;
        rest = tail;
    }
    parts
}

impl QuantLinear {
    /// Parallel `gemv` across up to `threads` row blocks on the shared
    /// pool. Each worker owns a disjoint contiguous slice of `y`.
    ///
    /// `threads` is a sharding hint capped at the shared pool's size —
    /// the real concurrency ceiling. Set `AMS_THREADS` to grow the pool
    /// (e.g. for oversubscription experiments); numerical results are
    /// identical at any worker count (row-sharded, per-row math fixed).
    pub fn gemv_parallel(&self, x: &[f32], y: &mut [f32], threads: usize) {
        assert_eq!(x.len(), self.packed.cols);
        assert_eq!(y.len(), self.packed.rows);
        let rows = self.packed.rows;
        let threads = threads.min(shared_pool().size());
        if threads <= 1 || rows < 2 * threads {
            self.gemv(x, y);
            return;
        }
        let per = rows.div_ceil(threads);
        let parts = split_rows(y, rows, per, 1);
        shared_pool().scope_parts(parts, &|_, (start, yslice): (usize, &mut [f32])| {
            with_worker_scratch(|scratch| {
                self.gemv_rows(start, start + yslice.len(), x, yslice, scratch);
            });
        });
    }

    /// Parallel batched product (see [`QuantLinear::gemm`]).
    pub fn gemm_parallel(&self, x: &Tensor, threads: usize) -> Tensor {
        let mut scratch = GemmScratch::new();
        let mut y = Tensor::zeros(&[x.rows(), self.packed.rows]);
        self.gemm_parallel_into(x, &mut y, threads, &mut scratch);
        y
    }

    /// Zero-alloc parallel batched product into a pre-shaped
    /// `y: [batch, rows]`. Row ranges of the `[rows, batch]` staging
    /// buffer are pre-split into disjoint chunks, one per worker; the
    /// single transpose into `y` happens on the caller thread.
    ///
    /// `threads` is a sharding hint capped at the shared pool's size (see
    /// [`QuantLinear::gemv_parallel`]; `AMS_THREADS` grows the pool).
    pub fn gemm_parallel_into(
        &self,
        x: &Tensor,
        y: &mut Tensor,
        threads: usize,
        scratch: &mut GemmScratch,
    ) {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.cols(), self.packed.cols);
        let batch = x.rows();
        let rows = self.packed.rows;
        assert_eq!(y.shape(), &[batch, rows]);
        let threads = threads.min(shared_pool().size());
        if threads <= 1 || rows < 2 * threads {
            return self.gemm_into(x, y, scratch);
        }
        let GemmScratch {
            x0, x1, x2, yt, ..
        } = scratch;
        // FP5.33 de-interleaved activation streams are built once on the
        // caller and shared read-only by every worker (skipped when the
        // kernel's scalar path would never read them, and by the
        // per-group paths — stream-direct decodes straight from the
        // packed words, the buffered fallback stages through the
        // worker-local codes/vals buffers).
        let deint = if self.packed.group_scales.is_none()
            && matches!(self.kernel, RowKernel::Fp533)
            && super::simd::fp533_uses_deint(self.packed.cols)
        {
            let groups = super::deinterleave3_batch(x, x0, x1, x2);
            Some((x0.as_slice(), x1.as_slice(), x2.as_slice(), groups))
        } else {
            None
        };
        yt.clear();
        yt.resize(rows * batch, 0.0);
        let per = rows.div_ceil(threads);
        let parts = split_rows(yt, rows, per, batch);
        shared_pool().scope_parts(parts, &|_, (start, chunk): (usize, &mut [f32])| {
            let nrows = chunk.len() / batch;
            with_worker_scratch(|ws| {
                let GemmScratch { codes, vals, .. } = ws;
                self.gemm_rows_t(start, start + nrows, x, deint, codes, vals, chunk);
            });
        });
        super::transpose_into(yt, rows, batch, y.data_mut());
    }
}

/// Pool-sharded dense GEMV (the FP16-reference baseline's analog of
/// [`QuantLinear::gemv_parallel`]): contiguous row blocks, one per worker,
/// each writing a disjoint slice of `y`. Per-row math is identical at any
/// worker count, so results match the serial path bit-for-bit.
pub fn dense_gemv_parallel(w: &Tensor, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(x.len(), w.cols());
    assert_eq!(y.len(), w.rows());
    let rows = w.rows();
    let threads = threads.min(shared_pool().size());
    if threads <= 1 || rows < 2 * threads {
        for (r, yv) in y.iter_mut().enumerate() {
            *yv = super::simd::dot_dense(w.row(r), x);
        }
        return;
    }
    let per = rows.div_ceil(threads);
    let parts = split_rows(y, rows, per, 1);
    shared_pool().scope_parts(parts, &|_, (start, yslice): (usize, &mut [f32])| {
        for (i, yv) in yslice.iter_mut().enumerate() {
            *yv = super::simd::dot_dense(w.row(start + i), x);
        }
    });
}

/// Pool-sharded dense batched product into a pre-shaped `y: [batch, rows]`
/// (see [`QuantLinear::gemm_parallel_into`] for the packed analog): workers
/// own disjoint row-range chunks of the `[rows, batch]` staging buffer, the
/// single transpose into `y` happens on the caller thread.
pub fn dense_gemm_parallel_into(
    w: &Tensor,
    x: &Tensor,
    y: &mut Tensor,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    assert_eq!(x.cols(), w.cols());
    let batch = x.rows();
    let rows = w.rows();
    assert_eq!(y.shape(), &[batch, rows]);
    let threads = threads.min(shared_pool().size());
    if threads <= 1 || rows < 2 * threads {
        return super::dense_gemm_into(w, x, y, scratch);
    }
    let yt = &mut scratch.yt;
    yt.clear();
    yt.resize(rows * batch, 0.0);
    let per = rows.div_ceil(threads);
    let parts = split_rows(yt, rows, per, batch);
    shared_pool().scope_parts(parts, &|_, (start, chunk): (usize, &mut [f32])| {
        let nrows = chunk.len() / batch;
        super::dense_rows_t(w, start, start + nrows, x, chunk);
    });
    super::transpose_into(yt, rows, batch, y.data_mut());
}

#[cfg(test)]
mod tests {
    use super::super::tests::make_linear;
    use super::super::GemmScratch;
    use super::{dense_gemm_parallel_into, dense_gemv_parallel};
    use crate::tensor::{init, Tensor};
    use crate::util::prng::Rng;

    #[test]
    fn parallel_matches_serial_gemv() {
        let mut rng = Rng::new(7);
        for name in ["fp16", "fp5.33", "fp4.25", "fp6-e2m3"] {
            let lin = make_linear(name, 64, 128, 3);
            let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y1 = vec![0f32; 64];
            let mut y4 = vec![0f32; 64];
            lin.gemv(&x, &mut y1);
            lin.gemv_parallel(&x, &mut y4, 4);
            assert_eq!(y1, y4, "{name}");
        }
    }

    #[test]
    fn parallel_matches_serial_gemm() {
        let mut rng = Rng::new(8);
        // Batch widths across the 8/4/2/1 tile ladder, incl. a ragged one.
        for name in ["fp4.25", "fp5.33", "fp16"] {
            let lin = make_linear(name, 48, 96, 4);
            for batch in [5usize, 8] {
                let x = init::gaussian(&[batch, 96], 0.0, 1.0, &mut rng);
                let a = lin.gemm(&x);
                let b = lin.gemm_parallel(&x, 4);
                assert_eq!(a, b, "{name} batch={batch}");
            }
        }
    }

    #[test]
    fn parallel_into_reuses_scratch() {
        let mut rng = Rng::new(9);
        let lin = make_linear("fp5.33", 48, 96, 5);
        let mut scratch = GemmScratch::new();
        for &batch in &[8usize, 3, 8] {
            let x = init::gaussian(&[batch, 96], 0.0, 1.0, &mut rng);
            let mut y = Tensor::zeros(&[batch, 48]);
            lin.gemm_parallel_into(&x, &mut y, 4, &mut scratch);
            assert_eq!(y, lin.gemm(&x), "batch={batch}");
        }
    }

    #[test]
    fn dense_parallel_matches_serial() {
        let mut rng = Rng::new(11);
        let w = init::gaussian(&[96, 128], 0.0, 0.5, &mut rng);
        // GEMV: sharded rows, identical per-row math -> exact equality.
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y1 = vec![0f32; 96];
        let mut y4 = vec![0f32; 96];
        for (r, yv) in y1.iter_mut().enumerate() {
            *yv = super::super::simd::dot_dense(w.row(r), &x);
        }
        dense_gemv_parallel(&w, &x, &mut y4, 4);
        assert_eq!(y1, y4);
        let mut y_auto = vec![0f32; 96];
        super::super::dense_gemv_auto(&w, &x, &mut y_auto);
        assert_eq!(y1, y_auto);
        // GEMM across ragged batch widths (tile ladder 8/4/2/1).
        let mut s1 = GemmScratch::new();
        let mut s4 = GemmScratch::new();
        for batch in [1usize, 5, 8, 11] {
            let xb = init::gaussian(&[batch, 128], 0.0, 1.0, &mut rng);
            let mut a = Tensor::zeros(&[batch, 96]);
            let mut b = Tensor::zeros(&[batch, 96]);
            super::super::dense_gemm_into(&w, &xb, &mut a, &mut s1);
            dense_gemm_parallel_into(&w, &xb, &mut b, 4, &mut s4);
            assert_eq!(a, b, "batch={batch}");
        }
    }

    /// Satellite: per-group tensors shard across the pool with results
    /// identical to the serial path (row-sharded, per-row math fixed).
    #[test]
    fn per_group_parallel_matches_serial() {
        use super::super::tests::make_linear_grouped;
        let mut rng = Rng::new(13);
        for g in [32usize, 64] {
            let lin = make_linear_grouped("fp4.25", 64, 128, g, 6);
            let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y1 = vec![0f32; 64];
            let mut y4 = vec![0f32; 64];
            lin.gemv(&x, &mut y1);
            lin.gemv_parallel(&x, &mut y4, 4);
            assert_eq!(y1, y4, "gemv g={g}");
            for batch in [5usize, 8] {
                let xb = init::gaussian(&[batch, 128], 0.0, 1.0, &mut rng);
                let a = lin.gemm(&xb);
                let b = lin.gemm_parallel(&xb, 4);
                assert_eq!(a, b, "gemm g={g} batch={batch}");
            }
        }
    }

    #[test]
    fn dense_parallel_small_falls_back() {
        let mut rng = Rng::new(12);
        let w = init::gaussian(&[3, 16], 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y = vec![0f32; 3];
        dense_gemv_parallel(&w, &x, &mut y, 8); // rows < 2*threads -> serial
        for (r, &yv) in y.iter().enumerate() {
            let want = super::super::simd::dot_dense(w.row(r), &x);
            assert_eq!(yv, want);
        }
    }

    #[test]
    fn small_matrix_falls_back() {
        let lin = make_linear("fp16", 3, 8, 5);
        let x = vec![1.0f32; 8];
        let mut y = vec![0f32; 3];
        lin.gemv_parallel(&x, &mut y, 8); // rows < 2*threads -> serial path
        let r = lin.gemv_reference(&x);
        for (a, b) in y.iter().zip(&r) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
