//! Vectorized decode + dot kernels (the §Perf hot path).
//!
//! Key identity (the SIMD form of §3.2's restoration, used by TC-FPx and
//! here): placing an FPx code's exponent+mantissa field at the top of the
//! f32 mantissa/exponent and rescaling by a power of two is *exact*, for
//! normals and subnormals alike:
//!
//! ```text
//! f32(code) = bitcast(sign << 31 | em << (23 - m)) * 2^(127 - bias)
//! ```
//!
//! * normal (E≠0): bitcast = 2^(E-127)·(1+man/2^m); ×2^(127-bias) = 2^(E-bias)·(1+man/2^m) ✓
//! * subnormal (E=0): bitcast = man·2^(-126-m);     ×2^(127-bias) = man·2^(1-bias-m)       ✓
//!
//! The 2^(127-bias) factor is folded into the per-channel scale, so decode
//! is just shift/and/or + the FMA the kernel already performs — no gather
//! tables. Written as clean scalar loops that LLVM auto-vectorizes, with
//! explicit AVX-512 paths where it cannot.

use crate::formats::FpFormat;

/// Exponent base for the arithmetic decode: `127 - bias - m`. The decoded
/// value is `(man | implicit·2^m) · 2^(max(E,1) + expo_base - 127)` — an
/// exact product of an integer-valued f32 and a power of two, never a
/// denormal (§Perf iteration log: bit-placement decode produced denormal
/// f32 inputs for FPx-subnormal codes, and x86 denormal multiplies are
/// microcoded at ~100 cycles — a measured 10–50× kernel slowdown).
#[inline]
pub fn expo_base(fmt: FpFormat) -> i32 {
    127 - fmt.bias() - fmt.mbits as i32
}

/// Scalar arithmetic decode of one code — exact for every format code.
#[inline(always)]
pub fn decode_arith(code: u32, e: u32, m: u32, expo_base: i32) -> f32 {
    let ef = (code >> m) & ((1 << e) - 1);
    let man = code & ((1 << m) - 1);
    let norm = u32::from(ef != 0);
    let mant = (man | (norm << m)) as f32;
    let eeff = ef.max(1) as i32;
    let scale = f32::from_bits(((eeff + expo_base) as u32) << 23);
    let v = mant * scale;
    if (code >> (e + m)) & 1 == 1 {
        -v
    } else {
        v
    }
}

/// Fused decode+dot over a code buffer:
/// `Σ (decode_raw(codes[i]) · fold) * x[i]` — the fold happens *inside*
/// the loop: pre-fold bit patterns are f32 denormals (their exponent field
/// holds the tiny FPx exponent), and FMA on denormals is microcoded on
/// x86 (~100 cycles/op, a measured 10–50× kernel slowdown). Multiplying by
/// 2^(127-bias) first lifts every value into the normal range (§Perf log).
/// Returns the final dequantized dot (multiply only by the channel scale).
pub fn dot_codes(codes: &[u16], x: &[f32], fmt: FpFormat) -> f32 {
    debug_assert!(codes.len() <= x.len());
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() {
            // SAFETY: feature checked at runtime.
            return unsafe { dot_codes_avx512(codes, x, e, m, eb) };
        }
    }
    dot_codes_scalar(codes, x, e, m, eb)
}

/// Decode a code buffer into final f32 values (pre-scale).
pub fn decode_codes(codes: &[u16], out: &mut [f32], fmt: FpFormat) {
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = decode_arith(u32::from(c), e, m, eb);
    }
}

fn dot_codes_scalar(codes: &[u16], x: &[f32], e: u32, m: u32, eb: i32) -> f32 {
    // Four independent accumulators: breaks the FMA dependency chain so
    // the loop pipelines (and auto-vectorizes).
    let mut acc = [0f32; 4];
    let n = codes.len();
    let chunks = n / 4;
    for i in 0..chunks {
        for j in 0..4 {
            let idx = i * 4 + j;
            acc[j] += decode_arith(u32::from(codes[idx]), e, m, eb) * x[idx];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for idx in chunks * 4..n {
        s += decode_arith(u32::from(codes[idx]), e, m, eb) * x[idx];
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[inline]
pub fn is_avx512() -> bool {
    use std::sync::OnceLock;
    static HAS: OnceLock<bool> = OnceLock::new();
    *HAS.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512bw")
    })
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
pub fn is_avx512() -> bool {
    false
}

/// AVX-512: 16 codes per iteration — widen u16→u32, shift/and/or into f32
/// bit patterns, FMA against the activation lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_codes_avx512(codes: &[u16], x: &[f32], e: u32, m: u32, eb: i32) -> f32 {
    use std::arch::x86_64::*;
    let n = codes.len();
    let dec = DecodeConsts::new(e, m, eb);
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let c16 = _mm512_loadu_si512(codes.as_ptr().add(i) as *const _);
        // Widen the two 256-bit halves.
        let lo = _mm512_cvtepu16_epi32(_mm512_castsi512_si256(c16));
        let hi = _mm512_cvtepu16_epi32(_mm512_extracti64x4_epi64::<1>(c16));
        let x0 = _mm512_loadu_ps(x.as_ptr().add(i));
        let x1 = _mm512_loadu_ps(x.as_ptr().add(i + 16));
        acc0 = _mm512_fmadd_ps(dec.decode(lo), x0, acc0);
        acc1 = _mm512_fmadd_ps(dec.decode(hi), x1, acc1);
        i += 32;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
    // Scalar tail.
    while i < n {
        s += decode_arith(u32::from(codes[i]), e, m, eb) * x[i];
        i += 1;
    }
    s
}

/// Shared AVX-512 arithmetic-decode constants (see [`decode_arith`]).
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy)]
struct DecodeConsts {
    m_v: std::arch::x86_64::__m512i,
    e_mask: std::arch::x86_64::__m512i,
    man_mask: std::arch::x86_64::__m512i,
    implicit: std::arch::x86_64::__m512i,
    one: std::arch::x86_64::__m512i,
    ebase: std::arch::x86_64::__m512i,
    sbits_v: std::arch::x86_64::__m512i,
}

#[cfg(target_arch = "x86_64")]
impl DecodeConsts {
    #[target_feature(enable = "avx512f")]
    unsafe fn new(e: u32, m: u32, eb: i32) -> Self {
        use std::arch::x86_64::*;
        DecodeConsts {
            m_v: _mm512_set1_epi32(m as i32),
            e_mask: _mm512_set1_epi32(((1u32 << e) - 1) as i32),
            man_mask: _mm512_set1_epi32(((1u32 << m) - 1) as i32),
            implicit: _mm512_set1_epi32(1i32 << m),
            one: _mm512_set1_epi32(1),
            ebase: _mm512_set1_epi32(eb),
            sbits_v: _mm512_set1_epi32((e + m) as i32),
        }
    }

    /// codes (u32 lanes) -> dequantized f32 lanes. No denormals anywhere.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn decode(&self, c: std::arch::x86_64::__m512i) -> std::arch::x86_64::__m512 {
        use std::arch::x86_64::*;
        let ef = _mm512_and_si512(_mm512_srlv_epi32(c, self.m_v), self.e_mask);
        let man = _mm512_and_si512(c, self.man_mask);
        let is_norm = _mm512_cmpgt_epi32_mask(ef, _mm512_setzero_si512());
        let mant = _mm512_mask_or_epi32(man, is_norm, man, self.implicit);
        let mant_f = _mm512_cvtepi32_ps(mant);
        let eeff = _mm512_max_epi32(ef, self.one);
        let scale = _mm512_castsi512_ps(_mm512_slli_epi32::<23>(_mm512_add_epi32(eeff, self.ebase)));
        let v = _mm512_mul_ps(mant_f, scale);
        // Apply sign: OR the sign bit into the (non-negative) product.
        let sign = _mm512_slli_epi32::<31>(_mm512_srlv_epi32(c, self.sbits_v));
        _mm512_castsi512_ps(_mm512_or_si512(_mm512_castps_si512(v), sign))
    }
}

/// How a segmented layout supplies the low bits of each code.
#[derive(Clone, Copy, Debug)]
pub enum LowBits {
    /// One LSB per code, 16 per u16 word (FP5 4+1).
    PerCode1,
    /// Two low bits per code, 8 per u16 word (FP6 4+2, TC-FPx).
    PerCode2,
    /// One shared bit per group of `k` codes (AMS e2m2 family).
    Group(usize),
}

/// Fused unpack+decode+dot for "high-nibble stream + low-bit stream"
/// layouts (FP6, FP5, FP4.5, FP4.25): the SIMD realization of the paper's
/// load → SHIFT/AND/OR → MMA pipeline. Returns the final (folded,
/// pre-scale) dot product, or None when the fast path does not apply
/// (non-x86, tiny rows, or k=3 whose groups straddle lanes).
pub fn dot_segmented(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    x: &[f32],
    fmt: FpFormat,
    low: LowBits,
) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            if let LowBits::Group(k) = low {
                if k != 2 && k != 4 {
                    return None; // k=3 groups straddle 16-lane blocks
                }
            }
            // SAFETY: feature checked.
            return Some(unsafe { dot_segmented_avx512(hi_words, low_words, cols, x, fmt, low) });
        }
    }
    let _ = (hi_words, low_words, cols, x, fmt, low);
    None
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_segmented_avx512(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    x: &[f32],
    fmt: FpFormat,
    low: LowBits,
) -> f32 {
    use std::arch::x86_64::*;
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(e, m, eb);
    let nib_shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
    let one = _mm512_set1_epi32(1);
    let low_shifts = match low {
        LowBits::PerCode1 => {
            _mm512_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
        }
        LowBits::PerCode2 => {
            _mm512_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14, 16, 18, 20, 22, 24, 26, 28, 30)
        }
        LowBits::Group(2) => _mm512_setr_epi32(0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7),
        LowBits::Group(_) => _mm512_setr_epi32(0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3),
    };
    // Bits the low value occupies in the code.
    let (low_width, low_mask) = match low {
        LowBits::PerCode2 => (2, _mm512_set1_epi32(3)),
        _ => (1, one),
    };
    let mut acc = _mm512_setzero_ps();
    let blocks = cols / 16;
    for b in 0..blocks {
        // 16 high nibbles from 4 consecutive u16 words.
        let hi64 = (hi_words.as_ptr().add(b * 4) as *const u64).read_unaligned();
        let vlo = _mm512_set1_epi32(hi64 as u32 as i32);
        let vhi = _mm512_set1_epi32((hi64 >> 32) as u32 as i32);
        let packed = _mm512_mask_blend_epi32(0xFF00, vlo, vhi);
        let nib = _mm512_and_si512(_mm512_srlv_epi32(packed, nib_shifts), _mm512_set1_epi32(0xF));
        // 16 low fields.
        let lw = match low {
            LowBits::PerCode1 => u32::from(*low_words.get_unchecked(b)),
            LowBits::PerCode2 => {
                let p = low_words.as_ptr().add(b * 2) as *const u32;
                p.read_unaligned()
            }
            LowBits::Group(k) => {
                // Group index of the block's first code.
                let g0 = b * 16 / k;
                u32::from(*low_words.get_unchecked(g0 / 16)) >> (g0 % 16)
            }
        };
        let lowv = _mm512_and_si512(
            _mm512_srlv_epi32(_mm512_set1_epi32(lw as i32), low_shifts),
            low_mask,
        );
        let code = _mm512_or_si512(_mm512_sllv_epi32(nib, _mm512_set1_epi32(low_width)), lowv);
        let v = dec.decode(code);
        acc = _mm512_fmadd_ps(v, _mm512_loadu_ps(x.as_ptr().add(b * 16)), acc);
    }
    let mut s = _mm512_reduce_add_ps(acc);
    // Scalar tail.
    for i in blocks * 16..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let lowbits = match low {
            LowBits::PerCode1 => (u32::from(low_words[i / 16]) >> (i % 16)) & 1,
            LowBits::PerCode2 => (u32::from(low_words[i / 8]) >> (2 * (i % 8))) & 3,
            LowBits::Group(k) => {
                let g = i / k;
                (u32::from(low_words[g / 16]) >> (g % 16)) & 1
            }
        };
        let code = (hi << low_width) | lowbits;
        s += decode_arith(code, e, m, eb) * x[i];
    }
    s
}

/// Fused FP5.33 dot. The continuous layout packs 3 codes + shared LSB per
/// u16; lanes decode three code streams (positions 0/1/2 of each group),
/// which dot against *pre-de-interleaved* activations `x0/x1/x2` where
/// `xp[j] = x[3j + p]` (built once per GEMV call, amortized over rows).
/// `None` when the fast path does not apply.
pub fn dot_fp533(
    words: &[u16],
    cols: usize,
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x: &[f32],
) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 48 {
            // SAFETY: feature checked.
            return Some(unsafe { dot_fp533_avx512(words, cols, x0, x1, x2, x) });
        }
    }
    let _ = (words, cols, x0, x1, x2, x);
    None
}

/// Split activations into the three stride-3 streams used by [`dot_fp533`].
pub fn deinterleave3(x: &[f32], x0: &mut Vec<f32>, x1: &mut Vec<f32>, x2: &mut Vec<f32>) {
    let groups = x.len().div_ceil(3);
    x0.clear();
    x1.clear();
    x2.clear();
    x0.resize(groups, 0.0);
    x1.resize(groups, 0.0);
    x2.resize(groups, 0.0);
    for (j, chunk) in x.chunks(3).enumerate() {
        x0[j] = chunk[0];
        if chunk.len() > 1 {
            x1[j] = chunk[1];
        }
        if chunk.len() > 2 {
            x2[j] = chunk[2];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_fp533_avx512(
    words: &[u16],
    cols: usize,
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x: &[f32],
) -> f32 {
    use std::arch::x86_64::*;
    let fmt = FpFormat::E2M3;
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(fmt.ebits, fmt.mbits, eb);
    let m5 = _mm512_set1_epi32(0x1F);
    let one = _mm512_set1_epi32(1);
    let full_groups = cols / 3; // groups with all 3 members in-range
    let blocks = full_groups / 16;
    let mut a0 = _mm512_setzero_ps();
    let mut a1 = _mm512_setzero_ps();
    let mut a2 = _mm512_setzero_ps();
    for b in 0..blocks {
        // 16 group words -> 16 u32 lanes.
        let w16 = _mm256_loadu_si256(words.as_ptr().add(b * 16) as *const _);
        let w = _mm512_cvtepu16_epi32(w16);
        let shared = _mm512_and_si512(_mm512_srli_epi32::<15>(w), one);
        let c0 = _mm512_or_si512(_mm512_slli_epi32::<1>(_mm512_and_si512(w, m5)), shared);
        let c1 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<5>(w), m5)),
            shared,
        );
        let c2 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<10>(w), m5)),
            shared,
        );
        a0 = _mm512_fmadd_ps(dec.decode(c0), _mm512_loadu_ps(x0.as_ptr().add(b * 16)), a0);
        a1 = _mm512_fmadd_ps(dec.decode(c1), _mm512_loadu_ps(x1.as_ptr().add(b * 16)), a1);
        a2 = _mm512_fmadd_ps(dec.decode(c2), _mm512_loadu_ps(x2.as_ptr().add(b * 16)), a2);
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(_mm512_add_ps(a0, a1), a2));
    // Scalar tail (remaining groups + ragged last group).
    for i in blocks * 48..cols {
        let w = u32::from(words[i / 3]);
        let shared = (w >> 15) & 1;
        let code = (((w >> (5 * (i % 3))) & 0x1F) << 1) | shared;
        s += decode_arith(code, fmt.ebits, fmt.mbits, eb) * x[i];
    }
    s
}

/// Fused 8-bit-code dot (FP8-e4m3): codes are a contiguous byte stream.
pub fn dot_bytes(words: &[u16], cols: usize, x: &[f32], fmt: FpFormat) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            // SAFETY: feature checked.
            return Some(unsafe { dot_bytes_avx512(words, cols, x, fmt) });
        }
    }
    let _ = (words, cols, x, fmt);
    None
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_bytes_avx512(words: &[u16], cols: usize, x: &[f32], fmt: FpFormat) -> f32 {
    use std::arch::x86_64::*;
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(fmt.ebits, fmt.mbits, eb);
    let bytes = words.as_ptr() as *const u8; // little-endian: byte i = code i
    let mut acc = _mm512_setzero_ps();
    let blocks = cols / 16;
    for b in 0..blocks {
        let c8 = _mm_loadu_si128(bytes.add(b * 16) as *const _);
        let c = _mm512_cvtepu8_epi32(c8);
        acc = _mm512_fmadd_ps(dec.decode(c), _mm512_loadu_ps(x.as_ptr().add(b * 16)), acc);
    }
    let mut s = _mm512_reduce_add_ps(acc);
    for i in blocks * 16..cols {
        let code = u32::from(*bytes.add(i));
        s += decode_arith(code, fmt.ebits, fmt.mbits, eb) * x[i];
    }
    s
}

/// Fused fp16-bits dot: `Σ fp16(words[i]) * x[i]` (the W16A16 baseline).
/// Uses VCVTPH2PS when available.
pub fn dot_fp16_bits(words: &[u16], x: &[f32], table: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() {
            return unsafe { dot_fp16_avx512(words, x) };
        }
    }
    let mut acc = 0f32;
    for (i, &w) in words.iter().enumerate() {
        acc += table[w as usize] * x[i];
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dot_fp16_avx512(words: &[u16], x: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = words.len();
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let h0 = _mm256_loadu_si256(words.as_ptr().add(i) as *const _);
        let h1 = _mm256_loadu_si256(words.as_ptr().add(i + 16) as *const _);
        let v0 = _mm512_cvtph_ps(h0);
        let v1 = _mm512_cvtph_ps(h1);
        acc0 = _mm512_fmadd_ps(v0, _mm512_loadu_ps(x.as_ptr().add(i)), acc0);
        acc1 = _mm512_fmadd_ps(v1, _mm512_loadu_ps(x.as_ptr().add(i + 16)), acc1);
        i += 32;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
    while i < n {
        s += crate::formats::fp16::fp16_to_f32(words[i]) * x[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn decode_identity_all_codes() {
        // decode_arith == FpFormat::decode for every code of every format,
        // and never produces a denormal f32.
        for fmt in [
            FpFormat::E2M1,
            FpFormat::E2M2,
            FpFormat::E2M3,
            FpFormat::E3M2,
            FpFormat::E4M3,
        ] {
            let eb = expo_base(fmt);
            for code in 0..fmt.code_count() as u16 {
                let got = decode_arith(u32::from(code), fmt.ebits, fmt.mbits, eb);
                assert_eq!(got, fmt.decode(code), "{} code {code}", fmt.name());
                assert!(got == 0.0 || got.abs() >= f32::MIN_POSITIVE);
            }
        }
    }

    #[test]
    fn dot_matches_reference() {
        let mut rng = Rng::new(1);
        for fmt in [FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2] {
            for n in [1usize, 15, 32, 33, 100, 1000] {
                let codes: Vec<u16> = (0..n)
                    .map(|_| (rng.next_u32() as u16) & fmt.code_mask())
                    .collect();
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let fused = dot_codes(&codes, &x, fmt);
                let reference: f32 = codes
                    .iter()
                    .zip(&x)
                    .map(|(&c, &xv)| fmt.decode(c) * xv)
                    .sum();
                assert!(
                    (fused - reference).abs() <= 2e-4 * (1.0 + reference.abs()),
                    "{} n={n}: {fused} vs {reference}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn decode_codes_buffer() {
        let fmt = FpFormat::E2M3;
        let codes: Vec<u16> = (0..fmt.code_count() as u16).collect();
        let mut out = vec![0f32; codes.len()];
        decode_codes(&codes, &mut out, fmt);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, fmt.decode(i as u16));
        }
    }

    #[test]
    fn fp16_dot_matches_table() {
        let mut rng = Rng::new(2);
        let table = crate::gemm::dequant_table(crate::formats::registry::Scheme::Fp16);
        for n in [1usize, 31, 32, 64, 257] {
            // Finite half values only (exponent < 0x1F).
            let words: Vec<u16> = (0..n)
                .map(|_| {
                    let w = rng.next_u32() as u16;
                    if (w >> 10) & 0x1F == 0x1F {
                        w & !(1 << 14)
                    } else {
                        w
                    }
                })
                .collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let fused = dot_fp16_bits(&words, &x, &table);
            let reference: f32 = words
                .iter()
                .zip(&x)
                .map(|(&w, &xv)| table[w as usize] * xv)
                .sum();
            let mag = reference.abs().max(words.iter().map(|&w| table[w as usize].abs()).fold(0.0, f32::max));
            assert!(
                (fused - reference).abs() <= 1e-2 * (1.0 + mag),
                "n={n}: {fused} vs {reference}"
            );
        }
    }
}
