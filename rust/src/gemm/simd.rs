//! Vectorized decode + dot kernels (the §Perf hot path).
//!
//! Key identity (the SIMD form of §3.2's restoration, used by TC-FPx and
//! here): placing an FPx code's exponent+mantissa field at the top of the
//! f32 mantissa/exponent and rescaling by a power of two is *exact*, for
//! normals and subnormals alike:
//!
//! ```text
//! f32(code) = bitcast(sign << 31 | em << (23 - m)) * 2^(127 - bias)
//! ```
//!
//! * normal (E≠0): bitcast = 2^(E-127)·(1+man/2^m); ×2^(127-bias) = 2^(E-bias)·(1+man/2^m) ✓
//! * subnormal (E=0): bitcast = man·2^(-126-m);     ×2^(127-bias) = man·2^(1-bias-m)       ✓
//!
//! The 2^(127-bias) factor is folded into the per-channel scale, so decode
//! is just shift/and/or + the FMA the kernel already performs — no gather
//! tables. Written as clean scalar loops that LLVM auto-vectorizes, with
//! explicit AVX-512 paths where it cannot.

use crate::formats::FpFormat;

/// Exponent base for the arithmetic decode: `127 - bias - m`. The decoded
/// value is `(man | implicit·2^m) · 2^(max(E,1) + expo_base - 127)` — an
/// exact product of an integer-valued f32 and a power of two, never a
/// denormal (§Perf iteration log: bit-placement decode produced denormal
/// f32 inputs for FPx-subnormal codes, and x86 denormal multiplies are
/// microcoded at ~100 cycles — a measured 10–50× kernel slowdown).
#[inline]
pub fn expo_base(fmt: FpFormat) -> i32 {
    127 - fmt.bias() - fmt.mbits as i32
}

/// Scalar arithmetic decode of one code — exact for every format code.
#[inline(always)]
pub fn decode_arith(code: u32, e: u32, m: u32, expo_base: i32) -> f32 {
    let ef = (code >> m) & ((1 << e) - 1);
    let man = code & ((1 << m) - 1);
    let norm = u32::from(ef != 0);
    let mant = (man | (norm << m)) as f32;
    let eeff = ef.max(1) as i32;
    let scale = f32::from_bits(((eeff + expo_base) as u32) << 23);
    let v = mant * scale;
    if (code >> (e + m)) & 1 == 1 {
        -v
    } else {
        v
    }
}

/// Fused decode+dot over a code buffer:
/// `Σ (decode_raw(codes[i]) · fold) * x[i]` — the fold happens *inside*
/// the loop: pre-fold bit patterns are f32 denormals (their exponent field
/// holds the tiny FPx exponent), and FMA on denormals is microcoded on
/// x86 (~100 cycles/op, a measured 10–50× kernel slowdown). Multiplying by
/// 2^(127-bias) first lifts every value into the normal range (§Perf log).
/// Returns the final dequantized dot (multiply only by the channel scale).
pub fn dot_codes(codes: &[u16], x: &[f32], fmt: FpFormat) -> f32 {
    debug_assert!(codes.len() <= x.len());
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() {
            // SAFETY: feature checked at runtime.
            return unsafe { dot_codes_avx512(codes, x, e, m, eb) };
        }
    }
    dot_codes_scalar(codes, x, e, m, eb)
}

/// Decode a code buffer into final f32 values (pre-scale).
pub fn decode_codes(codes: &[u16], out: &mut [f32], fmt: FpFormat) {
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = decode_arith(u32::from(c), e, m, eb);
    }
}

fn dot_codes_scalar(codes: &[u16], x: &[f32], e: u32, m: u32, eb: i32) -> f32 {
    // Four independent accumulators: breaks the FMA dependency chain so
    // the loop pipelines (and auto-vectorizes).
    let mut acc = [0f32; 4];
    let n = codes.len();
    let chunks = n / 4;
    for i in 0..chunks {
        for j in 0..4 {
            let idx = i * 4 + j;
            acc[j] += decode_arith(u32::from(codes[idx]), e, m, eb) * x[idx];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for idx in chunks * 4..n {
        s += decode_arith(u32::from(codes[idx]), e, m, eb) * x[idx];
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[inline]
pub fn is_avx512() -> bool {
    use std::sync::OnceLock;
    static HAS: OnceLock<bool> = OnceLock::new();
    *HAS.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512bw")
    })
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
pub fn is_avx512() -> bool {
    false
}

/// AVX-512: 16 codes per iteration — widen u16→u32, shift/and/or into f32
/// bit patterns, FMA against the activation lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_codes_avx512(codes: &[u16], x: &[f32], e: u32, m: u32, eb: i32) -> f32 {
    use std::arch::x86_64::*;
    let n = codes.len();
    let dec = DecodeConsts::new(e, m, eb);
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let c16 = _mm512_loadu_si512(codes.as_ptr().add(i) as *const _);
        // Widen the two 256-bit halves.
        let lo = _mm512_cvtepu16_epi32(_mm512_castsi512_si256(c16));
        let hi = _mm512_cvtepu16_epi32(_mm512_extracti64x4_epi64::<1>(c16));
        let x0 = _mm512_loadu_ps(x.as_ptr().add(i));
        let x1 = _mm512_loadu_ps(x.as_ptr().add(i + 16));
        acc0 = _mm512_fmadd_ps(dec.decode(lo), x0, acc0);
        acc1 = _mm512_fmadd_ps(dec.decode(hi), x1, acc1);
        i += 32;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
    // Scalar tail.
    while i < n {
        s += decode_arith(u32::from(codes[i]), e, m, eb) * x[i];
        i += 1;
    }
    s
}

/// Shared AVX-512 arithmetic-decode constants (see [`decode_arith`]).
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy)]
struct DecodeConsts {
    m_v: std::arch::x86_64::__m512i,
    e_mask: std::arch::x86_64::__m512i,
    man_mask: std::arch::x86_64::__m512i,
    implicit: std::arch::x86_64::__m512i,
    one: std::arch::x86_64::__m512i,
    ebase: std::arch::x86_64::__m512i,
    sbits_v: std::arch::x86_64::__m512i,
}

#[cfg(target_arch = "x86_64")]
impl DecodeConsts {
    #[target_feature(enable = "avx512f")]
    unsafe fn new(e: u32, m: u32, eb: i32) -> Self {
        use std::arch::x86_64::*;
        DecodeConsts {
            m_v: _mm512_set1_epi32(m as i32),
            e_mask: _mm512_set1_epi32(((1u32 << e) - 1) as i32),
            man_mask: _mm512_set1_epi32(((1u32 << m) - 1) as i32),
            implicit: _mm512_set1_epi32(1i32 << m),
            one: _mm512_set1_epi32(1),
            ebase: _mm512_set1_epi32(eb),
            sbits_v: _mm512_set1_epi32((e + m) as i32),
        }
    }

    /// codes (u32 lanes) -> dequantized f32 lanes. No denormals anywhere.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn decode(&self, c: std::arch::x86_64::__m512i) -> std::arch::x86_64::__m512 {
        use std::arch::x86_64::*;
        let ef = _mm512_and_si512(_mm512_srlv_epi32(c, self.m_v), self.e_mask);
        let man = _mm512_and_si512(c, self.man_mask);
        let is_norm = _mm512_cmpgt_epi32_mask(ef, _mm512_setzero_si512());
        let mant = _mm512_mask_or_epi32(man, is_norm, man, self.implicit);
        let mant_f = _mm512_cvtepi32_ps(mant);
        let eeff = _mm512_max_epi32(ef, self.one);
        let scale = _mm512_castsi512_ps(_mm512_slli_epi32::<23>(_mm512_add_epi32(eeff, self.ebase)));
        let v = _mm512_mul_ps(mant_f, scale);
        // Apply sign: OR the sign bit into the (non-negative) product.
        let sign = _mm512_slli_epi32::<31>(_mm512_srlv_epi32(c, self.sbits_v));
        _mm512_castsi512_ps(_mm512_or_si512(_mm512_castps_si512(v), sign))
    }
}

/// How a segmented layout supplies the low bits of each code.
#[derive(Clone, Copy, Debug)]
pub enum LowBits {
    /// One LSB per code, 16 per u16 word (FP5 4+1).
    PerCode1,
    /// Two low bits per code, 8 per u16 word (FP6 4+2, TC-FPx).
    PerCode2,
    /// One shared bit per group of `k` codes (AMS e2m2 family).
    Group(usize),
}

/// Fused unpack+decode+dot for "high-nibble stream + low-bit stream"
/// layouts (FP6, FP5, FP4.5, FP4.25): the SIMD realization of the paper's
/// load → SHIFT/AND/OR → MMA pipeline. Returns the final (folded,
/// pre-scale) dot product, or None when the fast path does not apply
/// (non-x86, tiny rows, or k=3 whose groups straddle lanes).
pub fn dot_segmented(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    x: &[f32],
    fmt: FpFormat,
    low: LowBits,
) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            if let LowBits::Group(k) = low {
                if k != 2 && k != 4 {
                    return None; // k=3 groups straddle 16-lane blocks
                }
            }
            // SAFETY: feature checked.
            return Some(unsafe { dot_segmented_avx512(hi_words, low_words, cols, x, fmt, low) });
        }
    }
    let _ = (hi_words, low_words, cols, x, fmt, low);
    None
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_segmented_avx512(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    x: &[f32],
    fmt: FpFormat,
    low: LowBits,
) -> f32 {
    use std::arch::x86_64::*;
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(e, m, eb);
    let nib_shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
    let one = _mm512_set1_epi32(1);
    let low_shifts = match low {
        LowBits::PerCode1 => {
            _mm512_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
        }
        LowBits::PerCode2 => {
            _mm512_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14, 16, 18, 20, 22, 24, 26, 28, 30)
        }
        LowBits::Group(2) => _mm512_setr_epi32(0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7),
        LowBits::Group(_) => _mm512_setr_epi32(0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3),
    };
    // Bits the low value occupies in the code.
    let (low_width, low_mask) = match low {
        LowBits::PerCode2 => (2, _mm512_set1_epi32(3)),
        _ => (1, one),
    };
    let mut acc = _mm512_setzero_ps();
    let blocks = cols / 16;
    for b in 0..blocks {
        // 16 high nibbles from 4 consecutive u16 words.
        let hi64 = (hi_words.as_ptr().add(b * 4) as *const u64).read_unaligned();
        let vlo = _mm512_set1_epi32(hi64 as u32 as i32);
        let vhi = _mm512_set1_epi32((hi64 >> 32) as u32 as i32);
        let packed = _mm512_mask_blend_epi32(0xFF00, vlo, vhi);
        let nib = _mm512_and_si512(_mm512_srlv_epi32(packed, nib_shifts), _mm512_set1_epi32(0xF));
        // 16 low fields.
        let lw = match low {
            LowBits::PerCode1 => u32::from(*low_words.get_unchecked(b)),
            LowBits::PerCode2 => {
                let p = low_words.as_ptr().add(b * 2) as *const u32;
                p.read_unaligned()
            }
            LowBits::Group(k) => {
                // Group index of the block's first code.
                let g0 = b * 16 / k;
                u32::from(*low_words.get_unchecked(g0 / 16)) >> (g0 % 16)
            }
        };
        let lowv = _mm512_and_si512(
            _mm512_srlv_epi32(_mm512_set1_epi32(lw as i32), low_shifts),
            low_mask,
        );
        let code = _mm512_or_si512(_mm512_sllv_epi32(nib, _mm512_set1_epi32(low_width)), lowv);
        let v = dec.decode(code);
        acc = _mm512_fmadd_ps(v, _mm512_loadu_ps(x.as_ptr().add(b * 16)), acc);
    }
    let mut s = _mm512_reduce_add_ps(acc);
    // Scalar tail.
    for i in blocks * 16..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let lowbits = match low {
            LowBits::PerCode1 => (u32::from(low_words[i / 16]) >> (i % 16)) & 1,
            LowBits::PerCode2 => (u32::from(low_words[i / 8]) >> (2 * (i % 8))) & 3,
            LowBits::Group(k) => {
                let g = i / k;
                (u32::from(low_words[g / 16]) >> (g % 16)) & 1
            }
        };
        let code = (hi << low_width) | lowbits;
        s += decode_arith(code, e, m, eb) * x[i];
    }
    s
}

/// Fused FP5.33 dot. The continuous layout packs 3 codes + shared LSB per
/// u16; lanes decode three code streams (positions 0/1/2 of each group),
/// which dot against *pre-de-interleaved* activations `x0/x1/x2` where
/// `xp[j] = x[3j + p]` (built once per GEMV call, amortized over rows).
/// `None` when the fast path does not apply.
pub fn dot_fp533(
    words: &[u16],
    cols: usize,
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x: &[f32],
) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 48 {
            // SAFETY: feature checked.
            return Some(unsafe { dot_fp533_avx512(words, cols, x0, x1, x2, x) });
        }
    }
    let _ = (words, cols, x0, x1, x2, x);
    None
}

/// Split activations into the three stride-3 streams used by [`dot_fp533`].
pub fn deinterleave3(x: &[f32], x0: &mut Vec<f32>, x1: &mut Vec<f32>, x2: &mut Vec<f32>) {
    let groups = x.len().div_ceil(3);
    x0.clear();
    x1.clear();
    x2.clear();
    x0.resize(groups, 0.0);
    x1.resize(groups, 0.0);
    x2.resize(groups, 0.0);
    for (j, chunk) in x.chunks(3).enumerate() {
        x0[j] = chunk[0];
        if chunk.len() > 1 {
            x1[j] = chunk[1];
        }
        if chunk.len() > 2 {
            x2[j] = chunk[2];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_fp533_avx512(
    words: &[u16],
    cols: usize,
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x: &[f32],
) -> f32 {
    use std::arch::x86_64::*;
    let fmt = FpFormat::E2M3;
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(fmt.ebits, fmt.mbits, eb);
    let m5 = _mm512_set1_epi32(0x1F);
    let one = _mm512_set1_epi32(1);
    let full_groups = cols / 3; // groups with all 3 members in-range
    let blocks = full_groups / 16;
    let mut a0 = _mm512_setzero_ps();
    let mut a1 = _mm512_setzero_ps();
    let mut a2 = _mm512_setzero_ps();
    for b in 0..blocks {
        // 16 group words -> 16 u32 lanes.
        let w16 = _mm256_loadu_si256(words.as_ptr().add(b * 16) as *const _);
        let w = _mm512_cvtepu16_epi32(w16);
        let shared = _mm512_and_si512(_mm512_srli_epi32::<15>(w), one);
        let c0 = _mm512_or_si512(_mm512_slli_epi32::<1>(_mm512_and_si512(w, m5)), shared);
        let c1 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<5>(w), m5)),
            shared,
        );
        let c2 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<10>(w), m5)),
            shared,
        );
        a0 = _mm512_fmadd_ps(dec.decode(c0), _mm512_loadu_ps(x0.as_ptr().add(b * 16)), a0);
        a1 = _mm512_fmadd_ps(dec.decode(c1), _mm512_loadu_ps(x1.as_ptr().add(b * 16)), a1);
        a2 = _mm512_fmadd_ps(dec.decode(c2), _mm512_loadu_ps(x2.as_ptr().add(b * 16)), a2);
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(_mm512_add_ps(a0, a1), a2));
    // Scalar tail (remaining groups + ragged last group).
    for i in blocks * 48..cols {
        let w = u32::from(words[i / 3]);
        let shared = (w >> 15) & 1;
        let code = (((w >> (5 * (i % 3))) & 0x1F) << 1) | shared;
        s += decode_arith(code, fmt.ebits, fmt.mbits, eb) * x[i];
    }
    s
}

/// Fused 8-bit-code dot (FP8-e4m3): codes are a contiguous byte stream.
pub fn dot_bytes(words: &[u16], cols: usize, x: &[f32], fmt: FpFormat) -> Option<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            // SAFETY: feature checked.
            return Some(unsafe { dot_bytes_avx512(words, cols, x, fmt) });
        }
    }
    let _ = (words, cols, x, fmt);
    None
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_bytes_avx512(words: &[u16], cols: usize, x: &[f32], fmt: FpFormat) -> f32 {
    use std::arch::x86_64::*;
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(fmt.ebits, fmt.mbits, eb);
    let bytes = words.as_ptr() as *const u8; // little-endian: byte i = code i
    let mut acc = _mm512_setzero_ps();
    let blocks = cols / 16;
    for b in 0..blocks {
        let c8 = _mm_loadu_si128(bytes.add(b * 16) as *const _);
        let c = _mm512_cvtepu8_epi32(c8);
        acc = _mm512_fmadd_ps(dec.decode(c), _mm512_loadu_ps(x.as_ptr().add(b * 16)), acc);
    }
    let mut s = _mm512_reduce_add_ps(acc);
    for i in blocks * 16..cols {
        let code = u32::from(*bytes.add(i));
        s += decode_arith(code, fmt.ebits, fmt.mbits, eb) * x[i];
    }
    s
}

/// Fused fp16-bits dot: `Σ fp16(words[i]) * x[i]` (the W16A16 baseline).
/// Uses VCVTPH2PS when available.
pub fn dot_fp16_bits(words: &[u16], x: &[f32], table: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() {
            return unsafe { dot_fp16_avx512(words, x) };
        }
    }
    let mut acc = 0f32;
    for (i, &w) in words.iter().enumerate() {
        acc += table[w as usize] * x[i];
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dot_fp16_avx512(words: &[u16], x: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = words.len();
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let h0 = _mm256_loadu_si256(words.as_ptr().add(i) as *const _);
        let h1 = _mm256_loadu_si256(words.as_ptr().add(i + 16) as *const _);
        let v0 = _mm512_cvtph_ps(h0);
        let v1 = _mm512_cvtph_ps(h1);
        acc0 = _mm512_fmadd_ps(v0, _mm512_loadu_ps(x.as_ptr().add(i)), acc0);
        acc1 = _mm512_fmadd_ps(v1, _mm512_loadu_ps(x.as_ptr().add(i + 16)), acc1);
        i += 32;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
    while i < n {
        s += crate::formats::fp16::fp16_to_f32(words[i]) * x[i];
        i += 1;
    }
    s
}

// --- tiled batch kernels (dotN) -----------------------------------------
//
// The batched GEMM hot path streams one packed row once against a *tile*
// of `T` activation rows (T ∈ {8, 4, 2, 1}, picked by the caller from the
// remaining batch width). Each code is decoded exactly once per row-tile
// and fan-out FMAd into `T` register accumulators, so the packed words —
// not dequantized f32 — are the only weight traffic. Activation rows come
// straight from row-major `X` (contiguous per row), so no transpose is
// needed. All dotn_* kernels are *total*: AVX-512 when available and the
// shape qualifies, an equivalent scalar loop otherwise.

/// Largest tile width the batched path uses (activation rows per pass).
pub const NTILE: usize = 8;

/// Every activation row must cover `n` elements — guards the unchecked
/// vector loads inside the AVX-512 tile kernels (safe-fn boundary).
#[inline]
fn assert_xs_len<const T: usize>(xs: &[&[f32]; T], n: usize) {
    for x in xs {
        assert!(x.len() >= n, "activation row too short: {} < {n}", x.len());
    }
}

/// Whether the FP5.33 AVX-512 fast path — and therefore the
/// de-interleaved activation streams it consumes — applies at this column
/// count on this host. Callers skip building the streams when false.
pub fn fp533_uses_deint(cols: usize) -> bool {
    is_avx512() && cols >= 48
}

/// Fused decode+dot of a code buffer against `T` activation rows.
/// Returns the pre-channel-scale dots (fold applied, see [`dot_codes`]).
pub fn dotn_codes<const T: usize>(codes: &[u16], xs: &[&[f32]; T], fmt: FpFormat) -> [f32; T] {
    assert_xs_len(xs, codes.len());
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && codes.len() >= 16 {
            // SAFETY: feature checked at runtime; xs lengths asserted.
            return unsafe { dotn_codes_avx512(codes, xs, e, m, eb) };
        }
    }
    let mut acc = [0f32; T];
    for (i, &c) in codes.iter().enumerate() {
        let v = decode_arith(u32::from(c), e, m, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_codes_avx512<const T: usize>(
    codes: &[u16],
    xs: &[&[f32]; T],
    e: u32,
    m: u32,
    eb: i32,
) -> [f32; T] {
    use std::arch::x86_64::*;
    let n = codes.len();
    let dec = DecodeConsts::new(e, m, eb);
    let mut acc = [_mm512_setzero_ps(); T];
    let mut i = 0usize;
    while i + 16 <= n {
        let c8 = _mm256_loadu_si256(codes.as_ptr().add(i) as *const _);
        let v = dec.decode(_mm512_cvtepu16_epi32(c8));
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(i)), acc[j]);
        }
        i += 16;
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    while i < n {
        let v = decode_arith(u32::from(codes[i]), e, m, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
        i += 1;
    }
    out
}

/// Table-gather dot of a code buffer against `T` activation rows (INT and
/// other LUT-served schemes). The `T`-wide inner fan-out auto-vectorizes.
pub fn dotn_table<const T: usize>(codes: &[u16], xs: &[&[f32]; T], table: &[f32]) -> [f32; T] {
    assert_xs_len(xs, codes.len());
    let mut acc = [0f32; T];
    for (i, &c) in codes.iter().enumerate() {
        let v = table[c as usize];
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

/// Fused fp16-bits dot against `T` activation rows (W16A16 baseline).
pub fn dotn_fp16_bits<const T: usize>(
    words: &[u16],
    xs: &[&[f32]; T],
    table: &[f32],
) -> [f32; T] {
    assert_xs_len(xs, words.len());
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && words.len() >= 16 {
            // SAFETY: feature checked; xs lengths asserted.
            return unsafe { dotn_fp16_avx512(words, xs) };
        }
    }
    let mut acc = [0f32; T];
    for (i, &w) in words.iter().enumerate() {
        let v = table[w as usize];
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dotn_fp16_avx512<const T: usize>(words: &[u16], xs: &[&[f32]; T]) -> [f32; T] {
    use std::arch::x86_64::*;
    let n = words.len();
    let mut acc = [_mm512_setzero_ps(); T];
    let mut i = 0usize;
    while i + 16 <= n {
        let v = _mm512_cvtph_ps(_mm256_loadu_si256(words.as_ptr().add(i) as *const _));
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(i)), acc[j]);
        }
        i += 16;
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    while i < n {
        let v = crate::formats::fp16::fp16_to_f32(words[i]);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
        i += 1;
    }
    out
}

/// Fused 8-bit-code dot (FP8-e4m3) against `T` activation rows.
pub fn dotn_bytes<const T: usize>(
    words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
) -> [f32; T] {
    assert_xs_len(xs, cols);
    assert!(words.len() * 2 >= cols, "byte stream too short");
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            // SAFETY: feature checked; stream and xs lengths asserted.
            return unsafe { dotn_bytes_avx512(words, cols, xs, e, m, eb) };
        }
    }
    let mut acc = [0f32; T];
    for i in 0..cols {
        // Little-endian: byte i of the u16 stream is code i.
        let code = (u32::from(words[i / 2]) >> (8 * (i % 2))) & 0xFF;
        let v = decode_arith(code, e, m, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_bytes_avx512<const T: usize>(
    words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    e: u32,
    m: u32,
    eb: i32,
) -> [f32; T] {
    use std::arch::x86_64::*;
    let dec = DecodeConsts::new(e, m, eb);
    let bytes = words.as_ptr() as *const u8; // little-endian: byte i = code i
    let mut acc = [_mm512_setzero_ps(); T];
    let blocks = cols / 16;
    for b in 0..blocks {
        let c8 = _mm_loadu_si128(bytes.add(b * 16) as *const _);
        let v = dec.decode(_mm512_cvtepu8_epi32(c8));
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(b * 16)), acc[j]);
        }
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    for i in blocks * 16..cols {
        let v = decode_arith(u32::from(*bytes.add(i)), e, m, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
    }
    out
}

/// Fused unpack+decode+dot for segmented layouts (FP6, FP5, FP4.x) against
/// `T` activation rows. Total: falls back to a scalar extract+decode loop
/// when the AVX-512 path does not apply (non-x86, tiny rows, or group
/// widths that straddle 16-lane blocks).
pub fn dotn_segmented<const T: usize>(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    low: LowBits,
) -> [f32; T] {
    assert_xs_len(xs, cols);
    assert!(hi_words.len() >= cols.div_ceil(4), "hi stream too short");
    let low_needed = match low {
        LowBits::PerCode1 => cols.div_ceil(16),
        LowBits::PerCode2 => cols.div_ceil(8),
        LowBits::Group(k) => cols.div_ceil(k).div_ceil(16),
    };
    assert!(low_words.len() >= low_needed, "low stream too short");
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        let lanes_ok = match low {
            LowBits::Group(k) => k == 2 || k == 4,
            _ => true,
        };
        if is_avx512() && cols >= 16 && lanes_ok {
            // SAFETY: feature checked; stream and xs lengths asserted.
            return unsafe { dotn_segmented_avx512(hi_words, low_words, cols, xs, fmt, low) };
        }
    }
    let low_width = match low {
        LowBits::PerCode2 => 2,
        _ => 1,
    };
    let mut acc = [0f32; T];
    for i in 0..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let lowbits = match low {
            LowBits::PerCode1 => (u32::from(low_words[i / 16]) >> (i % 16)) & 1,
            LowBits::PerCode2 => (u32::from(low_words[i / 8]) >> (2 * (i % 8))) & 3,
            LowBits::Group(k) => {
                let g = i / k;
                (u32::from(low_words[g / 16]) >> (g % 16)) & 1
            }
        };
        let v = decode_arith((hi << low_width) | lowbits, e, m, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_segmented_avx512<const T: usize>(
    hi_words: &[u16],
    low_words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    low: LowBits,
) -> [f32; T] {
    use std::arch::x86_64::*;
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(e, m, eb);
    let nib_shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
    let one = _mm512_set1_epi32(1);
    let low_shifts = match low {
        LowBits::PerCode1 => {
            _mm512_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
        }
        LowBits::PerCode2 => {
            _mm512_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14, 16, 18, 20, 22, 24, 26, 28, 30)
        }
        LowBits::Group(2) => _mm512_setr_epi32(0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7),
        LowBits::Group(_) => _mm512_setr_epi32(0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3),
    };
    let (low_width, low_mask) = match low {
        LowBits::PerCode2 => (2, _mm512_set1_epi32(3)),
        _ => (1, one),
    };
    let mut acc = [_mm512_setzero_ps(); T];
    let blocks = cols / 16;
    for b in 0..blocks {
        let hi64 = (hi_words.as_ptr().add(b * 4) as *const u64).read_unaligned();
        let vlo = _mm512_set1_epi32(hi64 as u32 as i32);
        let vhi = _mm512_set1_epi32((hi64 >> 32) as u32 as i32);
        let packed = _mm512_mask_blend_epi32(0xFF00, vlo, vhi);
        let nib = _mm512_and_si512(_mm512_srlv_epi32(packed, nib_shifts), _mm512_set1_epi32(0xF));
        let lw = match low {
            LowBits::PerCode1 => u32::from(*low_words.get_unchecked(b)),
            LowBits::PerCode2 => {
                let p = low_words.as_ptr().add(b * 2) as *const u32;
                p.read_unaligned()
            }
            LowBits::Group(k) => {
                let g0 = b * 16 / k;
                u32::from(*low_words.get_unchecked(g0 / 16)) >> (g0 % 16)
            }
        };
        let lowv = _mm512_and_si512(
            _mm512_srlv_epi32(_mm512_set1_epi32(lw as i32), low_shifts),
            low_mask,
        );
        let code = _mm512_or_si512(_mm512_sllv_epi32(nib, _mm512_set1_epi32(low_width)), lowv);
        let v = dec.decode(code);
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(b * 16)), acc[j]);
        }
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    for i in blocks * 16..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let lowbits = match low {
            LowBits::PerCode1 => (u32::from(low_words[i / 16]) >> (i % 16)) & 1,
            LowBits::PerCode2 => (u32::from(low_words[i / 8]) >> (2 * (i % 8))) & 3,
            LowBits::Group(k) => {
                let g = i / k;
                (u32::from(low_words[g / 16]) >> (g % 16)) & 1
            }
        };
        let v = decode_arith((hi << low_width) | lowbits, e, m, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
    }
    out
}

/// Hi-stream-only dot for segmented layouts: decode each code as
/// `hi << low_width` — the low mantissa bits zero-filled — against `T`
/// activation rows, reading **only** the high-nibble stream (the function
/// takes no low-word argument, so the draft path provably touches no
/// lo-stream memory). This is the mantissa-truncated draft decode of the
/// self-speculative path: the caller folds the least-squares
/// `hi_rescale` correction into the row/group scale. Works for every
/// segmented `LowBits` variant — with no shared bits to broadcast there
/// is no lane-alignment gate, so k=3 shared groups serve too.
pub fn dotn_segmented_hi<const T: usize>(
    hi_words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    low_width: u32,
) -> [f32; T] {
    assert_xs_len(xs, cols);
    assert!(hi_words.len() >= cols.div_ceil(4), "hi stream too short");
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && cols >= 16 {
            // SAFETY: feature checked; stream and xs lengths asserted.
            return unsafe { dotn_segmented_hi_avx512(hi_words, cols, xs, fmt, low_width) };
        }
    }
    let mut acc = [0f32; T];
    for i in 0..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let v = decode_arith(hi << low_width, e, m, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_segmented_hi_avx512<const T: usize>(
    hi_words: &[u16],
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    low_width: u32,
) -> [f32; T] {
    use std::arch::x86_64::*;
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(e, m, eb);
    let nib_shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
    let lw = _mm512_set1_epi32(low_width as i32);
    let mut acc = [_mm512_setzero_ps(); T];
    let blocks = cols / 16;
    for b in 0..blocks {
        let hi64 = (hi_words.as_ptr().add(b * 4) as *const u64).read_unaligned();
        let vlo = _mm512_set1_epi32(hi64 as u32 as i32);
        let vhi = _mm512_set1_epi32((hi64 >> 32) as u32 as i32);
        let packed = _mm512_mask_blend_epi32(0xFF00, vlo, vhi);
        let nib = _mm512_and_si512(_mm512_srlv_epi32(packed, nib_shifts), _mm512_set1_epi32(0xF));
        let code = _mm512_sllv_epi32(nib, lw);
        let v = dec.decode(code);
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(b * 16)), acc[j]);
        }
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    for i in blocks * 16..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let v = decode_arith(hi << low_width, e, m, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
    }
    out
}

/// Shared-bit segmented dot over a column *segment* of a row — the
/// stream-direct grouped kernel for the AMS (4 + 1/k) layouts, where a
/// `PerGroup` boundary can fall mid-word in the shared-bit stream (e.g.
/// g=32, k=4 → bit 8 of word 0). `hi_words` is the row's high-nibble
/// stream sliced at the segment (the caller guarantees `c0 % 4 == 0`);
/// `low_words` is the row's *full* shared-bit stream, addressed
/// absolutely through `g_base = c0 / k`, the shared-group index of the
/// segment's first code (`c0 % k == 0`). Total: AVX-512 for k ∈ {2, 4}
/// at in-word-aligned bases, an equivalent scalar loop otherwise. The
/// reduction structure matches [`dotn_dense`] block-for-block, so the
/// buffered grouped path (decode to values, dense segment dot) produces
/// bit-identical results.
pub fn dotn_segmented_group_at<const T: usize>(
    hi_words: &[u16],
    low_words: &[u16],
    g_base: usize,
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    k: usize,
) -> [f32; T] {
    assert!(k > 0, "shared-group width must be positive");
    assert_xs_len(xs, cols);
    assert!(hi_words.len() >= cols.div_ceil(4), "hi stream too short");
    if cols > 0 {
        let last_group = g_base + (cols - 1) / k;
        assert!(low_words.len() * 16 > last_group, "shared-bit stream too short");
    }
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        // Each 16-lane block broadcasts 16/k shared bits from one word;
        // that needs k ∈ {2, 4} and a base whose in-word bit offset is a
        // multiple of the per-block stride (guaranteed when the caller's
        // group size satisfies g % 16 == 0).
        let lanes_ok = (k == 2 || k == 4) && g_base % (16 / k) == 0;
        if is_avx512() && cols >= 16 && lanes_ok {
            // SAFETY: feature checked; stream and xs lengths asserted.
            return unsafe {
                dotn_segmented_group_at_avx512(hi_words, low_words, g_base, cols, xs, fmt, k)
            };
        }
    }
    let mut acc = [0f32; T];
    for i in 0..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let g = g_base + i / k;
        let shared = (u32::from(low_words[g / 16]) >> (g % 16)) & 1;
        let v = decode_arith((hi << 1) | shared, e, m, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_segmented_group_at_avx512<const T: usize>(
    hi_words: &[u16],
    low_words: &[u16],
    g_base: usize,
    cols: usize,
    xs: &[&[f32]; T],
    fmt: FpFormat,
    k: usize,
) -> [f32; T] {
    use std::arch::x86_64::*;
    let (e, m) = (fmt.ebits, fmt.mbits);
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(e, m, eb);
    let nib_shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
    let one = _mm512_set1_epi32(1);
    let low_shifts = if k == 2 {
        _mm512_setr_epi32(0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7)
    } else {
        _mm512_setr_epi32(0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3)
    };
    let mut acc = [_mm512_setzero_ps(); T];
    let blocks = cols / 16;
    for b in 0..blocks {
        let hi64 = (hi_words.as_ptr().add(b * 4) as *const u64).read_unaligned();
        let vlo = _mm512_set1_epi32(hi64 as u32 as i32);
        let vhi = _mm512_set1_epi32((hi64 >> 32) as u32 as i32);
        let packed = _mm512_mask_blend_epi32(0xFF00, vlo, vhi);
        let nib = _mm512_and_si512(_mm512_srlv_epi32(packed, nib_shifts), _mm512_set1_epi32(0xF));
        // Absolute shared-group index of the block's first code; the
        // 16/k bits the block needs never straddle a word (base offset
        // is a multiple of 16/k, checked by the caller gate).
        let g0 = g_base + b * 16 / k;
        let lw = u32::from(*low_words.get_unchecked(g0 / 16)) >> (g0 % 16);
        let lowv = _mm512_and_si512(
            _mm512_srlv_epi32(_mm512_set1_epi32(lw as i32), low_shifts),
            one,
        );
        let code = _mm512_or_si512(_mm512_slli_epi32::<1>(nib), lowv);
        let v = dec.decode(code);
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(b * 16)), acc[j]);
        }
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    for i in blocks * 16..cols {
        let hi = (u32::from(hi_words[i / 4]) >> (4 * (i % 4))) & 0xF;
        let g = g_base + i / k;
        let shared = (u32::from(low_words[g / 16]) >> (g % 16)) & 1;
        let v = decode_arith((hi << 1) | shared, e, m, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
    }
    out
}

/// Fused FP5.33 dot against `T` activation rows. `x0s/x1s/x2s` hold the
/// stride-3 de-interleaved streams of each activation row (built once per
/// GEMM call, see [`deinterleave3`]); `xs` are the natural rows used by
/// the scalar path and tail.
pub fn dotn_fp533<const T: usize>(
    words: &[u16],
    cols: usize,
    x0s: &[&[f32]; T],
    x1s: &[&[f32]; T],
    x2s: &[&[f32]; T],
    xs: &[&[f32]; T],
) -> [f32; T] {
    assert_xs_len(xs, cols);
    assert!(words.len() >= cols.div_ceil(3), "group stream too short");
    let fmt = FpFormat::E2M3;
    let eb = expo_base(fmt);
    #[cfg(target_arch = "x86_64")]
    {
        if fp533_uses_deint(cols) {
            let full_groups = cols / 3;
            assert_xs_len(x0s, full_groups);
            assert_xs_len(x1s, full_groups);
            assert_xs_len(x2s, full_groups);
            // SAFETY: feature checked; stream and xs lengths asserted.
            return unsafe { dotn_fp533_avx512(words, cols, x0s, x1s, x2s, xs) };
        }
    }
    let _ = (x0s, x1s, x2s);
    let mut acc = [0f32; T];
    for i in 0..cols {
        let w = u32::from(words[i / 3]);
        let shared = (w >> 15) & 1;
        let code = (((w >> (5 * (i % 3))) & 0x1F) << 1) | shared;
        let v = decode_arith(code, fmt.ebits, fmt.mbits, eb);
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dotn_fp533_avx512<const T: usize>(
    words: &[u16],
    cols: usize,
    x0s: &[&[f32]; T],
    x1s: &[&[f32]; T],
    x2s: &[&[f32]; T],
    xs: &[&[f32]; T],
) -> [f32; T] {
    use std::arch::x86_64::*;
    let fmt = FpFormat::E2M3;
    let eb = expo_base(fmt);
    let dec = DecodeConsts::new(fmt.ebits, fmt.mbits, eb);
    let m5 = _mm512_set1_epi32(0x1F);
    let one = _mm512_set1_epi32(1);
    let full_groups = cols / 3;
    let blocks = full_groups / 16;
    // Two accumulators per tile column: streams 0+2 and stream 1, keeping
    // each FMA chain short while bounding register pressure at T=8.
    let mut acc_a = [_mm512_setzero_ps(); T];
    let mut acc_b = [_mm512_setzero_ps(); T];
    for b in 0..blocks {
        let w16 = _mm256_loadu_si256(words.as_ptr().add(b * 16) as *const _);
        let w = _mm512_cvtepu16_epi32(w16);
        let shared = _mm512_and_si512(_mm512_srli_epi32::<15>(w), one);
        let c0 = _mm512_or_si512(_mm512_slli_epi32::<1>(_mm512_and_si512(w, m5)), shared);
        let c1 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<5>(w), m5)),
            shared,
        );
        let c2 = _mm512_or_si512(
            _mm512_slli_epi32::<1>(_mm512_and_si512(_mm512_srli_epi32::<10>(w), m5)),
            shared,
        );
        let v0 = dec.decode(c0);
        let v1 = dec.decode(c1);
        let v2 = dec.decode(c2);
        for j in 0..T {
            acc_a[j] = _mm512_fmadd_ps(v0, _mm512_loadu_ps(x0s[j].as_ptr().add(b * 16)), acc_a[j]);
            acc_b[j] = _mm512_fmadd_ps(v1, _mm512_loadu_ps(x1s[j].as_ptr().add(b * 16)), acc_b[j]);
            acc_a[j] = _mm512_fmadd_ps(v2, _mm512_loadu_ps(x2s[j].as_ptr().add(b * 16)), acc_a[j]);
        }
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(_mm512_add_ps(acc_a[j], acc_b[j]));
    }
    for i in blocks * 48..cols {
        let w = u32::from(words[i / 3]);
        let shared = (w >> 15) & 1;
        let code = (((w >> (5 * (i % 3))) & 0x1F) << 1) | shared;
        let v = decode_arith(code, fmt.ebits, fmt.mbits, eb);
        for j in 0..T {
            out[j] += v * xs[j][i];
        }
    }
    out
}

/// Dense f32 dot against `T` activation rows (FP16-reference baseline and
/// dense projections). Register-tiled like the packed kernels so speedup
/// comparisons measure the format, not kernel quality.
pub fn dotn_dense<const T: usize>(w: &[f32], xs: &[&[f32]; T]) -> [f32; T] {
    assert_xs_len(xs, w.len());
    #[cfg(target_arch = "x86_64")]
    {
        if is_avx512() && w.len() >= 16 {
            // SAFETY: feature checked; xs lengths asserted.
            return unsafe { dotn_dense_avx512(w, xs) };
        }
    }
    let mut acc = [0f32; T];
    for (i, &v) in w.iter().enumerate() {
        for j in 0..T {
            acc[j] += v * xs[j][i];
        }
    }
    acc
}

/// Dense f32 dot product (vectorized `Σ a[i]·b[i]`); `b` must cover `a`.
pub fn dot_dense(a: &[f32], b: &[f32]) -> f32 {
    dotn_dense::<1>(a, &[b])[0]
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dotn_dense_avx512<const T: usize>(w: &[f32], xs: &[&[f32]; T]) -> [f32; T] {
    use std::arch::x86_64::*;
    let n = w.len();
    let mut acc = [_mm512_setzero_ps(); T];
    let mut i = 0usize;
    while i + 16 <= n {
        let v = _mm512_loadu_ps(w.as_ptr().add(i));
        for j in 0..T {
            acc[j] = _mm512_fmadd_ps(v, _mm512_loadu_ps(xs[j].as_ptr().add(i)), acc[j]);
        }
        i += 16;
    }
    let mut out = [0f32; T];
    for j in 0..T {
        out[j] = _mm512_reduce_add_ps(acc[j]);
    }
    while i < n {
        for j in 0..T {
            out[j] += w[i] * xs[j][i];
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn decode_identity_all_codes() {
        // decode_arith == FpFormat::decode for every code of every format,
        // and never produces a denormal f32.
        for fmt in [
            FpFormat::E2M1,
            FpFormat::E2M2,
            FpFormat::E2M3,
            FpFormat::E3M2,
            FpFormat::E4M3,
        ] {
            let eb = expo_base(fmt);
            for code in 0..fmt.code_count() as u16 {
                let got = decode_arith(u32::from(code), fmt.ebits, fmt.mbits, eb);
                assert_eq!(got, fmt.decode(code), "{} code {code}", fmt.name());
                assert!(got == 0.0 || got.abs() >= f32::MIN_POSITIVE);
            }
        }
    }

    #[test]
    fn dot_matches_reference() {
        let mut rng = Rng::new(1);
        for fmt in [FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2] {
            for n in [1usize, 15, 32, 33, 100, 1000] {
                let codes: Vec<u16> = (0..n)
                    .map(|_| (rng.next_u32() as u16) & fmt.code_mask())
                    .collect();
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let fused = dot_codes(&codes, &x, fmt);
                let reference: f32 = codes
                    .iter()
                    .zip(&x)
                    .map(|(&c, &xv)| fmt.decode(c) * xv)
                    .sum();
                assert!(
                    (fused - reference).abs() <= 2e-4 * (1.0 + reference.abs()),
                    "{} n={n}: {fused} vs {reference}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn decode_codes_buffer() {
        let fmt = FpFormat::E2M3;
        let codes: Vec<u16> = (0..fmt.code_count() as u16).collect();
        let mut out = vec![0f32; codes.len()];
        decode_codes(&codes, &mut out, fmt);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, fmt.decode(i as u16));
        }
    }

    #[test]
    fn fp16_dot_matches_table() {
        let mut rng = Rng::new(2);
        let table = crate::gemm::dequant_table(crate::formats::registry::Scheme::Fp16);
        for n in [1usize, 31, 32, 64, 257] {
            // Finite half values only (exponent < 0x1F).
            let words: Vec<u16> = (0..n)
                .map(|_| {
                    let w = rng.next_u32() as u16;
                    if (w >> 10) & 0x1F == 0x1F {
                        w & !(1 << 14)
                    } else {
                        w
                    }
                })
                .collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let fused = dot_fp16_bits(&words, &x, &table);
            let reference: f32 = words
                .iter()
                .zip(&x)
                .map(|(&w, &xv)| table[w as usize] * xv)
                .sum();
            let mag = reference.abs().max(words.iter().map(|&w| table[w as usize].abs()).fold(0.0, f32::max));
            assert!(
                (fused - reference).abs() <= 1e-2 * (1.0 + mag),
                "n={n}: {fused} vs {reference}"
            );
        }
    }

    #[test]
    fn dotn_codes_matches_per_column() {
        let mut rng = Rng::new(9);
        let fmt = FpFormat::E2M3;
        for n in [1usize, 15, 16, 33, 100] {
            let codes: Vec<u16> = (0..n)
                .map(|_| (rng.next_u32() as u16) & fmt.code_mask())
                .collect();
            let cols: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect();
            let xs: [&[f32]; 4] = [&cols[0], &cols[1], &cols[2], &cols[3]];
            let tiled = dotn_codes(&codes, &xs, fmt);
            for j in 0..4 {
                let single = dot_codes(&codes, xs[j], fmt);
                assert!(
                    (tiled[j] - single).abs() <= 2e-4 * (1.0 + single.abs()),
                    "n={n} j={j}: {} vs {single}",
                    tiled[j]
                );
            }
        }
    }

    /// The shared-bit segment kernel (stream-direct grouped path) must
    /// match a scalar decode of the same codes at every word-aligned
    /// segment of the row, for both AVX-servable k values and for a
    /// scalar-only k.
    #[test]
    fn dotn_segmented_group_at_matches_reference() {
        let mut rng = Rng::new(21);
        let fmt = FpFormat::E2M2;
        let cols = 160usize;
        for k in [2usize, 4, 5] {
            // Synthetic codes with a consistent shared LSB per k-group.
            let mut codes = vec![0u16; cols];
            for g0 in (0..cols).step_by(k) {
                let shared = (rng.next_u32() & 1) as u16;
                for c in codes.iter_mut().skip(g0).take(k) {
                    *c = ((rng.next_u32() as u16 & 0xF) << 1) | shared;
                }
            }
            // Pack: hi-nibble stream + shared-bit stream (1 bit/group).
            let mut hi = vec![0u16; cols.div_ceil(4)];
            let mut lo = vec![0u16; cols.div_ceil(k).div_ceil(16)];
            for (i, &c) in codes.iter().enumerate() {
                hi[i / 4] |= ((c >> 1) & 0xF) << (4 * (i % 4));
            }
            for (g, grp) in codes.chunks(k).enumerate() {
                lo[g / 16] |= (grp[0] & 1) << (g % 16);
            }
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            // Segment sizes that keep c0 % (4, k) == 0.
            let g_seg = if k == 5 { 80 } else { 32 };
            let mut c0 = 0usize;
            while c0 < cols {
                let len = g_seg.min(cols - c0);
                let xs: [&[f32]; 2] = [&x[c0..c0 + len], &x[c0..c0 + len]];
                let d = dotn_segmented_group_at(&hi[c0 / 4..], &lo, c0 / k, len, &xs, fmt, k);
                let want: f32 = codes[c0..c0 + len]
                    .iter()
                    .zip(&x[c0..c0 + len])
                    .map(|(&c, &xv)| fmt.decode(c) * xv)
                    .sum();
                for got in d {
                    assert!(
                        (got - want).abs() <= 2e-4 * (1.0 + want.abs()),
                        "k={k} c0={c0}: {got} vs {want}"
                    );
                }
                c0 += len;
            }
        }
    }

    /// The hi-only draft kernel must equal a scalar decode of the
    /// mantissa-truncated codes (`(c >> w) << w`) — the zero-filled
    /// low-bits view of the same tensor — for both low widths and
    /// ragged/SIMD shapes.
    #[test]
    fn dotn_segmented_hi_matches_truncated_reference() {
        let mut rng = Rng::new(31);
        for (fmt, w) in [(FpFormat::E2M3, 2u32), (FpFormat::E2M2, 1)] {
            for cols in [7usize, 16, 61, 160] {
                let codes: Vec<u16> = (0..cols)
                    .map(|_| (rng.next_u32() as u16) & fmt.code_mask())
                    .collect();
                let mut hi = vec![0u16; cols.div_ceil(4)];
                for (i, &c) in codes.iter().enumerate() {
                    hi[i / 4] |= ((c >> w) & 0xF) << (4 * (i % 4));
                }
                let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let xs: [&[f32]; 2] = [&x, &x];
                let got = dotn_segmented_hi(&hi, cols, &xs, fmt, w);
                let want: f32 = codes
                    .iter()
                    .zip(&x)
                    .map(|(&c, &xv)| fmt.decode((c >> w) << w) * xv)
                    .sum();
                for g in got {
                    assert!(
                        (g - want).abs() <= 2e-4 * (1.0 + want.abs()),
                        "{} w={w} cols={cols}: {g} vs {want}",
                        fmt.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dotn_dense_matches_scalar() {
        let mut rng = Rng::new(10);
        for n in [1usize, 16, 47, 128] {
            let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let cols: Vec<Vec<f32>> = (0..8)
                .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect();
            let xs: [&[f32]; 8] = core::array::from_fn(|j| cols[j].as_slice());
            let tiled = dotn_dense(&w, &xs);
            for j in 0..8 {
                let scalar: f32 = w.iter().zip(xs[j]).map(|(&a, &b)| a * b).sum();
                assert!(
                    (tiled[j] - scalar).abs() <= 1e-4 * (1.0 + scalar.abs()),
                    "n={n} j={j}: {} vs {scalar}",
                    tiled[j]
                );
            }
        }
    }

    #[test]
    fn dotn_fp16_matches_table() {
        let mut rng = Rng::new(11);
        let table = crate::gemm::dequant_table(crate::formats::registry::Scheme::Fp16);
        let n = 64usize;
        let words: Vec<u16> = (0..n)
            .map(|_| {
                let w = rng.next_u32() as u16;
                if (w >> 10) & 0x1F == 0x1F {
                    w & !(1 << 14)
                } else {
                    w
                }
            })
            .collect();
        let cols: Vec<Vec<f32>> = (0..2)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let xs: [&[f32]; 2] = [&cols[0], &cols[1]];
        let tiled = dotn_fp16_bits(&words, &xs, &table);
        for j in 0..2 {
            let reference: f32 = words
                .iter()
                .zip(xs[j])
                .map(|(&w, &xv)| table[w as usize] * xv)
                .sum();
            assert!(
                (tiled[j] - reference).abs() <= 1e-2 * (1.0 + reference.abs()),
                "j={j}: {} vs {reference}",
                tiled[j]
            );
        }
    }
}
