//! Paged KV-cache subsystem: fixed-size pages, a free-list
//! [`PagePool`] with per-page refcounts, per-sequence block tables
//! ([`PagedKvCache`]), and prompt-prefix sharing over committed pages.
//!
//! The storage contract is the [`KvStore`] accessor trait: attention
//! reads and writes K/V strictly through per-`(layer, position)` row
//! slices, each contiguous in memory. The contiguous
//! [`KvCache`](crate::model::transformer::KvCache) implements it by
//! slicing one `[max_seq * kv_dim]` buffer per layer; [`PagedKvCache`]
//! implements it by slicing inside the page that holds the position.
//! Because the row view is identical either way, every `forward*` path
//! produces **bit-identical** logits over both backings (pinned by
//! `rust/tests/paged_parity.rs`) — paging changes where a row lives,
//! never the float sequence that touches it.
//!
//! Sharing model:
//!
//! - A page covers `page_size` consecutive positions across **all**
//!   layers (K and V), so one refcount shares a prompt-prefix chunk
//!   end to end. RoPE is applied to K at cache-write time and depends
//!   only on the absolute position, so a shared page is valid for every
//!   sequence whose prompt starts with the same tokens.
//! - Completed prefills commit their *full* prompt pages into a
//!   token-keyed prefix trie owned by the pool; later prompts that
//!   start with the same page-aligned chunks adopt the physical pages
//!   (refcount bump, no prefill compute) and copy-on-write on the first
//!   divergent write ([`PagedKvCache::reserve`]).
//! - When the pool runs dry, trie entries nobody references are evicted
//!   first ([`PagePool::evict_unreferenced`]); the scheduler escalates
//!   to preempting sequences only after that.

pub mod paged;
pub mod pool;
pub(crate) mod trie;

pub use paged::PagedKvCache;
pub use pool::{PageBuf, PageGeometry, PagePool, PoolExhausted};

use std::sync::atomic::{AtomicU64, Ordering};

/// Tenant namespace key. Every page allocation, quota check and prefix
/// trie is scoped by tenant: requests that never set one share
/// [`DEFAULT_TENANT`], which reproduces the single-tenant behavior
/// bit for bit.
pub type TenantId = u32;

/// The tenant every unlabeled request belongs to.
pub const DEFAULT_TENANT: TenantId = 0;

/// Accessor contract between the attention paths and a KV backing
/// store. Rows are contiguous `[kv_dim]` float slices; `k_row(l, t)`
/// for `t <= len()` must return exactly the bytes written by the
/// earlier `k_row_mut(l, t)`. Row methods are infallible — page
/// allocation happens in [`PagedKvCache::reserve`] (or implicitly on
/// first write), so the forward hot loops never see an allocator.
pub trait KvStore {
    /// Positions currently committed (the next write position).
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Commit positions `< len` (the forwards call this once per step /
    /// chunk, after all rows are written).
    fn set_len(&mut self, len: usize);
    /// Roll the sequence back to `len` positions **and release any
    /// storage the discarded tail held**. `set_len` only moves the
    /// logical frontier (the speculative verify pass rewinds with it and
    /// immediately rewrites the same rows); `truncate` is the rejection
    /// path — a paged backing returns whole tail pages to its pool.
    fn truncate(&mut self, len: usize) {
        self.set_len(len);
    }
    fn k_row(&self, layer: usize, pos: usize) -> &[f32];
    fn v_row(&self, layer: usize, pos: usize) -> &[f32];
    fn k_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32];
    fn v_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32];
}

/// Projection from a batch-slot element to its KV store, so
/// `forward_batch_with` can decode scheduler-owned slot types (which
/// carry a submission next to the cache) and bare caches through one
/// signature. The associated type keeps inference exact: the element
/// type alone determines the store, so `Vec<KvCache>`,
/// `Vec<&mut KvCache>` and `Vec<Active>` all resolve without
/// annotations.
pub trait AsKvStore {
    type Store: KvStore;
    fn kv(&self) -> &Self::Store;
    fn kv_mut(&mut self) -> &mut Self::Store;
}

impl<T: AsKvStore> AsKvStore for &mut T {
    type Store = T::Store;
    fn kv(&self) -> &T::Store {
        (**self).kv()
    }
    fn kv_mut(&mut self) -> &mut T::Store {
        (**self).kv_mut()
    }
}

/// Shared pool gauges, readable across threads (the engine facade reads
/// them live while replica schedulers mutate them). One instance spans
/// every replica's pool, so `pages_used`/`pages_capacity` aggregate the
/// fleet and `leaked` survives replica restarts — the chaos suite
/// asserts it stays 0 through panics and preemption storms.
#[derive(Debug, Default)]
pub struct KvGauges {
    /// Physical pages currently allocated (live `PageBuf`s).
    pub pages_used: AtomicU64,
    /// Sum of pool capacities currently alive.
    pub pages_capacity: AtomicU64,
    /// High-water mark of `pages_used`.
    pub pages_peak: AtomicU64,
    /// Prompt-prefix pages adopted from the trie instead of prefilled.
    pub prefix_hits: AtomicU64,
    /// Sequences preempted (or parked mid-prefill) on pool pressure.
    pub preemptions: AtomicU64,
    /// Drop-audit: pages a pool still considered sequence-held when it
    /// was destroyed. Non-zero means a block table outlived its
    /// scheduler — a leak.
    pub leaked: AtomicU64,
}

impl KvGauges {
    /// Mirror the pool gauges into the metrics registry under the
    /// `kv.*` names, so `Engine::metrics_snapshot` and METRICS.json see
    /// pool pressure alongside the latency histograms.
    pub fn export(&self, registry: &crate::obs::MetricsRegistry) {
        use crate::obs::names;
        let used = self.pages_used.load(Ordering::Relaxed);
        let capacity = self.pages_capacity.load(Ordering::Relaxed);
        registry.set_gauge(names::KV_PAGES_USED, used);
        registry.set_gauge(names::KV_PAGES_CAPACITY, capacity);
        registry.set_gauge(names::KV_PAGES_FREE, capacity.saturating_sub(used));
        registry.set_gauge(names::KV_PAGES_PEAK, self.pages_peak.load(Ordering::Relaxed));
        registry.set_gauge(names::KV_LEAKED, self.leaked.load(Ordering::Relaxed));
    }
}
