//! Per-sequence paged KV cache: a block table of refcounted pages that
//! grows one page at a time from a shared [`PagePool`].

use std::rc::Rc;

use super::pool::{PageBuf, PagePool, PoolExhausted};
use super::{AsKvStore, KvStore, TenantId, DEFAULT_TENANT};

/// KV storage for one sequence, backed by pool pages instead of a
/// worst-case contiguous buffer. Implements [`KvStore`], so every
/// `forward*` path runs over it unchanged — and bit-identically to the
/// contiguous cache, since attention only ever sees per-position row
/// slices.
///
/// Pages adopted from the prefix trie (or duplicated via [`fork`])
/// are shared; [`reserve`] copy-on-write forks a shared page before
/// the first write that lands in it.
///
/// [`fork`]: PagedKvCache::fork
/// [`reserve`]: PagedKvCache::reserve
pub struct PagedKvCache {
    // Declared before `pool` so pages recycle into a live pool on drop.
    pages: Vec<Rc<PageBuf>>,
    len: usize,
    pool: PagePool,
    /// Every page this sequence allocates debits this tenant's budget.
    tenant: TenantId,
}

impl PagedKvCache {
    pub fn new(pool: &PagePool) -> PagedKvCache {
        PagedKvCache::for_tenant(pool, DEFAULT_TENANT)
    }

    /// A cache whose allocations are debited to `tenant` (quota-aware).
    pub fn for_tenant(pool: &PagePool, tenant: TenantId) -> PagedKvCache {
        PagedKvCache {
            pages: Vec::new(),
            len: 0,
            pool: pool.clone(),
            tenant,
        }
    }

    /// Tenant this sequence's allocations are debited to.
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    pub fn page_size(&self) -> usize {
        self.pool.geometry().page_size
    }

    /// Physical pages this sequence holds (shared pages count once).
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Block table view (tests and the trie commit path).
    pub fn table(&self) -> &[Rc<PageBuf>] {
        &self.pages
    }

    /// Adopt already-committed prefix pages (refcount bumps, no
    /// compute); the cache then behaves as if those positions were
    /// prefilled. Only valid on an empty cache.
    pub fn adopt_prefix(&mut self, pages: Vec<Rc<PageBuf>>) {
        assert!(self.pages.is_empty() && self.len == 0, "adopt_prefix on a used cache");
        self.len = pages.len() * self.page_size();
        self.pages = pages;
    }

    /// Share this cache's pages with a second sequence (COW: either
    /// side forks a page when it first writes into it).
    pub fn fork(&self) -> PagedKvCache {
        PagedKvCache {
            pages: self.pages.clone(),
            len: self.len,
            pool: self.pool.clone(),
            tenant: self.tenant,
        }
    }

    /// Drop all pages back to the pool.
    pub fn reset(&mut self) {
        self.pages.clear();
        self.len = 0;
    }

    fn is_unique(&self, page_idx: usize) -> bool {
        Rc::strong_count(&self.pages[page_idx]) == 1
    }

    /// Pages `reserve(positions)` would have to allocate right now:
    /// missing tail pages plus shared pages in the upcoming write range
    /// that need a copy-on-write fork. The scheduler budgets admission
    /// and preemption against this.
    pub fn pages_needed(&self, positions: usize) -> usize {
        let ps = self.page_size();
        let need = positions.div_ceil(ps);
        let grow = need.saturating_sub(self.pages.len());
        let first_write = self.len / ps;
        let cow = (first_write..self.pages.len().min(need))
            .filter(|&pi| !self.is_unique(pi))
            .count();
        grow + cow
    }

    /// Make positions `< positions` writable: allocate missing tail
    /// pages and COW-fork shared pages the write range touches. After
    /// a successful reserve, row writes up to `positions` cannot fail.
    pub fn reserve(&mut self, positions: usize) -> Result<(), PoolExhausted> {
        let ps = self.page_size();
        let need = positions.div_ceil(ps);
        let first_write = self.len / ps;
        for pi in first_write..self.pages.len().min(need) {
            if !self.is_unique(pi) {
                self.cow_page(pi)?;
            }
        }
        while self.pages.len() < need {
            self.pages.push(self.pool.alloc_for(self.tenant)?);
        }
        Ok(())
    }

    /// Replace a shared page with a private copy of its contents.
    fn cow_page(&mut self, page_idx: usize) -> Result<(), PoolExhausted> {
        let mut fresh = self.pool.alloc_for(self.tenant)?;
        Rc::get_mut(&mut fresh)
            .expect("freshly allocated page is unshared")
            .floats_mut()
            .copy_from_slice(self.pages[page_idx].floats());
        self.pages[page_idx] = fresh;
        Ok(())
    }

    fn row(&self, layer: usize, which_v: bool, pos: usize) -> &[f32] {
        let geom = self.pool.geometry();
        let page = &self.pages[pos / geom.page_size];
        let off = geom.row_offset(layer, which_v, pos % geom.page_size);
        &page.floats()[off..off + geom.kv_dim]
    }

    fn row_mut(&mut self, layer: usize, which_v: bool, pos: usize) -> &mut [f32] {
        let geom = self.pool.geometry();
        let pi = pos / geom.page_size;
        // Implicit grow/COW keeps direct forward calls (tests, benches)
        // working without scheduler involvement; the scheduler reserves
        // ahead of time so this is a no-op on the serve path.
        if pi >= self.pages.len() {
            self.reserve(pos + 1).expect("kv page pool exhausted (reserve before writing)");
        }
        if !self.is_unique(pi) {
            self.cow_page(pi).expect("kv page pool exhausted (reserve before writing)");
        }
        let off = geom.row_offset(layer, which_v, pos % geom.page_size);
        let floats = Rc::get_mut(&mut self.pages[pi])
            .expect("page unshared after reserve")
            .floats_mut();
        &mut floats[off..off + geom.kv_dim]
    }
}

impl KvStore for PagedKvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Rejection rollback: drop the block-table entries wholly past the
    /// new frontier. Each dropped `Rc` that was this sequence's last
    /// reference recycles its page into the pool — page-at-a-time, no
    /// float copying. A page straddling `len` stays (its prefix rows are
    /// still live).
    fn truncate(&mut self, len: usize) {
        self.len = len;
        let keep = len.div_ceil(self.page_size());
        if keep < self.pages.len() {
            self.pages.truncate(keep);
        }
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.row(layer, false, pos)
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.row(layer, true, pos)
    }

    fn k_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        self.row_mut(layer, false, pos)
    }

    fn v_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        self.row_mut(layer, true, pos)
    }
}

impl AsKvStore for PagedKvCache {
    type Store = PagedKvCache;
    fn kv(&self) -> &PagedKvCache {
        self
    }
    fn kv_mut(&mut self) -> &mut PagedKvCache {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::pool::PageGeometry;
    use crate::kv::KvGauges;
    use std::sync::Arc;

    fn pool(capacity: usize) -> PagePool {
        let geom = PageGeometry {
            n_layers: 2,
            kv_dim: 4,
            page_size: 4,
        };
        PagePool::new(geom, capacity, Arc::new(KvGauges::default()))
    }

    fn write_pos(cache: &mut PagedKvCache, pos: usize, val: f32) {
        for layer in 0..2 {
            cache.k_row_mut(layer, pos).fill(val);
            cache.v_row_mut(layer, pos).fill(-val);
        }
        cache.set_len(pos + 1);
    }

    #[test]
    fn grows_one_page_at_a_time_and_reads_back() {
        let pool = pool(4);
        let mut cache = PagedKvCache::new(&pool);
        assert_eq!(cache.pages_held(), 0);
        for pos in 0..10 {
            write_pos(&mut cache, pos, pos as f32 + 1.0);
            assert_eq!(cache.pages_held(), pos / 4 + 1);
        }
        for pos in 0..10 {
            let want = pos as f32 + 1.0;
            assert!(cache.k_row(1, pos).iter().all(|&x| x == want));
            assert!(cache.v_row(0, pos).iter().all(|&x| x == -want));
        }
        assert_eq!(pool.used(), 3);
        cache.reset();
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn fork_shares_pages_and_cow_splits_on_divergent_write() {
        let pool = pool(4);
        let mut a = PagedKvCache::new(&pool);
        for pos in 0..4 {
            write_pos(&mut a, pos, 1.0);
        }
        let mut b = a.fork();
        // Physically identical: same page, one allocation.
        assert!(Rc::ptr_eq(&a.table()[0], &b.table()[0]));
        assert_eq!(pool.used(), 1);
        // First divergent write forks the shared page...
        write_pos(&mut b, 3, 9.0);
        assert!(!Rc::ptr_eq(&a.table()[0], &b.table()[0]));
        assert_eq!(pool.used(), 2);
        // ...copying the untouched positions and leaving `a` intact.
        assert!(b.k_row(0, 0).iter().all(|&x| x == 1.0));
        assert!(b.k_row(0, 3).iter().all(|&x| x == 9.0));
        assert!(a.k_row(0, 3).iter().all(|&x| x == 1.0));
    }

    #[test]
    fn cow_does_not_fork_pages_behind_the_write_frontier() {
        let pool = pool(4);
        let mut a = PagedKvCache::new(&pool);
        for pos in 0..6 {
            write_pos(&mut a, pos, 1.0);
        }
        let mut b = a.fork();
        // b's next write lands on page 1; page 0 stays shared.
        assert_eq!(b.pages_needed(7), 1);
        write_pos(&mut b, 6, 2.0);
        assert!(Rc::ptr_eq(&a.table()[0], &b.table()[0]));
        assert!(!Rc::ptr_eq(&a.table()[1], &b.table()[1]));
        assert_eq!(pool.used(), 3);
    }

    #[test]
    fn reserve_reports_exhaustion_without_partial_leak_confusion() {
        let pool = pool(2);
        let mut cache = PagedKvCache::new(&pool);
        assert!(cache.reserve(8).is_ok());
        let mut other = PagedKvCache::new(&pool);
        assert_eq!(other.reserve(4), Err(PoolExhausted));
        // Freeing makes the same reserve succeed.
        cache.reset();
        assert!(other.reserve(4).is_ok());
    }

    /// Speculative rollback: `truncate` frees whole tail pages back to
    /// the pool, keeps a straddling page alive, and leaves the surviving
    /// prefix readable; plain `set_len` frees nothing.
    #[test]
    fn truncate_returns_tail_pages_to_pool() {
        let pool = pool(4);
        let mut cache = PagedKvCache::new(&pool);
        for pos in 0..10 {
            write_pos(&mut cache, pos, pos as f32 + 1.0);
        }
        assert_eq!(pool.used(), 3);
        // Rewind without rollback: pages stay for the rewrite.
        cache.set_len(8);
        assert_eq!(pool.used(), 3);
        cache.set_len(10);
        // Reject back into the middle of page 1: page 2 frees, page 1
        // stays (positions 4..6 still live).
        cache.truncate(6);
        assert_eq!(cache.len(), 6);
        assert_eq!(cache.pages_held(), 2);
        assert_eq!(pool.used(), 2);
        for pos in 0..6 {
            let want = pos as f32 + 1.0;
            assert!(cache.k_row(0, pos).iter().all(|&x| x == want));
        }
        // Growing again reuses the recycled page.
        write_pos(&mut cache, 6, 99.0);
        assert_eq!(pool.used(), 2);
        cache.truncate(0);
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn adopted_prefix_counts_as_committed_positions() {
        let pool = pool(4);
        let mut a = PagedKvCache::new(&pool);
        for pos in 0..8 {
            write_pos(&mut a, pos, 3.0);
        }
        pool.commit_prefix(&[1, 2, 3, 4, 5, 6, 7, 8], &a.table()[..2]);
        let shared = pool.shared_prefix(&[1, 2, 3, 4, 5, 6, 7, 8, 9], 2);
        assert_eq!(shared.len(), 2);
        let mut b = PagedKvCache::new(&pool);
        b.adopt_prefix(shared);
        assert_eq!(b.len(), 8);
        assert!(Rc::ptr_eq(&a.table()[1], &b.table()[1]));
        assert!(b.k_row(0, 5).iter().all(|&x| x == 3.0));
    }
}
