//! Fixed-size KV page pool: free-list allocator, drop-recycling pages,
//! per-tenant accounting with optional quotas, and tenant-scoped
//! prompt-prefix tries that share committed pages across sequences —
//! never across tenants.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::fmt;
use std::mem;
use std::rc::{Rc, Weak};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use super::trie::PrefixTrie;
use super::{KvGauges, TenantId, DEFAULT_TENANT};
use crate::model::ModelConfig;

/// Shape of every page in a pool: one page holds K and V rows for
/// `page_size` consecutive positions across all layers, so a single
/// refcount covers a position range end to end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageGeometry {
    pub n_layers: usize,
    pub kv_dim: usize,
    /// Positions per page.
    pub page_size: usize,
}

impl PageGeometry {
    pub fn of(cfg: &ModelConfig, page_size: usize) -> PageGeometry {
        assert!(page_size > 0, "kv page size must be positive");
        PageGeometry {
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            page_size,
        }
    }

    pub fn floats_per_page(&self) -> usize {
        // Layout: [layer][k|v][slot][kv_dim].
        self.n_layers * 2 * self.page_size * self.kv_dim
    }

    pub(crate) fn row_offset(&self, layer: usize, which_v: bool, slot: usize) -> usize {
        debug_assert!(layer < self.n_layers && slot < self.page_size);
        ((layer * 2 + usize::from(which_v)) * self.page_size + slot) * self.kv_dim
    }
}

/// The pool has no free pages left (and the caller could not free any).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted;

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "kv page pool exhausted")
    }
}

impl std::error::Error for PoolExhausted {}

/// One physical KV page. Shared via `Rc`: `Rc::strong_count == 1`
/// means the owning block table may write into it; a shared page must
/// be copy-on-write forked first ([`super::PagedKvCache::reserve`]).
/// Dropping the last `Rc` recycles the buffer into its pool's free
/// list — pages can never leak back to the allocator individually,
/// which is what makes the pool drop-audit exact.
pub struct PageBuf {
    data: Vec<f32>,
    pool: Weak<PoolInner>,
    /// Tenant whose budget this page debits; Drop credits it back.
    tenant: TenantId,
}

impl PageBuf {
    pub fn floats(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view; reachable only through `Rc::get_mut`, i.e. when
    /// the page is unshared.
    pub(crate) fn floats_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl fmt::Debug for PageBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PageBuf").field("floats", &self.data.len()).finish()
    }
}

impl Drop for PageBuf {
    fn drop(&mut self) {
        // During PoolInner's own teardown the upgrade fails and the
        // buffer just frees; the pool's Drop already accounted for it.
        if let Some(pool) = self.pool.upgrade() {
            pool.free.borrow_mut().push(mem::take(&mut self.data));
            pool.used.set(pool.used.get() - 1);
            if let Some(n) = pool.used_by.borrow_mut().get_mut(&self.tenant) {
                *n -= 1;
            }
            pool.gauges.pages_used.fetch_sub(1, Relaxed);
        }
    }
}

pub(crate) struct PoolInner {
    geom: PageGeometry,
    capacity: usize,
    /// Per-tenant page ceiling; 0 = unlimited (no quota enforcement).
    quota: Cell<usize>,
    /// Recycled page buffers, ready for reuse without reallocation.
    free: RefCell<Vec<Vec<f32>>>,
    /// Live pages (everything allocated and not yet recycled).
    used: Cell<usize>,
    /// Live pages broken down by the tenant that allocated them
    /// (trie-cached prefix pages keep debiting their owner — a tenant's
    /// cached prefixes spend that tenant's quota, nobody else's).
    used_by: RefCell<HashMap<TenantId, usize>>,
    gauges: Arc<KvGauges>,
    /// One prefix trie per tenant: lookups can only ever see pages the
    /// same tenant committed, so identical prompts from different
    /// tenants never share pages or leak timing through `prefix_hits`.
    tries: RefCell<HashMap<TenantId, PrefixTrie>>,
}

impl PoolInner {
    fn cached_pages(&self) -> usize {
        self.tries.borrow().values().map(|t| t.pages()).sum()
    }
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        // Drop-audit: at pool teardown the only legitimate page holders
        // left are the prefix tries (sequences must be settled first).
        // Anything else still counted in `used` is a leaked block
        // table; the chaos suite asserts this stays zero through
        // panics and preemption storms.
        let held = self.used.get() as u64;
        let cached = self.cached_pages() as u64;
        self.gauges.leaked.fetch_add(held.saturating_sub(cached), Relaxed);
        self.gauges.pages_used.fetch_sub(held, Relaxed);
        self.gauges.pages_capacity.fetch_sub(self.capacity as u64, Relaxed);
    }
}

/// Fixed-capacity page allocator shared by every sequence on one
/// scheduler. Cloning is cheap (an `Rc` bump); all clones draw from the
/// same free list, trie, and capacity.
#[derive(Clone)]
pub struct PagePool {
    inner: Rc<PoolInner>,
}

impl PagePool {
    pub fn new(geom: PageGeometry, capacity: usize, gauges: Arc<KvGauges>) -> PagePool {
        assert!(capacity > 0, "kv pool needs at least one page");
        gauges.pages_capacity.fetch_add(capacity as u64, Relaxed);
        PagePool {
            inner: Rc::new(PoolInner {
                geom,
                capacity,
                quota: Cell::new(0),
                free: RefCell::new(Vec::new()),
                used: Cell::new(0),
                used_by: RefCell::new(HashMap::new()),
                gauges,
                tries: RefCell::new(HashMap::new()),
            }),
        }
    }

    /// Set the per-tenant page ceiling (0 disables quota enforcement).
    /// A quota larger than the pool is legal — capacity still binds.
    pub fn set_tenant_quota(&self, pages: usize) {
        self.inner.quota.set(pages);
    }

    /// Per-tenant page ceiling; 0 = unlimited.
    pub fn tenant_quota(&self) -> usize {
        self.inner.quota.get()
    }

    pub fn geometry(&self) -> PageGeometry {
        self.inner.geom
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Pages currently allocated (sequence-held plus trie-only).
    pub fn used(&self) -> usize {
        self.inner.used.get()
    }

    /// Pages that `alloc` can still hand out without freeing anything
    /// (capacity headroom; quota may bind a specific tenant sooner).
    pub fn available(&self) -> usize {
        self.inner.capacity - self.inner.used.get()
    }

    /// Live pages debited to `tenant` (sequence-held plus that tenant's
    /// trie-cached prefixes).
    pub fn used_by(&self, tenant: TenantId) -> usize {
        self.inner.used_by.borrow().get(&tenant).copied().unwrap_or(0)
    }

    /// Pages `tenant` can still allocate before hitting its quota *or*
    /// pool capacity, whichever binds first.
    pub fn tenant_available(&self, tenant: TenantId) -> usize {
        let cap = self.available();
        let quota = self.inner.quota.get();
        if quota == 0 {
            cap
        } else {
            cap.min(quota.saturating_sub(self.used_by(tenant)))
        }
    }

    /// Tenants currently holding at least one live page, with counts
    /// (fair-share preemption scores tenants by this).
    pub fn tenant_usage(&self) -> Vec<(TenantId, usize)> {
        let mut v: Vec<(TenantId, usize)> = self
            .inner
            .used_by
            .borrow()
            .iter()
            .filter(|(_, &n)| n > 0)
            .map(|(&t, &n)| (t, n))
            .collect();
        v.sort_unstable();
        v
    }

    pub fn gauges(&self) -> &Arc<KvGauges> {
        &self.inner.gauges
    }

    /// Allocate one zeroed page for the default tenant.
    pub fn alloc(&self) -> Result<Rc<PageBuf>, PoolExhausted> {
        self.alloc_for(DEFAULT_TENANT)
    }

    /// Allocate one zeroed page debited to `tenant`, recycling a
    /// retired buffer when one is on the free list. Fails when the pool
    /// is out of pages *or* the tenant is at its quota.
    pub fn alloc_for(&self, tenant: TenantId) -> Result<Rc<PageBuf>, PoolExhausted> {
        let inner = &self.inner;
        if inner.used.get() >= inner.capacity {
            return Err(PoolExhausted);
        }
        let quota = inner.quota.get();
        if quota > 0 && self.used_by(tenant) >= quota {
            return Err(PoolExhausted);
        }
        let data = match inner.free.borrow_mut().pop() {
            Some(mut buf) => {
                // Zero recycled buffers so a fresh page is
                // indistinguishable from a newly allocated one.
                buf.fill(0.0);
                buf
            }
            None => vec![0.0; inner.geom.floats_per_page()],
        };
        inner.used.set(inner.used.get() + 1);
        *inner.used_by.borrow_mut().entry(tenant).or_insert(0) += 1;
        let used_now = inner.gauges.pages_used.fetch_add(1, Relaxed) + 1;
        inner.gauges.pages_peak.fetch_max(used_now, Relaxed);
        Ok(Rc::new(PageBuf {
            data,
            pool: Rc::downgrade(inner),
            tenant,
        }))
    }

    /// Longest page-aligned prefix of `tokens` already committed by the
    /// default tenant (see [`PagePool::shared_prefix_for`]).
    pub fn shared_prefix(&self, tokens: &[u32], max_pages: usize) -> Vec<Rc<PageBuf>> {
        self.shared_prefix_for(DEFAULT_TENANT, tokens, max_pages)
    }

    /// Longest page-aligned prefix of `tokens` already committed to
    /// `tenant`'s trie, capped at `max_pages`. Returned pages are
    /// refcount bumps of the physical pages — adopting them skips their
    /// prefill. Only `tenant`'s own trie is consulted: another tenant's
    /// identical prompt can never be adopted (or even probed for).
    pub fn shared_prefix_for(
        &self,
        tenant: TenantId,
        tokens: &[u32],
        max_pages: usize,
    ) -> Vec<Rc<PageBuf>> {
        self.inner
            .tries
            .borrow()
            .get(&tenant)
            .map(|t| t.lookup(tokens, max_pages))
            .unwrap_or_default()
    }

    /// Commit a finished prefill's prompt pages for the default tenant.
    pub fn commit_prefix(&self, tokens: &[u32], pages: &[Rc<PageBuf>]) {
        self.commit_prefix_for(DEFAULT_TENANT, tokens, pages);
    }

    /// Commit the full prompt pages of a finished prefill into
    /// `tenant`'s trie so that tenant's later prompts with the same
    /// page-aligned prefix can adopt them. `tokens` must be
    /// page-aligned and `pages` must cover it.
    pub fn commit_prefix_for(&self, tenant: TenantId, tokens: &[u32], pages: &[Rc<PageBuf>]) {
        self.inner
            .tries
            .borrow_mut()
            .entry(tenant)
            .or_insert_with(|| PrefixTrie::new(self.inner.geom.page_size))
            .insert(tokens, pages);
    }

    /// Evict trie entries no live sequence references — across every
    /// tenant's trie — returning the number of pages released. The
    /// scheduler calls this before escalating to preemption.
    pub fn evict_unreferenced(&self) -> usize {
        self.inner
            .tries
            .borrow_mut()
            .values_mut()
            .map(|t| t.evict_unreferenced())
            .sum()
    }

    /// Pages currently held only by the prefix tries (diagnostics).
    pub fn cached_prefix_pages(&self) -> usize {
        self.inner.cached_pages()
    }
}

impl fmt::Debug for PagePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PagePool")
            .field("geom", &self.inner.geom)
            .field("capacity", &self.inner.capacity)
            .field("used", &self.inner.used.get())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    fn geom() -> PageGeometry {
        PageGeometry {
            n_layers: 2,
            kv_dim: 4,
            page_size: 8,
        }
    }

    #[test]
    fn alloc_free_recycles_and_tracks_gauges() {
        let gauges = Arc::new(KvGauges::default());
        let pool = PagePool::new(geom(), 2, Arc::clone(&gauges));
        assert_eq!(pool.available(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.available(), 0);
        assert!(pool.alloc().is_err());
        assert_eq!(gauges.pages_used.load(Relaxed), 2);
        assert_eq!(gauges.pages_peak.load(Relaxed), 2);
        drop(a);
        assert_eq!(pool.available(), 1);
        assert_eq!(gauges.pages_used.load(Relaxed), 1);
        // A recycled page comes back zeroed.
        let c = pool.alloc().unwrap();
        assert!(c.floats().iter().all(|&x| x == 0.0));
        drop((b, c));
        drop(pool);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
        assert_eq!(gauges.pages_capacity.load(Relaxed), 0);
        assert_eq!(gauges.leaked.load(Relaxed), 0);
    }

    #[test]
    fn refcount_shares_one_physical_page() {
        let pool = PagePool::new(geom(), 1, Arc::new(KvGauges::default()));
        let a = pool.alloc().unwrap();
        let b = Rc::clone(&a);
        // Shared: still one physical page, pool stays exhausted until
        // BOTH handles drop.
        assert_eq!(pool.used(), 1);
        assert!(pool.alloc().is_err());
        drop(a);
        assert!(pool.alloc().is_err());
        drop(b);
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn drop_audit_counts_pages_outliving_the_pool() {
        let gauges = Arc::new(KvGauges::default());
        let pool = PagePool::new(geom(), 2, Arc::clone(&gauges));
        let page = pool.alloc().unwrap();
        drop(pool);
        assert_eq!(gauges.leaked.load(Relaxed), 1);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
        // The straggler frees without touching the dead pool.
        drop(page);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
    }

    /// Tenant quotas bind per tenant, before pool capacity; freeing a
    /// tenant's page restores that tenant's (and only that tenant's)
    /// headroom, and accounting stays exact through recycling.
    #[test]
    fn tenant_quota_binds_before_capacity() {
        let gauges = Arc::new(KvGauges::default());
        let pool = PagePool::new(geom(), 4, Arc::clone(&gauges));
        pool.set_tenant_quota(2);
        let a = pool.alloc_for(1).unwrap();
        let b = pool.alloc_for(1).unwrap();
        assert_eq!(pool.used_by(1), 2);
        assert_eq!(pool.tenant_available(1), 0, "tenant 1 is at quota");
        assert!(pool.alloc_for(1).is_err(), "quota refuses tenant 1");
        // Pool capacity still has headroom for other tenants.
        assert_eq!(pool.available(), 2);
        assert_eq!(pool.tenant_available(2), 2);
        let c = pool.alloc_for(2).unwrap();
        assert_eq!(pool.used_by(2), 1);
        assert_eq!(pool.tenant_usage(), vec![(1, 2), (2, 1)]);
        // Dropping a tenant-1 page restores tenant 1's quota headroom.
        drop(a);
        assert_eq!(pool.used_by(1), 1);
        assert_eq!(pool.tenant_available(1), 1);
        let d = pool.alloc_for(1).unwrap();
        drop((b, c, d));
        drop(pool);
        assert_eq!(gauges.leaked.load(Relaxed), 0);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
    }

    /// Tenant-scoped tries: one tenant's committed prefix is invisible
    /// to every other tenant — no page sharing, no probe channel.
    #[test]
    fn prefix_tries_are_tenant_scoped() {
        let pool = PagePool::new(geom(), 4, Arc::new(KvGauges::default()));
        let prompt: Vec<u32> = (0..16).collect();
        let pages: Vec<_> = (0..2).map(|_| pool.alloc_for(1).unwrap()).collect();
        pool.commit_prefix_for(1, &prompt, &pages);
        assert_eq!(pool.shared_prefix_for(1, &prompt, 2).len(), 2);
        assert!(
            pool.shared_prefix_for(2, &prompt, 2).is_empty(),
            "tenant 2 must not see tenant 1's cached prefix"
        );
        assert_eq!(pool.cached_prefix_pages(), 2);
        drop(pages);
        assert_eq!(pool.evict_unreferenced(), 2);
        assert_eq!(pool.cached_prefix_pages(), 0);
    }

    #[test]
    fn row_offsets_tile_the_page_exactly() {
        let g = geom();
        let mut seen = vec![false; g.floats_per_page() / g.kv_dim];
        for layer in 0..g.n_layers {
            for which_v in [false, true] {
                for slot in 0..g.page_size {
                    let off = g.row_offset(layer, which_v, slot);
                    assert_eq!(off % g.kv_dim, 0);
                    let row = off / g.kv_dim;
                    assert!(!seen[row], "row aliased");
                    seen[row] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
