//! Fixed-size KV page pool: free-list allocator, drop-recycling pages,
//! and the prompt-prefix trie that shares committed pages across
//! sequences.

use std::cell::{Cell, RefCell};
use std::fmt;
use std::mem;
use std::rc::{Rc, Weak};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use super::trie::PrefixTrie;
use super::KvGauges;
use crate::model::ModelConfig;

/// Shape of every page in a pool: one page holds K and V rows for
/// `page_size` consecutive positions across all layers, so a single
/// refcount covers a position range end to end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageGeometry {
    pub n_layers: usize,
    pub kv_dim: usize,
    /// Positions per page.
    pub page_size: usize,
}

impl PageGeometry {
    pub fn of(cfg: &ModelConfig, page_size: usize) -> PageGeometry {
        assert!(page_size > 0, "kv page size must be positive");
        PageGeometry {
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            page_size,
        }
    }

    pub fn floats_per_page(&self) -> usize {
        // Layout: [layer][k|v][slot][kv_dim].
        self.n_layers * 2 * self.page_size * self.kv_dim
    }

    pub(crate) fn row_offset(&self, layer: usize, which_v: bool, slot: usize) -> usize {
        debug_assert!(layer < self.n_layers && slot < self.page_size);
        ((layer * 2 + usize::from(which_v)) * self.page_size + slot) * self.kv_dim
    }
}

/// The pool has no free pages left (and the caller could not free any).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted;

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "kv page pool exhausted")
    }
}

impl std::error::Error for PoolExhausted {}

/// One physical KV page. Shared via `Rc`: `Rc::strong_count == 1`
/// means the owning block table may write into it; a shared page must
/// be copy-on-write forked first ([`super::PagedKvCache::reserve`]).
/// Dropping the last `Rc` recycles the buffer into its pool's free
/// list — pages can never leak back to the allocator individually,
/// which is what makes the pool drop-audit exact.
pub struct PageBuf {
    data: Vec<f32>,
    pool: Weak<PoolInner>,
}

impl PageBuf {
    pub fn floats(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view; reachable only through `Rc::get_mut`, i.e. when
    /// the page is unshared.
    pub(crate) fn floats_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl fmt::Debug for PageBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PageBuf").field("floats", &self.data.len()).finish()
    }
}

impl Drop for PageBuf {
    fn drop(&mut self) {
        // During PoolInner's own teardown the upgrade fails and the
        // buffer just frees; the pool's Drop already accounted for it.
        if let Some(pool) = self.pool.upgrade() {
            pool.free.borrow_mut().push(mem::take(&mut self.data));
            pool.used.set(pool.used.get() - 1);
            pool.gauges.pages_used.fetch_sub(1, Relaxed);
        }
    }
}

pub(crate) struct PoolInner {
    geom: PageGeometry,
    capacity: usize,
    /// Recycled page buffers, ready for reuse without reallocation.
    free: RefCell<Vec<Vec<f32>>>,
    /// Live pages (everything allocated and not yet recycled).
    used: Cell<usize>,
    gauges: Arc<KvGauges>,
    trie: RefCell<PrefixTrie>,
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        // Drop-audit: at pool teardown the only legitimate page holder
        // left is the prefix trie (sequences must be settled first).
        // Anything else still counted in `used` is a leaked block
        // table; the chaos suite asserts this stays zero through
        // panics and preemption storms.
        let held = self.used.get() as u64;
        let cached = self.trie.borrow().pages() as u64;
        self.gauges.leaked.fetch_add(held.saturating_sub(cached), Relaxed);
        self.gauges.pages_used.fetch_sub(held, Relaxed);
        self.gauges.pages_capacity.fetch_sub(self.capacity as u64, Relaxed);
    }
}

/// Fixed-capacity page allocator shared by every sequence on one
/// scheduler. Cloning is cheap (an `Rc` bump); all clones draw from the
/// same free list, trie, and capacity.
#[derive(Clone)]
pub struct PagePool {
    inner: Rc<PoolInner>,
}

impl PagePool {
    pub fn new(geom: PageGeometry, capacity: usize, gauges: Arc<KvGauges>) -> PagePool {
        assert!(capacity > 0, "kv pool needs at least one page");
        gauges.pages_capacity.fetch_add(capacity as u64, Relaxed);
        PagePool {
            inner: Rc::new(PoolInner {
                geom,
                capacity,
                free: RefCell::new(Vec::new()),
                used: Cell::new(0),
                gauges,
                trie: RefCell::new(PrefixTrie::new(geom.page_size)),
            }),
        }
    }

    pub fn geometry(&self) -> PageGeometry {
        self.inner.geom
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Pages currently allocated (sequence-held plus trie-only).
    pub fn used(&self) -> usize {
        self.inner.used.get()
    }

    /// Pages that `alloc` can still hand out without freeing anything.
    pub fn available(&self) -> usize {
        self.inner.capacity - self.inner.used.get()
    }

    pub fn gauges(&self) -> &Arc<KvGauges> {
        &self.inner.gauges
    }

    /// Allocate one zeroed page, recycling a retired buffer when one is
    /// on the free list.
    pub fn alloc(&self) -> Result<Rc<PageBuf>, PoolExhausted> {
        let inner = &self.inner;
        if inner.used.get() >= inner.capacity {
            return Err(PoolExhausted);
        }
        let data = match inner.free.borrow_mut().pop() {
            Some(mut buf) => {
                // Zero recycled buffers so a fresh page is
                // indistinguishable from a newly allocated one.
                buf.fill(0.0);
                buf
            }
            None => vec![0.0; inner.geom.floats_per_page()],
        };
        inner.used.set(inner.used.get() + 1);
        let used_now = inner.gauges.pages_used.fetch_add(1, Relaxed) + 1;
        inner.gauges.pages_peak.fetch_max(used_now, Relaxed);
        Ok(Rc::new(PageBuf {
            data,
            pool: Rc::downgrade(inner),
        }))
    }

    /// Longest page-aligned prefix of `tokens` already committed to the
    /// trie, capped at `max_pages`. Returned pages are refcount bumps
    /// of the physical pages — adopting them skips their prefill.
    pub fn shared_prefix(&self, tokens: &[u32], max_pages: usize) -> Vec<Rc<PageBuf>> {
        self.inner.trie.borrow().lookup(tokens, max_pages)
    }

    /// Commit the full prompt pages of a finished prefill so later
    /// prompts with the same page-aligned prefix can adopt them.
    /// `tokens` must be page-aligned and `pages` must cover it.
    pub fn commit_prefix(&self, tokens: &[u32], pages: &[Rc<PageBuf>]) {
        self.inner.trie.borrow_mut().insert(tokens, pages);
    }

    /// Evict trie entries no live sequence references, returning the
    /// number of pages released. The scheduler calls this before
    /// escalating to preemption.
    pub fn evict_unreferenced(&self) -> usize {
        self.inner.trie.borrow_mut().evict_unreferenced()
    }

    /// Pages currently held only by the prefix trie (diagnostics).
    pub fn cached_prefix_pages(&self) -> usize {
        self.inner.trie.borrow().pages()
    }
}

impl fmt::Debug for PagePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PagePool")
            .field("geom", &self.inner.geom)
            .field("capacity", &self.inner.capacity)
            .field("used", &self.inner.used.get())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    fn geom() -> PageGeometry {
        PageGeometry {
            n_layers: 2,
            kv_dim: 4,
            page_size: 8,
        }
    }

    #[test]
    fn alloc_free_recycles_and_tracks_gauges() {
        let gauges = Arc::new(KvGauges::default());
        let pool = PagePool::new(geom(), 2, Arc::clone(&gauges));
        assert_eq!(pool.available(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.available(), 0);
        assert!(pool.alloc().is_err());
        assert_eq!(gauges.pages_used.load(Relaxed), 2);
        assert_eq!(gauges.pages_peak.load(Relaxed), 2);
        drop(a);
        assert_eq!(pool.available(), 1);
        assert_eq!(gauges.pages_used.load(Relaxed), 1);
        // A recycled page comes back zeroed.
        let c = pool.alloc().unwrap();
        assert!(c.floats().iter().all(|&x| x == 0.0));
        drop((b, c));
        drop(pool);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
        assert_eq!(gauges.pages_capacity.load(Relaxed), 0);
        assert_eq!(gauges.leaked.load(Relaxed), 0);
    }

    #[test]
    fn refcount_shares_one_physical_page() {
        let pool = PagePool::new(geom(), 1, Arc::new(KvGauges::default()));
        let a = pool.alloc().unwrap();
        let b = Rc::clone(&a);
        // Shared: still one physical page, pool stays exhausted until
        // BOTH handles drop.
        assert_eq!(pool.used(), 1);
        assert!(pool.alloc().is_err());
        drop(a);
        assert!(pool.alloc().is_err());
        drop(b);
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn drop_audit_counts_pages_outliving_the_pool() {
        let gauges = Arc::new(KvGauges::default());
        let pool = PagePool::new(geom(), 2, Arc::clone(&gauges));
        let page = pool.alloc().unwrap();
        drop(pool);
        assert_eq!(gauges.leaked.load(Relaxed), 1);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
        // The straggler frees without touching the dead pool.
        drop(page);
        assert_eq!(gauges.pages_used.load(Relaxed), 0);
    }

    #[test]
    fn row_offsets_tile_the_page_exactly() {
        let g = geom();
        let mut seen = vec![false; g.floats_per_page() / g.kv_dim];
        for layer in 0..g.n_layers {
            for which_v in [false, true] {
                for slot in 0..g.page_size {
                    let off = g.row_offset(layer, which_v, slot);
                    assert_eq!(off % g.kv_dim, 0);
                    let row = off / g.kv_dim;
                    assert!(!seen[row], "row aliased");
                    seen[row] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
