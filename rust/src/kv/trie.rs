//! Token-keyed prefix trie over committed KV pages.
//!
//! Each edge is an exact `page_size`-token chunk mapping to the
//! physical page that holds that chunk's K/V rows. Lookup walks the
//! prompt chunk by chunk and hands back `Rc` clones of every matched
//! page; insert commits a finished prefill's full prompt pages,
//! deduplicating against chunks already present (the existing page is
//! kept — same tokens at the same absolute positions produce the same
//! rows, so either copy is valid and keeping the old one preserves
//! sharing with its current holders).

use std::collections::HashMap;
use std::rc::Rc;

use super::pool::PageBuf;

#[derive(Default)]
struct Node {
    children: HashMap<Box<[u32]>, Edge>,
}

struct Edge {
    page: Rc<PageBuf>,
    node: Node,
}

pub(crate) struct PrefixTrie {
    page_size: usize,
    root: Node,
    pages: usize,
}

impl PrefixTrie {
    pub(crate) fn new(page_size: usize) -> PrefixTrie {
        PrefixTrie {
            page_size,
            root: Node::default(),
            pages: 0,
        }
    }

    /// Pages currently held by the trie.
    pub(crate) fn pages(&self) -> usize {
        self.pages
    }

    pub(crate) fn lookup(&self, tokens: &[u32], max_pages: usize) -> Vec<Rc<PageBuf>> {
        let mut node = &self.root;
        let mut out = Vec::new();
        for chunk in tokens.chunks_exact(self.page_size).take(max_pages) {
            match node.children.get(chunk) {
                Some(edge) => {
                    out.push(Rc::clone(&edge.page));
                    node = &edge.node;
                }
                None => break,
            }
        }
        out
    }

    pub(crate) fn insert(&mut self, tokens: &[u32], pages: &[Rc<PageBuf>]) {
        let mut added = 0;
        let mut node = &mut self.root;
        for (chunk, page) in tokens.chunks_exact(self.page_size).zip(pages) {
            let edge = node
                .children
                .entry(chunk.into())
                .or_insert_with(|| {
                    added += 1;
                    Edge {
                        page: Rc::clone(page),
                        node: Node::default(),
                    }
                });
            node = &mut edge.node;
        }
        self.pages += added;
    }

    /// Drop every entry whose page no live sequence shares
    /// (`Rc::strong_count == 1` — the trie holds the only handle),
    /// leaves first so a referenced deep chunk keeps its ancestors.
    /// Returns the number of pages released.
    pub(crate) fn evict_unreferenced(&mut self) -> usize {
        fn walk(node: &mut Node) -> usize {
            let mut removed = 0;
            node.children.retain(|_, edge| {
                removed += walk(&mut edge.node);
                let keep = !edge.node.children.is_empty() || Rc::strong_count(&edge.page) > 1;
                if !keep {
                    removed += 1;
                }
                keep
            });
            removed
        }
        let removed = walk(&mut self.root);
        self.pages -= removed;
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::pool::{PageGeometry, PagePool};
    use crate::kv::KvGauges;
    use std::sync::Arc;

    fn pool(capacity: usize) -> PagePool {
        let geom = PageGeometry {
            n_layers: 1,
            kv_dim: 2,
            page_size: 4,
        };
        PagePool::new(geom, capacity, Arc::new(KvGauges::default()))
    }

    #[test]
    fn lookup_matches_longest_committed_prefix() {
        let pool = pool(8);
        let mut trie = PrefixTrie::new(4);
        let prompt: Vec<u32> = (0..12).collect();
        let pages: Vec<_> = (0..3).map(|_| pool.alloc().unwrap()).collect();
        trie.insert(&prompt, &pages);
        assert_eq!(trie.pages(), 3);

        // Full match, capped match, partial match, miss.
        let hit = trie.lookup(&prompt, 3);
        assert_eq!(hit.len(), 3);
        assert!(Rc::ptr_eq(&hit[0], &pages[0]) && Rc::ptr_eq(&hit[2], &pages[2]));
        assert_eq!(trie.lookup(&prompt, 2).len(), 2);
        let diverging: Vec<u32> = (0..8).chain([99, 99, 99, 99]).collect();
        assert_eq!(trie.lookup(&diverging, 3).len(), 2);
        assert_eq!(trie.lookup(&[7, 7, 7, 7], 1).len(), 0);
        // A trailing partial chunk never matches.
        assert_eq!(trie.lookup(&prompt[..6], 9).len(), 1);
    }

    #[test]
    fn insert_dedups_existing_chunks() {
        let pool = pool(8);
        let mut trie = PrefixTrie::new(4);
        let prompt: Vec<u32> = (0..8).collect();
        let first: Vec<_> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        trie.insert(&prompt, &first);
        // Re-committing the same prefix with different physical pages
        // keeps the originals (they may already be shared).
        let second: Vec<_> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        trie.insert(&prompt, &second);
        assert_eq!(trie.pages(), 2);
        let hit = trie.lookup(&prompt, 2);
        assert!(Rc::ptr_eq(&hit[0], &first[0]) && Rc::ptr_eq(&hit[1], &first[1]));
    }

    #[test]
    fn evicts_only_unreferenced_leaves_first() {
        let pool = pool(8);
        let mut trie = PrefixTrie::new(4);
        let prompt: Vec<u32> = (0..8).collect();
        let pages: Vec<_> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        trie.insert(&prompt, &pages);
        // Keep a live reference to the DEEP page: its ancestor chain
        // must survive even though the root page itself is unshared.
        let held = Rc::clone(&pages[1]);
        drop(pages);
        assert_eq!(trie.evict_unreferenced(), 0);
        assert_eq!(trie.pages(), 2);
        drop(held);
        assert_eq!(trie.evict_unreferenced(), 2);
        assert_eq!(trie.pages(), 0);
        // Pages actually returned to the pool.
        assert_eq!(pool.available(), pool.capacity());
    }
}
