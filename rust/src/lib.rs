//! # AMS-Quant
//!
//! Reproduction of *"AMS-Quant: Adaptive Mantissa Sharing for
//! Floating-point Quantization"* as a three-layer Rust + JAX + Pallas
//! system.
//!
//! - [`formats`] — FPx format algebra (e2m3, e2m2, ... — Table 1).
//! - [`quant`] — the [`Quantizer`](quant::Quantizer) pipeline: per-layer
//!   [`QuantPlan`](quant::QuantPlan)s (mixed precision by layer/role),
//!   RTN → mantissa-sharing adaptive search → pack in one typed-error
//!   flow, with per-layer [`QuantReport`](quant::QuantReport)s.
//! - [`calib`] — activation-aware calibration: per-layer sensitivity
//!   analysis over tapped activations and automatic
//!   [`QuantPlan`](quant::QuantPlan) search under a bits/weight budget.
//! - [`pack`] — prepacked storage layouts (TC-FPx 4+2, FP5.33 half-word,
//!   FP4.25 segmented, ...) with per-row and per-group scale streams.
//! - [`restore`] — bit-level FPx→FP16 restoration (SHIFT/AND/OR and LUT).
//! - [`gemm`] — fused unpack–dequant GEMV/GEMM hot path.
//! - [`model`] — transformer inference engine + checkpoints.
//! - [`kv`] — paged KV-cache subsystem: fixed-size page pool,
//!   per-sequence block tables, prompt-prefix sharing (COW), and the
//!   [`KvStore`](kv::KvStore) accessor the attention paths run over.
//! - [`coordinator`] — the [`Engine`] serving facade: bounded admission,
//!   chunked prefill, continuous batching, streaming handles,
//!   cancellation, replica dispatch, and fault tolerance (supervised
//!   workers, deadlines, priority shedding, fault injection).
//! - [`spec`] — hi-stream self-speculative decoding: draft tokens from
//!   the hi mantissa stream alone, verify them in one full-precision
//!   batched pass (token-identical under greedy sampling).
//! - [`obs`] — observability: unified metrics registry, streaming
//!   log-bucketed histograms, per-request span traces (Chrome
//!   trace-event export), sampled per-path kernel timings.
//! - [`runtime`] — PJRT client running AOT-lowered JAX/Pallas artifacts.
//! - [`sim`] — roofline simulator of the paper's GPU (Table 3).
//! - [`baselines`] — INT RTN / W8A16 / TC-FPx comparators.
//! - [`eval`] — perplexity and task-accuracy harness (Table 2 proxies).
//! - [`tensor`], [`util`] — substrates built in-repo.

pub mod baselines;
pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod experiments;
pub mod formats;
pub mod gemm;
pub mod kv;
pub mod model;
pub mod obs;
pub mod pack;
pub mod quant;
pub mod report;
pub mod restore;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod spec;
pub mod tensor;
pub mod util;

pub use coordinator::{
    DispatchPolicy, Engine, EngineBuilder, EngineError, Event, FailPoints, FailSpec, GenRequest,
    GenResponse, Priority, RequestHandle, ServeStats,
};
pub use obs::{HistStat, MetricsSnapshot, SpanKind, TraceSink};
