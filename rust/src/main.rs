//! `ams-quant` — CLI for the AMS-Quant reproduction.
//!
//! Subcommands (one per experiment, DESIGN.md §6):
//!   formats            Table 1: format extremal values
//!   fig2a              CSV: representable-value distributions
//!   fig2b              CSV: model weight distributions (4 layers)
//!   fig3               preliminary RTN study (GSM8k proxy)
//!   table2             full accuracy matrix (Table 2 / Fig 5 proxy)
//!   table3 [--measured] simulated (default) or measured speedup grid
//!   fig6               combined speedup curves incl. baselines
//!   ksweep             A3: bits/weight vs MSE frontier
//!   calibrate          activation-statistics pass + budgeted plan search
//!   quantize           quantize a checkpoint, report size + error
//!   eval               evaluate a checkpoint under one scheme
//!   serve              run the batched serving workload (E9)
//!   sim                simulated latency detail for one shape
//!   pjrt               run an AOT artifact through the PJRT runtime
//!
//! Common flags: --artifacts DIR (default ./artifacts), --out FILE (write
//! markdown/CSV instead of stdout).

use ams_quant::calib::{CalibConfig, CalibReport, Calibrator};
use ams_quant::coordinator::{DispatchPolicy, Engine, GenRequest, Priority, RequestHandle};
use ams_quant::experiments as exp;
use ams_quant::formats::registry::Scheme;
use ams_quant::formats::FpFormat;
use ams_quant::model::checkpoint::{self, Checkpoint};
use ams_quant::model::transformer::Transformer;
use ams_quant::model::{synthetic_eval_text, tokenizer};
use ams_quant::quant::{Granularity, LayerRole, QuantConfig, QuantPlan, QuantReport, Quantizer};
use ams_quant::report::{f, Table};
use ams_quant::util::bench::BenchConfig;
use ams_quant::util::cli::Args;
use ams_quant::util::prng::Rng;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    match args.subcommand.as_deref() {
        Some("formats") => cmd_formats(args),
        Some("fig2a") => emit(args, exp::fig2a_csv()),
        Some("fig2b") => {
            let (model, _, kind) = exp::load_model(&artifacts)?;
            eprintln!("# weights: {kind}");
            emit(args, exp::fig2b_csv(&model))
        }
        Some("fig3") => cmd_accuracy(args, &artifacts, Scheme::fig3_set(), "Figure 3 (proxy)"),
        Some("table2") => cmd_accuracy(args, &artifacts, Scheme::table2_set(), "Table 2 (proxy)"),
        Some("table3") => cmd_table3(args),
        Some("fig6") => cmd_fig6(args),
        Some("ksweep") => cmd_ksweep(args),
        Some("calibrate") => cmd_calibrate(args, &artifacts),
        Some("quantize") => cmd_quantize(args, &artifacts),
        Some("eval") => cmd_eval(args, &artifacts),
        Some("serve") => cmd_serve(args, &artifacts),
        Some("sim") => {
            let rows = args.get_usize("rows", 9728);
            let cols = args.get_usize("cols", 2560);
            emit_table(args, &exp::sim_latency_table(rows, cols, &[1, 2, 4, 8, 16, 32]))
        }
        Some("pjrt") => cmd_pjrt(args, &artifacts),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand '{cmd}'\n");
            }
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "ams-quant — AMS-Quant (adaptive mantissa sharing) reproduction\n\n\
         usage: ams-quant <subcommand> [flags]\n\n\
         experiments:\n\
         \x20 formats | fig2a | fig2b | fig3 | table2 | table3 [--measured]\n\
         \x20 fig6 | ksweep | sim --rows R --cols C\n\
         tools:\n\
         \x20 calibrate [--budget-bits 5.0 --calib-tokens N --calib-window W]\n\
         \x20           [--include-lm-head]\n\
         \x20           [--report CALIB_REPORT.json --plan-out PLAN.json]\n\
         \x20 quantize --scheme S [--ckpt file.amsz] [--save out.amsq]\n\
         \x20          [--attn S2 --mlp S3 --lm-head S4 --group-size G]\n\
         \x20          [--auto-plan [--budget-bits B --calib-tokens N]]\n\
         \x20          [--plan PLAN.json]\n\
         \x20 eval --scheme S [--tokens N]\n\
         \x20 serve --requests N --max-batch B --replicas R\n\
         \x20       [--scheme S --attn S2 --mlp S3 --lm-head S4 --group-size G]\n\
         \x20       [--auto-plan | --plan PLAN.json]\n\
         \x20       [--quantized file.amsq   (exclusive of the plan flags)]\n\
         \x20       [--queue-capacity Q --dispatch least-outstanding|round-robin]\n\
         \x20       [--prefill-chunk P]\n\
         \x20       [--kv-page-size S --kv-pool-pages N  (0 = worst-case reserve)]\n\
         \x20       [--tenants N --tenant-quota-pages M  (multi-tenant KV isolation)]\n\
         \x20       [--deadline-ms T --queue-deadline-ms T]\n\
         \x20       [--priority interactive|bulk|mixed]\n\
         \x20       [--speculative [--draft-depth K]   (hi-stream draft/verify)]\n\
         \x20       [--trace-out TRACE.json   (Chrome trace-event span export)]\n\
         \x20       [--metrics-out METRICS.json [--metrics-interval-ms T]]\n\
         \x20 pjrt --artifact linear_fp5p33_256x128_b1.hlo.txt\n\
         plan flags: --scheme is the model-wide default; --attn/--mlp/--lm-head\n\
         \x20 override per role (mixed precision); --group-size G uses per-group\n\
         \x20 scales (g weights per scale) instead of per-channel; --auto-plan\n\
         \x20 searches the plan from calibration activations under --budget-bits;\n\
         \x20 --plan loads a plan JSON written by calibrate --plan-out\n\
         common flags: --artifacts DIR  --out FILE  --csv"
    );
}

fn emit(args: &Args, content: String) -> Result<()> {
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &content)?;
            eprintln!("wrote {path}");
        }
        None => print!("{content}"),
    }
    Ok(())
}

fn emit_table(args: &Args, t: &Table) -> Result<()> {
    let content = if args.has("csv") {
        t.to_csv()
    } else if args.get("out").is_some() {
        t.to_markdown()
    } else {
        t.to_console()
    };
    emit(args, content)
}

fn cmd_formats(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Table 1 — FP format properties (no inf/nan, MX convention)",
        &["format", "bits", "bias", "max normal", "min normal", "max subnormal", "min subnormal"],
    );
    for fmt in [FpFormat::E2M1, FpFormat::E2M2, FpFormat::E2M3, FpFormat::E3M2, FpFormat::E4M3] {
        t.row(vec![
            fmt.name(),
            fmt.bits().to_string(),
            fmt.bias().to_string(),
            format!("±{}", fmt.max_normal()),
            format!("±{}", fmt.min_normal()),
            format!("±{}", fmt.max_subnormal()),
            format!("±{}", fmt.min_subnormal()),
        ]);
    }
    emit_table(args, &t)
}

fn cmd_accuracy(args: &Args, artifacts: &Path, schemes: Vec<Scheme>, title: &str) -> Result<()> {
    let (model, heldout, kind) = exp::load_model(artifacts)?;
    let tokens = args.get_usize("tokens", 3000);
    eprintln!("# model: {kind}; eval tokens: {tokens}");
    let rows = exp::accuracy_suite(&model, &heldout, &schemes, tokens);
    let t = exp::accuracy_table(&rows, &format!("{title} — tiny LM ({kind})"));
    emit_table(args, &t)
}

fn cmd_table3(args: &Args) -> Result<()> {
    if args.has("measured") {
        let shrink = args.get_usize("shrink", 8);
        let threads = args.get_usize("threads", 1);
        let shapes = exp::scaled_table3_shapes(shrink);
        let cfg = BenchConfig::from_env();
        for t in exp::table3_measured(
            &shapes,
            &Scheme::table3_set()[1..],
            &[1, 2, 4, 8, 16, 32],
            &cfg,
            threads,
        ) {
            emit_table(args, &t)?;
            println!();
        }
    } else {
        for t in exp::table3_sim() {
            emit_table(args, &t)?;
            println!();
        }
    }
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    // Fig 6 = Table 3 curves + the W8A16 (int8) and TC-FPx baselines on the
    // MLP-down shapes. Simulated by default, measured with --measured.
    let schemes: Vec<Scheme> = ["fp8", "int8", "fp6", "fp5", "fp5.33", "fp4.25"]
        .iter()
        .map(|s| Scheme::parse(s).unwrap())
        .collect();
    if args.has("measured") {
        let shrink = args.get_usize("shrink", 8);
        let cfg = BenchConfig::from_env();
        for t in exp::table3_measured(
            &exp::scaled_table3_shapes(shrink),
            &schemes,
            &[1, 4, 16, 32],
            &cfg,
            args.get_usize("threads", 1),
        ) {
            emit_table(args, &t)?;
            println!();
        }
        return Ok(());
    }
    let dev = ams_quant::sim::Device::paper();
    for (name, rows, cols) in ams_quant::sim::table3_shapes() {
        let mut t = Table::new(
            &format!("Figure 6 (simulated) — {name} MLP-down"),
            &["Scheme", "b=1", "b=2", "b=4", "b=8", "b=16", "b=32"],
        );
        for &scheme in &schemes {
            let sp = ams_quant::sim::speedup_row(&dev, rows, cols, scheme, &[1, 2, 4, 8, 16, 32]);
            let mut cells = vec![scheme.label()];
            cells.extend(sp.iter().map(|&v| f(v, 2)));
            t.row(cells);
        }
        emit_table(args, &t)?;
        println!();
    }
    Ok(())
}

fn cmd_ksweep(args: &Args) -> Result<()> {
    let base = args.get_or("base", "e2m2");
    let fmt = match base {
        "e2m2" => FpFormat::E2M2,
        "e2m3" => FpFormat::E2M3,
        "e3m2" => FpFormat::E3M2,
        other => bail!("unknown base format '{other}'"),
    };
    let t = exp::k_sweep(fmt, &[2, 3, 4, 8, 16], args.get_u64("seed", 7));
    emit_table(args, &t)
}

/// Build the quantization plan described by the CLI flags: `--scheme` is
/// the model-wide default, `--attn`/`--mlp`/`--lm-head` override per
/// role, `--group-size G` switches the scale granularity to per-group.
/// Returns `None` for `--scheme fp32` (dense reference, no plan).
fn quantizer_from_args(args: &Args, default_scheme: &str) -> Result<Option<Quantizer>> {
    let scheme_name = args.get_or("scheme", default_scheme);
    if scheme_name == "fp32" {
        // Dense reference: plan flags would be silently dead — reject
        // them, mirroring the --quantized exclusivity check.
        for flag in ["attn", "mlp", "lm-head", "group-size"] {
            if args.get(flag).is_some() {
                bail!("--scheme fp32 serves the dense model; --{flag} cannot be combined");
            }
        }
        return Ok(None);
    }
    let gran = match args.get("group-size") {
        Some(g) => Granularity::PerGroup(
            g.parse::<usize>()
                .with_context(|| format!("--group-size '{g}' is not a number"))?,
        ),
        None => Granularity::PerChannel,
    };
    let cfg_for = |name: &str| -> Result<QuantConfig> {
        let scheme = Scheme::parse(name).map_err(|e| anyhow::anyhow!(e))?;
        // FP16 passthrough has no scale grid; it keeps per-channel
        // identity scales even under --group-size.
        let g = if scheme == Scheme::Fp16 { Granularity::PerChannel } else { gran };
        Ok(QuantConfig::paper(scheme).with_granularity(g))
    };
    let mut builder = QuantPlan::builder(cfg_for(scheme_name)?);
    for (flag, role) in [
        ("attn", LayerRole::Attention),
        ("mlp", LayerRole::Mlp),
        ("lm-head", LayerRole::LmHead),
    ] {
        if let Some(name) = args.get(flag) {
            builder = builder.role(role, cfg_for(name)?);
        }
    }
    let plan = builder.build().map_err(|e| anyhow::anyhow!("invalid plan: {e}"))?;
    Ok(Some(Quantizer::new(plan)))
}

/// Base model plus a matching calibration corpus for `calibrate` /
/// `quantize`. Without `--ckpt` this is exactly `exp::load_model`'s
/// model/heldout pair (one rule, one read — the same pair `serve`
/// uses), so a searched plan is always applied to the model it was
/// calibrated on. An explicit `--ckpt` pairs with the synthetic
/// grammar text; `Calibrator::collect` rejects the pair cleanly if the
/// checkpoint's vocab cannot embed it.
fn load_base_with_corpus(args: &Args, artifacts: &Path) -> Result<(Transformer, Vec<u32>)> {
    if let Some(ckpt) = args.get("ckpt") {
        let model = Transformer::from_checkpoint(&Checkpoint::load(Path::new(ckpt))?)?;
        return Ok((model, tokenizer::encode(&synthetic_eval_text())));
    }
    let (model, heldout, kind) = exp::load_model(artifacts)?;
    if kind == "synthetic" {
        eprintln!("# trained artifacts missing; using synthetic model");
    }
    Ok((model, heldout))
}

// (No seed flag: the CLI corpus is the deterministic held-out/synthetic
// text, so a seed would be recorded but change nothing. `CalibConfig::
// seed` stays an API-level knob for `Calibrator::synthetic_corpus`.)
fn calib_config_from_args(args: &Args) -> CalibConfig {
    CalibConfig {
        budget_bits: args.get_f64("budget-bits", 5.0),
        calib_tokens: args.get_usize("calib-tokens", 4096),
        window: args.get_usize("calib-window", 128),
        include_lm_head: args.has("include-lm-head"),
        ..CalibConfig::default()
    }
}

/// Resolve the quantization source for `quantize`/`serve`:
/// `--auto-plan` searches the plan from calibration activations,
/// `--plan FILE` loads a plan JSON, otherwise the manual plan flags
/// apply (`None` = dense reference). The manual flags conflict with
/// both automatic paths rather than being silently ignored.
fn resolve_quantizer(
    args: &Args,
    corpus: &[u32],
    base: &Transformer,
    default_scheme: &str,
) -> Result<Option<(Quantizer, Option<CalibReport>)>> {
    const MANUAL: [&str; 5] = ["scheme", "attn", "mlp", "lm-head", "group-size"];
    if args.has("auto-plan") {
        for flag in MANUAL {
            if args.get(flag).is_some() {
                bail!("--auto-plan searches the plan from calibration data; --{flag} cannot be combined");
            }
        }
        if args.get("plan").is_some() {
            bail!("--auto-plan and --plan are exclusive (one searches, one loads)");
        }
        let cfg = calib_config_from_args(args);
        eprintln!(
            "# calibrating: budget {} bits/w over {} corpus tokens",
            cfg.budget_bits,
            corpus.len().min(cfg.calib_tokens)
        );
        let (plan, report) = Calibrator::new(cfg)
            .calibrate(base, corpus)
            .map_err(|e| anyhow::anyhow!("calibration failed: {e}"))?;
        eprintln!(
            "# searched plan: achieved {:.3} bits/w (budget {}), act-SQNR {:.2} dB",
            report.achieved_bits, report.budget_bits, report.act_sqnr_db
        );
        return Ok(Some((Quantizer::new(plan), Some(report))));
    }
    if let Some(path) = args.get("plan") {
        for flag in MANUAL {
            if args.get(flag).is_some() {
                bail!("--plan loads a complete plan; --{flag} cannot be combined");
            }
        }
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read plan {path}"))?;
        let j = ams_quant::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let plan = QuantPlan::from_json(&j).map_err(|e| anyhow::anyhow!("invalid plan: {e}"))?;
        return Ok(Some((Quantizer::new(plan), None)));
    }
    Ok(quantizer_from_args(args, default_scheme)?.map(|q| (q, None)))
}

/// The `calibrate` subcommand: activation-statistics pass → sensitivity
/// scores → budgeted plan search → CALIB_REPORT.json (+ optional plan
/// JSON for `quantize --plan` / `serve --plan`).
fn cmd_calibrate(args: &Args, artifacts: &Path) -> Result<()> {
    let (base, corpus) = load_base_with_corpus(args, artifacts)?;
    let cfg = calib_config_from_args(args);
    eprintln!(
        "# calibrating: budget {} bits/w, {} corpus tokens (window {}), lm_head {}",
        cfg.budget_bits,
        corpus.len().min(cfg.calib_tokens),
        cfg.window,
        if cfg.include_lm_head { "scored" } else { "dense" },
    );
    let (plan, report) = Calibrator::new(cfg)
        .calibrate(&base, &corpus)
        .map_err(|e| anyhow::anyhow!("calibration failed: {e}"))?;
    emit_table(args, &report.table())?;
    eprintln!(
        "# achieved {:.3} bits/w (budget {}, {}), act-SQNR {:.2} dB over {} calib tokens",
        report.achieved_bits,
        report.budget_bits,
        if report.budget_met { "met" } else { "NOT met" },
        report.act_sqnr_db,
        report.calib_tokens,
    );
    let rpath = args.get_or("report", "CALIB_REPORT.json");
    std::fs::write(rpath, report.to_json().to_string_pretty())?;
    eprintln!("# wrote calibration report {rpath}");
    if let Some(ppath) = args.get("plan-out") {
        std::fs::write(ppath, plan.to_json().to_string_pretty())?;
        eprintln!("# wrote plan {ppath} (use with quantize/serve --plan)");
    }
    Ok(())
}

fn report_table(reports: &[QuantReport], title: &str) -> Table {
    let mut t = Table::new(
        title,
        &[
            "layer", "role", "scheme", "gran", "bits/w", "scale b/w", "MSE", "SQNR dB",
            "hi SQNR dB", "shared=1",
        ],
    );
    for r in reports {
        let gran = match r.granularity {
            Granularity::PerTensor => "tensor".to_string(),
            Granularity::PerChannel => "channel".to_string(),
            Granularity::PerGroup(g) => format!("group({g})"),
        };
        let shared = if r.shared_groups > 0 {
            format!("{:.1}%", 100.0 * r.shared_ones as f64 / r.shared_groups as f64)
        } else {
            "-".to_string()
        };
        t.row(vec![
            r.layer.clone(),
            r.role.name().to_string(),
            r.scheme.label(),
            gran,
            f(r.bits_per_weight, 3),
            f(r.scale_bits_per_weight, 3),
            format!("{:.3e}", r.mse),
            f(r.sqnr_db, 2),
            // "-" = no hi/lo split, the hi-only draft decode cannot
            // serve this layout.
            if r.hi_sqnr_db.is_nan() { "-".to_string() } else { f(r.hi_sqnr_db, 2) },
            shared,
        ]);
    }
    t
}

fn cmd_quantize(args: &Args, artifacts: &Path) -> Result<()> {
    let (base, corpus) = load_base_with_corpus(args, artifacts)?;
    let (quantizer, calib) = resolve_quantizer(args, &corpus, &base, "fp4.25")?
        .context("quantize needs a quantized scheme (fp32 is the dense reference)")?;
    let (q, reports) = base
        .quantized_report(&quantizer)
        .map_err(|e| anyhow::anyhow!("quantization failed: {e}"))?;
    let dense_bytes = base.projection_bytes();
    let q_bytes = q.projection_bytes();
    let scheme = quantizer.plan().default_config().scheme;
    let t = report_table(
        &reports,
        &format!("Per-layer quantization report — default {}", scheme.label()),
    );
    emit_table(args, &t)?;
    let mean_mse = reports.iter().map(|r| r.mse).sum::<f64>() / reports.len().max(1) as f64;
    // Honest compression: the scale streams (material under per-group)
    // count against the packed size.
    let scale_bytes = q.projection_scale_bytes();
    eprintln!(
        "# projections: {} -> {} payload + {} scale bytes ({:.2}x vs fp16 incl. scales); \
         mean weight MSE {:.3e}",
        dense_bytes,
        q_bytes,
        scale_bytes,
        dense_bytes as f64 / (q_bytes + scale_bytes) as f64,
        mean_mse
    );
    if let Some(path) = args.get("save") {
        // Auto-planned exports carry their calibration provenance in the
        // AMSQ header — the checkpoint records how its plan was found.
        let prov = calib.as_ref().map(|r| r.provenance());
        checkpoint::save_quantized_with(&q, Path::new(path), prov.as_ref())?;
        eprintln!(
            "# wrote quantized checkpoint {path}{}",
            if prov.is_some() { " (calibration provenance embedded)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &Path) -> Result<()> {
    let scheme = Scheme::parse(args.get_or("scheme", "fp5.33")).map_err(|e| anyhow::anyhow!(e))?;
    let (base, heldout, kind) = exp::load_model(artifacts)?;
    let rows = exp::accuracy_suite(
        &base,
        &heldout,
        &[Scheme::Fp16, scheme],
        args.get_usize("tokens", 3000),
    );
    let t = exp::accuracy_table(&rows, &format!("Eval — {} vs FP16 ({kind})", scheme.label()));
    emit_table(args, &t)
}

fn cmd_serve(args: &Args, artifacts: &Path) -> Result<()> {
    let n_requests = args.get_usize("requests", 16);
    let max_batch = args.get_usize("max-batch", 8);
    let max_new = args.get_usize("max-new-tokens", 32);
    let replicas = args.get_usize("replicas", 1);
    let queue_capacity = args.get_usize("queue-capacity", 64);
    let dispatch = match args.get_or("dispatch", "least-outstanding") {
        "round-robin" => DispatchPolicy::RoundRobin,
        "least-outstanding" => DispatchPolicy::LeastOutstanding,
        other => bail!("unknown dispatch policy '{other}' (least-outstanding | round-robin)"),
    };
    let prefill_chunk = args.get_usize("prefill-chunk", 128);
    // Paged-KV knobs: page granularity and pool capacity. Pool 0 (the
    // default) reserves the worst case — max_batch full-context
    // sequences — so nothing preempts; a smaller explicit pool
    // over-commits memory and leans on continuous batching +
    // preemption.
    let kv_page_size = args.get_usize("kv-page-size", 16);
    let kv_pool_pages = args.get_usize("kv-pool-pages", 0);
    // Multi-tenant knobs: requests round-robin across N tenant
    // namespaces (1, the default, keeps everything in the shared
    // default tenant — bit-identical single-tenant serving),
    // optionally with a per-tenant KV page quota (0 = unlimited).
    let tenants = args.get_usize("tenants", 1);
    if tenants == 0 {
        bail!("--tenants must be at least 1");
    }
    let tenant_quota_pages = args.get_usize("tenant-quota-pages", 0);
    // Fault-tolerance knobs: optional per-request deadlines (0 = none)
    // and the workload's priority mix. "mixed" alternates interactive /
    // bulk so the priority lanes and shed path are exercised.
    let total_deadline = match args.get_u64("deadline-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let queue_deadline = match args.get_u64("queue-deadline-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    // Self-speculative decoding: draft from the hi mantissa stream,
    // verify full precision. Token-identical under greedy sampling.
    let speculative = args.has("speculative");
    let draft_depth = args.get_usize("draft-depth", 4);
    if draft_depth == 0 {
        bail!("--draft-depth must be at least 1");
    }
    let priority_of = |id: u64| -> Priority {
        match args.get_or("priority", "interactive") {
            "bulk" => Priority::Bulk,
            "mixed" => {
                if id % 2 == 1 {
                    Priority::Bulk
                } else {
                    Priority::Interactive
                }
            }
            _ => Priority::Interactive,
        }
    };
    if !matches!(args.get_or("priority", "interactive"), "interactive" | "bulk" | "mixed") {
        bail!(
            "unknown priority '{}' (interactive | bulk | mixed)",
            args.get_or("priority", "interactive")
        );
    }
    let (base, heldout, kind) = exp::load_model(artifacts)?;
    // --quantized loads a prequantized AMSQ export (the offline
    // "quantize once" artifact) — its scheme is baked in, so the plan
    // flags (manual, --plan and --auto-plan alike) are rejected rather
    // than silently ignored; otherwise the plan flags quantize here.
    let model = if let Some(qpath) = args.get("quantized") {
        for flag in ["scheme", "attn", "mlp", "lm-head", "group-size", "plan"] {
            if args.get(flag).is_some() {
                bail!(
                    "--quantized serves the scheme baked into {qpath}; --{flag} cannot be \
                     combined (re-export with `quantize --save` to change the plan)"
                );
            }
        }
        if args.has("auto-plan") {
            bail!(
                "--quantized serves the plan baked into {qpath}; --auto-plan cannot be \
                 combined (re-export with `quantize --auto-plan --save`)"
            );
        }
        let (m, prov) = checkpoint::load_quantized_meta(Path::new(qpath))?;
        if let Some(p) = prov {
            eprintln!("# calibration provenance: {}", p.to_string());
        }
        m
    } else {
        // Serve calibrates against the model + heldout pair it serves —
        // no separate corpus load that could drift from `exp::load_model`.
        match resolve_quantizer(args, &heldout, &base, "fp5.33")? {
            None => base,
            Some((quantizer, _)) => base
                .quantized_with(&quantizer)
                .map_err(|e| anyhow::anyhow!("quantization failed: {e}"))?,
        }
    };
    // Report what is actually served (the loaded/applied scheme), not
    // what a flag claimed.
    let served = model
        .scheme
        .map(|s| s.id())
        .unwrap_or_else(|| "fp32 (dense)".to_string());
    eprintln!(
        "# serving tiny LM ({kind}) under {served}: {n_requests} requests, \
         max_batch={max_batch}, replicas={replicas}, queue_capacity={queue_capacity}"
    );

    // Observability exports: Chrome trace-event spans and the typed
    // metrics snapshot, optionally rewritten on a timer while serving.
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let metrics_interval = args.get_u64("metrics-interval-ms", 0);
    if metrics_interval > 0 && metrics_out.is_none() {
        bail!("--metrics-interval-ms needs --metrics-out");
    }

    let mut rng = Rng::new(args.get_u64("seed", 0));
    let eng = Engine::builder()
        .replicas(replicas)
        .max_batch(max_batch)
        .queue_capacity(queue_capacity)
        .dispatch(dispatch)
        .prefill_chunk(prefill_chunk)
        .kv_page_size(kv_page_size)
        .kv_pool_pages(kv_pool_pages)
        .tenant_quota_pages(tenant_quota_pages)
        .speculative(speculative)
        .draft_depth(draft_depth)
        .seed(1)
        .build(model);
    let done = std::sync::atomic::AtomicBool::new(false);
    let responses: Vec<_> = std::thread::scope(|s| -> Result<Vec<_>> {
        if metrics_interval > 0 {
            if let Some(path) = metrics_out.clone() {
                let eng = &eng;
                let done = &done;
                s.spawn(move || {
                    while !done.load(std::sync::atomic::Ordering::Relaxed) {
                        std::thread::sleep(std::time::Duration::from_millis(metrics_interval));
                        let snap = eng.metrics_snapshot();
                        let _ = std::fs::write(&path, snap.to_json().to_string_pretty());
                    }
                });
            }
        }
        // The writer thread exits on `done`; set it on *every* path out
        // of the scope (an early `?` would otherwise leave it spinning
        // and the scope joining forever).
        let run = (|| -> Result<Vec<_>> {
            let handles: Vec<RequestHandle> = (0..n_requests as u64)
                .map(|id| {
                    let start = rng.range(0, heldout.len().saturating_sub(40).max(1));
                    let prompt: Vec<u32> =
                        heldout[start..(start + 16).min(heldout.len())].to_vec();
                    let mut req =
                        GenRequest::greedy(id, prompt, max_new).with_priority(priority_of(id));
                    if tenants > 1 {
                        req = req.with_tenant((id % tenants as u64) as u32);
                    }
                    if let Some(d) = queue_deadline {
                        req = req.with_queue_deadline(d);
                    }
                    if let Some(d) = total_deadline {
                        req = req.with_total_deadline(d);
                    }
                    eng.submit(req).map_err(|e| anyhow::anyhow!("submit failed: {e}"))
                })
                .collect::<Result<_>>()?;
            Ok(handles.into_iter().filter_map(|h| h.wait()).collect())
        })();
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        run
    })?;
    eng.drain();
    // One snapshot feeds the CLI table, METRICS.json and the sanity
    // line below — `MetricsSnapshot::rows` is the only formatter.
    let snap = eng.metrics_snapshot();
    let trace = eng.trace();
    eng.shutdown();
    if let Some(path) = &metrics_out {
        std::fs::write(path, snap.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        eprintln!("# wrote metrics snapshot {}", path.display());
    }
    if let Some(path) = &trace_out {
        std::fs::write(path, trace.to_chrome_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        eprintln!(
            "# wrote Chrome trace ({} events, {} dropped) {} — open in ui.perfetto.dev",
            trace.len(),
            trace.dropped(),
            path.display()
        );
    }

    let mut t = Table::new("Serving report (E9)", &["metric", "value"]);
    for (k, v) in snap.rows() {
        t.row(vec![k, v]);
    }
    emit_table(args, &t)?;
    if let Some(r) = responses.first() {
        eprintln!("# sample continuation: {:?}", tokenizer::decode(&r.tokens));
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt(_args: &Args, _artifacts: &Path) -> Result<()> {
    bail!("this binary was built without the 'pjrt' feature (rebuild with `--features pjrt`)")
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt(args: &Args, artifacts: &Path) -> Result<()> {
    let manifest_path = artifacts.join("manifest.json");
    let manifest = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("run `make artifacts` first ({})", manifest_path.display()))?;
    let entries = ams_quant::util::json::parse(&manifest).map_err(|e| anyhow::anyhow!("{e}"))?;
    let entries = entries.as_arr().context("manifest is not an array")?.to_vec();
    let name = args
        .get("artifact")
        .unwrap_or("linear_fp5p33_256x128_b1.hlo.txt");
    let entry = entries
        .iter()
        .find(|e| e.req_str("file").map(|v| v == name).unwrap_or(false))
        .with_context(|| format!("artifact '{name}' not in manifest"))?;
    let scheme = Scheme::parse(entry.req_str("scheme").unwrap()).map_err(|e| anyhow::anyhow!(e))?;
    let rows = entry.req_usize("rows").unwrap();
    let cols = entry.req_usize("cols").unwrap();
    let batch = entry.req_usize("batch").unwrap();

    let mut rng = Rng::new(1);
    let w = ams_quant::model::synthetic::llm_weight(rows, cols, &Default::default(), &mut rng);
    let lin = exp::make_linear(&w, scheme);
    let x = exp::random_acts(batch, cols, &mut rng);

    let rt = ams_quant::runtime::Runtime::cpu()?;
    eprintln!("# platform: {}", rt.platform());
    let exe = rt.load(&artifacts.join(name))?;
    let y = exe.run_linear(&lin.packed, x.data(), batch)?;
    let ynative = lin.gemm(&x);
    let mut max_err = 0f32;
    for (a, b) in y.iter().zip(ynative.data()) {
        max_err = max_err.max((a - b).abs());
    }
    println!("pjrt {name}: [{batch}x{rows}] computed; max |pjrt - native| = {max_err:.2e}");
    if max_err > 1e-3 {
        bail!("PJRT/native mismatch: {max_err}");
    }
    Ok(())
}
