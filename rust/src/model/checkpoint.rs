//! AMSZ checkpoint container: a minimal self-describing tensor archive
//! shared between the JAX trainer (writer, see python/compile/ckpt_io.py)
//! and the rust engine (reader), plus a writer on the rust side for
//! synthetic models and quantized exports.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"AMSZ1\n"
//! u32    header_len
//! bytes  header JSON: {"config": {...},
//!                      "tensors": [{"name","shape":[..],"offset","count"}]}
//! bytes  f32 payload (offsets are element offsets into this region)
//! ```
//!
//! **Quantized export (AMSQ)** — the "quantize once offline, serve
//! millions" artifact produced by [`save_quantized`] and read back by
//! [`load_quantized`]: packed word streams, per-row scales and the
//! per-group scale streams of every projection, plus the dense
//! embeddings/norms, in one self-describing file:
//! ```text
//! magic  b"AMSQ1\n"
//! u32    header_len
//! bytes  header JSON: {"config": {...}, "scheme": "fp4.25",
//!                      "f32_len": N,
//!                      "tensors": [
//!                        {"name","kind":"dense","shape":[..],"off","count"} |
//!                        {"name","kind":"packed","scheme","rows","cols",
//!                         "row_stride","words_off","words_count",
//!                         "scales_off","scales_count",
//!                         "group_size","groups_per_row",
//!                         "gscales_off","gscales_count"}]}
//! bytes  f32 region (N little-endian floats: dense tensors + scales)
//! bytes  u16 region (packed words)
//! ```

use super::transformer::{LayerWeights, Linear, Transformer};
use super::ModelConfig;
use crate::formats::registry::Scheme;
use crate::gemm::QuantLinear;
use crate::pack::{GroupScales, PackedTensor};
use crate::tensor::Tensor;
use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"AMSZ1\n";
const QMAGIC: &[u8; 6] = b"AMSQ1\n";

/// In-memory checkpoint: named f32 tensors + model config.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new(config: ModelConfig) -> Checkpoint {
        Checkpoint {
            config,
            tensors: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            let mut e = Json::obj();
            e.set("name", Json::Str(name.clone()))
                .set(
                    "shape",
                    Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                )
                .set("offset", Json::Num(offset as f64))
                .set("count", Json::Num(t.len() as f64));
            entries.push(e);
            offset += t.len();
        }
        let mut header = Json::obj();
        header
            .set("config", self.config.to_json())
            .set("tensors", Json::Arr(entries));
        let hbytes = header.to_string().into_bytes();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(hbytes.len() as u32).to_le_bytes())?;
        f.write_all(&hbytes)?;
        for t in self.tensors.values() {
            for &x in t.data() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an AMSZ checkpoint", path.display());
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?).map_err(|e| anyhow::anyhow!("{e}"))?;
        let config = ModelConfig::from_json(
            header
                .get("config")
                .context("header missing 'config'")?,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;

        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        let floats: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut tensors = BTreeMap::new();
        for e in header
            .get("tensors")
            .and_then(|t| t.as_arr())
            .context("header missing 'tensors'")?
        {
            let name = e.req_str("name").map_err(|e| anyhow::anyhow!("{e}"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(|s| s.as_arr())
                .context("tensor missing shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = e.req_usize("offset").map_err(|e| anyhow::anyhow!("{e}"))?;
            let count = e.req_usize("count").map_err(|e| anyhow::anyhow!("{e}"))?;
            if offset + count > floats.len() {
                bail!("tensor '{name}' exceeds payload ({} floats)", floats.len());
            }
            tensors.insert(
                name.to_string(),
                Tensor::from_vec(&shape, floats[offset..offset + count].to_vec()),
            );
        }
        Ok(Checkpoint { config, tensors })
    }
}

/// Accumulates the two payload regions while the header is built.
struct QPayload {
    f32s: Vec<f32>,
    words: Vec<u16>,
}

impl QPayload {
    fn push_f32(&mut self, data: &[f32]) -> (usize, usize) {
        let off = self.f32s.len();
        self.f32s.extend_from_slice(data);
        (off, data.len())
    }

    fn push_words(&mut self, data: &[u16]) -> (usize, usize) {
        let off = self.words.len();
        self.words.extend_from_slice(data);
        (off, data.len())
    }
}

fn dense_entry(name: &str, shape: &[usize], data: &[f32], p: &mut QPayload) -> Json {
    let (off, count) = p.push_f32(data);
    let mut e = Json::obj();
    e.set("name", Json::Str(name.to_string()))
        .set("kind", Json::Str("dense".to_string()))
        .set(
            "shape",
            Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()),
        )
        .set("off", Json::Num(off as f64))
        .set("count", Json::Num(count as f64));
    e
}

fn linear_entry(name: &str, l: &Linear, p: &mut QPayload) -> Json {
    match l {
        Linear::Dense(t) => dense_entry(name, t.shape(), t.data(), p),
        Linear::Quant(q) => {
            let pk = &q.packed;
            let (woff, wcount) = p.push_words(&pk.words);
            let (soff, scount) = p.push_f32(&pk.scales);
            let mut e = Json::obj();
            e.set("name", Json::Str(name.to_string()))
                .set("kind", Json::Str("packed".to_string()))
                .set("scheme", Json::Str(pk.scheme.id()))
                .set("rows", Json::Num(pk.rows as f64))
                .set("cols", Json::Num(pk.cols as f64))
                .set("row_stride", Json::Num(pk.row_stride as f64))
                .set("words_off", Json::Num(woff as f64))
                .set("words_count", Json::Num(wcount as f64))
                .set("scales_off", Json::Num(soff as f64))
                .set("scales_count", Json::Num(scount as f64));
            if let Some(gs) = &pk.group_scales {
                let (goff, gcount) = p.push_f32(&gs.scales);
                e.set("group_size", Json::Num(gs.group_size as f64))
                    .set("groups_per_row", Json::Num(gs.groups_per_row as f64))
                    .set("gscales_off", Json::Num(goff as f64))
                    .set("gscales_count", Json::Num(gcount as f64));
            }
            e
        }
    }
}

/// Export a (typically quantized) model: packed projections keep their
/// word streams and scale streams verbatim, so a reload serves
/// bit-identical logits. Dense projections (e.g. an untargeted lm_head)
/// are stored dense.
pub fn save_quantized(model: &Transformer, path: &Path) -> Result<()> {
    save_quantized_with(model, path, None)
}

/// [`save_quantized`] with an optional provenance blob embedded into the
/// header under `"calibration"` — the auto-plan workflow records how the
/// plan was searched (budget, achieved bits, corpus size, seed; see
/// [`CalibReport::provenance`](crate::calib::CalibReport::provenance)),
/// so a checkpoint carries its own calibration audit trail.
pub fn save_quantized_with(
    model: &Transformer,
    path: &Path,
    provenance: Option<&Json>,
) -> Result<()> {
    let mut p = QPayload { f32s: Vec::new(), words: Vec::new() };
    let mut entries = Vec::new();
    entries.push(dense_entry("embed", model.embed.shape(), model.embed.data(), &mut p));
    entries.push(dense_entry("final_norm", &[model.final_norm.len()], &model.final_norm, &mut p));
    entries.push(linear_entry("lm_head", &model.lm_head, &mut p));
    for (i, l) in model.layers.iter().enumerate() {
        entries.push(dense_entry(
            &format!("layers.{i}.attn_norm"),
            &[l.attn_norm.len()],
            &l.attn_norm,
            &mut p,
        ));
        entries.push(dense_entry(
            &format!("layers.{i}.mlp_norm"),
            &[l.mlp_norm.len()],
            &l.mlp_norm,
            &mut p,
        ));
        for (field, lin) in [
            ("wq", &l.wq),
            ("wk", &l.wk),
            ("wv", &l.wv),
            ("wo", &l.wo),
            ("w_gate", &l.w_gate),
            ("w_up", &l.w_up),
            ("w_down", &l.w_down),
        ] {
            entries.push(linear_entry(&format!("layers.{i}.{field}"), lin, &mut p));
        }
    }
    let mut header = Json::obj();
    header
        .set("config", model.cfg.to_json())
        .set("f32_len", Json::Num(p.f32s.len() as f64))
        .set("tensors", Json::Arr(entries));
    if let Some(s) = model.scheme {
        header.set("scheme", Json::Str(s.id()));
    }
    if let Some(p) = provenance {
        header.set("calibration", p.clone());
    }
    let hbytes = header.to_string().into_bytes();

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(QMAGIC)?;
    f.write_all(&(hbytes.len() as u32).to_le_bytes())?;
    f.write_all(&hbytes)?;
    for &x in &p.f32s {
        f.write_all(&x.to_le_bytes())?;
    }
    for &w in &p.words {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

fn read_dense(e: &Json, f32s: &[f32]) -> Result<Tensor> {
    let shape: Vec<usize> = e
        .get("shape")
        .and_then(|s| s.as_arr())
        .context("dense tensor missing shape")?
        .iter()
        .map(|d| d.as_usize().unwrap_or(0))
        .collect();
    let off = e.req_usize("off").map_err(|e| anyhow::anyhow!("{e}"))?;
    let count = e.req_usize("count").map_err(|e| anyhow::anyhow!("{e}"))?;
    if off + count > f32s.len() {
        bail!("dense tensor exceeds f32 region");
    }
    if shape.iter().product::<usize>() != count {
        bail!("dense tensor shape {shape:?} does not match count {count}");
    }
    Ok(Tensor::from_vec(&shape, f32s[off..off + count].to_vec()))
}

fn read_linear(e: &Json, f32s: &[f32], words: &[u16]) -> Result<Linear> {
    match e.req_str("kind").map_err(|e| anyhow::anyhow!("{e}"))? {
        "dense" => Ok(Linear::Dense(read_dense(e, f32s)?)),
        "packed" => {
            let scheme = Scheme::parse(e.req_str("scheme").map_err(|e| anyhow::anyhow!("{e}"))?)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let u = |k: &str| -> Result<usize> {
                e.req_usize(k).map_err(|e| anyhow::anyhow!("{e}"))
            };
            let (rows, cols, row_stride) = (u("rows")?, u("cols")?, u("row_stride")?);
            let (woff, wcount) = (u("words_off")?, u("words_count")?);
            let (soff, scount) = (u("scales_off")?, u("scales_count")?);
            // Full geometry validation: a corrupt/truncated header must
            // fail the load, never panic (or decode garbage) at serve
            // time.
            if row_stride != crate::pack::row_stride(scheme, cols) {
                bail!(
                    "row_stride {row_stride} does not match scheme {} at {cols} cols",
                    scheme.id()
                );
            }
            if wcount != rows * row_stride {
                bail!("words_count {wcount} != rows {rows} * row_stride {row_stride}");
            }
            if scount != rows {
                bail!("scales_count {scount} != rows {rows}");
            }
            if woff + wcount > words.len() || soff + scount > f32s.len() {
                bail!("packed tensor exceeds payload");
            }
            let group_scales = match e.get("group_size").map(|g| g.as_usize()) {
                None => None,
                Some(None) | Some(Some(0)) => bail!("invalid group_size in packed tensor"),
                Some(Some(group_size)) => {
                    let groups_per_row = u("groups_per_row")?;
                    if groups_per_row != cols.div_ceil(group_size) {
                        bail!(
                            "groups_per_row {groups_per_row} != ceil({cols}/{group_size})"
                        );
                    }
                    let (goff, gcount) = (u("gscales_off")?, u("gscales_count")?);
                    if gcount != rows * groups_per_row {
                        bail!("gscales_count {gcount} != rows {rows} * groups {groups_per_row}");
                    }
                    if goff + gcount > f32s.len() {
                        bail!("group scales exceed f32 region");
                    }
                    Some(GroupScales {
                        group_size,
                        groups_per_row,
                        scales: f32s[goff..goff + gcount].to_vec(),
                    })
                }
            };
            // The validated constructor re-checks the whole stream
            // geometry (incl. the group-scale stream), so a corrupt
            // header that slipped past the field checks above still
            // fails the load instead of the serve path.
            let packed = PackedTensor::new(
                scheme,
                rows,
                cols,
                words[woff..woff + wcount].to_vec(),
                f32s[soff..soff + scount].to_vec(),
                group_scales,
            )
            .map_err(|e| anyhow::anyhow!("packed tensor geometry: {e}"))?;
            Ok(Linear::Quant(QuantLinear::new(packed)))
        }
        other => bail!("unknown tensor kind '{other}'"),
    }
}

/// Load a quantized model exported by [`save_quantized`].
pub fn load_quantized(path: &Path) -> Result<Transformer> {
    load_quantized_meta(path).map(|(model, _)| model)
}

/// [`load_quantized`] plus the header's calibration provenance blob
/// (when the export embedded one via [`save_quantized_with`]).
pub fn load_quantized_meta(path: &Path) -> Result<(Transformer, Option<Json>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != QMAGIC {
        bail!("{}: not an AMSQ quantized checkpoint", path.display());
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = parse(std::str::from_utf8(&hbytes)?).map_err(|e| anyhow::anyhow!("{e}"))?;
    let config = ModelConfig::from_json(header.get("config").context("header missing 'config'")?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let scheme = match header.get("scheme").and_then(|s| s.as_str()) {
        Some(id) => Some(Scheme::parse(id).map_err(|e| anyhow::anyhow!("{e}"))?),
        None => None,
    };
    let f32_len = header
        .req_usize("f32_len")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let provenance = header.get("calibration").cloned();

    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    if payload.len() < f32_len * 4 {
        bail!("payload shorter than declared f32 region");
    }
    let (fbytes, wbytes) = payload.split_at(f32_len * 4);
    let f32s: Vec<f32> = fbytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let words: Vec<u16> = wbytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect();

    let mut by_name: BTreeMap<String, &Json> = BTreeMap::new();
    let entries = header
        .get("tensors")
        .and_then(|t| t.as_arr())
        .context("header missing 'tensors'")?;
    for e in entries {
        by_name.insert(
            e.req_str("name").map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
            e,
        );
    }
    let entry = |name: &str| -> Result<&&Json> {
        by_name
            .get(name)
            .with_context(|| format!("quantized checkpoint missing tensor '{name}'"))
    };
    let densev = |name: &str| -> Result<Vec<f32>> {
        Ok(read_dense(entry(name)?, &f32s)?.data().to_vec())
    };

    // Every tensor is cross-checked against the model config, so a file
    // that is internally consistent but disagrees with its own config
    // fails the load instead of panicking (or serving garbage) at serve
    // time.
    let (d, kvd, dff, vocab) = (
        config.d_model,
        config.kv_dim(),
        config.d_ff,
        config.vocab_size,
    );
    let check_dims = |name: &str, l: &Linear, out_dim: usize, in_dim: usize| -> Result<()> {
        if l.out_dim() != out_dim || l.in_dim() != in_dim {
            bail!(
                "tensor '{name}' is [{}x{}] but the config expects [{out_dim}x{in_dim}]",
                l.out_dim(),
                l.in_dim()
            );
        }
        Ok(())
    };
    let check_vec = |name: &str, v: &[f32]| -> Result<()> {
        if v.len() != d {
            bail!("norm '{name}' has {} weights, config d_model is {d}", v.len());
        }
        Ok(())
    };
    let mut layers = Vec::with_capacity(config.n_layers);
    for i in 0..config.n_layers {
        let lin = |field: &str, out_dim: usize, in_dim: usize| -> Result<Linear> {
            let name = format!("layers.{i}.{field}");
            let l = read_linear(entry(&name)?, &f32s, &words)?;
            check_dims(&name, &l, out_dim, in_dim)?;
            Ok(l)
        };
        let attn_norm = densev(&format!("layers.{i}.attn_norm"))?;
        check_vec(&format!("layers.{i}.attn_norm"), &attn_norm)?;
        let mlp_norm = densev(&format!("layers.{i}.mlp_norm"))?;
        check_vec(&format!("layers.{i}.mlp_norm"), &mlp_norm)?;
        layers.push(LayerWeights {
            attn_norm,
            wq: lin("wq", d, d)?,
            wk: lin("wk", kvd, d)?,
            wv: lin("wv", kvd, d)?,
            wo: lin("wo", d, d)?,
            mlp_norm,
            w_gate: lin("w_gate", dff, d)?,
            w_up: lin("w_up", dff, d)?,
            w_down: lin("w_down", d, dff)?,
        });
    }
    let embed = read_dense(entry("embed")?, &f32s)?;
    if embed.shape() != [vocab, d].as_slice() {
        bail!("embed is {:?}, config expects [{vocab}, {d}]", embed.shape());
    }
    let final_norm = densev("final_norm")?;
    check_vec("final_norm", &final_norm)?;
    let lm_head = read_linear(entry("lm_head")?, &f32s, &words)?;
    check_dims("lm_head", &lm_head, vocab, d)?;
    Ok((
        Transformer {
            cfg: config,
            embed,
            layers,
            final_norm,
            lm_head,
            scheme,
        },
        provenance,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new(ModelConfig::test_tiny());
        ck.insert("a", init::gaussian(&[4, 8], 0.0, 1.0, &mut rng));
        ck.insert("b.c", init::gaussian(&[3], 0.0, 1.0, &mut rng));
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.amsz");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config, ck.config);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a").unwrap(), ck.get("a").unwrap());
        assert_eq!(back.get("b.c").unwrap(), ck.get("b.c").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let ck = Checkpoint::new(ModelConfig::test_tiny());
        assert!(ck.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.amsz");
        std::fs::write(&path, b"NOTAMSZ...").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // An AMSZ file is not an AMSQ file and vice versa.
        assert!(load_quantized(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// A truncated AMSQ payload must fail the load with a clean error —
    /// geometry is validated up front, never discovered as a panic (or
    /// silent garbage) at serve time.
    #[test]
    fn truncated_quantized_rejected_cleanly() {
        use crate::model::synthetic::synthetic_checkpoint;
        use crate::quant::{QuantConfig, Quantizer};
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 78);
        let base = Transformer::from_checkpoint(&ck).unwrap();
        let q = base
            .quantized_with(
                &Quantizer::uniform(QuantConfig::paper(Scheme::parse("fp4.25").unwrap())).unwrap(),
            )
            .unwrap();
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.amsq");
        save_quantized(&q, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() - 64, bytes.len() / 2] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(load_quantized(&path).is_err(), "cut at {cut} must error");
        }
        std::fs::remove_file(&path).ok();
    }

    /// Calibration provenance embedded at export survives the round trip
    /// verbatim, and plain exports report `None`.
    #[test]
    fn calibration_provenance_roundtrip() {
        use crate::model::synthetic::synthetic_checkpoint;
        use crate::quant::{QuantConfig, Quantizer};
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 79);
        let base = Transformer::from_checkpoint(&ck).unwrap();
        let q = base
            .quantized_with(
                &Quantizer::uniform(QuantConfig::paper(Scheme::parse("fp5.33").unwrap())).unwrap(),
            )
            .unwrap();
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prov.amsq");

        let mut prov = Json::obj();
        prov.set("budget_bits", Json::Num(5.0))
            .set("achieved_bits", Json::Num(4.98))
            .set("calib_tokens", Json::Num(4096.0))
            .set("seed", Json::Num(7.0));
        save_quantized_with(&q, &path, Some(&prov)).unwrap();
        let (back, meta) = load_quantized_meta(&path).unwrap();
        assert_eq!(meta.as_ref(), Some(&prov), "provenance survives verbatim");
        // The model itself is unaffected by the extra header field.
        let mut c1 = q.new_cache();
        let mut c2 = back.new_cache();
        assert_eq!(q.forward(3, 0, &mut c1), back.forward(3, 0, &mut c2));

        save_quantized(&q, &path).unwrap();
        let (_, meta) = load_quantized_meta(&path).unwrap();
        assert!(meta.is_none(), "plain exports carry no provenance");
        std::fs::remove_file(&path).ok();
    }

    /// Acceptance: a mixed-precision, per-group quantized model exports
    /// to AMSQ and reloads serving bit-identical logits — the packed
    /// words, row scales and group-scale streams all survive verbatim.
    #[test]
    fn quantized_export_import_exact() {
        use crate::model::synthetic::synthetic_checkpoint;
        use crate::quant::{Granularity, LayerRole, QuantConfig, QuantPlan, Quantizer};
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 77);
        let base = Transformer::from_checkpoint(&ck).unwrap();
        let plan = QuantPlan::builder(
            QuantConfig::paper(Scheme::parse("fp4.25").unwrap())
                .with_granularity(Granularity::PerGroup(32)),
        )
        .role(LayerRole::Attention, QuantConfig::paper(Scheme::parse("fp6").unwrap()))
        .role(LayerRole::LmHead, QuantConfig::paper(Scheme::parse("fp8").unwrap()))
        .build()
        .unwrap();
        let q = base.quantized_with(&Quantizer::new(plan)).unwrap();

        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.amsq");
        save_quantized(&q, &path).unwrap();
        let back = load_quantized(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(back.cfg, q.cfg);
        assert_eq!(back.scheme, q.scheme);
        // Mixed precision and group scales survived.
        match (&back.layers[0].wq, &back.layers[0].w_gate, &back.lm_head) {
            (Linear::Quant(wq), Linear::Quant(gate), Linear::Quant(head)) => {
                assert_eq!(wq.packed.scheme, Scheme::parse("fp6").unwrap());
                assert_eq!(gate.packed.scheme, Scheme::parse("fp4.25").unwrap());
                assert!(gate.packed.group_scales.is_some(), "per-group stream restored");
                assert_eq!(head.packed.scheme, Scheme::parse("fp8").unwrap());
            }
            _ => panic!("projections must reload packed"),
        }
        // Bit-identical serving.
        let mut c1 = q.new_cache();
        let mut c2 = back.new_cache();
        for (p, &t) in [1u32, 5, 9, 2].iter().enumerate() {
            let l1 = q.forward(t, p, &mut c1);
            let l2 = back.forward(t, p, &mut c2);
            assert_eq!(l1, l2, "pos {p}");
        }
    }
}
