//! AMSZ checkpoint container: a minimal self-describing tensor archive
//! shared between the JAX trainer (writer, see python/compile/ckpt_io.py)
//! and the rust engine (reader), plus a writer on the rust side for
//! synthetic models and quantized exports.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"AMSZ1\n"
//! u32    header_len
//! bytes  header JSON: {"config": {...},
//!                      "tensors": [{"name","shape":[..],"offset","count"}]}
//! bytes  f32 payload (offsets are element offsets into this region)
//! ```

use super::ModelConfig;
use crate::tensor::Tensor;
use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"AMSZ1\n";

/// In-memory checkpoint: named f32 tensors + model config.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new(config: ModelConfig) -> Checkpoint {
        Checkpoint {
            config,
            tensors: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            let mut e = Json::obj();
            e.set("name", Json::Str(name.clone()))
                .set(
                    "shape",
                    Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                )
                .set("offset", Json::Num(offset as f64))
                .set("count", Json::Num(t.len() as f64));
            entries.push(e);
            offset += t.len();
        }
        let mut header = Json::obj();
        header
            .set("config", self.config.to_json())
            .set("tensors", Json::Arr(entries));
        let hbytes = header.to_string().into_bytes();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(hbytes.len() as u32).to_le_bytes())?;
        f.write_all(&hbytes)?;
        for t in self.tensors.values() {
            for &x in t.data() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an AMSZ checkpoint", path.display());
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?).map_err(|e| anyhow::anyhow!("{e}"))?;
        let config = ModelConfig::from_json(
            header
                .get("config")
                .context("header missing 'config'")?,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;

        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        let floats: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut tensors = BTreeMap::new();
        for e in header
            .get("tensors")
            .and_then(|t| t.as_arr())
            .context("header missing 'tensors'")?
        {
            let name = e.req_str("name").map_err(|e| anyhow::anyhow!("{e}"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(|s| s.as_arr())
                .context("tensor missing shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = e.req_usize("offset").map_err(|e| anyhow::anyhow!("{e}"))?;
            let count = e.req_usize("count").map_err(|e| anyhow::anyhow!("{e}"))?;
            if offset + count > floats.len() {
                bail!("tensor '{name}' exceeds payload ({} floats)", floats.len());
            }
            tensors.insert(
                name.to_string(),
                Tensor::from_vec(&shape, floats[offset..offset + count].to_vec()),
            );
        }
        Ok(Checkpoint { config, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init;
    use crate::util::prng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new(ModelConfig::test_tiny());
        ck.insert("a", init::gaussian(&[4, 8], 0.0, 1.0, &mut rng));
        ck.insert("b.c", init::gaussian(&[3], 0.0, 1.0, &mut rng));
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.amsz");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config, ck.config);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a").unwrap(), ck.get("a").unwrap());
        assert_eq!(back.get("b.c").unwrap(), ck.get("b.c").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let ck = Checkpoint::new(ModelConfig::test_tiny());
        assert!(ck.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("ams_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.amsz");
        std::fs::write(&path, b"NOTAMSZ...").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
