//! Model substrate: a Qwen/Llama-style decoder-only transformer inference
//! engine whose linear layers run through the packed AMS kernels, plus the
//! checkpoint container, synthetic LLM-like weight generators, byte
//! tokenizer and sampler.
//!
//! The same architecture is implemented in JAX at `python/compile/model.py`
//! (build-time); `rust/tests/parity.rs` asserts logits parity on a shared
//! checkpoint.

pub mod checkpoint;
pub mod sampler;
pub mod synthetic;
pub mod tokenizer;
pub mod transformer;

use crate::util::json::{Json, JsonError};

/// Deterministic evaluation text used when no trained checkpoint exists
/// (same grammar family as python/compile/corpus.py).
pub fn synthetic_eval_text() -> String {
    let mut s = String::new();
    let objs = ["lamp", "door", "cube", "ring"];
    let cols = ["red", "blue", "green", "gold"];
    for i in 0..120 {
        let o = objs[i % objs.len()];
        let c = cols[(i * 7) % cols.len()];
        s.push_str(&format!("the {o} is {c}. "));
        if i % 3 == 0 {
            let motif = ['a', 'b', 'c', 'd'][i % 4];
            for _ in 0..6 {
                s.push(motif);
                s.push(((b'a' + (i % 26) as u8) as char).to_ascii_lowercase());
            }
            s.push(' ');
        }
    }
    s
}

/// Architecture hyperparameters (serialized into checkpoint headers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub const ROPE_THETA: f64 = 10_000.0;
    pub const NORM_EPS: f32 = 1e-5;

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Total parameter count (tied embedding counted once, lm_head untied).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = 2 * d // norms
            + d * d // wq
            + 2 * self.kv_dim() * d // wk, wv
            + d * d // wo
            + 3 * self.d_ff * d; // gate, up, down
        self.vocab_size * d // embed
            + self.n_layers * per_layer
            + d // final norm
            + self.vocab_size * d // lm_head
    }

    /// A ~tiny config for unit tests.
    pub fn test_tiny() -> ModelConfig {
        ModelConfig {
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 64,
            max_seq: 64,
        }
    }

    /// The build-time-trained char LM (see python/compile/train_lm.py).
    pub fn tiny_lm() -> ModelConfig {
        ModelConfig {
            vocab_size: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 344,
            max_seq: 256,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("vocab_size", Json::Num(self.vocab_size as f64))
            .set("d_model", Json::Num(self.d_model as f64))
            .set("n_layers", Json::Num(self.n_layers as f64))
            .set("n_heads", Json::Num(self.n_heads as f64))
            .set("n_kv_heads", Json::Num(self.n_kv_heads as f64))
            .set("d_ff", Json::Num(self.d_ff as f64))
            .set("max_seq", Json::Num(self.max_seq as f64));
        o
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig, JsonError> {
        Ok(ModelConfig {
            vocab_size: j.req_usize("vocab_size")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            n_kv_heads: j.req_usize("n_kv_heads")?,
            d_ff: j.req_usize("d_ff")?,
            max_seq: j.req_usize("max_seq")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_json_roundtrip() {
        let c = ModelConfig::tiny_lm();
        let j = c.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn head_dims() {
        let c = ModelConfig::test_tiny();
        assert_eq!(c.head_dim(), 8);
        assert_eq!(c.kv_dim(), 16);
    }

    #[test]
    fn param_count_sane() {
        let c = ModelConfig::tiny_lm();
        // ~1.5M params for the tiny LM.
        let p = c.param_count();
        assert!(p > 700_000 && p < 3_000_000, "params={p}");
    }
}
