//! Token samplers over logits: greedy, temperature, top-k.

use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    Greedy,
    Temperature(f32),
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    /// Deterministic argmax decoding? The speculative scheduler only
    /// runs draft/verify rounds for greedy sequences — token identity
    /// between speculative and plain decoding holds under argmax only.
    pub fn is_greedy(&self) -> bool {
        matches!(self, Sampler::Greedy)
    }

    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::Temperature(t) => {
                let probs = softmax_t(logits, t);
                rng.categorical(&probs) as u32
            }
            Sampler::TopK { k, temperature } => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k.max(1));
                let top: Vec<f32> = idx.iter().map(|&i| logits[i]).collect();
                let probs = softmax_t(&top, temperature);
                idx[rng.categorical(&probs)] as u32
            }
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn softmax_t(logits: &[f32], t: f32) -> Vec<f32> {
    let t = t.max(1e-4);
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - m) / t).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.5, -1.0, 2.4];
        let mut rng = Rng::new(1);
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![0.0, 5.0, 1.0];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            assert_eq!(Sampler::Temperature(0.01).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn topk_restricts_support() {
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let t = Sampler::TopK {
                k: 2,
                temperature: 1.0,
            }
            .sample(&logits, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = vec![1.0, 1.0, 1.0];
        let mut rng = Rng::new(4);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[Sampler::Temperature(1.0).sample(&logits, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
