//! Synthetic LLM-like weights (substitution for the paper's Llama/Qwen
//! checkpoints — DESIGN.md §2).
//!
//! Figure 2b of the paper shows per-layer weight distributions: bell-shaped,
//! heavier-tailed than Gaussian, with a small set of input channels whose
//! magnitudes are systematically larger (the channel-wise outlier structure
//! that motivates input-dim mantissa sharing). We generate exactly that
//! family: a Gaussian/Laplace mixture with per-input-channel outlier gains.

use super::checkpoint::Checkpoint;
use super::ModelConfig;
use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// Parameters of the synthetic weight family.
#[derive(Clone, Copy, Debug)]
pub struct WeightProfile {
    /// Base standard deviation (LLM layers are typically ~N(0, 0.02²)).
    pub sigma: f32,
    /// Fraction of values drawn from the heavier Laplace tail.
    pub laplace_frac: f64,
    /// Fraction of input channels that are outliers.
    pub outlier_frac: f64,
    /// Magnitude gain of outlier channels.
    pub outlier_gain: f32,
}

impl Default for WeightProfile {
    fn default() -> Self {
        WeightProfile {
            sigma: 0.02,
            laplace_frac: 0.1,
            outlier_frac: 0.01,
            outlier_gain: 8.0,
        }
    }
}

/// Generate one `[out_channels, in_channels]` weight matrix.
pub fn llm_weight(rows: usize, cols: usize, profile: &WeightProfile, rng: &mut Rng) -> Tensor {
    // Choose outlier input channels once per matrix (channel-wise pattern).
    let n_out = ((cols as f64 * profile.outlier_frac).round() as usize).min(cols);
    let mut gain = vec![1.0f32; cols];
    for _ in 0..n_out {
        let c = rng.range(0, cols);
        gain[c] = profile.outlier_gain;
    }
    let mut data = Vec::with_capacity(rows * cols);
    for _ in 0..rows {
        for g in gain.iter().take(cols) {
            let base = if rng.uniform() < profile.laplace_frac {
                rng.laplace(profile.sigma as f64 / std::f64::consts::SQRT_2) as f32
            } else {
                rng.normal_f32(0.0, profile.sigma)
            };
            data.push(base * g);
        }
    }
    Tensor::from_vec(&[rows, cols], data)
}

/// Random init of a full model checkpoint (used for serving benches and
/// engine tests; the *trained* tiny LM comes from python/compile/train_lm.py).
pub fn synthetic_checkpoint(cfg: &ModelConfig, seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let profile = WeightProfile::default();
    let d = cfg.d_model;
    let mut ck = Checkpoint::new(*cfg);
    // Scaled-down init so activations stay sane through depth.
    let scale = |t: Tensor, s: f32| t.scale(s);
    ck.insert(
        "embed",
        scale(llm_weight(cfg.vocab_size, d, &profile, &mut rng), 1.0),
    );
    for i in 0..cfg.n_layers {
        let ones = Tensor::from_vec(&[d], vec![1.0; d]);
        ck.insert(&format!("layers.{i}.attn_norm"), ones.clone());
        ck.insert(&format!("layers.{i}.mlp_norm"), ones);
        ck.insert(
            &format!("layers.{i}.wq"),
            llm_weight(d, d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.wk"),
            llm_weight(cfg.kv_dim(), d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.wv"),
            llm_weight(cfg.kv_dim(), d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.wo"),
            llm_weight(d, d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.w_gate"),
            llm_weight(cfg.d_ff, d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.w_up"),
            llm_weight(cfg.d_ff, d, &profile, &mut rng),
        );
        ck.insert(
            &format!("layers.{i}.w_down"),
            llm_weight(d, cfg.d_ff, &profile, &mut rng),
        );
    }
    ck.insert("final_norm", Tensor::from_vec(&[d], vec![1.0; d]));
    ck.insert("lm_head", llm_weight(cfg.vocab_size, d, &profile, &mut rng));
    ck
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bell_shaped_moments() {
        let mut rng = Rng::new(1);
        let w = llm_weight(256, 512, &WeightProfile::default(), &mut rng);
        let mean = w.mean();
        assert!(mean.abs() < 1e-3, "mean={mean}");
        // Excess kurtosis > 0 (heavier than Gaussian due to outliers+Laplace).
        let var = w
            .data()
            .iter()
            .map(|&x| (x as f64 - mean).powi(2))
            .sum::<f64>()
            / w.len() as f64;
        let kurt = w
            .data()
            .iter()
            .map(|&x| (x as f64 - mean).powi(4))
            .sum::<f64>()
            / (w.len() as f64 * var * var);
        assert!(kurt > 3.2, "kurtosis={kurt} not heavy-tailed");
    }

    #[test]
    fn outlier_channels_exist() {
        let mut rng = Rng::new(2);
        let profile = WeightProfile {
            outlier_frac: 0.05,
            ..WeightProfile::default()
        };
        let w = llm_weight(128, 200, &profile, &mut rng);
        // Column amax distribution should have a clear high tail.
        let mut col_amax = vec![0f32; 200];
        for r in 0..128 {
            for (c, m) in col_amax.iter_mut().enumerate() {
                *m = m.max(w.at2(r, c).abs());
            }
        }
        col_amax.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = col_amax[100];
        let top = col_amax[199];
        assert!(top > 3.0 * med, "top={top} med={med}");
    }

    #[test]
    fn checkpoint_complete() {
        let cfg = ModelConfig::test_tiny();
        let ck = synthetic_checkpoint(&cfg, 3);
        // 2 norms + 7 projections per layer + embed + final_norm + lm_head.
        assert_eq!(ck.tensors.len(), cfg.n_layers * 9 + 3);
        assert_eq!(ck.get("embed").unwrap().shape(), &[64, 32]);
        assert_eq!(ck.get("layers.1.w_down").unwrap().shape(), &[32, 64]);
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = ModelConfig::test_tiny();
        let a = synthetic_checkpoint(&cfg, 7);
        let b = synthetic_checkpoint(&cfg, 7);
        assert_eq!(a.get("layers.0.wq").unwrap(), b.get("layers.0.wq").unwrap());
    }
}
