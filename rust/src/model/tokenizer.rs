//! Byte-level tokenizer: token id = byte value. Vocab 256 matches the
//! build-time char LM; no merges, fully reversible.

/// Encode text into byte token ids.
pub fn encode(text: &str) -> Vec<u32> {
    text.as_bytes().iter().map(|&b| u32::from(b)).collect()
}

/// Decode token ids back into text (lossy on invalid UTF-8).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

pub const VOCAB_SIZE: usize = 256;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "hello AMS-Quant 4.25!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn ids_in_vocab() {
        assert!(encode("äöü→").iter().all(|&t| (t as usize) < VOCAB_SIZE));
    }

    #[test]
    fn empty() {
        assert_eq!(encode(""), Vec::<u32>::new());
        assert_eq!(decode(&[]), "");
    }
}
