//! Decoder-only transformer inference engine (Qwen-style: RMSNorm, RoPE,
//! GQA attention, SwiGLU MLP) whose seven per-layer projections run through
//! the packed AMS GEMV/GEMM kernels.
//!
//! Single-token decode (`forward`) and batched decode across independent
//! sequences (`forward_batch`) — the latter is the workload of Table 3:
//! the linear layers see a `[batch, d]` GEMM while attention stays
//! per-sequence against its own KV cache.
//!
//! The `*_with` variants take a caller-owned [`ForwardScratch`] (create
//! one per `Transformer` user — scheduler, bench loop, worker thread) and
//! perform zero heap allocation at steady state; large projections are
//! dispatched onto the shared thread pool automatically (see
//! [`crate::gemm::QuantLinear::gemm_auto_into`]).

use super::checkpoint::Checkpoint;
use super::ModelConfig;
use crate::formats::registry::Scheme;
use crate::gemm::{dense_gemm_auto_into, dense_gemv_auto, DecodePrecision, GemmScratch, QuantLinear};
use crate::quant::{LayerRole, QuantConfig, QuantError, QuantReport, Quantizer};
use crate::tensor::Tensor;
use crate::kv::{AsKvStore, KvStore};
use anyhow::Result;

/// A projection: dense f32 (FP16-reference path) or packed-quantized.
#[derive(Clone, Debug)]
pub enum Linear {
    Dense(Tensor),
    Quant(QuantLinear),
}

impl Linear {
    pub fn out_dim(&self) -> usize {
        match self {
            Linear::Dense(t) => t.rows(),
            Linear::Quant(q) => q.rows(),
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            Linear::Dense(t) => t.cols(),
            Linear::Quant(q) => q.cols(),
        }
    }

    /// `y = W x`. Allocates a transient scratch for the quantized path;
    /// hot loops use [`Linear::apply_with`].
    pub fn apply(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Linear::Dense(w) => dense_gemv_auto(w, x, y),
            Linear::Quant(q) => q.gemv(x, y),
        }
    }

    /// Zero-alloc `y = W x` against a caller-owned scratch. Large
    /// projections — packed *and* dense-reference — self-dispatch onto the
    /// shared pool, so baseline numbers at high thread counts stay fair.
    pub fn apply_with(&self, x: &[f32], y: &mut [f32], scratch: &mut GemmScratch) {
        match self {
            Linear::Dense(w) => dense_gemv_auto(w, x, y),
            Linear::Quant(q) => q.gemv_auto(x, y, scratch),
        }
    }

    /// `Y[batch, out] = X[batch, in] Wᵀ` (allocating convenience wrapper).
    pub fn apply_batch(&self, x: &Tensor) -> Tensor {
        let mut scratch = GemmScratch::new();
        let mut y = Tensor::zeros(&[x.rows(), self.out_dim()]);
        self.apply_batch_into(x, &mut y, &mut scratch);
        y
    }

    /// Zero-alloc batched apply: re-shapes `y` to `[batch, out]` in place
    /// and runs the tiled fused kernels (packed) or the register-tiled
    /// dense kernel (FP16-reference baseline).
    pub fn apply_batch_into(&self, x: &Tensor, y: &mut Tensor, scratch: &mut GemmScratch) {
        self.apply_batch_prec_into(x, y, scratch, DecodePrecision::Full);
    }

    /// [`Linear::apply_batch_into`] with a decode-precision request: the
    /// speculative draft forward asks for [`DecodePrecision::HiOnly`],
    /// which segmented packed layouts serve by streaming only the hi
    /// mantissa words (see [`QuantLinear::gemm_prec_into`]). Dense
    /// projections have no hi/lo split and ignore `prec`; packed layouts
    /// without a split fall back to full decode.
    pub fn apply_batch_prec_into(
        &self,
        x: &Tensor,
        y: &mut Tensor,
        scratch: &mut GemmScratch,
        prec: DecodePrecision,
    ) {
        y.resize(&[x.rows(), self.out_dim()]);
        match self {
            Linear::Dense(w) => dense_gemm_auto_into(w, x, y, scratch),
            Linear::Quant(q) => q.gemm_prec_into(x, y, scratch, prec),
        }
    }

    /// Storage bytes of the weight payload.
    pub fn payload_bytes(&self) -> usize {
        match self {
            Linear::Dense(t) => t.len() * 2, // counted as fp16 storage
            Linear::Quant(q) => q.packed.payload_bytes(),
        }
    }

    /// Storage bytes of the f32 scale streams (0 for dense).
    pub fn scale_bytes(&self) -> usize {
        match self {
            Linear::Dense(_) => 0,
            Linear::Quant(q) => q.packed.scale_bytes(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Linear,
    pub w_up: Linear,
    pub w_down: Linear,
}

/// Per-sequence contiguous KV cache, sized worst-case at construction
/// (`max_seq` positions per layer). The serve path uses the paged
/// [`crate::kv::PagedKvCache`] instead; this stays as the
/// zero-bookkeeping backing for single-sequence tools (eval, calib,
/// benches) and as the reference side of the paged parity suite — both
/// implement [`KvStore`], so every `forward*` runs over either.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// Per layer: [max_seq * kv_dim].
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pub len: usize,
    kv_dim: usize,
}

impl KvCache {
    /// Fully initialized from the config — `kv_dim` included, so a
    /// cache built here works with the forwards directly (no
    /// post-construction patching by `new_cache`).
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: vec![vec![0.0; cfg.max_seq * cfg.kv_dim()]; cfg.n_layers],
            v: vec![vec![0.0; cfg.max_seq * cfg.kv_dim()]; cfg.n_layers],
            len: 0,
            kv_dim: cfg.kv_dim(),
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        &self.k[layer][pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        &self.v[layer][pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    fn k_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        &mut self.k[layer][pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    fn v_row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        &mut self.v[layer][pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }
}

impl AsKvStore for KvCache {
    type Store = KvCache;
    fn kv(&self) -> &KvCache {
        self
    }
    fn kv_mut(&mut self) -> &mut KvCache {
        self
    }
}

/// Reusable per-worker buffers for the decode paths. Create once per
/// `Transformer` user; every buffer grows to its high-water mark on first
/// use and the forward loops allocate nothing afterwards.
#[derive(Clone, Debug)]
pub struct ForwardScratch {
    gemm: GemmScratch,
    h: Vec<f32>,
    scores: Vec<f32>,
    logits: Vec<f32>,
    qi: Vec<f32>,
    /// Per staged row: (cache index, write position).
    slots: Vec<(usize, usize)>,
    xb: Tensor,
    hb: Tensor,
    qb: Tensor,
    kxb: Tensor,
    vxb: Tensor,
    attnb: Tensor,
    ob: Tensor,
    gateb: Tensor,
    upb: Tensor,
    actb: Tensor,
    downb: Tensor,
    logitsb: Tensor,
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        let empty = || Tensor::zeros(&[0, 0]);
        ForwardScratch {
            gemm: GemmScratch::new(),
            h: Vec::new(),
            scores: Vec::new(),
            logits: Vec::new(),
            qi: Vec::new(),
            slots: Vec::new(),
            xb: empty(),
            hb: empty(),
            qb: empty(),
            kxb: empty(),
            vxb: empty(),
            attnb: empty(),
            ob: empty(),
            gateb: empty(),
            upb: empty(),
            actb: empty(),
            downb: empty(),
            logitsb: empty(),
        }
    }
}

impl Default for ForwardScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Re-size a scratch vector to `n` zeros without shrinking capacity.
#[inline]
fn ensure(v: &mut Vec<f32>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

/// The [`ForwardScratch`] buffers one decoder layer needs, borrowed as a
/// bundle so [`Transformer::layer_body`] can be the single copy of the
/// rmsnorm → QKV → attend → SwiGLU sequence shared by every `forward*`
/// variant (single-token, batched decode, prefill, draft, verify).
struct LayerBufs<'a> {
    gemm: &'a mut GemmScratch,
    scores: &'a mut Vec<f32>,
    qi: &'a mut Vec<f32>,
    hb: &'a mut Tensor,
    qb: &'a mut Tensor,
    kxb: &'a mut Tensor,
    vxb: &'a mut Tensor,
    attnb: &'a mut Tensor,
    ob: &'a mut Tensor,
    gateb: &'a mut Tensor,
    upb: &'a mut Tensor,
    actb: &'a mut Tensor,
    downb: &'a mut Tensor,
}

#[derive(Clone, Debug)]
pub struct Transformer {
    pub cfg: ModelConfig,
    pub embed: Tensor, // [vocab, d]
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Linear,
    /// Scheme the projections are stored in (None = dense reference).
    pub scheme: Option<Scheme>,
}

fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + ModelConfig::NORM_EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

/// NeoX-style rotary embedding applied in place to one head vector.
fn rope(v: &mut [f32], pos: usize, head_dim: usize) {
    let half = head_dim / 2;
    for i in 0..half {
        let freq = (ModelConfig::ROPE_THETA as f32).powf(-2.0 * i as f32 / head_dim as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (v[i], v[i + half]);
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// Rope every K head of one freshly written cache row in place. RoPE
/// depends only on the absolute position, which is what makes
/// prefix-shared KV pages valid across sequences.
fn rope_k<S: KvStore + ?Sized>(cache: &mut S, li: usize, pos: usize, n_kv_heads: usize, hd: usize) {
    let kr = cache.k_row_mut(li, pos);
    for g in 0..n_kv_heads {
        rope(&mut kr[g * hd..(g + 1) * hd], pos, hd);
    }
}

/// One query's attention over the cache prefix `0..=pos`, reading K/V
/// through the [`KvStore`] row accessor. Every forward variant —
/// single-token, batched decode, and the prefill family — funnels its
/// attention through this one body, so paged and contiguous caches see
/// the identical float sequence and logits stay bit-identical across
/// backings (the GEMM staging around it differs per variant; the
/// per-position math does not).
#[allow(clippy::too_many_arguments)]
fn attend<S: KvStore + ?Sized>(
    cache: &S,
    li: usize,
    pos: usize,
    n_heads: usize,
    n_kv_heads: usize,
    hd: usize,
    q: &[f32],
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    let heads_per_kv = n_heads / n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    ensure(scores, pos + 1);
    for hh in 0..n_heads {
        let g = hh / heads_per_kv;
        let qh = &q[hh * hd..(hh + 1) * hd];
        for (t, s) in scores.iter_mut().enumerate() {
            let kh = &cache.k_row(li, t)[g * hd..(g + 1) * hd];
            *s = qh.iter().zip(kh).map(|(&a, &b)| a * b).sum::<f32>() * scale;
        }
        softmax_inplace(scores);
        let oh = &mut out[hh * hd..(hh + 1) * hd];
        oh.fill(0.0);
        for (t, &p) in scores.iter().enumerate() {
            let vh = &cache.v_row(li, t)[g * hd..(g + 1) * hd];
            for i in 0..hd {
                oh[i] += p * vh[i];
            }
        }
    }
}

impl Transformer {
    /// Load a dense (reference) model from a checkpoint.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<Transformer> {
        let cfg = ck.config;
        let lin = |name: &str| -> Result<Linear> { Ok(Linear::Dense(ck.get(name)?.clone())) };
        let vecf = |name: &str| -> Result<Vec<f32>> { Ok(ck.get(name)?.data().to_vec()) };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            layers.push(LayerWeights {
                attn_norm: vecf(&format!("layers.{i}.attn_norm"))?,
                wq: lin(&format!("layers.{i}.wq"))?,
                wk: lin(&format!("layers.{i}.wk"))?,
                wv: lin(&format!("layers.{i}.wv"))?,
                wo: lin(&format!("layers.{i}.wo"))?,
                mlp_norm: vecf(&format!("layers.{i}.mlp_norm"))?,
                w_gate: lin(&format!("layers.{i}.w_gate"))?,
                w_up: lin(&format!("layers.{i}.w_up"))?,
                w_down: lin(&format!("layers.{i}.w_down"))?,
            });
        }
        Ok(Transformer {
            cfg,
            embed: ck.get("embed")?.clone(),
            layers,
            final_norm: vecf("final_norm")?,
            lm_head: lin("lm_head")?,
            scheme: None,
        })
    }

    /// Uniform quantization convenience: every projection under one
    /// config (see [`Transformer::quantized_with`] for mixed precision).
    pub fn quantized(&self, qcfg: &QuantConfig) -> Result<Transformer, QuantError> {
        self.quantized_with(&Quantizer::uniform(*qcfg)?)
    }

    /// Quantize every projection (wq/wk/wv/wo/gate/up/down) under a
    /// per-layer [`QuantPlan`](crate::quant::QuantPlan) — the offline
    /// "quantize once, serve millions" step. Embeddings and norms stay
    /// dense, as in weight-only LLM deployments (they are a small
    /// fraction of the weights); the lm_head also stays dense unless the
    /// plan explicitly targets [`LayerRole::LmHead`] (or the exact layer
    /// name `lm_head`).
    pub fn quantized_with(&self, quantizer: &Quantizer) -> Result<Transformer, QuantError> {
        self.quantized_inner(quantizer, None)
    }

    /// Like [`Transformer::quantized_with`], additionally returning the
    /// per-layer [`QuantReport`]s (bits/weight, MSE, SQNR, chosen shared
    /// bits) the offline adaptive-search workflow inspects. Building the
    /// reports costs an extra reconstruction pass per projection;
    /// [`Transformer::quantized_with`] skips it.
    pub fn quantized_report(
        &self,
        quantizer: &Quantizer,
    ) -> Result<(Transformer, Vec<QuantReport>), QuantError> {
        let mut reports = Vec::new();
        let model = self.quantized_inner(quantizer, Some(&mut reports))?;
        Ok((model, reports))
    }

    fn quantized_inner(
        &self,
        quantizer: &Quantizer,
        mut reports: Option<&mut Vec<QuantReport>>,
    ) -> Result<Transformer, QuantError> {
        // Every exact-name override must name a real projection — a typo
        // in a plan must not silently fall back to the default config.
        for name in quantizer.plan().layer_names() {
            let known = name == "lm_head"
                || name
                    .strip_prefix("layers.")
                    .and_then(|rest| rest.split_once('.'))
                    .map(|(i, field)| {
                        i.parse::<usize>().map(|i| i < self.layers.len()).unwrap_or(false)
                            && matches!(
                                field,
                                "wq" | "wk" | "wv" | "wo" | "w_gate" | "w_up" | "w_down"
                            )
                    })
                    .unwrap_or(false);
            if !known {
                return Err(QuantError::UnknownLayer { layer: name.to_string() });
            }
        }
        let mut requant = |name: String, role: LayerRole, l: &Linear| -> Result<Linear, QuantError> {
            let w = match l {
                Linear::Dense(t) => t,
                Linear::Quant(_) => return Err(QuantError::SourceNotDense { layer: name }),
            };
            let packed = match reports.as_deref_mut() {
                Some(reps) => {
                    let (packed, report) = quantizer.quantize_layer(&name, role, w)?;
                    reps.push(report);
                    packed
                }
                None => quantizer.quantize_for(&name, role, w)?,
            };
            Ok(Linear::Quant(QuantLinear::new(packed)))
        };
        let mut layers = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            layers.push(LayerWeights {
                attn_norm: l.attn_norm.clone(),
                wq: requant(format!("layers.{i}.wq"), LayerRole::Attention, &l.wq)?,
                wk: requant(format!("layers.{i}.wk"), LayerRole::Attention, &l.wk)?,
                wv: requant(format!("layers.{i}.wv"), LayerRole::Attention, &l.wv)?,
                wo: requant(format!("layers.{i}.wo"), LayerRole::Attention, &l.wo)?,
                mlp_norm: l.mlp_norm.clone(),
                w_gate: requant(format!("layers.{i}.w_gate"), LayerRole::Mlp, &l.w_gate)?,
                w_up: requant(format!("layers.{i}.w_up"), LayerRole::Mlp, &l.w_up)?,
                w_down: requant(format!("layers.{i}.w_down"), LayerRole::Mlp, &l.w_down)?,
            });
        }
        let lm_head = if quantizer.plan().has_role(LayerRole::LmHead) {
            requant("lm_head".to_string(), LayerRole::LmHead, &self.lm_head)?
        } else {
            self.lm_head.clone()
        };
        Ok(Transformer {
            cfg: self.cfg,
            embed: self.embed.clone(),
            layers,
            final_norm: self.final_norm.clone(),
            lm_head,
            scheme: Some(quantizer.plan().default_config().scheme),
        })
    }

    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.cfg)
    }

    /// Fresh decode scratch sized lazily by first use.
    pub fn new_scratch(&self) -> ForwardScratch {
        ForwardScratch::new()
    }

    /// Projection weight bytes (the quantity the paper's speedup divides).
    pub fn projection_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.payload_bytes()
                    + l.wk.payload_bytes()
                    + l.wv.payload_bytes()
                    + l.wo.payload_bytes()
                    + l.w_gate.payload_bytes()
                    + l.w_up.payload_bytes()
                    + l.w_down.payload_bytes()
            })
            .sum()
    }

    /// Projection scale-stream bytes (excluded from
    /// [`Transformer::projection_bytes`]; material for per-group scales
    /// — `32/g` bits/weight — so size reporting adds it explicitly).
    pub fn projection_scale_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.scale_bytes()
                    + l.wk.scale_bytes()
                    + l.wv.scale_bytes()
                    + l.wo.scale_bytes()
                    + l.w_gate.scale_bytes()
                    + l.w_up.scale_bytes()
                    + l.w_down.scale_bytes()
            })
            .sum()
    }

    /// Single-token decode step: returns logits. `pos` must equal
    /// `cache.len`. Allocating convenience wrapper over
    /// [`Transformer::forward_with`].
    pub fn forward<C: AsKvStore>(&self, token: u32, pos: usize, cache: &mut C) -> Vec<f32> {
        let mut scratch = ForwardScratch::new();
        self.forward_with(token, pos, cache, &mut scratch).to_vec()
    }

    /// Single-token decode step against a caller-owned scratch; the
    /// returned logits borrow the scratch. Zero heap allocation at steady
    /// state. Runs over any [`KvStore`] backing (contiguous or paged).
    pub fn forward_with<'s, C: AsKvStore>(
        &self,
        token: u32,
        pos: usize,
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
    ) -> &'s [f32] {
        assert_eq!(pos, cache.kv().len(), "positions must be fed in order");
        self.decode_inner(&[token], std::slice::from_mut(cache), scratch, DecodePrecision::Full)
            .row(0)
    }

    /// Single-token *draft* decode: same math as
    /// [`Transformer::forward_with`] but every projection runs at
    /// [`DecodePrecision::HiOnly`] — segmented layouts stream only their
    /// hi mantissa words (~half the weight traffic), everything else
    /// falls back to full decode. The KV row written at `pos` is
    /// draft-quality; the speculative controller overwrites it with
    /// full-precision values during the verify pass before it can leak
    /// into committed state.
    pub fn forward_draft_with<'s, C: AsKvStore>(
        &self,
        token: u32,
        pos: usize,
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
    ) -> &'s [f32] {
        assert_eq!(pos, cache.kv().len(), "positions must be fed in order");
        self.decode_inner(&[token], std::slice::from_mut(cache), scratch, DecodePrecision::HiOnly)
            .row(0)
    }

    /// One decoder layer over `n` staged rows: rmsnorm → QKV → KV write +
    /// rope → attend → wo + residual → rmsnorm → SwiGLU → down +
    /// residual. `slots[i] = (cache index, position)` assigns row `i` its
    /// KV slot. Every row's K/V is written (and roped) before any row
    /// attends: decode rows live in disjoint caches, prefill/verify rows
    /// are consecutive positions of one cache — causal either way, and it
    /// is what lets the verify pass overwrite draft-quality KV rows
    /// before attention can read them.
    #[allow(clippy::too_many_arguments)]
    fn layer_body<C: AsKvStore>(
        &self,
        li: usize,
        layer: &LayerWeights,
        prec: DecodePrecision,
        caches: &mut [C],
        slots: &[(usize, usize)],
        xb: &mut Tensor,
        bufs: &mut LayerBufs<'_>,
        mut taps: Option<&mut crate::calib::stats::ModelTaps>,
    ) {
        let cfg = &self.cfg;
        let (d, hd) = (cfg.d_model, cfg.head_dim());
        let n = xb.rows();
        debug_assert_eq!(slots.len(), n);
        bufs.hb.resize(&[n, d]);
        for i in 0..n {
            rmsnorm(xb.row(i), &layer.attn_norm, bufs.hb.row_mut(i));
        }
        if let Some(t) = taps.as_deref_mut() {
            t.layers[li].attn_in.record_rows(bufs.hb);
        }
        layer.wq.apply_batch_prec_into(bufs.hb, bufs.qb, bufs.gemm, prec); // [n, d]
        layer.wk.apply_batch_prec_into(bufs.hb, bufs.kxb, bufs.gemm, prec); // [n, kvd]
        layer.wv.apply_batch_prec_into(bufs.hb, bufs.vxb, bufs.gemm, prec);
        for (i, &(ci, pos)) in slots.iter().enumerate() {
            let kv = caches[ci].kv_mut();
            kv.k_row_mut(li, pos).copy_from_slice(bufs.kxb.row(i));
            kv.v_row_mut(li, pos).copy_from_slice(bufs.vxb.row(i));
            rope_k(kv, li, pos, cfg.n_kv_heads, hd);
        }
        bufs.attnb.resize(&[n, d]);
        for (i, &(ci, pos)) in slots.iter().enumerate() {
            bufs.qi.clear();
            bufs.qi.extend_from_slice(bufs.qb.row(i));
            for hh in 0..cfg.n_heads {
                rope(&mut bufs.qi[hh * hd..(hh + 1) * hd], pos, hd);
            }
            attend(
                caches[ci].kv(),
                li,
                pos,
                cfg.n_heads,
                cfg.n_kv_heads,
                hd,
                bufs.qi,
                bufs.attnb.row_mut(i),
                bufs.scores,
            );
        }
        if let Some(t) = taps.as_deref_mut() {
            t.layers[li].attn_out.record_rows(bufs.attnb);
        }
        layer.wo.apply_batch_prec_into(bufs.attnb, bufs.ob, bufs.gemm, prec);
        for i in 0..n {
            let xr = xb.row_mut(i);
            for (j, &v) in bufs.ob.row(i).iter().enumerate() {
                xr[j] += v;
            }
        }
        for i in 0..n {
            rmsnorm(xb.row(i), &layer.mlp_norm, bufs.hb.row_mut(i));
        }
        if let Some(t) = taps.as_deref_mut() {
            t.layers[li].mlp_in.record_rows(bufs.hb);
        }
        layer.w_gate.apply_batch_prec_into(bufs.hb, bufs.gateb, bufs.gemm, prec);
        layer.w_up.apply_batch_prec_into(bufs.hb, bufs.upb, bufs.gemm, prec);
        bufs.actb.resize(&[n, cfg.d_ff]);
        for i in 0..n {
            let ar = bufs.actb.row_mut(i);
            let gr = bufs.gateb.row(i);
            let ur = bufs.upb.row(i);
            for j in 0..cfg.d_ff {
                ar[j] = silu(gr[j]) * ur[j];
            }
        }
        if let Some(t) = taps.as_deref_mut() {
            t.layers[li].mlp_act.record_rows(bufs.actb);
        }
        layer.w_down.apply_batch_prec_into(bufs.actb, bufs.downb, bufs.gemm, prec);
        for i in 0..n {
            let xr = xb.row_mut(i);
            for (j, &v) in bufs.downb.row(i).iter().enumerate() {
                xr[j] += v;
            }
        }
    }

    /// Shared decode driver: appends one token per cache (row `i` →
    /// `caches[i]` at its current length) and returns `[batch, vocab]`
    /// logits borrowing the scratch.
    fn decode_inner<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        caches: &mut [C],
        scratch: &'s mut ForwardScratch,
        prec: DecodePrecision,
    ) -> &'s Tensor {
        let b = tokens.len();
        assert_eq!(b, caches.len());
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let ForwardScratch {
            gemm,
            scores,
            qi,
            slots,
            xb,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
            logitsb,
            ..
        } = scratch;

        slots.clear();
        for (i, c) in caches.iter().enumerate() {
            let pos = c.kv().len();
            assert!(pos < cfg.max_seq, "sequence overflow");
            slots.push((i, pos));
        }
        xb.resize(&[b, d]);
        for (i, &t) in tokens.iter().enumerate() {
            xb.row_mut(i).copy_from_slice(self.embed.row(t as usize));
        }
        let mut bufs = LayerBufs {
            gemm,
            scores,
            qi,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
        };
        for (li, layer) in self.layers.iter().enumerate() {
            self.layer_body(li, layer, prec, caches, slots, xb, &mut bufs, None);
        }
        for c in caches.iter_mut() {
            let kv = c.kv_mut();
            let len = kv.len();
            kv.set_len(len + 1);
        }
        for i in 0..b {
            bufs.qi.clear();
            bufs.qi.extend_from_slice(xb.row(i));
            rmsnorm(bufs.qi, &self.final_norm, xb.row_mut(i));
        }
        self.lm_head.apply_batch_prec_into(xb, logitsb, bufs.gemm, prec);
        logitsb
    }

    /// Batched decode across independent sequences (allocating wrapper
    /// over [`Transformer::forward_batch_with`]): `tokens[i]` is appended
    /// to `caches[i]` at its own position.
    pub fn forward_batch<C: AsKvStore>(&self, tokens: &[u32], caches: &mut [C]) -> Tensor {
        let mut scratch = ForwardScratch::new();
        self.forward_batch_with(tokens, caches, &mut scratch).clone()
    }

    /// Batched decode against a caller-owned scratch; the returned logits
    /// `[batch, vocab]` borrow the scratch. Linear layers run as one
    /// `[batch, ·]` tiled fused GEMM; attention runs per sequence. Zero
    /// heap allocation at steady state (the caches are mutated in place —
    /// no per-step cache churn).
    pub fn forward_batch_with<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        caches: &mut [C],
        scratch: &'s mut ForwardScratch,
    ) -> &'s Tensor {
        self.decode_inner(tokens, caches, scratch, DecodePrecision::Full)
    }

    /// Chunked prefill (allocating wrapper over
    /// [`Transformer::forward_prefill_with`]).
    pub fn forward_prefill<C: AsKvStore>(&self, tokens: &[u32], cache: &mut C) -> Vec<f32> {
        let mut scratch = ForwardScratch::new();
        self.forward_prefill_with(tokens, cache, &mut scratch).to_vec()
    }

    /// Chunked prefill: append `tokens` (a prompt, or a chunk of one) to a
    /// single sequence's cache in one pass. Every projection sees one
    /// `[n, ·]` GEMM through the tiled fused kernels instead of `n` GEMVs;
    /// attention is causal inside the chunk and attends the cache prefix.
    /// Returns logits for the last position only (all prefill needs: one
    /// lm_head GEMV instead of an `[n, vocab]` GEMM) — equal to feeding
    /// the tokens one at a time through [`Transformer::forward_with`]:
    /// the tile kernels accumulate each output column in the same order at
    /// any tile width.
    pub fn forward_prefill_with<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
    ) -> &'s [f32] {
        self.prefill_inner(tokens, cache, scratch, None, true)
    }

    /// Prefill an *intermediate* chunk of a prompt: identical cache
    /// writes to [`Transformer::forward_prefill_with`] but no final-norm
    /// / lm_head pass — those logits would be discarded anyway, and at a
    /// 128-position chunk cap a long prompt would otherwise pay one
    /// useless `[vocab, d]` GEMV per chunk. Call
    /// [`Transformer::forward_prefill_with`] for the last chunk to get
    /// the next-token logits.
    pub fn forward_prefill_chunk<C: AsKvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        scratch: &mut ForwardScratch,
    ) {
        self.prefill_inner(tokens, cache, scratch, None, false);
    }

    /// Chunked prefill with calibration taps: identical math to
    /// [`Transformer::forward_prefill_with`], additionally folding every
    /// projection-input activation block into the running per-channel
    /// moments of `taps` (see [`crate::calib::stats::ModelTaps`]). The
    /// taps record running statistics only — no activation storage — so
    /// a calibration corpus of any length streams at O(d) extra memory.
    pub fn forward_prefill_tapped<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
        taps: &mut crate::calib::stats::ModelTaps,
    ) -> &'s [f32] {
        self.prefill_inner(tokens, cache, scratch, Some(taps), true)
    }

    fn prefill_inner<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
        mut taps: Option<&mut crate::calib::stats::ModelTaps>,
        need_logits: bool,
    ) -> &'s [f32] {
        // The tapped path always needs the head pass (head_in site +
        // token accounting live there).
        let need_logits = need_logits || taps.is_some();
        let n = tokens.len();
        assert!(n > 0, "empty prefill chunk");
        let pos0 = cache.kv().len();
        assert!(pos0 + n <= self.cfg.max_seq, "sequence overflow");
        let cfg = &self.cfg;
        let d = cfg.d_model;

        let ForwardScratch {
            gemm,
            scores,
            logits,
            h,
            qi,
            slots,
            xb,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
            ..
        } = scratch;

        slots.clear();
        slots.extend((0..n).map(|i| (0usize, pos0 + i)));
        xb.resize(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            xb.row_mut(i).copy_from_slice(self.embed.row(t as usize));
        }
        let mut bufs = LayerBufs {
            gemm,
            scores,
            qi,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
        };
        let caches = std::slice::from_mut(cache);
        for (li, layer) in self.layers.iter().enumerate() {
            self.layer_body(
                li,
                layer,
                DecodePrecision::Full,
                caches,
                slots,
                xb,
                &mut bufs,
                taps.as_deref_mut(),
            );
        }
        caches[0].kv_mut().set_len(pos0 + n);
        if !need_logits {
            // Intermediate chunk: the cache is written; skip the head.
            ensure(logits, 0);
            return logits;
        }
        ensure(h, d);
        rmsnorm(xb.row(n - 1), &self.final_norm, h);
        if let Some(t) = taps.as_deref_mut() {
            // Only the last position's head input exists in the chunked
            // prefill (one lm_head GEMV per chunk) — record that row.
            t.head_in.record(&h[..d]);
            t.tokens_seen += n as u64;
            t.windows += 1;
        }
        ensure(logits, cfg.vocab_size);
        self.lm_head.apply_with(h, logits, bufs.gemm);
        logits
    }

    /// Batched *verify* forward for speculative decoding: append `tokens`
    /// prefill-style to one cache at full precision — overwriting any
    /// draft-quality KV rows at those positions before attention reads
    /// them — and return logits for **every** position, `[n, vocab]`,
    /// the scores the accept-longest-prefix rule compares against the
    /// draft tokens. Row `i`'s logits are bit-identical to what a plain
    /// decode step at position `pos0 + i` would produce for the packed
    /// segmented schemes: the tile kernels accumulate each output lane
    /// independently of batch width, and attention reads the same float
    /// rows either way.
    pub fn forward_verify_with<'s, C: AsKvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        scratch: &'s mut ForwardScratch,
    ) -> &'s Tensor {
        let n = tokens.len();
        assert!(n > 0, "empty verify chunk");
        let pos0 = cache.kv().len();
        assert!(pos0 + n <= self.cfg.max_seq, "sequence overflow");
        let cfg = &self.cfg;
        let d = cfg.d_model;

        let ForwardScratch {
            gemm,
            scores,
            qi,
            slots,
            xb,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
            logitsb,
            ..
        } = scratch;

        slots.clear();
        slots.extend((0..n).map(|i| (0usize, pos0 + i)));
        xb.resize(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            xb.row_mut(i).copy_from_slice(self.embed.row(t as usize));
        }
        let mut bufs = LayerBufs {
            gemm,
            scores,
            qi,
            hb,
            qb,
            kxb,
            vxb,
            attnb,
            ob,
            gateb,
            upb,
            actb,
            downb,
        };
        let caches = std::slice::from_mut(cache);
        for (li, layer) in self.layers.iter().enumerate() {
            self.layer_body(li, layer, DecodePrecision::Full, caches, slots, xb, &mut bufs, None);
        }
        caches[0].kv_mut().set_len(pos0 + n);
        for i in 0..n {
            bufs.qi.clear();
            bufs.qi.extend_from_slice(xb.row(i));
            rmsnorm(bufs.qi, &self.final_norm, xb.row_mut(i));
        }
        self.lm_head.apply_batch_into(xb, logitsb, bufs.gemm);
        logitsb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::synthetic_checkpoint;

    fn tiny_model() -> Transformer {
        let ck = synthetic_checkpoint(&ModelConfig::test_tiny(), 42);
        Transformer::from_checkpoint(&ck).unwrap()
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let m = tiny_model();
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        let l1 = m.forward(3, 0, &mut c1);
        let l2 = m.forward(3, 0, &mut c2);
        assert_eq!(l1.len(), m.cfg.vocab_size);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_with_reused_scratch_matches_forward() {
        let m = tiny_model();
        let mut ca = m.new_cache();
        let mut cb = m.new_cache();
        let mut scratch = m.new_scratch();
        for (p, &t) in [1u32, 5, 9, 2].iter().enumerate() {
            let fresh = m.forward(t, p, &mut ca);
            let reused = m.forward_with(t, p, &mut cb, &mut scratch);
            assert_eq!(fresh.as_slice(), reused, "pos {p}");
        }
    }

    #[test]
    fn forward_batch_with_reused_scratch_matches() {
        let m = tiny_model();
        let mut scratch = m.new_scratch();
        // Varying batch widths through one scratch (continuous batching).
        let mut caches: Vec<KvCache> = (0..3).map(|_| m.new_cache()).collect();
        let l3 = m
            .forward_batch_with(&[1, 2, 3], &mut caches, &mut scratch)
            .clone();
        let mut fresh: Vec<KvCache> = (0..3).map(|_| m.new_cache()).collect();
        let l3_fresh = m.forward_batch(&[1, 2, 3], &mut fresh);
        assert_eq!(l3, l3_fresh);
        // Shrink the batch: reuse two of the caches.
        let mut two: Vec<&mut KvCache> = caches.iter_mut().take(2).collect();
        let l2 = m.forward_batch_with(&[7, 8], &mut two, &mut scratch).clone();
        let mut two_fresh: Vec<&mut KvCache> = fresh.iter_mut().take(2).collect();
        let l2_fresh = m.forward_batch(&[7, 8], &mut two_fresh);
        assert_eq!(l2, l2_fresh);
    }

    #[test]
    fn cache_affects_later_tokens() {
        let m = tiny_model();
        // Same token at pos 1 after different histories -> different logits.
        let mut ca = m.new_cache();
        m.forward(1, 0, &mut ca);
        let la = m.forward(5, 1, &mut ca);
        let mut cb = m.new_cache();
        m.forward(2, 0, &mut cb);
        let lb = m.forward(5, 1, &mut cb);
        assert_ne!(la, lb);
    }

    #[test]
    #[should_panic(expected = "positions must be fed in order")]
    fn out_of_order_positions_panic() {
        let m = tiny_model();
        let mut c = m.new_cache();
        m.forward(1, 1, &mut c);
    }

    #[test]
    fn batch_matches_single() {
        let m = tiny_model();
        // Three sequences with different histories.
        let hists: Vec<Vec<u32>> = vec![vec![1, 2], vec![7], vec![3, 4]];
        let next = [9u32, 8, 7];
        // Single-path reference.
        let mut refs = Vec::new();
        for (hist, &n) in hists.iter().zip(&next) {
            let mut c = m.new_cache();
            for (p, &t) in hist.iter().enumerate() {
                m.forward(t, p, &mut c);
            }
            refs.push(m.forward(n, hist.len(), &mut c));
        }
        // Batched path: replay histories one token at a time (batch),
        // then the probe tokens.
        let mut caches: Vec<KvCache> = (0..3).map(|_| m.new_cache()).collect();
        for (i, hist) in hists.iter().enumerate() {
            for (p, &t) in hist.iter().enumerate() {
                m.forward(t, p, &mut caches[i]);
            }
        }
        let logits = m.forward_batch(&next, &mut caches);
        for i in 0..3 {
            for j in 0..m.cfg.vocab_size {
                assert!(
                    (logits.at2(i, j) - refs[i][j]).abs() < 1e-4,
                    "seq {i} logit {j}: {} vs {}",
                    logits.at2(i, j),
                    refs[i][j]
                );
            }
        }
    }

    /// Acceptance: chunked prefill vs token-by-token, for the dense
    /// reference and every packed serving scheme family. Logits of the
    /// last prompt position must agree, and the caches must be
    /// interchangeable for subsequent decode steps.
    #[test]
    fn prefill_matches_token_by_token_all_schemes() {
        let m = tiny_model();
        let prompt = [1u32, 5, 9, 2, 17, 33];
        let mut models = vec![("dense".to_string(), m.clone())];
        for name in ["fp16", "fp8", "fp6", "fp5.33", "fp4.25", "fp4", "int8", "int4"] {
            let scheme = Scheme::parse(name).unwrap();
            models.push((name.to_string(), m.quantized(&QuantConfig::paper(scheme)).unwrap()));
        }
        for (name, model) in &models {
            let mut c_tok = model.new_cache();
            let mut l_tok = Vec::new();
            for (p, &t) in prompt.iter().enumerate() {
                l_tok = model.forward(t, p, &mut c_tok);
            }
            let mut c_pre = model.new_cache();
            let l_pre = model.forward_prefill(&prompt, &mut c_pre);
            assert_eq!(c_pre.len, prompt.len(), "{name}");
            assert_eq!(l_pre.len(), l_tok.len(), "{name}");
            for (j, (a, b)) in l_pre.iter().zip(&l_tok).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{name} logit {j}: {a} vs {b}"
                );
            }
            // Continue decoding one token from both caches: histories must
            // be interchangeable.
            let mut s = model.new_scratch();
            let la = model.forward_with(7, prompt.len(), &mut c_tok, &mut s).to_vec();
            let lb = model.forward_with(7, prompt.len(), &mut c_pre, &mut s).to_vec();
            for (j, (a, b)) in lb.iter().zip(&la).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{name} post-decode logit {j}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn prefill_in_chunks_matches_single_chunk() {
        let m = tiny_model().quantized(&QuantConfig::paper(Scheme::parse("fp5.33").unwrap())).unwrap();
        let prompt = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let mut scratch = m.new_scratch();
        let mut c1 = m.new_cache();
        let l1 = m.forward_prefill_with(&prompt, &mut c1, &mut scratch).to_vec();
        let mut c2 = m.new_cache();
        m.forward_prefill_with(&prompt[..3], &mut c2, &mut scratch);
        let l2 = m.forward_prefill_with(&prompt[3..], &mut c2, &mut scratch).to_vec();
        assert_eq!(c2.len, prompt.len());
        for (j, (a, b)) in l2.iter().zip(&l1).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "logit {j}: {a} vs {b}"
            );
        }
    }

    /// An intermediate chunk via `forward_prefill_chunk` (no head pass)
    /// leaves the cache identical to `forward_prefill_with`, so the
    /// final chunk's logits match the one-pass prefill.
    #[test]
    fn prefill_chunk_skips_head_but_matches() {
        let m = tiny_model();
        let prompt = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let mut scratch = m.new_scratch();
        let mut c1 = m.new_cache();
        let l1 = m.forward_prefill_with(&prompt, &mut c1, &mut scratch).to_vec();
        let mut c2 = m.new_cache();
        m.forward_prefill_chunk(&prompt[..5], &mut c2, &mut scratch);
        assert_eq!(c2.len, 5, "chunk advanced the cache");
        let l2 = m.forward_prefill_with(&prompt[5..], &mut c2, &mut scratch).to_vec();
        for (j, (a, b)) in l2.iter().zip(&l1).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "logit {j}: {a} vs {b}"
            );
        }
    }

    /// Tapped prefill returns the same logits as the untapped path and
    /// fills every tap site.
    #[test]
    fn tapped_prefill_matches_and_records() {
        let m = tiny_model();
        let prompt = [1u32, 5, 9, 2, 17];
        let mut scratch = m.new_scratch();
        let mut c1 = m.new_cache();
        let plain = m.forward_prefill_with(&prompt, &mut c1, &mut scratch).to_vec();
        let mut taps = crate::calib::stats::ModelTaps::new(&m.cfg);
        let mut c2 = m.new_cache();
        let tapped = m
            .forward_prefill_tapped(&prompt, &mut c2, &mut scratch, &mut taps)
            .to_vec();
        assert_eq!(plain, tapped, "taps must not perturb the math");
        assert_eq!(taps.tokens_seen, prompt.len() as u64);
        assert_eq!(taps.windows, 1);
        for name in ["layers.0.wq", "layers.1.wo", "layers.0.w_up", "layers.1.w_down"] {
            let s = taps.stats_for(name).unwrap();
            assert_eq!(s.rows(), prompt.len() as u64, "{name}");
            assert!(s.mean_sq(0).is_finite() && s.abs_max() > 0.0, "{name}");
        }
        assert_eq!(taps.head_in.rows(), 1, "head taps the last position only");
    }

    #[test]
    #[should_panic(expected = "sequence overflow")]
    fn prefill_overflow_panics() {
        let m = tiny_model();
        let mut c = m.new_cache();
        let too_long: Vec<u32> = (0..m.cfg.max_seq as u32 + 1).map(|i| i % 60).collect();
        m.forward_prefill(&too_long, &mut c);
    }

    #[test]
    fn quantized_model_close_to_dense() {
        let m = tiny_model();
        let q6 = m.quantized(&QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap())).unwrap();
        let q4 = m.quantized(&QuantConfig::paper(Scheme::parse("fp4-e2m1").unwrap())).unwrap();
        let mut cd = m.new_cache();
        let mut c6 = q6.new_cache();
        let mut c4 = q4.new_cache();
        let mut d6 = 0f64;
        let mut d4 = 0f64;
        for (p, &t) in [1u32, 5, 9, 2].iter().enumerate() {
            let ld = m.forward(t, p, &mut cd);
            let l6 = q6.forward(t, p, &mut c6);
            let l4 = q4.forward(t, p, &mut c4);
            d6 += ld
                .iter()
                .zip(&l6)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            d4 += ld
                .iter()
                .zip(&l4)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        assert!(d6 > 0.0, "fp6 must differ from fp32 somewhere");
        assert!(d6 < d4, "fp6 logit error {d6} must beat fp4 {d4}");
    }

    #[test]
    fn fp16_scheme_near_lossless() {
        let m = tiny_model();
        let qf = m.quantized(&QuantConfig::paper(Scheme::Fp16)).unwrap();
        let mut cd = m.new_cache();
        let mut cf = qf.new_cache();
        for (p, &t) in [1u32, 5, 9].iter().enumerate() {
            let ld = m.forward(t, p, &mut cd);
            let lf = qf.forward(t, p, &mut cf);
            for (a, b) in ld.iter().zip(&lf) {
                assert!((a - b).abs() < 0.02, "{a} vs {b}");
            }
        }
    }

    /// Tentpole acceptance: a mixed-precision plan (fp6 attention /
    /// fp4.25-per-group MLP / fp8 lm_head) quantizes through one
    /// `Quantizer`, reports per layer, and serves logits close to dense.
    #[test]
    fn mixed_precision_plan_quantizes_and_serves() {
        use crate::quant::{Granularity, QuantPlan};
        let m = tiny_model();
        let plan = QuantPlan::builder(
            QuantConfig::paper(Scheme::parse("fp4.25").unwrap())
                .with_granularity(Granularity::PerGroup(32)),
        )
        .role(LayerRole::Attention, QuantConfig::paper(Scheme::parse("fp6").unwrap()))
        .role(LayerRole::LmHead, QuantConfig::paper(Scheme::parse("fp8").unwrap()))
        .build()
        .unwrap();
        let (q, reports) = m.quantized_report(&Quantizer::new(plan)).unwrap();
        // 7 projections per layer + lm_head, each with a report.
        assert_eq!(reports.len(), m.cfg.n_layers * 7 + 1);
        let by_name = |n: &str| reports.iter().find(|r| r.layer == n).unwrap();
        assert_eq!(by_name("layers.0.wq").scheme, Scheme::parse("fp6").unwrap());
        assert_eq!(by_name("layers.0.w_gate").scheme, Scheme::parse("fp4.25").unwrap());
        assert_eq!(
            by_name("layers.0.w_gate").granularity,
            Granularity::PerGroup(32)
        );
        assert_eq!(by_name("lm_head").scheme, Scheme::parse("fp8").unwrap());
        assert!(matches!(q.lm_head, Linear::Quant(_)), "lm_head override quantizes it");
        // The attention projections carry more bits than the MLP ones.
        assert!(by_name("layers.0.wq").bits_per_weight > by_name("layers.0.w_up").bits_per_weight);
        // Serving stays close to dense.
        let mut cd = m.new_cache();
        let mut cq = q.new_cache();
        for (p, &t) in [1u32, 5, 9].iter().enumerate() {
            let ld = m.forward(t, p, &mut cd);
            let lq = q.forward(t, p, &mut cq);
            assert!(lq.iter().all(|v| v.is_finite()));
            let err: f64 = ld
                .iter()
                .zip(&lq)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / ld.len() as f64;
            assert!(err < 1.0, "pos {p}: logit mse {err}");
        }
    }

    /// A uniform per-group model decodes through the fused per-group
    /// path end-to-end and matches the per-channel model's quality class.
    #[test]
    fn per_group_model_decodes() {
        use crate::quant::Granularity;
        let m = tiny_model();
        let cfg = QuantConfig::paper(Scheme::parse("fp4.25").unwrap());
        let qc = m.quantized(&cfg).unwrap();
        let qg = m
            .quantized(&cfg.with_granularity(Granularity::PerGroup(32)))
            .unwrap();
        let mut cd = m.new_cache();
        let mut cc = qc.new_cache();
        let mut cg = qg.new_cache();
        let mut err_c = 0f64;
        let mut err_g = 0f64;
        for (p, &t) in [1u32, 5, 9, 2].iter().enumerate() {
            let ld = m.forward(t, p, &mut cd);
            let lc = qc.forward(t, p, &mut cc);
            let lg = qg.forward(t, p, &mut cg);
            assert!(lg.iter().all(|v| v.is_finite()));
            err_c += ld.iter().zip(&lc).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
            err_g += ld.iter().zip(&lg).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
        }
        // Finer scales must not be wildly worse; typically better.
        assert!(err_g < err_c * 2.0, "per-group {err_g} vs per-channel {err_c}");
    }

    #[test]
    fn quantized_source_must_be_dense() {
        let m = tiny_model();
        let q = m.quantized(&QuantConfig::paper(Scheme::parse("fp6").unwrap())).unwrap();
        match q.quantized(&QuantConfig::paper(Scheme::parse("fp4").unwrap())) {
            Err(QuantError::SourceNotDense { layer }) => assert_eq!(layer, "layers.0.wq"),
            other => panic!("expected SourceNotDense, got {other:?}"),
        }
    }

    #[test]
    fn unknown_layer_override_rejected() {
        use crate::quant::QuantPlan;
        let m = tiny_model();
        let plan = QuantPlan::builder(QuantConfig::paper(Scheme::parse("fp4.25").unwrap()))
            .layer("layers.99.wq", QuantConfig::paper(Scheme::parse("fp6").unwrap()))
            .build()
            .unwrap();
        match m.quantized_with(&Quantizer::new(plan)) {
            Err(QuantError::UnknownLayer { layer }) => assert_eq!(layer, "layers.99.wq"),
            other => panic!("expected UnknownLayer, got {other:?}"),
        }
        // A valid exact-name override flows through.
        let plan = QuantPlan::builder(QuantConfig::paper(Scheme::parse("fp4.25").unwrap()))
            .layer("layers.0.w_down", QuantConfig::paper(Scheme::parse("fp8").unwrap()))
            .build()
            .unwrap();
        let (_, reports) = m.quantized_report(&Quantizer::new(plan)).unwrap();
        let rep = reports.iter().find(|r| r.layer == "layers.0.w_down").unwrap();
        assert_eq!(rep.scheme, Scheme::parse("fp8").unwrap());
    }

    /// The verify forward returns, for every fed position, logits
    /// bit-identical to feeding the same tokens through plain batched
    /// decode — the property the speculative accept rule relies on —
    /// and leaves an interchangeable cache.
    #[test]
    fn verify_forward_matches_decode_bitwise() {
        use crate::quant::Granularity;
        let m = tiny_model();
        for (name, gran) in [
            ("fp6-e2m3", Granularity::PerChannel),
            ("fp5-e2m2", Granularity::PerChannel),
            ("fp4.25", Granularity::PerGroup(32)),
        ] {
            let q = m
                .quantized(&QuantConfig::paper(Scheme::parse(name).unwrap()).with_granularity(gran))
                .unwrap();
            let mut scratch = q.new_scratch();
            let prompt = [1u32, 5, 9];
            let step = [2u32, 17, 33, 7];
            let mut c_dec = q.new_cache();
            q.forward_prefill_with(&prompt, &mut c_dec, &mut scratch);
            let mut c_ver = c_dec.clone();
            let mut dec_logits = Vec::new();
            for &t in &step {
                let l = q
                    .forward_batch_with(&[t], std::slice::from_mut(&mut c_dec), &mut scratch)
                    .clone();
                dec_logits.push(l.row(0).to_vec());
            }
            let ver = q.forward_verify_with(&step, &mut c_ver, &mut scratch).clone();
            for (i, dl) in dec_logits.iter().enumerate() {
                for (j, (a, b)) in ver.row(i).iter().zip(dl).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name} pos {i} logit {j}");
                }
            }
            assert_eq!(c_ver.len, c_dec.len, "{name}");
            for li in 0..q.cfg.n_layers {
                for p in 0..c_ver.len {
                    assert_eq!(c_ver.k_row(li, p), c_dec.k_row(li, p), "{name} k {li}/{p}");
                    assert_eq!(c_ver.v_row(li, p), c_dec.v_row(li, p), "{name} v {li}/{p}");
                }
            }
        }
    }

    /// Draft steps write hi-only KV rows; rewinding the length and
    /// running the verify forward over the same positions leaves the
    /// cache exactly as if the tokens had been decoded at full precision
    /// all along (the per-layer write-before-attend ordering guarantees
    /// no draft-quality row is ever read by the verify pass).
    #[test]
    fn verify_overwrites_draft_kv() {
        let m = tiny_model();
        let q = m.quantized(&QuantConfig::paper(Scheme::parse("fp4.25").unwrap())).unwrap();
        let mut scratch = q.new_scratch();
        let prompt = [3u32, 1, 4];
        let mut c_spec = q.new_cache();
        q.forward_prefill_with(&prompt, &mut c_spec, &mut scratch);
        let mut c_ref = c_spec.clone();
        let l0 = q.forward_draft_with(7, 3, &mut c_spec, &mut scratch).to_vec();
        assert!(l0.iter().all(|v| v.is_finite()));
        q.forward_draft_with(9, 4, &mut c_spec, &mut scratch);
        c_spec.set_len(3);
        q.forward_verify_with(&[7, 9], &mut c_spec, &mut scratch);
        q.forward_verify_with(&[7, 9], &mut c_ref, &mut scratch);
        for li in 0..q.cfg.n_layers {
            for p in 0..5 {
                assert_eq!(c_spec.k_row(li, p), c_ref.k_row(li, p), "k {li}/{p}");
                assert_eq!(c_spec.v_row(li, p), c_ref.v_row(li, p), "v {li}/{p}");
            }
        }
    }

    /// On a model with no hi/lo split anywhere (dense reference) the
    /// draft forward is exactly the full forward.
    #[test]
    fn draft_on_dense_model_is_full_forward() {
        let m = tiny_model();
        let mut s = m.new_scratch();
        let mut ca = m.new_cache();
        let mut cb = m.new_cache();
        for (p, &t) in [1u32, 5, 9].iter().enumerate() {
            let a = m.forward_with(t, p, &mut ca, &mut s).to_vec();
            let b = m.forward_draft_with(t, p, &mut cb, &mut s).to_vec();
            assert_eq!(a, b, "pos {p}");
        }
    }

    /// The hi-only draft forward differs from the full forward on a
    /// segmented-scheme model (it really is reading less mantissa) but
    /// stays finite and usable as a proposal distribution.
    #[test]
    fn draft_on_segmented_model_runs_hi_only() {
        let m = tiny_model();
        let q = m.quantized(&QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap())).unwrap();
        let mut s = q.new_scratch();
        let mut ca = q.new_cache();
        let mut cb = q.new_cache();
        let mut differed = false;
        for (p, &t) in [1u32, 5, 9].iter().enumerate() {
            let a = q.forward_with(t, p, &mut ca, &mut s).to_vec();
            let b = q.forward_draft_with(t, p, &mut cb, &mut s).to_vec();
            assert!(b.iter().all(|v| v.is_finite()), "pos {p}");
            differed |= a != b;
        }
        assert!(differed, "hi-only draft must not equal the full forward");
    }

    #[test]
    fn projection_bytes_scale_with_scheme() {
        let m = tiny_model();
        let dense = m.projection_bytes() as f64; // fp16-equivalent
        let q425 = m
            .quantized(&QuantConfig::paper(Scheme::parse("fp4.25").unwrap())).unwrap()
            .projection_bytes() as f64;
        let ratio = dense / q425;
        assert!(
            (ratio - 16.0 / 4.25).abs() / (16.0 / 4.25) < 0.15,
            "compression ratio {ratio}"
        );
    }
}
