//! Streaming log-bucketed histogram: O(1) memory, exact count/sum,
//! bounded-relative-error quantiles.
//!
//! Values land in power-of-two buckets keyed by their binary exponent
//! (bucket `i` covers `[2^(MIN_EXP+i), 2^(MIN_EXP+i+1))`), so a
//! histogram is a fixed array of 64 counters no matter how many samples
//! it absorbs — unlike [`crate::util::metrics::Summary`], which stores
//! every sample and grows without bound on a long serve run. Count,
//! sum, min and max are tracked exactly; quantiles come back as the
//! arithmetic midpoint (`1.5·2^e`) of the bucket holding the
//! nearest-rank sample, which pins the *relative* error to one bucket's
//! width: the true nearest-rank sample `q` and the reported value `r`
//! share a bucket, so `r/q ∈ [0.75, 1.5)` for positive samples. The
//! quantile property suite asserts exactly this envelope against exact
//! sorted-sample quantiles.
//!
//! All state is atomic — recording is lock-free, panic-safe (a replica
//! crash mid-record cannot poison anything), and cheap enough for
//! sampled kernel-timing hooks on the decode hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets.
pub const BUCKETS: usize = 64;

/// Binary exponent of the lowest bucket's left edge: `2^-40 ≈ 9.1e-13`.
/// With 64 buckets the top edge is `2^24 ≈ 1.7e7` — sub-picosecond to
/// ~194 days when values are seconds. Out-of-range values clamp to the
/// edge buckets (count/sum stay exact; only the quantile degrades).
pub const MIN_EXP: i32 = -40;

/// Left edge of bucket `i`.
#[inline]
pub fn bucket_lo(i: usize) -> f64 {
    (2f64).powi(MIN_EXP + i as i32)
}

/// Reported representative of bucket `i`: its arithmetic midpoint.
#[inline]
pub fn bucket_mid(i: usize) -> f64 {
    1.5 * bucket_lo(i)
}

/// Bucket index for a value: its IEEE-754 binary exponent shifted by
/// `MIN_EXP` and clamped. Zero, negatives, NaN and subnormals land in
/// bucket 0; infinities in the top bucket.
#[inline]
pub fn bucket_index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let biased = ((v.to_bits() >> 52) & 0x7ff) as i32;
    let e = if biased == 0 { MIN_EXP } else { biased - 1023 };
    (e - MIN_EXP).clamp(0, BUCKETS as i32 - 1) as usize
}

/// Lock-free CAS add on an f64 stored as bits.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Lock-free CAS min/max on an f64 stored as bits (non-negative values
/// only — their bit patterns order like the floats themselves).
fn extreme_f64(cell: &AtomicU64, v: f64, keep_smaller: bool) {
    let vb = v.to_bits();
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_f = f64::from_bits(cur);
        let replace = if keep_smaller { v < cur_f } else { v > cur_f };
        if !replace {
            return;
        }
        match cell.compare_exchange_weak(cur, vb, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// The streaming histogram. See the [module docs](self) for the model.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    /// NaN/negative/infinite samples refused by [`Histogram::record`] —
    /// kept out of every statistic so a few bad samples cannot drive
    /// `min` to 0 or collapse p50 into the zero bucket, but still
    /// visible (telemetry producing garbage is itself a signal).
    rejected: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
            rejected: AtomicU64::new(0),
        }
    }

    /// Record one sample. Invalid samples (NaN, negative, ±infinity)
    /// are *rejected* — counted in [`Histogram::rejected`] and excluded
    /// from count/sum/min/max/buckets — instead of being clamped to
    /// zero, which silently drove `min` to 0 and inflated the zero
    /// bucket until p50 collapsed on a few bad samples. A literal `0.0`
    /// is a valid sample and lands in the zero bucket.
    pub fn record(&self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.sum_bits, v);
        extreme_f64(&self.min_bits, v, true);
        extreme_f64(&self.max_bits, v, false);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Samples refused by [`Histogram::record`] for being NaN, negative
    /// or infinite; excluded from every other statistic.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Quantile from the bucket counts: the midpoint of the bucket
    /// holding the rank-`round(p·(n−1))+1` sample (0.0 on an empty
    /// histogram). The rank rule deliberately matches
    /// [`Summary::percentile`](crate::util::metrics::Summary::percentile)
    /// so both select the same order statistic and the reported midpoint
    /// provably shares a bucket with the exact answer — relative error
    /// is bounded by one bucket's width.
    pub fn quantile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (((p * (n - 1) as f64).round() as u64) + 1).clamp(1, n);
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.buckets[i].load(Ordering::Relaxed);
            if cum >= rank {
                // The zero bucket also holds literal zeros; report its
                // left edge rather than a fabricated midpoint.
                return if i == 0 && self.min() == 0.0 { 0.0 } else { bucket_mid(i) };
            }
        }
        self.max()
    }

    pub fn min(&self) -> f64 {
        let m = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Point-in-time summary (count, exact sum/mean/min/max, midpoint
    /// p50/p90/p99).
    pub fn stat(&self) -> HistStat {
        let count = self.count();
        let sum = self.sum();
        HistStat {
            count,
            sum,
            mean: if count > 0 { sum / count as f64 } else { 0.0 },
            min: self.min(),
            max: self.max(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            rejected: self.rejected(),
        }
    }
}

/// Serializable snapshot of one histogram.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistStat {
    pub count: u64,
    pub sum: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// Invalid (NaN/negative/infinite) samples refused at record time.
    pub rejected: u64,
}

impl HistStat {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("count", Json::Num(self.count as f64))
            .set("sum", Json::Num(self.sum))
            .set("mean", Json::Num(self.mean))
            .set("min", Json::Num(self.min))
            .set("max", Json::Num(self.max))
            .set("p50", Json::Num(self.p50))
            .set("p90", Json::Num(self.p90))
            .set("p99", Json::Num(self.p99))
            .set("rejected", Json::Num(self.rejected as f64));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::metrics::Summary;
    use crate::util::proptest::{run_prop, Strategy};

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = Histogram::new();
        let s = h.stat();
        assert_eq!(s.count, 0);
        assert_eq!(s.sum, 0.0);
        assert_eq!(s.p50, 0.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn count_sum_min_max_are_exact() {
        let h = Histogram::new();
        for v in [0.5, 0.25, 4.0, 0.125] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 4.875).abs() < 1e-12);
        assert_eq!(h.min(), 0.125);
        assert_eq!(h.max(), 4.0);
    }

    /// Regression (telemetry pollution): invalid samples are rejected —
    /// counted separately, excluded from count/sum/min/max/quantiles —
    /// so a few NaN/negative samples can no longer drive `min` to 0 or
    /// collapse p50 into the zero bucket. Literal zeros stay valid.
    #[test]
    fn invalid_samples_are_rejected_not_clamped() {
        let h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        assert_eq!(h.count(), 1, "only the literal zero is a sample");
        assert_eq!(h.rejected(), 4);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0, "all-zero histogram reports 0");

        // Bad samples leave real statistics untouched.
        let h = Histogram::new();
        h.record(0.5);
        h.record(f64::NAN);
        h.record(-1.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.rejected(), 2);
        assert_eq!(h.min(), 0.5, "rejected samples cannot drag min to 0");
        assert_eq!(h.max(), 0.5);
        assert!(h.quantile(0.5) > 0.0, "p50 must not collapse to the zero bucket");
        assert_eq!(h.stat().rejected, 2, "snapshot carries the rejected count");
    }

    #[test]
    fn quantile_lands_in_the_right_bucket() {
        let h = Histogram::new();
        // 90 samples near 1ms, 10 near 1s: p50 must sit in the ms
        // bucket, p99 in the seconds bucket.
        for _ in 0..90 {
            h.record(1.0e-3);
        }
        for _ in 0..10 {
            h.record(1.0);
        }
        let p50 = h.quantile(0.50);
        assert!((0.5e-3..2.0e-3).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((1.0..2.0).contains(&p99), "p99={p99}");
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record(1e-3 * (1 + (t * 1000 + i) % 7) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert!(h.sum() > 0.0);
    }

    /// Positive-sample generator with adversarial shape mixing: uniform
    /// spans, heavy tails, near-bucket-boundary clusters and ties.
    struct AdversarialSamples;

    impl Strategy for AdversarialSamples {
        type Value = Vec<f64>;

        fn generate(&self, rng: &mut crate::util::prng::Rng) -> Vec<f64> {
            let len = rng.range(1, 400);
            let mode = rng.below(4);
            (0..len)
                .map(|_| match mode {
                    // Wide log-uniform span (1ns .. 100s).
                    0 => 1e-9 * 1e11f64.powf(rng.uniform()),
                    // Heavy tail around 1ms.
                    1 => 1e-3 * (1.0 + rng.laplace(4.0).abs()),
                    // Clustered at power-of-two boundaries (worst case
                    // for bucket assignment).
                    2 => (2f64).powi(rng.range(0, 20) as i32 - 10),
                    // Massive ties.
                    _ => [1e-4, 2.5e-3, 0.7][rng.range(0, 3)],
                })
                .collect()
        }

        fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
            let mut out = Vec::new();
            if v.len() > 1 {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[v.len() / 2..].to_vec());
            }
            out
        }
    }

    /// Property (satellite): histogram quantiles stay within one
    /// bucket's relative error of the exact sorted-sample quantile. The
    /// histogram reports the midpoint of the bucket holding the same
    /// order statistic `Summary::percentile` selects, so report and
    /// exact value share a bucket: ratio ∈ [0.75, 1.5] (the upper bound
    /// is attained when the sample sits exactly on a bucket edge).
    #[test]
    fn quantiles_within_one_bucket_relative_error() {
        run_prop(
            "hist-quantile-bounded-error",
            0x0B5E,
            120,
            &AdversarialSamples,
            |samples| {
                let h = Histogram::new();
                let mut exact = Summary::new();
                for &v in samples {
                    h.record(v);
                    exact.record(v);
                }
                for p in [50.0, 90.0, 99.0] {
                    let want = exact.percentile(p);
                    let got = h.quantile(p / 100.0);
                    let ratio = got / want;
                    if !(0.75..=1.5).contains(&ratio) {
                        return Err(format!(
                            "p{p}: hist {got} vs exact {want} (ratio {ratio})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Regression (satellite): on a realistic latency distribution the
    /// histogram percentiles track the old exact sample-vector math
    /// within the documented error envelope — the serve report may swap
    /// sources without visibly moving.
    #[test]
    fn serve_percentiles_match_exact_summary_within_bounds() {
        let mut rng = crate::util::prng::Rng::new(0xCAFE);
        let h = Histogram::new();
        let mut exact = Summary::new();
        for _ in 0..5000 {
            // Log-normal-ish request latencies centered near 80ms.
            let v = 0.08 * (rng.normal() * 0.6).exp();
            h.record(v);
            exact.record(v);
        }
        for p in [50.0, 90.0, 99.0] {
            let want = exact.percentile(p);
            let got = h.quantile(p / 100.0);
            let ratio = got / want;
            assert!(
                (0.75..=1.5).contains(&ratio),
                "p{p}: hist {got} vs exact {want} (ratio {ratio})"
            );
        }
        assert_eq!(h.count(), 5000);
    }
}
