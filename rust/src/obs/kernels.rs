//! Sampled per-decode-path kernel timings.
//!
//! The GEMM entry points are far below the engine — threading a
//! registry handle through every model forward would contaminate the
//! whole call graph — so kernel timing goes through one process-wide
//! sink. To keep the decode hot path unperturbed, calls are *sampled*:
//! [`should_sample`] is a single relaxed fetch-add (amortized over the
//! O(rows·cols) kernel work it guards) and only every
//! [`SAMPLE_EVERY`]-th call pays for two `Instant` reads and a
//! lock-free histogram record. Timing is measurement, not behavior —
//! the sink never influences kernel output, so the process-global here
//! does not compromise the determinism the failpoint registry's
//! injected-state rule protects.

use super::hist::{HistStat, Histogram};
use super::registry::names;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Record one out of this many kernel calls.
pub const SAMPLE_EVERY: u64 = 16;

/// Which kernel family served the call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Grouped decode straight off the packed stream.
    StreamDirect,
    /// Grouped decode through the dequantized group buffer.
    Buffered,
    /// Hi-stream-only (draft precision) decode.
    HiOnly,
}

impl KernelPath {
    pub fn metric_name(self) -> &'static str {
        match self {
            KernelPath::StreamDirect => names::GEMM_STREAM_DIRECT,
            KernelPath::Buffered => names::GEMM_BUFFERED,
            KernelPath::HiOnly => names::GEMM_HI_ONLY,
        }
    }
}

struct Sink {
    stream_direct: Histogram,
    buffered: Histogram,
    hi_only: Histogram,
    calls: AtomicU64,
}

static SINK: OnceLock<Sink> = OnceLock::new();

fn sink() -> &'static Sink {
    SINK.get_or_init(|| Sink {
        stream_direct: Histogram::new(),
        buffered: Histogram::new(),
        hi_only: Histogram::new(),
        calls: AtomicU64::new(0),
    })
}

/// Cheap per-call gate: true on every [`SAMPLE_EVERY`]-th call.
#[inline]
pub fn should_sample() -> bool {
    sink().calls.fetch_add(1, Ordering::Relaxed) % SAMPLE_EVERY == 0
}

/// Record one sampled kernel call.
pub fn record(path: KernelPath, secs: f64) {
    let s = sink();
    match path {
        KernelPath::StreamDirect => s.stream_direct.record(secs),
        KernelPath::Buffered => s.buffered.record(secs),
        KernelPath::HiOnly => s.hi_only.record(secs),
    }
}

/// Snapshot the three per-path histograms as `(metric name, stat)`.
pub fn stats() -> [(&'static str, HistStat); 3] {
    let s = sink();
    [
        (names::GEMM_STREAM_DIRECT, s.stream_direct.stat()),
        (names::GEMM_BUFFERED, s.buffered.stat()),
        (names::GEMM_HI_ONLY, s.hi_only.stat()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global and shared across the whole test
    // binary, so assertions are monotone (counts only grow), never
    // exact.
    #[test]
    fn record_lands_in_the_right_path() {
        let before = stats();
        record(KernelPath::StreamDirect, 1e-5);
        record(KernelPath::Buffered, 2e-5);
        record(KernelPath::HiOnly, 3e-5);
        let after = stats();
        for i in 0..3 {
            assert_eq!(after[i].0, before[i].0);
            assert!(after[i].1.count >= before[i].1.count + 1, "{}", after[i].0);
        }
    }

    #[test]
    fn sampling_gate_fires_at_least_once_per_window() {
        let fired = (0..SAMPLE_EVERY).filter(|_| should_sample()).count();
        assert!(fired >= 1, "one call in every window must sample");
    }
}
