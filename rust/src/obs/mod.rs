//! Engine observability: a unified metrics registry, streaming
//! log-bucketed latency histograms, per-request span tracing, and
//! sampled kernel timings.
//!
//! This layer is the single telemetry substrate behind the serving
//! engine (ROADMAP: production-scale serving needs attributable
//! latency, not one tok/s number):
//!
//! - [`registry::MetricsRegistry`] — named counters, gauges and
//!   [`hist::Histogram`]s (power-of-two buckets, O(1) memory, exact
//!   count/sum, bounded-relative-error p50/p90/p99). TTFT, queue wait,
//!   total latency, step time, prefill-chunk time and spec round times
//!   all record here; the old grow-forever sample vectors are gone.
//! - [`trace::TraceSink`] — per-request span timelines
//!   (`Queued→Admitted→PrefillChunk×n→DecodeStep/SpecRound×n→
//!   Preempted/Resumed→Terminal`) in bounded per-replica rings,
//!   exported as Chrome trace-event JSON (`serve --trace-out`,
//!   Perfetto-viewable). Overflow drops the oldest events and counts
//!   them — never panics, never grows unbounded.
//! - [`kernels`] — per-decode-path (`StreamDirect`/`Buffered`/`HiOnly`)
//!   GEMM timings, sampled every Nth call so the hot path stays
//!   unperturbed.
//! - [`snapshot::MetricsSnapshot`] — the typed, serializable snapshot
//!   `Engine::metrics_snapshot()` returns; its `rows()` formatter is
//!   the only thing the CLI serving report prints, so CLI output, JSON
//!   export and bench probes cannot drift apart.

pub mod hist;
pub mod kernels;
pub mod registry;
pub mod snapshot;
pub mod trace;

pub use hist::{HistStat, Histogram};
pub use kernels::KernelPath;
pub use registry::{labeled, names, parse_labeled, Gauge, MetricsRegistry, RegistrySnapshot};
pub use snapshot::{
    FaultSection, KvSection, MetricsSnapshot, ServeSection, SpecSection, TraceSection,
};
pub use trace::{SpanEvent, SpanKind, TraceSink, DEFAULT_RING_CAP};
