//! A unified registry of named counters, gauges and streaming
//! histograms — the single metrics substrate behind the serving engine.
//!
//! Registration (`counter`/`gauge`/`histogram`) is get-or-create under a
//! short-lived lock and returns an `Arc` handle; hot paths hold the
//! handle and record lock-free through the atomics inside. A
//! [`MetricsRegistry::snapshot`] walks every registered metric into
//! plain sorted maps, which the engine folds into its typed
//! [`MetricsSnapshot`](super::snapshot::MetricsSnapshot).
//!
//! Metric names are dotted paths (`serve.ttft_s`, `gemm.buffered_s`);
//! the well-known ones live in [`names`] so the recorder, the snapshot
//! formatter and the bench probes can never drift apart on a string.

use super::hist::{HistStat, Histogram};
use crate::util::metrics::Counter;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Well-known metric names. Histogram values are seconds unless the
/// suffix says otherwise.
pub mod names {
    /// Submission → first generated token (queue wait included).
    pub const TTFT: &str = "serve.ttft_s";
    /// Submission → terminal event.
    pub const LATENCY: &str = "serve.latency_s";
    /// Submission → admission into the running batch.
    pub const QUEUE_WAIT: &str = "serve.queue_wait_s";
    /// One scheduler decode step (plain or speculative).
    pub const STEP_TIME: &str = "serve.step_time_s";
    /// One chunked-prefill forward.
    pub const PREFILL_CHUNK: &str = "serve.prefill_chunk_s";
    /// One full speculative round (draft + verify + accept).
    pub const SPEC_ROUND: &str = "spec.round_s";
    /// Draft phase of a speculative round (hi-stream forwards).
    pub const SPEC_DRAFT: &str = "spec.draft_s";
    /// Verify phase of a speculative round (full-precision forward).
    pub const SPEC_VERIFY: &str = "spec.verify_s";
    /// Sampled stream-direct grouped-decode kernel calls.
    pub const GEMM_STREAM_DIRECT: &str = "gemm.stream_direct_s";
    /// Sampled buffered grouped-decode kernel calls.
    pub const GEMM_BUFFERED: &str = "gemm.buffered_s";
    /// Sampled hi-only (draft-precision) kernel calls.
    pub const GEMM_HI_ONLY: &str = "gemm.hi_only_s";
    /// KV page-pool gauges (fed from [`crate::kv::KvGauges`]).
    pub const KV_PAGES_USED: &str = "kv.pages_used";
    pub const KV_PAGES_FREE: &str = "kv.pages_free";
    pub const KV_PAGES_CAPACITY: &str = "kv.pages_capacity";
    pub const KV_PAGES_PEAK: &str = "kv.pages_peak";
    pub const KV_LEAKED: &str = "kv.pages_leaked";
    /// Span events dropped to ring-buffer wraparound.
    pub const TRACE_DROPPED: &str = "trace.events_dropped";
    /// Request-lifecycle counters, ticked live by the replica workers
    /// (the merged `ServeStats` is only available after shutdown; these
    /// back `Engine::metrics_snapshot` while the engine serves).
    pub const REQUESTS: &str = "serve.requests";
    pub const CANCELLED: &str = "serve.cancelled";
    pub const FAILED: &str = "serve.failed";
    pub const TIMED_OUT: &str = "serve.timed_out";
    pub const TOKENS_GENERATED: &str = "serve.tokens_generated";
    pub const DECODE_STEPS: &str = "serve.decode_steps";
    pub const BATCHED_TOKENS: &str = "serve.batched_tokens";
    /// Highest batch occupancy any replica observed (gauge).
    pub const PEAK_CONCURRENCY: &str = "serve.peak_concurrency";
    /// Speculative-decoding counters (fleet totals across replicas).
    pub const SPEC_DRAFTED: &str = "spec.drafted";
    pub const SPEC_ACCEPTED: &str = "spec.accepted";
    pub const SPEC_ROUNDS: &str = "spec.rounds";
    /// Admission-queue gauges: live depth summed over replicas, and the
    /// deepest backlog any replica's queue ever held.
    pub const QUEUE_DEPTH: &str = "queue.depth";
    pub const QUEUE_DEPTH_PEAK: &str = "queue.depth_peak";
}

/// Compose a labeled metric name: `labeled("serve.ttft_s", "tenant", 3)`
/// → `serve.ttft_s{tenant=3}`. Labeled metrics are ordinary registry
/// entries under the composed name, so they flow through
/// [`MetricsRegistry::snapshot`], `MetricsSnapshot` and METRICS.json
/// with no extra plumbing; the base (unlabeled) name keeps aggregating
/// across labels.
pub fn labeled(name: &str, label: &str, value: impl std::fmt::Display) -> String {
    format!("{name}{{{label}={value}}}")
}

/// Split a labeled metric name back into `(base, label, value)`;
/// `None` for unlabeled names. Inverse of [`labeled`].
pub fn parse_labeled(name: &str) -> Option<(&str, &str, &str)> {
    let open = name.find('{')?;
    let inner = name[open + 1..].strip_suffix('}')?;
    let (label, value) = inner.split_once('=')?;
    Some((&name[..open], label, value))
}

/// A settable instantaneous value (pool occupancy, queue depth, ...).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// See the [module docs](self) for the model.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry::default())
    }

    /// Get-or-create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().expect("metrics registry");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().expect("metrics registry");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.hists.lock().expect("metrics registry");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-create a labeled counter (`name{label=value}`).
    pub fn counter_labeled(
        &self,
        name: &str,
        label: &str,
        value: impl std::fmt::Display,
    ) -> Arc<Counter> {
        self.counter(&labeled(name, label, value))
    }

    /// Get-or-create a labeled gauge (`name{label=value}`).
    pub fn gauge_labeled(
        &self,
        name: &str,
        label: &str,
        value: impl std::fmt::Display,
    ) -> Arc<Gauge> {
        self.gauge(&labeled(name, label, value))
    }

    /// Get-or-create a labeled histogram (`name{label=value}`).
    pub fn histogram_labeled(
        &self,
        name: &str,
        label: &str,
        value: impl std::fmt::Display,
    ) -> Arc<Histogram> {
        self.histogram(&labeled(name, label, value))
    }

    /// One-shot conveniences for cold paths.
    pub fn set_gauge(&self, name: &str, v: u64) {
        self.gauge(name).set(v);
    }

    pub fn record(&self, name: &str, v: f64) {
        self.histogram(name).record(v);
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let counters = self
            .counters
            .lock()
            .expect("metrics registry")
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .expect("metrics registry")
            .iter()
            .map(|(k, g)| (k.clone(), g.get()))
            .collect();
        let hists = self
            .hists
            .lock()
            .expect("metrics registry")
            .iter()
            .map(|(k, h)| (k.clone(), h.stat()))
            .collect();
        RegistrySnapshot { counters, gauges, hists }
    }
}

/// Point-in-time copy of a [`MetricsRegistry`], sorted by name.
#[derive(Clone, Debug, Default)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistStat>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_returns_same_metric() {
        let reg = MetricsRegistry::new();
        reg.counter("a").add(2);
        reg.counter("a").inc();
        assert_eq!(reg.counter("a").get(), 3);
        reg.gauge("g").set(7);
        assert_eq!(reg.gauge("g").get(), 7);
        reg.histogram("h").record(1.0);
        reg.histogram("h").record(2.0);
        assert_eq!(reg.histogram("h").count(), 2);
    }

    #[test]
    fn snapshot_carries_every_registered_metric() {
        let reg = MetricsRegistry::new();
        reg.counter("c.one").add(5);
        reg.set_gauge("g.two", 9);
        reg.record("h.three", 0.25);
        let s = reg.snapshot();
        assert_eq!(s.counters["c.one"], 5);
        assert_eq!(s.gauges["g.two"], 9);
        assert_eq!(s.hists["h.three"].count, 1);
        assert_eq!(s.hists["h.three"].sum, 0.25);
    }

    #[test]
    fn labeled_metrics_compose_parse_and_snapshot() {
        assert_eq!(labeled(names::TTFT, "tenant", 3), "serve.ttft_s{tenant=3}");
        assert_eq!(
            parse_labeled("serve.ttft_s{tenant=3}"),
            Some(("serve.ttft_s", "tenant", "3"))
        );
        assert_eq!(parse_labeled(names::TTFT), None);

        let reg = MetricsRegistry::new();
        reg.histogram_labeled(names::TTFT, "tenant", 0).record(0.1);
        reg.histogram_labeled(names::TTFT, "tenant", 1).record(0.2);
        reg.counter_labeled(names::REQUESTS, "tenant", 1).inc();
        reg.gauge_labeled(names::KV_PAGES_USED, "tenant", 1).set(5);
        // Labeled handles are distinct metrics under the composed name.
        let s = reg.snapshot();
        assert_eq!(s.hists["serve.ttft_s{tenant=0}"].count, 1);
        assert_eq!(s.hists["serve.ttft_s{tenant=1}"].count, 1);
        assert_eq!(s.counters["serve.requests{tenant=1}"], 1);
        assert_eq!(s.gauges["kv.pages_used{tenant=1}"], 5);
        // Same label → same underlying metric.
        reg.counter_labeled(names::REQUESTS, "tenant", 1).inc();
        assert_eq!(reg.counter_labeled(names::REQUESTS, "tenant", 1).get(), 2);
    }

    #[test]
    fn handles_record_lock_free_across_threads() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram(names::STEP_TIME);
        let c = reg.counter("steps");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = Arc::clone(&h);
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..500 {
                        h.record(1e-3);
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(h.count(), 2000);
        assert_eq!(c.get(), 2000);
    }
}
