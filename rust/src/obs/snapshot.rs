//! The typed, serializable metrics snapshot and its single formatter.
//!
//! [`MetricsSnapshot`] is what [`Engine::metrics_snapshot`]
//! (`crate::coordinator::engine::Engine::metrics_snapshot`) returns: a
//! point-in-time copy of every serving scalar, the fault and KV-pool
//! counters, the span-trace health, and every registry histogram as a
//! bounded-error [`HistStat`]. `to_json()` is the shape `serve
//! --metrics-out METRICS.json` writes; [`MetricsSnapshot::rows`] is the
//! one formatter behind the CLI serving report — CLI output, JSON
//! export and bench probes all read the same struct, so they cannot
//! disagree on a field.

use super::hist::HistStat;
use super::registry::{names, parse_labeled};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Request/throughput scalars (the old `ServeStats` surface).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSection {
    pub requests: u64,
    pub cancelled: u64,
    pub timed_out: u64,
    pub failed: u64,
    pub shed: u64,
    pub retries: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub batched_tokens: u64,
    pub wall_s: f64,
    pub throughput_tps: f64,
    pub mean_batch_occupancy: f64,
    pub peak_concurrency: usize,
}

/// Speculative-decoding scalars.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecSection {
    pub drafted: u64,
    pub accepted: u64,
    pub acceptance_rate: f64,
}

/// Fault-path scalars (mirrors `FaultCounters`).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSection {
    pub panics_recovered: u64,
    pub restarts: u64,
    pub timeouts: u64,
    pub sheds: u64,
    pub retries: u64,
}

/// KV page-pool scalars (mirrors `KvGauges`).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvSection {
    pub page_size: u64,
    pub pages_capacity: u64,
    pub pages_used: u64,
    pub pages_peak: u64,
    pub pages_leaked: u64,
    pub prefix_hits: u64,
    pub preemptions: u64,
}

/// Span-trace ring health.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSection {
    pub events_retained: u64,
    pub events_dropped: u64,
}

/// See the [module docs](self).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub serve: ServeSection,
    pub spec: SpecSection,
    pub faults: FaultSection,
    pub kv: KvSection,
    pub trace: TraceSection,
    /// Every registry counter, sorted by name.
    pub counters: BTreeMap<String, u64>,
    /// Every registry gauge, sorted by name.
    pub gauges: BTreeMap<String, u64>,
    /// Every histogram (TTFT, queue wait, step time, prefill chunk,
    /// spec rounds, per-path kernel timings), sorted by name.
    pub hists: BTreeMap<String, HistStat>,
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

impl MetricsSnapshot {
    /// Histogram stat by name (`obs::names::*`); zeroed when the
    /// histogram never recorded.
    pub fn hist(&self, name: &str) -> HistStat {
        self.hists.get(name).copied().unwrap_or_default()
    }

    /// The METRICS.json shape.
    pub fn to_json(&self) -> Json {
        let mut serve = Json::obj();
        serve
            .set("requests", num(self.serve.requests))
            .set("cancelled", num(self.serve.cancelled))
            .set("timed_out", num(self.serve.timed_out))
            .set("failed", num(self.serve.failed))
            .set("shed", num(self.serve.shed))
            .set("retries", num(self.serve.retries))
            .set("tokens_generated", num(self.serve.tokens_generated))
            .set("decode_steps", num(self.serve.decode_steps))
            .set("batched_tokens", num(self.serve.batched_tokens))
            .set("wall_s", Json::Num(self.serve.wall_s))
            .set("throughput_tps", Json::Num(self.serve.throughput_tps))
            .set("mean_batch_occupancy", Json::Num(self.serve.mean_batch_occupancy))
            .set("peak_concurrency", num(self.serve.peak_concurrency as u64));
        let mut spec = Json::obj();
        spec.set("drafted", num(self.spec.drafted))
            .set("accepted", num(self.spec.accepted))
            .set("acceptance_rate", Json::Num(self.spec.acceptance_rate));
        let mut faults = Json::obj();
        faults
            .set("panics_recovered", num(self.faults.panics_recovered))
            .set("restarts", num(self.faults.restarts))
            .set("timeouts", num(self.faults.timeouts))
            .set("sheds", num(self.faults.sheds))
            .set("retries", num(self.faults.retries));
        let mut kv = Json::obj();
        kv.set("page_size", num(self.kv.page_size))
            .set("pages_capacity", num(self.kv.pages_capacity))
            .set("pages_used", num(self.kv.pages_used))
            .set("pages_peak", num(self.kv.pages_peak))
            .set("pages_leaked", num(self.kv.pages_leaked))
            .set("prefix_hits", num(self.kv.prefix_hits))
            .set("preemptions", num(self.kv.preemptions));
        let mut trace = Json::obj();
        trace
            .set("events_retained", num(self.trace.events_retained))
            .set("events_dropped", num(self.trace.events_dropped));
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, num(*v));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, num(*v));
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            hists.set(k, h.to_json());
        }
        let mut root = Json::obj();
        root.set("schema", Json::Str("ams-metrics/1".to_string()))
            .set("serve", serve)
            .set("spec", spec)
            .set("faults", faults)
            .set("kv", kv)
            .set("trace", trace)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("hists", hists);
        root
    }

    /// The single `(metric, value)` row formatter behind the CLI
    /// serving report. Every consumer renders these rows; nothing
    /// formats snapshot fields by hand.
    pub fn rows(&self) -> Vec<(String, String)> {
        fn f(v: f64, places: usize) -> String {
            format!("{v:.places$}")
        }
        let s = &self.serve;
        let lat = self.hist(names::LATENCY);
        let ttft = self.hist(names::TTFT);
        let step = self.hist(names::STEP_TIME);
        let queue = self.hist(names::QUEUE_WAIT);
        let mut rows: Vec<(String, String)> = vec![
            ("requests".into(), s.requests.to_string()),
            ("tokens generated".into(), s.tokens_generated.to_string()),
            ("wall s".into(), f(s.wall_s, 3)),
            ("throughput tok/s".into(), f(s.throughput_tps, 1)),
            ("mean batch occupancy".into(), f(s.mean_batch_occupancy, 2)),
            ("latency p50 s".into(), f(lat.p50, 3)),
            ("latency p90 s".into(), f(lat.p90, 3)),
            ("latency p99 s".into(), f(lat.p99, 3)),
            ("ttft p50 s".into(), f(ttft.p50, 4)),
            ("ttft p90 s".into(), f(ttft.p90, 4)),
            ("ttft p99 s".into(), f(ttft.p99, 4)),
            ("queue wait p90 s".into(), f(queue.p90, 4)),
            ("step time p50 s".into(), f(step.p50, 5)),
            ("step time p99 s".into(), f(step.p99, 5)),
            // Degradation is part of the report: a run that recovered
            // from faults or shed load should say so, not hide it in a
            // lower request count.
            ("cancelled".into(), s.cancelled.to_string()),
            ("timed out".into(), s.timed_out.to_string()),
            ("failed".into(), s.failed.to_string()),
            ("shed".into(), s.shed.to_string()),
            ("retries".into(), s.retries.to_string()),
            ("panics recovered".into(), self.faults.panics_recovered.to_string()),
            ("replica restarts".into(), self.faults.restarts.to_string()),
            // Paged-KV economics: pool pressure, prefix reuse and the
            // preemptions paid for over-committing pages.
            ("kv page size".into(), self.kv.page_size.to_string()),
            ("kv pages peak".into(), self.kv.pages_peak.to_string()),
            ("kv pages leaked".into(), self.kv.pages_leaked.to_string()),
            ("kv prefix hits".into(), self.kv.prefix_hits.to_string()),
            ("kv preemptions".into(), self.kv.preemptions.to_string()),
            ("peak concurrency".into(), s.peak_concurrency.to_string()),
            // Speculative economics; rows stay in the report even when
            // speculation is off (all zero) so downstream parsers see a
            // stable schema.
            ("tokens drafted".into(), self.spec.drafted.to_string()),
            ("drafts accepted".into(), self.spec.accepted.to_string()),
            ("acceptance rate".into(), f(self.spec.acceptance_rate, 3)),
            ("trace events retained".into(), self.trace.events_retained.to_string()),
            ("trace events dropped".into(), self.trace.events_dropped.to_string()),
        ];
        // Per-path kernel timings, only when something sampled (the
        // scalar rows above are schema-stable; the kernel rows are
        // diagnostics).
        for name in [names::GEMM_STREAM_DIRECT, names::GEMM_BUFFERED, names::GEMM_HI_ONLY] {
            let h = self.hist(name);
            if h.count > 0 {
                rows.push((format!("{name} p50/p99"), format!("{:.2e}/{:.2e}", h.p50, h.p99)));
            }
        }
        // Per-tenant latency breakdown — present only when requests
        // carried tenant labels (multi-tenant serving); single-tenant
        // runs keep the exact legacy row set. BTreeMap order keeps the
        // tenants sorted.
        for (name, h) in &self.hists {
            if h.count == 0 {
                continue;
            }
            if let Some((base, "tenant", t)) = parse_labeled(name) {
                if base == names::TTFT {
                    rows.push((
                        format!("ttft p50/p99 s tenant={t}"),
                        format!("{:.4}/{:.4}", h.p50, h.p99),
                    ));
                } else if base == names::LATENCY {
                    rows.push((
                        format!("latency p50/p99 s tenant={t}"),
                        format!("{:.3}/{:.3}", h.p50, h.p99),
                    ));
                }
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        snap.serve.requests = 12;
        snap.serve.tokens_generated = 384;
        snap.serve.wall_s = 1.5;
        snap.serve.throughput_tps = 256.0;
        snap.kv.pages_peak = 40;
        snap.trace.events_dropped = 3;
        snap.hists.insert(
            names::TTFT.to_string(),
            HistStat {
                count: 12,
                sum: 0.6,
                mean: 0.05,
                min: 0.01,
                max: 0.2,
                p50: 0.04,
                p90: 0.1,
                p99: 0.19,
                rejected: 0,
            },
        );
        snap.counters.insert("serve.requests".into(), 12);
        snap.gauges.insert(names::KV_PAGES_USED.into(), 7);
        snap
    }

    #[test]
    fn json_round_trips_and_carries_percentiles() {
        let snap = sample();
        let text = snap.to_json().to_string_pretty();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("serve").unwrap().req_usize("requests").unwrap(), 12);
        let ttft = doc.get("hists").unwrap().get(names::TTFT).unwrap();
        assert_eq!(ttft.req_f64("p90").unwrap(), 0.1);
        assert_eq!(ttft.req_f64("p99").unwrap(), 0.19);
        assert_eq!(doc.get("gauges").unwrap().req_usize(names::KV_PAGES_USED).unwrap(), 7);
        assert_eq!(
            doc.get("trace").unwrap().req_usize("events_dropped").unwrap(),
            3
        );
    }

    #[test]
    fn rows_and_json_agree_on_the_same_fields() {
        let snap = sample();
        let rows = snap.rows();
        let lookup = |k: &str| {
            rows.iter()
                .find(|(name, _)| name == k)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| panic!("missing row {k}"))
        };
        assert_eq!(lookup("requests"), "12");
        assert_eq!(lookup("ttft p90 s"), "0.1000");
        assert_eq!(lookup("trace events dropped"), "3");
        // Same values through the JSON path — one source, two renders.
        let doc = json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(
            doc.get("hists").unwrap().get(names::TTFT).unwrap().req_f64("p90").unwrap(),
            0.1
        );
    }

    /// Labeled (per-tenant) histograms surface as extra report rows and
    /// flow through METRICS.json under their composed names; runs with
    /// no tenant labels keep the legacy row set untouched.
    #[test]
    fn tenant_labeled_hists_add_rows_and_json_entries() {
        let mut snap = sample();
        assert!(!snap.rows().iter().any(|(k, _)| k.contains("tenant=")));
        snap.hists.insert(
            crate::obs::labeled(names::TTFT, "tenant", 1),
            HistStat { count: 4, p50: 0.02, p99: 0.09, ..HistStat::default() },
        );
        snap.hists.insert(
            crate::obs::labeled(names::LATENCY, "tenant", 1),
            HistStat { count: 4, p50: 0.5, p99: 1.25, ..HistStat::default() },
        );
        // Zero-count labels stay out of the report.
        snap.hists
            .insert(crate::obs::labeled(names::TTFT, "tenant", 2), HistStat::default());
        let rows = snap.rows();
        let lookup = |k: &str| {
            rows.iter()
                .find(|(name, _)| name == k)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| panic!("missing row {k}"))
        };
        assert_eq!(lookup("ttft p50/p99 s tenant=1"), "0.0200/0.0900");
        assert_eq!(lookup("latency p50/p99 s tenant=1"), "0.500/1.250");
        assert!(!rows.iter().any(|(k, _)| k.contains("tenant=2")));
        let doc = json::parse(&snap.to_json().to_string()).unwrap();
        let labeled = doc.get("hists").unwrap().get("serve.ttft_s{tenant=1}").unwrap();
        assert_eq!(labeled.req_f64("p99").unwrap(), 0.09);
    }

    #[test]
    fn missing_histograms_render_zeroed_not_panic() {
        let snap = MetricsSnapshot::default();
        let rows = snap.rows();
        assert!(rows.iter().any(|(k, v)| k == "latency p50 s" && v == "0.000"));
        assert!(!rows.iter().any(|(k, _)| k.starts_with("gemm.")));
    }
}
