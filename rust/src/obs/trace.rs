//! Per-request span timelines in bounded per-replica ring buffers,
//! exportable as Chrome trace-event JSON (open in Perfetto or
//! `chrome://tracing`).
//!
//! The scheduler emits typed [`SpanKind`] events as a request moves
//! through its lifecycle:
//!
//! ```text
//! Queued → Admitted → PrefillChunk×n → DecodeStep/SpecRound×n
//!        → (Preempted → Resumed)* → exactly one terminal
//!          (Done | Cancelled | TimedOut | Failed)
//! ```
//!
//! Timestamps are microseconds on a single monotonic epoch shared by
//! every replica, so cross-replica interleaving (preemption storms,
//! chunked-prefill fairness, spec acceptance collapse) lines up on one
//! Perfetto timeline. Each replica owns a bounded ring: when it fills,
//! the **oldest** events are dropped and counted — export degrades
//! gracefully instead of growing without bound or panicking (the
//! `trace-buffer` failpoint forces this wraparound mid-run in chaos
//! tests). Duration events (`PrefillChunk`, `DecodeStep`, `SpecRound`)
//! become Chrome complete events (`ph:"X"`); lifecycle markers become
//! instants (`ph:"i"`). Replica index maps to `tid`, so each replica's
//! schedule renders as its own track.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Typed span event kinds — the request lifecycle plus scheduler
/// interventions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Accepted by the engine and dispatched to a replica queue.
    Queued,
    /// Admitted from the queue into the running batch.
    Admitted,
    /// One chunked-prefill forward (duration).
    PrefillChunk,
    /// One plain batched decode step (duration).
    DecodeStep,
    /// One speculative draft+verify round (duration).
    SpecRound,
    /// Parked to relieve KV page-pool pressure.
    Preempted,
    /// Un-parked back into the running batch.
    Resumed,
    /// Terminal: finished normally.
    Done,
    /// Terminal: cancelled by the caller.
    Cancelled,
    /// Terminal: a queue/total deadline expired.
    TimedOut,
    /// Terminal: replica panic or unservable request.
    Failed,
}

impl SpanKind {
    /// Event name in the exported trace.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Queued => "queued",
            SpanKind::Admitted => "admitted",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::SpecRound => "spec_round",
            SpanKind::Preempted => "preempted",
            SpanKind::Resumed => "resumed",
            SpanKind::Done => "done",
            SpanKind::Cancelled => "cancelled",
            SpanKind::TimedOut => "timed_out",
            SpanKind::Failed => "failed",
        }
    }

    /// Trace category (`cat`) — the phase the event belongs to. The
    /// acceptance smoke asserts ≥ 4 distinct categories show up in a
    /// speculative chaos run.
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Queued | SpanKind::Admitted => "queue",
            SpanKind::PrefillChunk => "prefill",
            SpanKind::DecodeStep => "decode",
            SpanKind::SpecRound => "spec",
            SpanKind::Preempted | SpanKind::Resumed => "sched",
            SpanKind::Done | SpanKind::Cancelled | SpanKind::TimedOut | SpanKind::Failed => {
                "terminal"
            }
        }
    }

    /// Done, Cancelled, TimedOut or Failed — exactly one per request.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            SpanKind::Done | SpanKind::Cancelled | SpanKind::TimedOut | SpanKind::Failed
        )
    }

    /// True for events that carry a duration (Chrome `ph:"X"`).
    pub fn has_duration(self) -> bool {
        matches!(
            self,
            SpanKind::PrefillChunk | SpanKind::DecodeStep | SpanKind::SpecRound
        )
    }
}

/// One recorded span event.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    /// Request id ([`crate::coordinator::GenRequest::id`]).
    pub req: u64,
    pub kind: SpanKind,
    /// Microseconds since the sink's epoch (start of the span for
    /// duration events).
    pub ts_us: u64,
    /// Span length in microseconds; 0 for instant events.
    pub dur_us: u64,
}

struct Ring {
    events: VecDeque<SpanEvent>,
}

/// Bounded per-replica span sink. See the [module docs](self).
pub struct TraceSink {
    epoch: Instant,
    rings: Vec<Mutex<Ring>>,
    cap_per_replica: usize,
    dropped: AtomicU64,
}

/// Default ring capacity per replica (events, not requests).
pub const DEFAULT_RING_CAP: usize = 65_536;

impl TraceSink {
    pub fn new(replicas: usize, cap_per_replica: usize) -> Arc<TraceSink> {
        let cap = cap_per_replica.max(1);
        Arc::new(TraceSink {
            epoch: Instant::now(),
            rings: (0..replicas.max(1))
                .map(|_| Mutex::new(Ring { events: VecDeque::new() }))
                .collect(),
            cap_per_replica: cap,
            dropped: AtomicU64::new(0),
        })
    }

    /// Microseconds since this sink's epoch — the shared monotonic
    /// timebase for every event.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record an event on a replica's ring, dropping the oldest event
    /// when the ring is full.
    pub fn push(&self, replica: usize, ev: SpanEvent) {
        let ring = &self.rings[replica.min(self.rings.len() - 1)];
        let mut r = ring.lock().expect("trace ring");
        if r.events.len() >= self.cap_per_replica {
            r.events.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        r.events.push_back(ev);
    }

    /// Record an instant event stamped now.
    pub fn instant(&self, replica: usize, req: u64, kind: SpanKind) {
        let ts_us = self.now_us();
        self.push(replica, SpanEvent { req, kind, ts_us, dur_us: 0 });
    }

    /// Record a duration event that started at `start_us` (from
    /// [`TraceSink::now_us`]) and ends now.
    pub fn span(&self, replica: usize, req: u64, kind: SpanKind, start_us: u64) {
        let now = self.now_us();
        self.push(
            replica,
            SpanEvent { req, kind, ts_us: start_us, dur_us: now.saturating_sub(start_us) },
        );
    }

    /// Forced wraparound: drop the oldest half of a replica's ring (the
    /// `trace-buffer` failpoint's degradation path). Counters stay
    /// intact and retained events keep their order.
    pub fn force_wrap(&self, replica: usize) {
        let ring = &self.rings[replica.min(self.rings.len() - 1)];
        let mut r = ring.lock().expect("trace ring");
        let drop_n = r.events.len() / 2;
        r.events.drain(..drop_n);
        self.dropped.fetch_add(drop_n as u64, Ordering::Relaxed);
    }

    /// Events dropped to wraparound (forced or capacity).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Retained events across every replica as `(replica, event)`,
    /// sorted by timestamp.
    pub fn events(&self) -> Vec<(usize, SpanEvent)> {
        let mut out = Vec::new();
        for (tid, ring) in self.rings.iter().enumerate() {
            let r = ring.lock().expect("trace ring");
            out.extend(r.events.iter().map(|&e| (tid, e)));
        }
        out.sort_by_key(|&(_, e)| e.ts_us);
        out
    }

    /// Total retained events.
    pub fn len(&self) -> usize {
        self.rings
            .iter()
            .map(|r| r.lock().expect("trace ring").events.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export as Chrome trace-event JSON: `{"traceEvents": [...]}` with
    /// one process, one thread track per replica. Duration events are
    /// complete events (`ph:"X"` with `ts`+`dur`), lifecycle markers are
    /// thread-scoped instants (`ph:"i"`, `s:"t"`). Open the file
    /// directly in <https://ui.perfetto.dev> or `chrome://tracing`.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for (tid, ev) in self.events() {
            let mut args = Json::obj();
            args.set("req", Json::Num(ev.req as f64));
            let mut o = Json::obj();
            o.set("name", Json::Str(ev.kind.name().to_string()))
                .set("cat", Json::Str(ev.kind.category().to_string()))
                .set("ts", Json::Num(ev.ts_us as f64))
                .set("pid", Json::Num(0.0))
                .set("tid", Json::Num(tid as f64))
                .set("args", args);
            if ev.kind.has_duration() {
                o.set("ph", Json::Str("X".to_string()))
                    .set("dur", Json::Num(ev.dur_us as f64));
            } else {
                o.set("ph", Json::Str("i".to_string()))
                    .set("s", Json::Str("t".to_string()));
            }
            events.push(o);
        }
        let mut root = Json::obj();
        root.set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", Json::Str("ms".to_string()))
            .set("dropped_events", Json::Num(self.dropped() as f64));
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timestamps_are_monotone_per_replica() {
        let sink = TraceSink::new(2, 64);
        for i in 0..10 {
            sink.instant(i % 2, i as u64, SpanKind::DecodeStep);
        }
        for tid in 0..2 {
            let ts: Vec<u64> = sink
                .events()
                .into_iter()
                .filter(|&(t, _)| t == tid)
                .map(|(_, e)| e.ts_us)
                .collect();
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "replica {tid}: {ts:?}");
        }
    }

    #[test]
    fn ring_drops_oldest_on_overflow() {
        let sink = TraceSink::new(1, 4);
        for i in 0..10u64 {
            sink.push(0, SpanEvent { req: i, kind: SpanKind::DecodeStep, ts_us: i, dur_us: 0 });
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        let reqs: Vec<u64> = sink.events().iter().map(|&(_, e)| e.req).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9], "newest events retained in order");
    }

    #[test]
    fn force_wrap_halves_ring_and_counts_drops() {
        let sink = TraceSink::new(1, 64);
        for i in 0..10u64 {
            sink.push(0, SpanEvent { req: i, kind: SpanKind::DecodeStep, ts_us: i, dur_us: 0 });
        }
        sink.force_wrap(0);
        assert_eq!(sink.len(), 5);
        assert_eq!(sink.dropped(), 5);
        let reqs: Vec<u64> = sink.events().iter().map(|&(_, e)| e.req).collect();
        assert_eq!(reqs, vec![5, 6, 7, 8, 9]);
    }

    #[test]
    fn chrome_export_is_wellformed() {
        let sink = TraceSink::new(2, 64);
        sink.instant(0, 1, SpanKind::Queued);
        let t0 = sink.now_us();
        sink.span(0, 1, SpanKind::PrefillChunk, t0);
        sink.instant(1, 2, SpanKind::Done);
        let doc = sink.to_chrome_json();
        let text = doc.to_string();
        let parsed = crate::util::json::parse(&text).expect("round-trips through the parser");
        let evs = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(evs.len(), 3);
        for e in evs {
            for field in ["name", "cat", "ph", "ts", "pid", "tid"] {
                assert!(e.get(field).is_some(), "event lacks {field}: {e:?}");
            }
        }
        let durs: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .map(|e| e.get("name").and_then(|n| n.as_str()).unwrap())
            .collect();
        assert_eq!(durs, vec!["prefill_chunk"]);
    }

    #[test]
    fn terminal_kinds_are_exactly_the_four() {
        use SpanKind::*;
        for k in [Queued, Admitted, PrefillChunk, DecodeStep, SpecRound, Preempted, Resumed] {
            assert!(!k.is_terminal());
        }
        for k in [Done, Cancelled, TimedOut, Failed] {
            assert!(k.is_terminal());
        }
    }
}
