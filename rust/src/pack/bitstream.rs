//! Dense little-endian bit stream over u16 words — the generic fallback
//! packer for formats without a specialized layout, and the reference the
//! specialized layouts are validated against (equal word counts).

/// Writes values LSB-first into a u16 word slice.
pub struct BitWriter<'a> {
    words: &'a mut [u16],
    bitpos: usize,
}

impl<'a> BitWriter<'a> {
    pub fn new(words: &'a mut [u16]) -> Self {
        BitWriter { words, bitpos: 0 }
    }

    /// Append the low `bits` bits of `v`.
    pub fn put(&mut self, v: u32, bits: u32) {
        debug_assert!(bits <= 16);
        let mut v = v & ((1u32 << bits) - 1);
        let mut remaining = bits as usize;
        while remaining > 0 {
            let word = self.bitpos / 16;
            let off = self.bitpos % 16;
            let take = remaining.min(16 - off);
            self.words[word] |= ((v & ((1 << take) - 1)) as u16) << off;
            v >>= take;
            self.bitpos += take;
            remaining -= take;
        }
    }

    pub fn bits_written(&self) -> usize {
        self.bitpos
    }
}

/// Reads values LSB-first from a u16 word slice.
pub struct BitReader<'a> {
    words: &'a [u16],
    bitpos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u16]) -> Self {
        BitReader { words, bitpos: 0 }
    }

    pub fn get(&mut self, bits: u32) -> u32 {
        debug_assert!(bits <= 16);
        let mut out = 0u32;
        let mut got = 0usize;
        let mut remaining = bits as usize;
        while remaining > 0 {
            let word = self.bitpos / 16;
            let off = self.bitpos % 16;
            let take = remaining.min(16 - off);
            let chunk = (u32::from(self.words[word]) >> off) & ((1 << take) - 1);
            out |= chunk << got;
            got += take;
            self.bitpos += take;
            remaining -= take;
        }
        out
    }

    pub fn skip(&mut self, bits: usize) {
        self.bitpos += bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut rng = Rng::new(1);
        let widths = [1u32, 3, 5, 7, 11, 13, 16];
        let vals: Vec<(u32, u32)> = (0..200)
            .map(|i| {
                let b = widths[i % widths.len()];
                ((rng.next_u32()) & ((1u32 << b) - 1), b)
            })
            .collect();
        let total_bits: usize = vals.iter().map(|&(_, b)| b as usize).sum();
        let mut words = vec![0u16; total_bits.div_ceil(16)];
        let mut w = BitWriter::new(&mut words);
        for &(v, b) in &vals {
            w.put(v, b);
        }
        assert_eq!(w.bits_written(), total_bits);
        let mut r = BitReader::new(&words);
        for &(v, b) in &vals {
            assert_eq!(r.get(b), v);
        }
    }

    #[test]
    fn cross_word_boundary() {
        let mut words = vec![0u16; 2];
        let mut w = BitWriter::new(&mut words);
        w.put(0x1FFF, 13);
        w.put(0x5, 3);
        w.put(0xAB, 8);
        let mut r = BitReader::new(&words);
        assert_eq!(r.get(13), 0x1FFF);
        assert_eq!(r.get(3), 0x5);
        assert_eq!(r.get(8), 0xAB);
    }

    #[test]
    fn skip_advances() {
        let words = [0xFFFFu16, 0x0001];
        let mut r = BitReader::new(&words);
        r.skip(16);
        assert_eq!(r.get(1), 1);
        assert_eq!(r.get(1), 0);
    }

    #[test]
    fn masks_extra_high_bits() {
        let mut words = vec![0u16; 1];
        let mut w = BitWriter::new(&mut words);
        w.put(0xFFFF_FFFF, 4); // only low 4 bits land
        let mut r = BitReader::new(&words);
        assert_eq!(r.get(4), 0xF);
        assert_eq!(r.get(12), 0);
    }
}
