//! Prepacked weight storage (§3.2 / §3.3 of the paper).
//!
//! Quantized codes are packed ahead of time into `u16` words so the runtime
//! streams only regular-width memory. Per scheme (bits/weight in the limit):
//!
//! - **FP16**: native half words (16).
//! - **FP8-e4m3 / INT8**: two codes per word (8).
//! - **FP6 (e2m3/e3m2), TC-FPx (4+2)**: a high-4-bit segment stream (4
//!   codes/word) plus a low-2-bit segment stream (8 codes/word) → 6.
//! - **FP5 (4+1)**: high-4 stream + mantissa-LSB stream (16/word) → 5.
//! - **FP5.33 (e2m3, k=3)**: *continuous packing*: one u16 holds three
//!   5-bit high segments and the shared LSB — the paper's special case
//!   where a group fits a half-word exactly → 16/3 ≈ 5.33.
//! - **FP4.5 / FP4.33 / FP4.25 (e2m2, k∈{2,3,4})**: high-4 stream + one
//!   shared bit per group (16 groups/word) → 4 + 1/k.
//! - **INT4**: four codes per word (4).
//! - **other AMS formats**: generic dense bit-stream fallback.
//!
//! Each row (output channel) is packed independently and starts word-
//! aligned; within a row the high-segment region precedes the shared/low
//! region. Relative to the paper's 16-weight tiles this is a row-level
//! segmentation — identical word counts and streaming behaviour, simpler
//! addressing (documented deviation, DESIGN.md §7).
//!
//! **Scale streams.** Every packed tensor carries one f32 scale per row.
//! Group-wise quantization (`Granularity::PerGroup(g)`, the
//! FineQuant/M-ANT axis) additionally carries a [`GroupScales`] stream:
//! `ceil(cols/g)` f32 scales per row at a fixed per-row stride, so each
//! row's group scales start word-aligned and are sliced without division.
//! For per-group tensors the per-row scales are identity (1.0) — the
//! group scale is folded into the decode by the fused kernels, the same
//! way the exponent rebias is folded today (see
//! [`crate::gemm`]).

pub mod bitstream;

use crate::formats::registry::Scheme;
use crate::formats::FpFormat;
use crate::quant::{Granularity, QuantError, QuantizedTensor, ShareDim};
use crate::tensor::Tensor;
use bitstream::{BitReader, BitWriter};

/// Per-group scale stream of a group-wise quantized [`PackedTensor`]:
/// row-major `[rows, groups_per_row]`, each row starting at
/// `r * groups_per_row` (word-aligned per row; tail groups of a ragged
/// row share the stride).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupScales {
    /// Contiguous group width along the input dimension.
    pub group_size: usize,
    /// `ceil(cols / group_size)` — the per-row stride of `scales`.
    pub groups_per_row: usize,
    /// `rows * groups_per_row` scales.
    pub scales: Vec<f32>,
}

impl GroupScales {
    /// One row's group scales.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.scales[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }
}

/// Packed weights ready for the GEMV hot path / PJRT buffers.
#[derive(Clone, Debug)]
pub struct PackedTensor {
    pub scheme: Scheme,
    pub rows: usize,
    pub cols: usize,
    /// All rows' words, row-major, `row_stride` words per row.
    pub words: Vec<u16>,
    pub row_stride: usize,
    /// One scale per row (identity when `group_scales` carries the real
    /// scales).
    pub scales: Vec<f32>,
    /// Per-group scale stream — `Some` iff the tensor was quantized with
    /// `Granularity::PerGroup`.
    pub group_scales: Option<GroupScales>,
}

impl PackedTensor {
    /// Validated constructor: every stream is cross-checked against the
    /// declared geometry, so a truncated words / row-scale / group-scale
    /// stream fails *here* — at pack or checkpoint-load time — with a
    /// typed [`QuantError`] instead of indexing out of bounds (or
    /// decoding garbage) in the serve hot path. `row_stride` is derived
    /// from the scheme; callers with an externally declared stride
    /// compare it first.
    pub fn new(
        scheme: Scheme,
        rows: usize,
        cols: usize,
        words: Vec<u16>,
        scales: Vec<f32>,
        group_scales: Option<GroupScales>,
    ) -> Result<PackedTensor, QuantError> {
        let row_stride = row_stride(scheme, cols);
        if words.len() != rows * row_stride {
            return Err(QuantError::StreamGeometry {
                stream: "packed words",
                expected: rows * row_stride,
                got: words.len(),
            });
        }
        if scales.len() != rows {
            return Err(QuantError::StreamGeometry {
                stream: "row scales",
                expected: rows,
                got: scales.len(),
            });
        }
        if let Some(gs) = &group_scales {
            if gs.group_size == 0 {
                return Err(QuantError::InvalidGroupSize { g: 0, reason: "must be positive" });
            }
            let groups = cols.div_ceil(gs.group_size);
            if gs.groups_per_row != groups {
                return Err(QuantError::StreamGeometry {
                    stream: "groups per row",
                    expected: groups,
                    got: gs.groups_per_row,
                });
            }
            if gs.scales.len() != rows * groups {
                return Err(QuantError::StreamGeometry {
                    stream: "group scales",
                    expected: rows * groups,
                    got: gs.scales.len(),
                });
            }
        }
        Ok(PackedTensor {
            scheme,
            rows,
            cols,
            words,
            row_stride,
            scales,
            group_scales,
        })
    }

    pub fn row_words(&self, r: usize) -> &[u16] {
        &self.words[r * self.row_stride..(r + 1) * self.row_stride]
    }

    /// One row's words split into the (high/primary, low/shared) segment
    /// streams — the addressable unit of the stream-direct grouped
    /// kernels. Single-stream layouts (FP16, byte codes, dense
    /// bit-streams) return the whole row as the primary stream and an
    /// empty low stream.
    pub fn row_streams(&self, r: usize) -> (&[u16], &[u16]) {
        let words = self.row_words(r);
        let hi = hi_stream_words(self.scheme, self.cols).min(words.len());
        words.split_at(hi)
    }

    /// Total storage bytes for the quantized payload (excludes scales).
    pub fn payload_bytes(&self) -> usize {
        self.words.len() * 2
    }

    /// Bytes of the f32 scale streams (per-row scales + the per-group
    /// stream when present). Not part of [`PackedTensor::payload_bytes`]
    /// / [`PackedTensor::bits_per_weight`]: per-row scales are constant
    /// across schemes, but per-group scales add a real `32/g` bits per
    /// weight that size accounting must not hide.
    pub fn scale_bytes(&self) -> usize {
        let group = self.group_scales.as_ref().map_or(0, |gs| gs.scales.len());
        (self.scales.len() + group) * 4
    }

    /// Achieved bits per weight of the packed code payload (includes
    /// row-alignment padding, excludes the scale streams — see
    /// [`PackedTensor::scale_bytes`]).
    pub fn bits_per_weight(&self) -> f64 {
        (self.payload_bytes() * 8) as f64 / (self.rows * self.cols) as f64
    }

    /// Effective scale granularity of this tensor.
    pub fn granularity(&self) -> Granularity {
        match &self.group_scales {
            Some(gs) => Granularity::PerGroup(gs.group_size),
            None => Granularity::PerChannel,
        }
    }

    /// The scale applied to element `(r, c)` at dequantization.
    #[inline]
    pub fn scale_for(&self, r: usize, c: usize) -> f32 {
        match &self.group_scales {
            Some(gs) => gs.scales[r * gs.groups_per_row + c / gs.group_size],
            None => self.scales[r],
        }
    }

    /// Reference dequantization: unpack every row, decode through the
    /// scheme's table, apply the per-row or per-group scale. The oracle
    /// the fused GEMV/GEMM kernels are parity-tested against.
    pub fn dequantize(&self) -> Tensor {
        let table = crate::gemm::dequant_table(self.scheme);
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let mut codes = vec![0u16; self.cols];
        for r in 0..self.rows {
            unpack_row(self.scheme, self.row_words(r), self.cols, &mut codes);
            let orow = out.row_mut(r);
            for (c, o) in orow.iter_mut().enumerate() {
                *o = table[codes[c] as usize] * self.scale_for(r, c);
            }
        }
        out
    }
}

/// Words of the high/primary segment stream at the front of each packed
/// row — the split point of [`PackedTensor::row_streams`]. Equal to the
/// full [`row_stride`] for single-stream layouts.
pub fn hi_stream_words(scheme: Scheme, cols: usize) -> usize {
    match scheme {
        // Two-stream layouts: a 4-bit high-segment stream precedes the
        // low/shared-bit stream.
        Scheme::Fp(f) if f.bits() == 6 || f.bits() == 5 => cols.div_ceil(4),
        Scheme::Ams { base, k } if !(base == FpFormat::E2M3 && k == 3) && base.bits() == 5 => {
            cols.div_ceil(4)
        }
        Scheme::Ams { base, k } if !(base == FpFormat::E2M3 && k == 3) => {
            (cols * (base.bits() as usize - 1)).div_ceil(16)
        }
        // Everything else is a single stream.
        _ => row_stride(scheme, cols),
    }
}

/// Whether every `Granularity::PerGroup(g)` boundary lands on an
/// addressable position of this scheme's packed streams: word-aligned in
/// the high/byte streams and the per-code low streams (`g % 16 == 0`
/// covers all of them), on a 3-code word boundary for the continuous
/// FP5.33 layout, and on a shared-bit group boundary for the AMS
/// segmented layouts. This is the *layout* precondition for decoding a
/// group segment straight from the packed words without touching
/// neighbouring groups; the kernels in [`crate::gemm`] additionally
/// require a segment-capable kernel family before taking the
/// stream-direct path.
pub fn group_segments_aligned(scheme: Scheme, g: usize) -> bool {
    if g == 0 || g % 16 != 0 {
        return false;
    }
    match scheme {
        // One code per word / byte stream / nibble streams: any 16-code
        // boundary is a word boundary in every stream.
        Scheme::Fp16 => true,
        Scheme::Fp(f) if matches!(f.bits(), 4..=6 | 8) => true,
        Scheme::Int { bits: 4 | 8 } => true,
        // Continuous FP5.33: one u16 holds a whole 3-code group.
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => g % 3 == 0,
        // Segmented AMS: shared-bit groups must not straddle a segment.
        Scheme::Ams { base, k } if base.bits() == 5 => g % k == 0,
        // Generic dense bit-streams have no word-aligned segments.
        _ => false,
    }
}

/// Words per row for a scheme at a given column count.
pub fn row_stride(scheme: Scheme, cols: usize) -> usize {
    match scheme {
        Scheme::Fp16 => cols,
        Scheme::Fp(f) if f.bits() == 8 => cols.div_ceil(2),
        Scheme::Int { bits: 8 } => cols.div_ceil(2),
        Scheme::Int { bits: 4 } => cols.div_ceil(4),
        Scheme::Fp(f) if f.bits() == 6 => cols.div_ceil(4) + cols.div_ceil(8),
        Scheme::Fp(f) if f.bits() == 5 => cols.div_ceil(4) + cols.div_ceil(16),
        Scheme::Fp(f) if f.bits() == 4 => cols.div_ceil(4),
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => cols.div_ceil(3),
        Scheme::Ams { base, k } if base.bits() == 5 => {
            cols.div_ceil(4) + cols.div_ceil(k).div_ceil(16)
        }
        // Generic fallback: dense (bits-1)-bit stream + shared-bit stream.
        Scheme::Ams { base, k } => {
            (cols * (base.bits() as usize - 1)).div_ceil(16) + cols.div_ceil(k).div_ceil(16)
        }
        Scheme::Fp(f) => (cols * f.bits() as usize).div_ceil(16),
        Scheme::Int { bits } => (cols * bits as usize).div_ceil(16),
    }
}

/// Pack a quantized tensor into the word-stream layouts the kernels
/// serve. Input-dim sharing only; every granularity packs — per-tensor
/// broadcasts to per-row, per-group emits the word-aligned
/// [`GroupScales`] stream. Malformed inputs surface a typed
/// [`QuantError`] instead of panicking.
pub fn pack(q: &QuantizedTensor) -> Result<PackedTensor, QuantError> {
    if q.share_dim != ShareDim::Input {
        return Err(QuantError::UnpackableShareDim { share_dim: q.share_dim });
    }
    let (scales, group_scales) = match q.granularity {
        Granularity::PerChannel => {
            if q.scales.len() != q.rows {
                return Err(QuantError::ScaleCountMismatch {
                    expected: q.rows,
                    got: q.scales.len(),
                });
            }
            (q.scales.clone(), None)
        }
        Granularity::PerTensor => {
            if q.scales.is_empty() {
                return Err(QuantError::ScaleCountMismatch { expected: 1, got: 0 });
            }
            (vec![q.scales[0]; q.rows], None)
        }
        Granularity::PerGroup(g) => {
            if g == 0 {
                return Err(QuantError::InvalidGroupSize { g, reason: "must be positive" });
            }
            let groups_per_row = q.cols.div_ceil(g);
            let expected = q.rows * groups_per_row;
            if q.scales.len() != expected {
                return Err(QuantError::ScaleCountMismatch {
                    expected,
                    got: q.scales.len(),
                });
            }
            (
                vec![1.0; q.rows],
                Some(GroupScales {
                    group_size: g,
                    groups_per_row,
                    scales: q.scales.clone(),
                }),
            )
        }
    };
    let stride = row_stride(q.scheme, q.cols);
    let mut words = vec![0u16; q.rows * stride];
    for r in 0..q.rows {
        let row_codes = &q.codes[r * q.cols..(r + 1) * q.cols];
        pack_row(q.scheme, row_codes, &mut words[r * stride..(r + 1) * stride]);
    }
    PackedTensor::new(q.scheme, q.rows, q.cols, words, scales, group_scales)
}

/// Pack one row of codes into `out` (len = row_stride).
pub fn pack_row(scheme: Scheme, codes: &[u16], out: &mut [u16]) {
    match scheme {
        Scheme::Fp16 => out[..codes.len()].copy_from_slice(codes),
        Scheme::Fp(f) if f.bits() == 8 => pack_fixed(codes, 8, out),
        Scheme::Int { bits: 8 } => pack_fixed(codes, 8, out),
        Scheme::Int { bits: 4 } => pack_fixed(codes, 4, out),
        Scheme::Fp(f) if f.bits() == 6 => {
            // TC-FPx (4+2): high-4 stream then low-2 stream.
            let hi_words = codes.len().div_ceil(4);
            for (i, &c) in codes.iter().enumerate() {
                out[i / 4] |= ((c >> 2) & 0xF) << (4 * (i % 4));
                out[hi_words + i / 8] |= (c & 0x3) << (2 * (i % 8));
            }
        }
        Scheme::Fp(f) if f.bits() == 5 => {
            // (4+1): high-4 stream then LSB stream.
            let hi_words = codes.len().div_ceil(4);
            for (i, &c) in codes.iter().enumerate() {
                out[i / 4] |= ((c >> 1) & 0xF) << (4 * (i % 4));
                out[hi_words + i / 16] |= (c & 1) << (i % 16);
            }
        }
        Scheme::Fp(f) if f.bits() == 4 => pack_fixed(codes, 4, out),
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => {
            // Continuous: [hi0|hi1|hi2|shared] per u16. The shared LSB is
            // identical across the group, read it from the first member.
            for (g, grp) in codes.chunks(3).enumerate() {
                let mut w: u16 = (grp[0] & 1) << 15;
                for (j, &c) in grp.iter().enumerate() {
                    w |= ((c >> 1) & 0x1F) << (5 * j);
                }
                out[g] = w;
            }
        }
        Scheme::Ams { base, k } if base.bits() == 5 => {
            // Segmented: high-4 stream + shared-bit stream (1 bit / group).
            let hi_words = codes.len().div_ceil(4);
            for (i, &c) in codes.iter().enumerate() {
                out[i / 4] |= ((c >> 1) & 0xF) << (4 * (i % 4));
            }
            for (g, grp) in codes.chunks(k).enumerate() {
                out[hi_words + g / 16] |= (grp[0] & 1) << (g % 16);
            }
        }
        Scheme::Ams { base, k } => {
            // Generic: dense (bits-1)-bit high stream + shared-bit stream.
            let hb = base.bits() - 1;
            let hi_words = (codes.len() * hb as usize).div_ceil(16);
            let mut w = BitWriter::new(&mut out[..hi_words]);
            for &c in codes {
                w.put(u32::from(c >> 1), hb);
            }
            for (g, grp) in codes.chunks(k).enumerate() {
                out[hi_words + g / 16] |= (grp[0] & 1) << (g % 16);
            }
        }
        Scheme::Fp(f) => {
            let mut w = BitWriter::new(out);
            for &c in codes {
                w.put(u32::from(c), f.bits());
            }
        }
        Scheme::Int { bits } => {
            let mut w = BitWriter::new(out);
            for &c in codes {
                w.put(u32::from(c), bits);
            }
        }
    }
}

fn pack_fixed(codes: &[u16], bits: u32, out: &mut [u16]) {
    let per = (16 / bits) as usize;
    let mask = (1u16 << bits) - 1;
    for (i, &c) in codes.iter().enumerate() {
        out[i / per] |= (c & mask) << (bits as usize * (i % per));
    }
}

/// Unpack one row of a packed tensor back into full codes.
pub fn unpack_row(scheme: Scheme, words: &[u16], cols: usize, out: &mut [u16]) {
    match scheme {
        Scheme::Fp16 => out[..cols].copy_from_slice(&words[..cols]),
        Scheme::Fp(f) if f.bits() == 8 => unpack_fixed(words, 8, cols, out),
        Scheme::Int { bits: 8 } => unpack_fixed(words, 8, cols, out),
        Scheme::Int { bits: 4 } => unpack_fixed(words, 4, cols, out),
        Scheme::Fp(f) if f.bits() == 6 => {
            let hi_words = cols.div_ceil(4);
            for (i, o) in out.iter_mut().enumerate().take(cols) {
                let hi = (words[i / 4] >> (4 * (i % 4))) & 0xF;
                let lo = (words[hi_words + i / 8] >> (2 * (i % 8))) & 0x3;
                *o = (hi << 2) | lo;
            }
        }
        Scheme::Fp(f) if f.bits() == 5 => {
            let hi_words = cols.div_ceil(4);
            for (i, o) in out.iter_mut().enumerate().take(cols) {
                let hi = (words[i / 4] >> (4 * (i % 4))) & 0xF;
                let lsb = (words[hi_words + i / 16] >> (i % 16)) & 1;
                *o = (hi << 1) | lsb;
            }
        }
        Scheme::Fp(f) if f.bits() == 4 => unpack_fixed(words, 4, cols, out),
        Scheme::Ams { base, k } if base == FpFormat::E2M3 && k == 3 => {
            for (i, o) in out.iter_mut().enumerate().take(cols) {
                let w = words[i / 3];
                let hi = (w >> (5 * (i % 3))) & 0x1F;
                let shared = (w >> 15) & 1;
                *o = (hi << 1) | shared;
            }
        }
        Scheme::Ams { base, k } if base.bits() == 5 => {
            // Group-outer loop: no per-element division by the runtime `k`.
            let hi_words = cols.div_ceil(4);
            let mut g = 0usize;
            let mut i = 0usize;
            while i < cols {
                let shared = (words[hi_words + g / 16] >> (g % 16)) & 1;
                let end = (i + k).min(cols);
                while i < end {
                    let hi = (words[i / 4] >> (4 * (i % 4))) & 0xF;
                    out[i] = (hi << 1) | shared;
                    i += 1;
                }
                g += 1;
            }
        }
        Scheme::Ams { base, k } => {
            let hb = base.bits() - 1;
            let hi_words = (cols * hb as usize).div_ceil(16);
            let mut r = BitReader::new(&words[..hi_words]);
            for (i, o) in out.iter_mut().enumerate().take(cols) {
                let hi = r.get(hb) as u16;
                let g = i / k;
                let shared = (words[hi_words + g / 16] >> (g % 16)) & 1;
                *o = (hi << 1) | shared;
            }
        }
        Scheme::Fp(f) => {
            let mut r = BitReader::new(words);
            for o in out.iter_mut().take(cols) {
                *o = r.get(f.bits()) as u16;
            }
        }
        Scheme::Int { bits } => {
            let mut r = BitReader::new(words);
            for o in out.iter_mut().take(cols) {
                *o = r.get(bits) as u16;
            }
        }
    }
}

fn unpack_fixed(words: &[u16], bits: u32, cols: usize, out: &mut [u16]) {
    let per = (16 / bits) as usize;
    let mask = (1u16 << bits) - 1;
    for (i, o) in out.iter_mut().enumerate().take(cols) {
        *o = (words[i / per] >> (bits as usize * (i % per))) & mask;
    }
}

/// Unpack a whole tensor back into a `QuantizedTensor` (codes + scales at
/// the packed granularity). Shared-bit metadata is reconstructed from the
/// codes.
pub fn unpack(p: &PackedTensor) -> QuantizedTensor {
    let fmt = p
        .scheme
        .fp_format()
        .unwrap_or(FpFormat::E5M10);
    let mut codes = vec![0u16; p.rows * p.cols];
    for r in 0..p.rows {
        unpack_row(
            p.scheme,
            p.row_words(r),
            p.cols,
            &mut codes[r * p.cols..(r + 1) * p.cols],
        );
    }
    let shared_bits = match p.scheme {
        Scheme::Ams { k, .. } => {
            let mut bits = Vec::with_capacity(p.rows * p.cols.div_ceil(k));
            for r in 0..p.rows {
                for c0 in (0..p.cols).step_by(k) {
                    bits.push((codes[r * p.cols + c0] & 1) as u8);
                }
            }
            bits
        }
        _ => Vec::new(),
    };
    let (granularity, scales) = match &p.group_scales {
        Some(gs) => (Granularity::PerGroup(gs.group_size), gs.scales.clone()),
        None => (Granularity::PerChannel, p.scales.clone()),
    };
    QuantizedTensor {
        fmt,
        scheme: p.scheme,
        rows: p.rows,
        cols: p.cols,
        codes,
        granularity,
        scales,
        shared_bits,
        share_dim: ShareDim::Input,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sharing::quantize;
    use crate::quant::QuantConfig;
    use crate::tensor::{init, Tensor};
    use crate::util::prng::Rng;
    use crate::util::proptest::{run_prop, USize};

    fn quantize_named(name: &str, rows: usize, cols: usize, seed: u64) -> QuantizedTensor {
        let mut rng = Rng::new(seed);
        let w = init::gaussian(&[rows, cols], 0.0, 0.02, &mut rng);
        quantize(&w, &QuantConfig::paper(Scheme::parse(name).unwrap())).unwrap()
    }

    const SCHEMES: &[&str] = &[
        "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4-e2m1", "fp8-e4m3", "fp5.33", "fp4.5",
        "fp4.3", "fp4.25", "ams-e3m2-k4", "ams-e4m3-k2",
    ];

    #[test]
    fn roundtrip_all_schemes() {
        for name in SCHEMES {
            let q = quantize_named(name, 5, 67, 42);
            let p = pack(&q).unwrap();
            let u = unpack(&p);
            assert_eq!(u.codes, q.codes, "{name}");
            assert_eq!(u.scales, q.scales, "{name}");
        }
    }

    #[test]
    fn roundtrip_fp16() {
        // FP16 scheme: words are raw fp16 bit patterns.
        use crate::formats::fp16::f32_to_fp16;
        let codes: Vec<u16> = [0.5f32, -1.25, 3.0, 100.0]
            .iter()
            .map(|&x| f32_to_fp16(x))
            .collect();
        let mut out = vec![0u16; row_stride(Scheme::Fp16, 4)];
        pack_row(Scheme::Fp16, &codes, &mut out);
        let mut back = vec![0u16; 4];
        unpack_row(Scheme::Fp16, &out, 4, &mut back);
        assert_eq!(back, codes);
    }

    #[test]
    fn bits_per_weight_converges() {
        // At large, divisible cols the packed size matches the scheme's
        // nominal bits/weight exactly.
        let cases = [
            ("fp6-e2m3", 6.0),
            ("fp5-e2m2", 5.0),
            ("fp5.33", 16.0 / 3.0),
            ("fp4.5", 4.5),
            ("fp4.25", 4.25),
            ("fp4-e2m1", 4.0),
            ("fp8-e4m3", 8.0),
        ];
        for (name, expect) in cases {
            let q = quantize_named(name, 2, 768, 7); // 768 divisible by 3,4,16,k*16
            let p = pack(&q).unwrap();
            let bpw = p.bits_per_weight();
            assert!(
                (bpw - expect).abs() < 1e-9,
                "{name}: bpw={bpw}, expect {expect}"
            );
        }
    }

    #[test]
    fn fp533_matches_paper_packing() {
        // Paper §3.3: three weights + shared LSB fit one half-word.
        let q = quantize_named("fp5.33", 1, 9, 3);
        let p = pack(&q).unwrap();
        assert_eq!(p.row_stride, 3);
        // Decode word 0 by hand.
        let w = p.words[0];
        for j in 0..3 {
            let hi = (w >> (5 * j)) & 0x1F;
            let shared = (w >> 15) & 1;
            assert_eq!((hi << 1) | shared, q.codes[j]);
        }
    }

    #[test]
    fn fp425_matches_paper_packing() {
        // Paper §3.2: 64 weights -> 16 u16 of 4-bit segments + 1 u16 of
        // 16 shared LSBs.
        let q = quantize_named("fp4.25", 1, 64, 4);
        let p = pack(&q).unwrap();
        assert_eq!(p.row_stride, 16 + 1);
        let hi_words = 16;
        for i in 0..64 {
            let hi = (p.words[i / 4] >> (4 * (i % 4))) & 0xF;
            let g = i / 4;
            let shared = (p.words[hi_words + g / 16] >> (g % 16)) & 1;
            assert_eq!((hi << 1) | shared, q.codes[i], "i={i}");
        }
    }

    #[test]
    fn fp6_tcfpx_4_2_split() {
        // 16 weights -> 4 high words + 2 low words = 6 memory accesses.
        let q = quantize_named("fp6-e2m3", 1, 16, 5);
        let p = pack(&q).unwrap();
        assert_eq!(p.row_stride, 4 + 2);
    }

    #[test]
    fn prop_roundtrip_random_shapes() {
        run_prop(
            "pack-roundtrip",
            0xBEEF,
            60,
            &USize { lo: 1, hi: 130 },
            |&cols| {
                for name in SCHEMES {
                    let q = quantize_named(name, 3, cols, cols as u64);
                    let p = pack(&q).unwrap();
                    let u = unpack(&p);
                    if u.codes != q.codes {
                        return Err(format!("{name} cols={cols}: codes mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dequantize_after_roundtrip_identical() {
        for name in ["fp5.33", "fp4.25", "fp6-e2m3"] {
            let q = quantize_named(name, 4, 50, 6);
            let dq1 = q.dequantize();
            let dq2 = unpack(&pack(&q).unwrap()).dequantize();
            assert_eq!(dq1, dq2, "{name}");
        }
    }

    /// Per-group tensors pack with the word-aligned scale stream and
    /// roundtrip (codes, scales *and* granularity) exactly.
    #[test]
    fn per_group_roundtrip() {
        let mut rng = Rng::new(1);
        for name in SCHEMES {
            for (cols, g) in [(150usize, 32usize), (64, 64), (130, 128)] {
                let w = init::gaussian(&[3, cols], 0.0, 0.5, &mut rng);
                let cfg = QuantConfig::paper(Scheme::parse(name).unwrap())
                    .with_granularity(Granularity::PerGroup(g));
                let q = quantize(&w, &cfg).unwrap();
                let p = pack(&q).unwrap();
                assert_eq!(p.granularity(), Granularity::PerGroup(g), "{name}");
                assert!(p.scales.iter().all(|&s| s == 1.0), "{name}: row scales identity");
                let gs = p.group_scales.as_ref().unwrap();
                assert_eq!(gs.groups_per_row, cols.div_ceil(g), "{name}");
                assert_eq!(gs.scales.len(), 3 * cols.div_ceil(g), "{name}");
                assert_eq!(gs.row(1).len(), gs.groups_per_row);
                let u = unpack(&p);
                assert_eq!(u.codes, q.codes, "{name} g={g}");
                assert_eq!(u.scales, q.scales, "{name} g={g}");
                assert_eq!(u.granularity, Granularity::PerGroup(g), "{name}");
                assert_eq!(u.dequantize(), q.dequantize(), "{name} g={g}");
                // PackedTensor::dequantize is the same oracle.
                assert_eq!(p.dequantize(), q.dequantize(), "{name} g={g}");
            }
        }
    }

    /// Unsupported layouts surface typed errors, not panics.
    #[test]
    fn pack_rejects_with_typed_errors() {
        let mut rng = Rng::new(1);
        let w = init::gaussian(&[2, 8], 0.0, 1.0, &mut rng);
        // Output-dim sharing is analysis-only.
        let mut cfg = QuantConfig::paper(Scheme::parse("fp4.25").unwrap());
        cfg.share_dim = ShareDim::Output;
        let q = quantize(&w, &cfg).unwrap();
        assert!(matches!(
            pack(&q),
            Err(QuantError::UnpackableShareDim { share_dim: ShareDim::Output })
        ));
        // Corrupt scale count.
        let mut q = quantize(&w, &QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap())).unwrap();
        q.scales.pop();
        assert!(matches!(
            pack(&q),
            Err(QuantError::ScaleCountMismatch { expected: 2, got: 1 })
        ));
        // Zero group size.
        let mut q = quantize(&w, &QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap())).unwrap();
        q.granularity = Granularity::PerGroup(0);
        assert!(matches!(pack(&q), Err(QuantError::InvalidGroupSize { g: 0, .. })));
    }

    /// Satellite (PR 5): a truncated words / scale / group-scale stream
    /// is a typed error at construction, not an out-of-bounds panic in
    /// the decode hot path.
    #[test]
    fn constructor_rejects_truncated_streams() {
        let scheme = Scheme::parse("fp4.25").unwrap();
        let (rows, cols) = (3usize, 64usize);
        let stride = row_stride(scheme, cols);
        let mk_gs = |n: usize| {
            Some(GroupScales {
                group_size: 32,
                groups_per_row: 2,
                scales: vec![1.0; n],
            })
        };
        // Well-formed baseline constructs.
        assert!(PackedTensor::new(
            scheme,
            rows,
            cols,
            vec![0u16; rows * stride],
            vec![1.0; rows],
            mk_gs(rows * 2),
        )
        .is_ok());
        // Truncated word payload.
        assert!(matches!(
            PackedTensor::new(scheme, rows, cols, vec![0u16; rows * stride - 1],
                vec![1.0; rows], None),
            Err(QuantError::StreamGeometry { stream: "packed words", .. })
        ));
        // Short row-scale stream.
        assert!(matches!(
            PackedTensor::new(scheme, rows, cols, vec![0u16; rows * stride],
                vec![1.0; rows - 1], None),
            Err(QuantError::StreamGeometry { stream: "row scales", .. })
        ));
        // Short group-scale stream (the truncated-AMSQ shape).
        assert!(matches!(
            PackedTensor::new(scheme, rows, cols, vec![0u16; rows * stride],
                vec![1.0; rows], mk_gs(rows * 2 - 1)),
            Err(QuantError::StreamGeometry { stream: "group scales", expected: 6, got: 5 })
        ));
        // Inconsistent groups_per_row.
        let bad = Some(GroupScales { group_size: 32, groups_per_row: 3, scales: vec![1.0; 9] });
        assert!(matches!(
            PackedTensor::new(scheme, rows, cols, vec![0u16; rows * stride],
                vec![1.0; rows], bad),
            Err(QuantError::StreamGeometry { stream: "groups per row", .. })
        ));
    }

    /// The stream-direct layout predicate: word-aligned g on segmented /
    /// byte layouts, shared-group divisibility for AMS, never for the
    /// generic dense bit-streams.
    #[test]
    fn group_segment_alignment_predicate() {
        let p = |name: &str, g: usize| group_segments_aligned(Scheme::parse(name).unwrap(), g);
        for g in [32usize, 64, 128] {
            for name in ["fp8", "fp6-e2m3", "fp6-e3m2", "fp5-e2m2", "fp4.5", "fp4.25", "int4", "int8"] {
                assert!(p(name, g), "{name} g={g}");
            }
            // k = 3 shared groups straddle any 16-multiple boundary.
            assert!(!p("fp4.33", g), "fp4.33 g={g}");
            assert!(!p("fp5.33", g), "fp5.33 g={g}");
            // Generic dense bit-stream (5-bit hi stream) has no word
            // boundaries at code granularity.
            assert!(!p("ams-e3m2-k4", g), "ams-e3m2-k4 g={g}");
        }
        // Ragged group sizes never align.
        for name in ["fp8", "fp6-e2m3", "fp4.25"] {
            for g in [0usize, 8, 24, 48 + 1, 100] {
                assert!(!p(name, g), "{name} g={g}");
            }
        }
        // 48 is word-aligned and a 3-multiple: fp5.33 segments exactly.
        assert!(p("fp5.33", 48));
        assert!(p("fp6-e2m3", 48));
    }

    /// `row_streams` splits each row at the documented hi/low boundary.
    #[test]
    fn row_streams_split_points() {
        let cases = [
            ("fp6-e2m3", 61usize, 61usize.div_ceil(4)),
            ("fp5-e2m2", 61, 61usize.div_ceil(4)),
            ("fp4.25", 64, 16),
            ("fp8", 61, 61usize.div_ceil(2)), // single stream: all hi
            ("fp5.33", 61, 61usize.div_ceil(3)), // continuous: all hi
            ("ams-e3m2-k4", 61, (61 * 5usize).div_ceil(16)),
        ];
        for (name, cols, hi) in cases {
            let q = quantize_named(name, 2, cols, 9);
            let p = pack(&q).unwrap();
            let (h, l) = p.row_streams(1);
            assert_eq!(h.len(), hi, "{name}");
            assert_eq!(h.len() + l.len(), p.row_stride, "{name}");
            assert_eq!(hi_stream_words(p.scheme, cols), hi, "{name}");
        }
    }

    #[test]
    fn per_tensor_broadcasts() {
        let mut rng = Rng::new(2);
        let w = init::gaussian(&[3, 12], 0.0, 1.0, &mut rng);
        let mut cfg = QuantConfig::paper(Scheme::parse("fp6-e2m3").unwrap());
        cfg.granularity = Granularity::PerTensor;
        let q = crate::quant::rtn::quantize_rtn(&w, cfg.scheme, cfg.granularity).unwrap();
        let p = pack(&q).unwrap();
        assert_eq!(p.scales.len(), 3);
        assert!(p.scales.iter().all(|&s| s == p.scales[0]));
        let dq = unpack(&p).dequantize();
        let t = Tensor::from_vec(&[3, 12], dq.data().to_vec());
        assert!(w.mse(&t) < 0.05);
    }
}
